"""Continuous-traffic replay sweep: trace shape x buffer policy, plus one
mid-stream A/B hot-swap arm.

Each cell replays one arrival trace (constant / diurnal / bursty — one
diurnal cell also churns) through the ``TrafficExperiment`` event loop
under a fixed simulated-time budget and reports the continuous-traffic
headline: **time-to-quality**, the first simulated second at which the
anytime-eval test loss crosses a target derived from the constant-rate
baseline's best loss.  Round-shaped "rounds to accuracy" does not exist in
an open-ended stream — simulated seconds to a quality bar is the
comparable unit across traces and policies.

The A/B arm replays one diurnal trace against two algorithm schedules —
fedpac_soap throughout vs fedpac_soap hot-swapped to fedavg mid-stream —
with identical arrival realizations (shared trace seed), so the metric gap
is attributable to the swap alone.

Returns the structured ``BENCH_traffic.json`` row list
(``{"name", "us_per_call", "derived": {...}}`` — ``repro.obs.bench``);
``us_per_call`` is wall microseconds per server flush.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, materialize_cached

SCENARIO = "cifar_like_cnn_dir0.05"
N_CLIENTS = 10

# (tag, trace kind, trace kwargs, churn?) — rates are arrivals per
# simulated second against a ~1s mean client latency, so the pool stays
# busy without the backlog growing unboundedly
TRACE_GRID = [
    ("constant", "constant", {"rate": 6.0}, False),
    ("diurnal", "diurnal", {"base": 6.0, "amplitude": 0.8, "period": 4.0},
     False),
    ("bursty", "bursty", {"base": 4.0, "jump": 0.6, "decay": 1.2}, False),
    ("diurnal_churn", "diurnal",
     {"base": 6.0, "amplitude": 0.8, "period": 4.0}, True),
]
POLICIES = ("count", "interval")


def _build(algo, bundle, tc, *, rounds):
    from repro.api import AsyncConfig, build_experiment
    return build_experiment(
        algo, scenario=bundle,
        async_cfg=AsyncConfig(buffer_size=3, concurrency=4),
        traffic=tc, n_clients=N_CLIENTS, rounds=rounds, local_steps=5,
        scenario_seed=7, seed=0)


def run(quick: bool = True):
    from repro.api import ChurnConfig, TrafficConfig
    from repro.fed.traffic import run_ab, time_to_quality

    sim_budget = 8.0 if quick else 30.0
    eval_every = 1.0
    rounds = 10 if quick else 30          # FedConfig bookkeeping only
    bundle = materialize_cached(SCENARIO, 7, N_CLIENTS)

    cells = []
    for tag, kind, tkw, churn in TRACE_GRID:
        for policy in POLICIES:
            tc = TrafficConfig(
                trace=kind, trace_kwargs=tkw, buffer_policy=policy,
                flush_interval=1.0 if policy == "interval" else None,
                eval_every=eval_every,
                churn=ChurnConfig(join_rate=0.5, leave_rate=0.5,
                                  initial_active=8) if churn else None)
            exp = _build("fedpac_soap", bundle, tc, rounds=rounds)
            t0 = time.perf_counter()
            summary = exp.run_stream(sim_budget=sim_budget)
            wall = time.perf_counter() - t0
            cells.append((f"traffic_{tag}_{policy}", tag, policy, summary,
                          list(exp.eval_history), wall))

    # quality bar: within 5% of the constant-rate count-policy baseline's
    # best anytime test loss — reachable by construction in that cell,
    # comparable across every other one
    base_ev = cells[0][4]
    target = min(r["test_loss"] for r in base_ev) * 1.05

    rows = []
    for name, tag, policy, s, ev, wall in cells:
        ttq = time_to_quality(ev, "test_loss", target,
                              higher_is_better=False)
        us = wall / max(s["flushes"], 1) * 1e6
        emit(name, us,
             f"ttq_sim_s={ttq if ttq is not None else 'never'};"
             f"flushes={s['flushes']};loss={ev[-1]['test_loss']:.4f};"
             f"backlog={s['backlog']};discarded={s['discarded']}")
        rows.append({"name": name, "us_per_call": us, "derived": {
            "trace": tag, "policy": policy, "target_loss": float(target),
            "ttq_sim_s": None if ttq is None else float(ttq),
            "flushes": int(s["flushes"]), "sim_time": float(s["sim_time"]),
            "final_loss": float(ev[-1]["test_loss"]),
            "final_acc": float(ev[-1]["test_acc"]),
            "backlog": int(s["backlog"]), "dropped": int(s["dropped"]),
            "discarded": int(s["discarded"]),
            "joins": int(s["joins"]), "leaves": int(s["leaves"])}})

    # --- mid-stream A/B hot-swap: same trace, swap vs no swap ------------
    tkw = {"base": 6.0, "amplitude": 0.8, "period": 4.0}
    tc_a = TrafficConfig(trace="diurnal", trace_kwargs=tkw,
                         eval_every=eval_every)
    tc_b = TrafficConfig(trace="diurnal", trace_kwargs=tkw,
                         eval_every=eval_every, swap_to="fedavg",
                         swap_at=sim_budget / 2)
    a = _build("fedpac_soap", bundle, tc_a, rounds=rounds)
    b = _build("fedpac_soap", bundle, tc_b, rounds=rounds)
    t0 = time.perf_counter()
    out = run_ab(a, b, sim_budget=sim_budget)
    wall = time.perf_counter() - t0
    ttq_a = time_to_quality(out["eval_a"], "test_loss", target,
                            higher_is_better=False)
    ttq_b = time_to_quality(out["eval_b"], "test_loss", target,
                            higher_is_better=False)
    flushes = out["a"]["flushes"] + out["b"]["flushes"]
    us = wall / max(flushes, 1) * 1e6
    emit("traffic_ab_hotswap", us,
         f"ttq_a={ttq_a if ttq_a is not None else 'never'};"
         f"ttq_b={ttq_b if ttq_b is not None else 'never'};"
         f"loss_a={out['eval_a'][-1]['test_loss']:.4f};"
         f"loss_b={out['eval_b'][-1]['test_loss']:.4f};"
         f"swapped_to={b.spec.name}")
    rows.append({"name": "traffic_ab_hotswap", "us_per_call": us,
                 "derived": {
                     "trace": "diurnal", "swap_to": "fedavg",
                     "swap_at": float(sim_budget / 2),
                     "target_loss": float(target),
                     "ttq_a": None if ttq_a is None else float(ttq_a),
                     "ttq_b": None if ttq_b is None else float(ttq_b),
                     "final_loss_a": float(out["eval_a"][-1]["test_loss"]),
                     "final_loss_b": float(out["eval_b"][-1]["test_loss"]),
                     "flushes_a": int(out["a"]["flushes"]),
                     "flushes_b": int(out["b"]["flushes"]),
                     "discarded_b": int(out["b"]["discarded"])}})
    return rows


if __name__ == "__main__":
    run(quick=False)
