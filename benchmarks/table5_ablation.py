"""Table 5 reproduction: component-wise ablation of FedPAC_SOAP —
Local SOAP vs alignment-only vs correction-only vs full.
Claim: each component improves over Local SOAP; full is best."""
from __future__ import annotations

from benchmarks.common import run_algorithm, emit

VARIANTS = ["local_soap", "align_only_soap", "correct_only_soap",
            "fedpac_soap"]


def run(quick: bool = True):
    rounds = 15 if quick else 50
    accs = {}
    for v in VARIANTS:
        exp, hist, wall = run_algorithm(v, scenario="cifar_like_cnn_dir0.05",
                                        scenario_seed=3, rounds=rounds,
                                        local_steps=5)
        accs[v] = hist[-1]["test_acc"]
        emit(f"table5_{v}", wall / rounds * 1e6, f"acc={accs[v]:.4f}")
    emit("table5_claim_components", 0.0,
         f"full_best={accs['fedpac_soap'] >= max(accs['align_only_soap'], accs['correct_only_soap']) - 0.02};"
         f"accs={ {k: round(v,4) for k,v in accs.items()} }")
    return accs


if __name__ == "__main__":
    run(quick=False)
