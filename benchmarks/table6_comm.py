"""Table 6 / 11 reproduction: per-round communication & compute cost across
aggregation strategies, incl. the SVD-compressed FedPAC_light upload.
Claims: FedPAC costs |x| + c|Theta|; _light stays within ~1.1-1.3x of Local
while keeping most of the accuracy gain.

Byte counts come from ``comm_bytes_per_round``, which measures the wire
messages the geometry transport actually encodes (``transport.wire_bytes``)
— the factored U·s·Vᵀ payload for _light, not an analytic formula.  See
benchmarks/transport_bench.py for the full codec x rank x quantization
sweep."""
from __future__ import annotations

from benchmarks.common import run_algorithm, emit


def run(quick: bool = True):
    rounds = 12 if quick else 40
    rows = {}
    for algo in ["local_soap", "fedpac_soap", "fedpac_soap_light",
                 "local_muon", "fedpac_muon", "fedpac_muon_light"]:
        exp, hist, wall = run_algorithm(algo,
                                        scenario="cifar_like_cnn_dir0.05",
                                        scenario_seed=4, rounds=rounds,
                                        local_steps=5, svd_rank=4)
        comm = exp.comm_bytes_per_round()
        rows[algo] = (hist[-1]["test_acc"], comm, wall / rounds)
        emit(f"table6_{algo}", wall / rounds * 1e6,
             f"acc={rows[algo][0]:.4f};comm_MB={comm/1e6:.3f};"
             f"s_per_round={rows[algo][2]:.2f}")
    base = rows["local_soap"][1]
    emit("table6_claim_light_cheap", 0.0,
         f"full_x={rows['fedpac_soap'][1]/base:.2f};"
         f"light_x={rows['fedpac_soap_light'][1]/base:.2f};"
         f"light_under_1.5x={rows['fedpac_soap_light'][1] < 1.5*base}")
    return rows


if __name__ == "__main__":
    run(quick=False)
