"""Sync vs naive-async vs staleness-aware FedPAC across latency heterogeneity.

Beyond-paper sweep: the paper's tables assume lock-step rounds; this measures
what preconditioner drift costs under the buffered-asynchronous execution
model, where stragglers deliver geometries trained several versions ago.
Three runners per heterogeneity level (persistent per-client lognormal speed
sigma in HETS):

  sync_fedpac        lock-step FedPAC_SOAP (upper bound, no staleness)
  async_naive_soa    buffered-async Local SOAP, no staleness handling
                     (FedSOA under FedBuff — geometry drifts AND goes stale)
  async_fedpac_stale buffered-async FedPAC_SOAP with polynomial staleness
                     decay on deltas/Theta and freshness-scaled mixing

Emits final train loss, test accuracy, mean arrival staleness and simulated
wall-clock per runner, plus a ``*_gap`` row asserting the acceptance
comparison (aware <= naive).
"""
from __future__ import annotations

import time

from benchmarks.common import emit, materialize_cached
from repro.api import build_experiment
from repro.fed import AsyncConfig, FedConfig, LatencyModel
from repro.scenarios import cifar_like


def _fed(algo, *, runtime, rounds, n_clients, seed):
    return FedConfig(algorithm=algo, n_clients=n_clients, participation=0.5,
                     rounds=rounds, local_steps=4, lr=3e-3, beta=0.5,
                     seed=seed, runtime=runtime)


def run(quick: bool = True, seed: int = 0):
    rounds = 12 if quick else 50
    n_clients = 8 if quick else 20
    hets = [0.0, 1.5] if quick else [0.0, 0.5, 1.0, 2.0]
    # one materialization shared by every (heterogeneity x runner) cell
    scenario = materialize_cached(
        cifar_like(model="cnn", n=1500 if quick else 4000, image_size=8,
                   n_classes=4, alpha=0.1, batch=8, n_clients=n_clients),
        seed, n_clients)

    for het in hets:
        latency = LatencyModel(heterogeneity=het, jitter=0.25)
        naive_cfg = AsyncConfig(buffer_size=2, staleness_mode="none",
                                latency=latency)
        aware_cfg = AsyncConfig(buffer_size=2, staleness_mode="poly",
                                staleness_alpha=0.5, latency=latency)
        runners = [
            ("sync_fedpac", _fed("fedpac_soap", runtime="sync",
                                 rounds=rounds, n_clients=n_clients,
                                 seed=seed), None),
            ("async_naive_soa", _fed("local_soap", runtime="async",
                                     rounds=rounds, n_clients=n_clients,
                                     seed=seed), naive_cfg),
            ("async_fedpac_stale", _fed("fedpac_soap", runtime="async",
                                        rounds=rounds, n_clients=n_clients,
                                        seed=seed), aware_cfg),
        ]
        finals = {}
        for name, fed, acfg in runners:
            exp = build_experiment(fed.algorithm, scenario=scenario,
                                   fed=fed, async_cfg=acfg)
            t0 = time.perf_counter()
            hist = exp.run()
            wall = time.perf_counter() - t0
            last = hist[-1]
            # compare on the *global* objective: under non-IID data, naive
            # async lowers clients' local loss by drifting toward their
            # local optima, which is exactly what hurts the global model
            finals[name] = last["test_loss"]
            stale = last.get("staleness", 0.0)
            simt = last.get("sim_time", float(fed.rounds))
            emit(f"async_drift_h{het:g}_{name}",
                 wall / fed.rounds * 1e6,
                 f"test_loss={last['test_loss']:.4f};"
                 f"acc={last['test_acc']:.3f};local_loss={last['loss']:.4f};"
                 f"stale={stale:.2f};sim_t={simt:.1f}")
        gap = finals["async_naive_soa"] - finals["async_fedpac_stale"]
        emit(f"async_drift_h{het:g}_gap", 0.0,
             f"naive-aware={gap:.4f};aware_wins={gap >= 0.0}")
