"""Table 1 / Fig. 2 / Fig. 5 reproduction (scaled): test accuracy under
Dirichlet non-IID for first-order, Local second-order (FedSOA), and FedPAC
variants, on CNN and ViT backbones over synthetic images.

Scenarios come from the registry (``cifar_like_cnn`` / ``cifar_like_vit``);
each severity level is the same registered task under another
``PartitionSpec`` — the declarative form of the paper's alpha sweep.

Paper claims validated (ordering, not absolute numbers — synthetic data):
  1. On non-IID data, Local second-order optimizers degrade vs their FedPAC
     counterparts.
  2. FedPAC_X >= Local_X for each second-order optimizer X.
  3. Degradation grows as alpha shrinks.
"""
from __future__ import annotations

from benchmarks.common import run_algorithm, emit
from repro.scenarios import PartitionSpec, resolve

ALGOS = ["fedavg", "local_adamw", "local_sophia", "fedpac_sophia",
         "local_muon", "fedpac_muon", "local_soap", "fedpac_soap"]


def run(quick: bool = True, model: str = "cnn"):
    rounds = 25 if quick else 60
    partitions = [("iid", PartitionSpec("iid")),
                  ("dir0.1", PartitionSpec("dirichlet", alpha=0.1))]
    if not quick:
        partitions[1:1] = [("dir0.5", PartitionSpec("dirichlet", alpha=0.5))]
        partitions.append(("dir0.05",
                           PartitionSpec("dirichlet", alpha=0.05)))
    base = resolve(f"cifar_like_{model}")
    results = {}
    for aname, part in partitions:
        scn = base.with_partition(part, suffix=aname)
        for algo in ALGOS:
            exp, hist, wall = run_algorithm(
                algo, scenario=scn, rounds=rounds, local_steps=5,
                participation=0.5)
            acc = hist[-1]["test_acc"]
            results[(aname, algo)] = acc
            emit(f"table1_{model}_{aname}_{algo}",
                 wall / rounds * 1e6,
                 f"acc={acc:.4f};loss={hist[-1]['loss']:.4f};"
                 f"drift={hist[-1]['drift']:.3e}")
    # claim checks
    for aname, _ in partitions:
        if aname == "iid":
            continue
        for o in ["sophia", "muon", "soap"]:
            local = results[(aname, f"local_{o}")]
            pac = results[(aname, f"fedpac_{o}")]
            emit(f"table1_claim_{model}_{aname}_{o}", 0.0,
                 f"fedpac={pac:.4f};local={local:.4f};"
                 f"improves={pac >= local}")
    return results


if __name__ == "__main__":
    run(quick=False, model="cnn")
    run(quick=False, model="vit")
