"""Table 1 / Fig. 2 / Fig. 5 reproduction (scaled): test accuracy under
Dirichlet non-IID for first-order, Local second-order (FedSOA), and FedPAC
variants, on CNN and ViT backbones over synthetic images.

Paper claims validated (ordering, not absolute numbers — synthetic data):
  1. On non-IID data, Local second-order optimizers degrade vs their FedPAC
     counterparts.
  2. FedPAC_X >= Local_X for each second-order optimizer X.
  3. Degradation grows as alpha shrinks.
"""
from __future__ import annotations

import time

from benchmarks.common import make_fed_vision_problem, run_algorithm, emit

ALGOS = ["fedavg", "local_adamw", "local_sophia", "fedpac_sophia",
         "local_muon", "fedpac_muon", "local_soap", "fedpac_soap"]


def run(quick: bool = True, model: str = "cnn"):
    rounds = 25 if quick else 60
    alphas = [(None, "iid"), (0.1, "dir0.1")] if quick else \
        [(None, "iid"), (0.5, "dir0.5"), (0.1, "dir0.1"), (0.05, "dir0.05")]
    results = {}
    for alpha, aname in alphas:
        params, loss_fn, batch_fn, eval_fn = make_fed_vision_problem(
            model=model, alpha=alpha, n_clients=10)
        for algo in ALGOS:
            t0 = time.perf_counter()
            exp, hist, wall = run_algorithm(
                algo, params, loss_fn, batch_fn, eval_fn, rounds=rounds,
                local_steps=5, participation=0.5)
            acc = hist[-1]["test_acc"]
            results[(aname, algo)] = acc
            emit(f"table1_{model}_{aname}_{algo}",
                 wall / rounds * 1e6,
                 f"acc={acc:.4f};loss={hist[-1]['loss']:.4f};"
                 f"drift={hist[-1]['drift']:.3e}")
    # claim checks
    for aname in [a for _, a in alphas if a != "iid"]:
        for o in ["sophia", "muon", "soap"]:
            local = results[(aname, f"local_{o}")]
            pac = results[(aname, f"fedpac_{o}")]
            emit(f"table1_claim_{model}_{aname}_{o}", 0.0,
                 f"fedpac={pac:.4f};local={local:.4f};"
                 f"improves={pac >= local}")
    return results


if __name__ == "__main__":
    run(quick=False, model="cnn")
    run(quick=False, model="vit")
