"""Algorithm x scenario x heterogeneity sweep through the one builder.

The cross-scenario claim of the paper ("FedPAC stabilizes second-order FL
across vision and language tasks, across non-IID severity") as a single
declarative grid: every cell is ``build_experiment(algorithm,
scenario=spec)`` where ``spec`` is a registered catalog task under a swept
``PartitionSpec`` — no per-benchmark wiring anywhere.

Emits ``scenario_matrix_*`` rows on stdout (the harness CSV) and writes the
full grid to one CSV file (``out=``, default ``scenario_matrix.csv``) with
final train loss, task metric, measured label-skew TV, and wall clock.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import emit
from repro.api import build_experiment
from repro.scenarios import PartitionSpec, materialize, resolve

SCENARIOS = ("cifar_like_cnn", "cifar_like_vit", "lm_zipf")
ALGOS_QUICK = ("local_soap", "fedpac_soap")
ALGOS_FULL = ("fedavg", "local_soap", "fedpac_soap", "fedpac_muon")


def _partitions(quick: bool, doc_level: bool):
    min_size = 1 if doc_level else 2
    parts = [("dir0.1", PartitionSpec("dirichlet", alpha=0.1,
                                      min_size=min_size)),
             ("iid", PartitionSpec("iid"))]
    if not quick:
        parts[1:1] = [("dir0.05", PartitionSpec("dirichlet", alpha=0.05,
                                                min_size=min_size)),
                      ("shard", PartitionSpec("shard", shards_per_client=2))]
    return parts


def _shrink(spec, quick: bool):
    """Quick mode: same scenario, CI-sized data/model."""
    if not quick:
        return spec
    if spec.source == "synth_image":
        return dataclasses.replace(
            spec, n_clients=6,
            source_kwargs=dict(spec.source_kwargs, n=900, n_eval=256))
    return dataclasses.replace(
        spec, n_clients=4,
        source_kwargs=dict(spec.source_kwargs, n_docs=64, tokens_per_doc=200,
                           n_eval_docs=4, vocab=128),
        model_kwargs=dict(spec.model_kwargs, layers=1, d_model=32))


def run(quick: bool = True, out: str = "scenario_matrix.csv"):
    rounds = 3 if quick else 25
    algos = ALGOS_QUICK if quick else ALGOS_FULL
    lines = ["scenario,partition,algorithm,rounds,final_loss,metric_name,"
             "metric,label_tv,s_per_round"]
    for scn_name in SCENARIOS:
        base = _shrink(resolve(scn_name), quick)
        for pname, part in _partitions(quick,
                                       doc_level=base.source == "lm_zipf"):
            spec = base.with_partition(part, suffix=pname)
            # one materialization per task cell, shared across algorithms
            bundle = materialize(spec, seed=0, n_clients=spec.n_clients)
            for algo in algos:
                exp = build_experiment(algo, scenario=bundle, rounds=rounds,
                                       local_steps=2 if quick else 5)
                t0 = time.perf_counter()
                hist = exp.run()
                per_round = (time.perf_counter() - t0) / rounds
                last = hist[-1]
                mname = "test_acc" if "test_acc" in last else "eval_loss"
                tv = exp.scenario.partition_stats.get("label_tv", 0.0)
                emit(f"scenario_matrix_{scn_name}_{pname}_{algo}",
                     per_round * 1e6,
                     f"loss={last['loss']:.4f};{mname}={last[mname]:.4f};"
                     f"tv={tv:.3f}")
                lines.append(
                    f"{scn_name},{pname},{algo},{rounds},"
                    f"{last['loss']:.6f},{mname},{last[mname]:.6f},"
                    f"{tv:.4f},{per_round:.3f}")
    with open(out, "w") as f:
        f.write("\n".join(lines) + "\n")
    emit("scenario_matrix_csv", 0.0,
         f"rows={len(lines) - 1};path={out}")
    return lines


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(quick=False)
