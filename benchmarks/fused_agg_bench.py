"""Fused decode-aggregate flush micro-benchmark.

Times the two server-side reductions of a cohort of encoded uploads —

  decode   jax.vmap(codec.decode) materializes the (B, ...) f32 stack,
           then one dot_general contraction forms sum_i w_i Delta_i
  fused    codec.accumulate reduces the wire payloads straight into the
           weighted sum (kernels/fused_agg); the decoded per-client stack
           never exists

— across codec x wire_dtype x cohort size, at a fixed synthetic model
tree.  Alongside wall time the rows record the *analytic peak
intermediate bytes* of each path: the decode path must hold B dense f32
trees, the fused path only the wire payloads plus one dense output, so
the memory ratio is the headline at million-client cohort scale even
where small-cohort wall times tie.  Each cell also asserts the two paths
agree (allclose, f32), so the speedup is never measured against a wrong
answer.

Returns structured rows appended to ``BENCH_transport.json`` by
``benchmarks/transport_bench.run`` (and printable standalone via
``python -m benchmarks.run --only fused_agg``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.transport import TransportConfig, resolve_codec
from repro.core.transport import wire_bytes as wire_bytes_of
from repro.utils.tree import client_weighted_sum

# one transformer-ish block: two matrices wide enough to quantize/factor
# plus a narrow passthrough leaf
SHAPES = {"wq": (256, 256), "wo": (256, 128), "b": (128,)}
CODECS = ("qblock", "lowrank_svd", "lowrank_svd+qblock")
WIRE_DTYPES = ("f32", "bf16")


def _stacked_tree(b: int, seed: int = 0):
    keys = jax.random.split(jax.random.key(seed), len(SHAPES))
    return {name: 0.1 * jax.random.normal(k, (b,) + shape, jnp.float32)
            for k, (name, shape) in zip(keys, SHAPES.items())}


def _dense_bytes() -> int:
    return sum(4 * int(jnp.prod(jnp.asarray(s))) for s in SHAPES.values())


def bench_cell(codec_name: str, wire_dtype: str, cohort: int,
               iters: int = 5):
    """One (codec, wire_dtype, cohort) cell -> structured BENCH row."""
    cfg = TransportConfig(rank=8, block=128, wire_dtype=wire_dtype)
    codec = resolve_codec(codec_name, cfg)
    stacked = _stacked_tree(cohort)
    msgs = jax.jit(jax.vmap(codec.encode))(stacked)
    w = 0.5 + 0.5 * jax.random.uniform(jax.random.key(1), (cohort,))

    fused = jax.jit(codec.accumulate)
    decode = jax.jit(lambda m, ww: client_weighted_sum(
        jax.vmap(codec.decode)(m), ww))

    a = jax.block_until_ready(fused(msgs, w))
    bb = jax.block_until_ready(decode(msgs, w))
    maxdiff = max(float(jnp.max(jnp.abs(x - y)))
                  for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(bb)))
    if maxdiff > 1e-4:
        raise AssertionError(
            f"fused/decode disagree for {codec_name}/{wire_dtype}: "
            f"maxdiff={maxdiff}")

    us_fused, _ = timeit(fused, msgs, w, iters=iters)
    us_decode, _ = timeit(decode, msgs, w, iters=iters)

    # peak transient bytes beyond the resident wire messages: the decode
    # path materializes the full (B, ...) f32 stack; the fused path's
    # largest intermediate is one dense f32 output tree
    decoded_stack = cohort * _dense_bytes()
    fused_peak = _dense_bytes()
    wire = wire_bytes_of(msgs)
    name = f"fused_agg_{codec_name.replace('+', '_')}_{wire_dtype}_c{cohort}"
    emit(name, us_fused,
         f"x_decode={us_decode / us_fused:.2f};"
         f"peak_ratio={decoded_stack / fused_peak:.1f};"
         f"wire_KB={wire / 1e3:.1f};maxdiff={maxdiff:.1e}")
    return {"name": name, "us_per_call": us_fused,
            "derived": {"codec": codec_name, "wire_dtype": wire_dtype,
                        "cohort": cohort, "us_fused": us_fused,
                        "us_decode": us_decode,
                        "x_decode": us_decode / us_fused,
                        "wire_bytes": int(wire),
                        "decoded_stack_bytes": int(decoded_stack),
                        "fused_peak_bytes": int(fused_peak),
                        "peak_bytes_ratio": decoded_stack / fused_peak,
                        "maxdiff": maxdiff}}


def run(quick: bool = True):
    cohorts = (16, 64) if quick else (16, 64, 256)
    iters = 3 if quick else 10
    rows = []
    for codec_name in CODECS:
        for wire_dtype in WIRE_DTYPES:
            if codec_name == "qblock" and wire_dtype == "bf16":
                continue   # int8 payload + f32 scales: no bf16 wire form
            for cohort in cohorts:
                rows.append(bench_cell(codec_name, wire_dtype, cohort,
                                       iters=iters))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(quick=False)
