"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Default mode is sized for a
single-CPU container; pass --full for paper-scale rounds.

Benchmarks that return structured rows (exec_scaling, transport) also
publish ``BENCH_executor.json`` / ``BENCH_transport.json`` under
``--bench-dir`` — the stable perf-trajectory documents (``repro.obs.bench``
schema) CI validates and archives.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only table1,...]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# (job name, BENCH file stem) for jobs whose run() returns structured rows
BENCH_JOBS = {"exec_scaling": "executor", "transport": "transport",
              "traffic": "traffic"}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale rounds (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: table1,table1_vit,fig3,"
                         "table3,table4,table5,table6,async_drift,"
                         "exec_scaling,transport,fused_agg,scenario_matrix,"
                         "traffic")
    ap.add_argument("--bench-dir", default=".",
                    help="directory for the BENCH_*.json perf-trajectory "
                         "documents (exec_scaling/transport jobs)")
    args = ap.parse_args(argv)
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (table1_noniid, fig3_drift, table3_llm,
                            table4_beta, table5_ablation, table6_comm,
                            seed_robustness, async_drift, executor_scaling,
                            transport_bench, fused_agg_bench,
                            scenario_matrix, traffic_replay)
    from benchmarks.common import emit

    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    jobs = [
        ("table1", lambda: table1_noniid.run(quick=quick, model="cnn")),
        ("table1_vit", lambda: table1_noniid.run(quick=quick, model="vit")),
        ("fig3", lambda: fig3_drift.run(quick=quick)),
        ("table3", lambda: table3_llm.run(quick=quick)),
        ("table4", lambda: table4_beta.run(quick=quick)),
        ("table5", lambda: table5_ablation.run(quick=quick)),
        ("table6", lambda: table6_comm.run(quick=quick)),
        ("async_drift", lambda: async_drift.run(quick=quick)),
        ("exec_scaling", lambda: executor_scaling.run(quick=quick)),
        ("transport", lambda: transport_bench.run(quick=quick)),
        ("traffic", lambda: traffic_replay.run(quick=quick)),
        # standalone micro-bench (no training): the same rows also ride
        # inside the transport job's BENCH_transport.json
        ("fused_agg", lambda: fused_agg_bench.run(quick=quick)),
        ("scenario_matrix", lambda: scenario_matrix.run(quick=quick)),
        ("robust", lambda: seed_robustness.run(quick=quick)),
    ]
    failures = 0
    for name, fn in jobs:
        if only and name not in only:
            continue
        try:
            result = fn()
            if name in BENCH_JOBS and result:
                from repro.obs import write_bench
                path = os.path.join(args.bench_dir,
                                    f"BENCH_{BENCH_JOBS[name]}.json")
                write_bench(path, BENCH_JOBS[name], result,
                            config={"quick": quick})
                emit(f"{name}_bench_written", 0.0, path)
        except Exception as e:  # noqa: BLE001
            failures += 1
            emit(f"{name}_ERROR", 0.0, f"{type(e).__name__}:{str(e)[:120]}")
    emit("total_wall_s", (time.perf_counter() - t0) * 1e6,
         f"failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
