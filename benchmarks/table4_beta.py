"""Table 4 reproduction: sensitivity of FedPAC_SOAP to the correction
strength beta.  Claim: interior optimum (beta=0 underuses the correction,
beta->1 over-regularizes)."""
from __future__ import annotations

from benchmarks.common import run_algorithm, emit


def run(quick: bool = True):
    rounds = 15 if quick else 50
    betas = [0.0, 0.5, 0.9] if quick else [0.0, 0.1, 0.3, 0.5, 0.7, 0.9]
    accs = {}
    for beta in betas:
        exp, hist, wall = run_algorithm(
            "fedpac_soap", scenario="cifar_like_cnn_dir0.05",
            scenario_seed=2, rounds=rounds, local_steps=5, beta=beta)
        accs[beta] = hist[-1]["test_acc"]
        emit(f"table4_beta{beta}", wall / rounds * 1e6,
             f"acc={accs[beta]:.4f}")
    best = max(accs, key=accs.get)
    emit("table4_claim_interior_optimum", 0.0,
         f"best_beta={best};interior={0.0 < best < 0.9};accs={accs}")
    return accs


if __name__ == "__main__":
    run(quick=False)
