"""Shared benchmark scaffolding: timing, CSV emission, tiny fed problems."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.api import build_experiment
from repro.data import make_image_classification, dirichlet_partition
from repro.models.vision import (
    init_cnn, cnn_apply, init_vit, vit_apply, classification_loss, accuracy,
)
from repro.fed import FedConfig

ROWS = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timeit(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out


def make_fed_vision_problem(*, model: str = "cnn", n: int = 3000,
                            image_size: int = 12, n_classes: int = 8,
                            n_clients: int = 10, alpha: float = 0.1,
                            seed: int = 0, batch: int = 16,
                            noise: float = 2.5):
    """Dirichlet-partitioned synthetic image task + model + loss/eval fns."""
    n_test = 768
    X_all, y_all = make_image_classification(n + n_test,
                                             image_size=image_size,
                                             n_classes=n_classes, seed=seed,
                                             noise=noise)
    X, y = X_all[:n], y_all[:n]
    Xe, ye = jnp.asarray(X_all[n:]), jnp.asarray(y_all[n:])
    if alpha is None:  # IID
        rng = np.random.default_rng(seed)
        idx = rng.permutation(n)
        parts = np.array_split(idx, n_clients)
    else:
        parts = dirichlet_partition(y, n_clients, alpha, seed=seed)

    if model == "cnn":
        params = init_cnn(jax.random.key(seed), n_classes=n_classes, width=8,
                          blocks=2)
        apply = cnn_apply
    else:
        params, meta = init_vit(jax.random.key(seed), image_size=image_size,
                                patch=4, d_model=48, layers=2, heads=2,
                                n_classes=n_classes)
        apply = lambda p, x: vit_apply(p, meta, x)

    def loss_fn(p, b):
        return classification_loss(apply(p, b["x"]), b["y"])

    @jax.jit
    def eval_logits(p):
        return apply(p, Xe)

    def eval_fn(p):
        logits = eval_logits(p)
        return {"test_acc": accuracy(logits, ye),
                "test_loss": classification_loss(logits, ye)}

    def batch_fn(cid, rng):
        # fixed size (with replacement) so cohort batches stack
        idx = rng.choice(parts[cid], size=batch, replace=True)
        return {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}

    return params, loss_fn, batch_fn, eval_fn


# per-task-tuned lrs (the paper grid-searches per optimizer; Sophia's clip
# makes its LM lr far too small for the small vision task)
VISION_LRS = {"sophia": 2e-2}


def run_algorithm(algo: str, params, loss_fn, batch_fn, eval_fn, *,
                  n_clients=10, participation=0.5, rounds=20, local_steps=5,
                  lr=None, beta=0.5, seed=0, svd_rank=8, theta_codec=None,
                  delta_codec=None, error_feedback=True):
    if lr is None and "sophia" in algo:
        lr = VISION_LRS["sophia"]
    fed = FedConfig(algorithm=algo, n_clients=n_clients,
                    participation=participation, rounds=rounds,
                    local_steps=local_steps, lr=lr, beta=beta, seed=seed,
                    svd_rank=svd_rank, theta_codec=theta_codec,
                    delta_codec=delta_codec, error_feedback=error_feedback)
    exp = build_experiment(algo, params=params, loss_fn=loss_fn,
                           client_batch_fn=batch_fn, eval_fn=eval_fn, fed=fed)
    t0 = time.perf_counter()
    hist = exp.run()
    wall = time.perf_counter() - t0
    return exp, hist, wall
