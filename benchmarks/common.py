"""Shared benchmark scaffolding: timing, CSV emission, scenario runners."""
from __future__ import annotations

import time

import jax

from repro.api import build_experiment
from repro.fed import FedConfig
from repro.scenarios import cifar_like, materialize, resolve

ROWS = []

# sweeps run many algorithms over the same task: materialize each
# (scenario, seed, n_clients) once and share the bundle (data, partition,
# params, jitted eval) across cells
_SCENARIO_CACHE = {}


def materialize_cached(scenario, seed: int, n_clients: int):
    spec = resolve(scenario)
    key = (repr(spec), seed, n_clients)
    if key not in _SCENARIO_CACHE:
        _SCENARIO_CACHE[key] = materialize(spec, seed=seed,
                                           n_clients=n_clients)
    return _SCENARIO_CACHE[key]


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timeit(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out


def make_fed_vision_problem(*, model: str = "cnn", n: int = 3000,
                            image_size: int = 12, n_classes: int = 8,
                            n_clients: int = 10, alpha: float = 0.1,
                            seed: int = 0, batch: int = 16,
                            noise: float = 2.5):
    """Dirichlet-partitioned synthetic image task + model + loss/eval fns.

    Legacy adapter: builds the equivalent (unregistered) ``ScenarioSpec``
    via ``repro.scenarios.cifar_like`` and materializes it — the golden
    test in ``tests/test_scenarios.py`` pins this path bitwise against the
    registered ``cifar_like_cnn`` catalog entry.  Prefer
    ``build_experiment(algorithm, scenario=...)`` in new code.
    """
    spec = cifar_like(model=model, n=n, image_size=image_size,
                      n_classes=n_classes, alpha=alpha, batch=batch,
                      noise=noise)
    return materialize(spec, seed=seed, n_clients=n_clients).problem()


# per-task-tuned lrs (the paper grid-searches per optimizer; Sophia's clip
# makes its LM lr far too small for the small vision task)
VISION_LRS = {"sophia": 2e-2}


def run_algorithm(algo: str, params=None, loss_fn=None, batch_fn=None,
                  eval_fn=None, *, scenario=None, scenario_seed=None,
                  n_clients=10, participation=0.5, rounds=20, local_steps=5,
                  lr=None, beta=0.5, seed=0, svd_rank=8, theta_codec=None,
                  delta_codec=None, error_feedback=True, trace_sink=None):
    """Run one algorithm on an explicit problem bundle or a scenario.

    ``scenario`` (a registered name or ``ScenarioSpec``) routes through
    ``build_experiment(algorithm, scenario=...)``; ``scenario_seed``
    defaults to the fed seed.  The vision Sophia lr override applies on
    both paths (every caller here is a vision-scale problem — LM tables
    drive ``build_experiment`` directly).

    ``trace_sink`` (a ``repro.obs.Sink``) attaches the observability trace
    before running — round events then carry the jit-pure telemetry
    (drift, beta trajectory, ...) benchmarks can read instead of
    recomputing from history.
    """
    if lr is None and "sophia" in algo:
        lr = VISION_LRS["sophia"]
    fed = FedConfig(algorithm=algo, n_clients=n_clients,
                    participation=participation, rounds=rounds,
                    local_steps=local_steps, lr=lr, beta=beta, seed=seed,
                    svd_rank=svd_rank, theta_codec=theta_codec,
                    delta_codec=delta_codec, error_feedback=error_feedback)
    if scenario is not None:
        bundle = materialize_cached(
            scenario, scenario_seed if scenario_seed is not None else seed,
            n_clients)
        exp = build_experiment(algo, scenario=bundle, fed=fed)
    else:
        exp = build_experiment(algo, params=params, loss_fn=loss_fn,
                               client_batch_fn=batch_fn, eval_fn=eval_fn,
                               fed=fed)
    if trace_sink is not None:
        from repro.obs import attach
        attach(exp, trace_sink)
    t0 = time.perf_counter()
    hist = exp.run()
    wall = time.perf_counter() - t0
    return exp, hist, wall
