"""Fig. 3 reproduction: preconditioner drift (Def. 1) of Local SOAP vs
FedPAC_SOAP across rounds, plus rounds-to-accuracy-threshold.

Claims: (i) FedPAC reduces *normalized* drift ||Theta_i - mean|| / ||mean||;
(ii) lower drift correlates with reaching the accuracy threshold sooner.

The drift/beta trajectories are read from the observability telemetry
stream (a ``repro.obs.MemorySink`` attached to the run) — the jit-pure
diagnostics the round itself computed — not recomputed from the metrics
history.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_algorithm, emit


def run(quick: bool = True):
    from repro.obs import MemorySink
    rounds = 30 if quick else 60
    out = {}
    for algo in ["local_soap", "fedpac_soap"]:
        sink = MemorySink()
        exp, hist, wall = run_algorithm(algo, scenario="cifar_like_cnn",
                                        scenario_seed=1, rounds=rounds,
                                        local_steps=5, trace_sink=sink)
        tele = [e["telemetry"] for e in sink.rounds()]
        accs = [h["test_acc"] for h in hist]
        drifts = [t["drift"] for t in tele]
        thresh = 0.30
        reach = next((i + 1 for i, a in enumerate(accs) if a >= thresh),
                     None)
        out[algo] = dict(acc=accs[-1], drift_final=drifts[-1],
                         drift_mean=float(np.mean(drifts)), reach=reach,
                         beta_final=tele[-1]["beta_next"])
        emit(f"fig3_{algo}", wall / rounds * 1e6,
             f"acc={accs[-1]:.4f};mean_drift={np.mean(drifts):.3e};"
             f"beta_final={tele[-1]['beta_next']:.3f};"
             f"rounds_to_{thresh}={reach}")
    emit("fig3_claim_drift_accel", 0.0,
         f"fedpac_acc={out['fedpac_soap']['acc']:.4f};"
         f"local_acc={out['local_soap']['acc']:.4f};"
         f"fedpac_faster={out['fedpac_soap']['acc'] >= out['local_soap']['acc']}")
    return out


if __name__ == "__main__":
    run(quick=False)
