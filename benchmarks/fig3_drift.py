"""Fig. 3 reproduction: preconditioner drift (Def. 1) of Local SOAP vs
FedPAC_SOAP across rounds, plus rounds-to-accuracy-threshold.

Claims: (i) FedPAC reduces *normalized* drift ||Theta_i - mean|| / ||mean||;
(ii) lower drift correlates with reaching the accuracy threshold sooner.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_algorithm, emit


def run(quick: bool = True):
    rounds = 30 if quick else 60
    out = {}
    for algo in ["local_soap", "fedpac_soap"]:
        exp, hist, wall = run_algorithm(algo, scenario="cifar_like_cnn",
                                        scenario_seed=1, rounds=rounds,
                                        local_steps=5)
        accs = [h["test_acc"] for h in hist]
        drifts = [h["drift"] for h in hist]
        thresh = 0.30
        reach = next((i + 1 for i, a in enumerate(accs) if a >= thresh),
                     None)
        out[algo] = dict(acc=accs[-1], drift_final=drifts[-1],
                         drift_mean=float(np.mean(drifts)), reach=reach)
        emit(f"fig3_{algo}", wall / rounds * 1e6,
             f"acc={accs[-1]:.4f};mean_drift={np.mean(drifts):.3e};"
             f"rounds_to_{thresh}={reach}")
    emit("fig3_claim_drift_accel", 0.0,
         f"fedpac_acc={out['fedpac_soap']['acc']:.4f};"
         f"local_acc={out['local_soap']['acc']:.4f};"
         f"fedpac_faster={out['fedpac_soap']['acc'] >= out['local_soap']['acc']}")
    return out


if __name__ == "__main__":
    run(quick=False)
