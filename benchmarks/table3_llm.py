"""Table 3 reproduction (scaled): federated LM pre-training on non-IID token
streams (C4 stand-in) with LLaMA-family models; train loss after R rounds.

Claims: Local AdamW/second-order >> FedAvg; FedPAC_X matches-or-beats Local_X.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro import configs
from repro.data import make_lm_corpus
from repro.fed import FedConfig, FederatedExperiment
from repro.models import model as M

ALGOS = ["fedavg", "local_adamw", "local_sophia", "fedpac_sophia",
         "local_muon", "fedpac_muon", "local_soap", "fedpac_soap"]


def run(quick: bool = True, arch: str = "llama-60m"):
    cfg = configs.get_reduced(arch, layers=2, d_model=128,
                              vocab=256).replace(dtype="float32")
    rounds = 30 if quick else 60
    n_clients, K, B, seq = 8, 5, 8, 32
    streams = make_lm_corpus(n_clients, 60_000, vocab=cfg.vocab_size,
                             hetero=0.9, seed=0)
    params = M.init_params(cfg, jax.random.key(0))

    def loss_fn(p, batch):
        return M.loss_fn(p, batch, cfg)

    results = {}
    import time
    for algo in ALGOS:
        rng = np.random.default_rng(0)

        def batch_fn(cid, rng_):
            s = streams[cid]
            starts = rng_.integers(0, len(s) - seq - 1, B)
            idx = starts[:, None] + np.arange(seq + 1)
            w = s[idx]
            return {"tokens": jnp.asarray(w[:, :-1]),
                    "labels": jnp.asarray(w[:, 1:])}

        fed = FedConfig(algorithm=algo, n_clients=n_clients,
                        participation=0.25, rounds=rounds, local_steps=K,
                        seed=0)
        exp = FederatedExperiment(fed, params, loss_fn, batch_fn)
        t0 = time.perf_counter()
        hist = exp.run()
        wall = time.perf_counter() - t0
        results[algo] = hist[-1]["loss"]
        emit(f"table3_{arch}_{algo}", wall / rounds * 1e6,
             f"train_loss={hist[-1]['loss']:.4f}")
    emit(f"table3_claim_{arch}", 0.0,
         f"fedavg={results['fedavg']:.3f};"
         f"soap_local={results['local_soap']:.3f};"
         f"soap_fedpac={results['fedpac_soap']:.3f};"
         f"second_order_beats_fedavg="
         f"{results['local_soap'] < results['fedavg']};"
         f"fedpac_matches_or_beats="
         f"{results['fedpac_soap'] <= results['local_soap'] + 0.05}")
    return results


if __name__ == "__main__":
    run(quick=False)
