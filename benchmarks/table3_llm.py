"""Table 3 reproduction (scaled): federated LM pre-training on non-IID token
streams (C4 stand-in) with LLaMA-family models; train loss after R rounds.

The task is the registered ``lm_zipf`` scenario — topic-skewed documents
partitioned by Dirichlet over topic labels — sized up here toward the
paper's setting (d_model=128, vocab=256, ~60k tokens/client).

Claims: Local AdamW/second-order >> FedAvg; FedPAC_X matches-or-beats Local_X.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, materialize_cached
from repro.api import build_experiment
from repro.fed import FedConfig
from repro.scenarios import lm_zipf

ALGOS = ["fedavg", "local_adamw", "local_sophia", "fedpac_sophia",
         "local_muon", "fedpac_muon", "local_soap", "fedpac_soap"]


def scenario(arch: str = "llama-60m"):
    # ~60k tokens/client at the default 256 docs over 8 clients
    return lm_zipf(tokens_per_doc=1900, arch=arch, d_model=128,
                   name=f"lm_zipf_table3_{arch}")


def run(quick: bool = True, arch: str = "llama-60m"):
    rounds = 30 if quick else 60
    scn = materialize_cached(scenario(arch), 0, 8)
    results = {}
    for algo in ALGOS:
        fed = FedConfig(algorithm=algo, n_clients=8, participation=0.25,
                        rounds=rounds, local_steps=5, seed=0)
        exp = build_experiment(algo, scenario=scn, fed=fed)
        t0 = time.perf_counter()
        hist = exp.run()
        wall = time.perf_counter() - t0
        results[algo] = hist[-1]["loss"]
        emit(f"table3_{arch}_{algo}", wall / rounds * 1e6,
             f"train_loss={hist[-1]['loss']:.4f};"
             f"eval_loss={hist[-1]['eval_loss']:.4f}")
    emit(f"table3_claim_{arch}", 0.0,
         f"fedavg={results['fedavg']:.3f};"
         f"soap_local={results['local_soap']:.3f};"
         f"soap_fedpac={results['fedpac_soap']:.3f};"
         f"second_order_beats_fedavg="
         f"{results['local_soap'] < results['fedavg']};"
         f"fedpac_matches_or_beats="
         f"{results['fedpac_soap'] <= results['local_soap'] + 0.05}")
    return results


if __name__ == "__main__":
    run(quick=False)
