"""Geometry-transport sweep: codec x rank x quantization vs bytes/round and
final test loss, plus the error-feedback claim.

Every byte count is measured from the encoded wire messages
(``transport.wire_bytes``), never from analytic formulas.  Claims:
  - factored/quantized codecs cut the Theta payload multiples below dense
    while keeping most of FedPAC's accuracy;
  - a lossy *delta* codec with error feedback reaches lower test loss
    than the same codec without it (the residual is delayed, not lost).

Returns the structured ``BENCH_transport.json`` row list
(``{"name", "us_per_call", "derived": {...}}`` — see ``repro.obs.bench``),
including the fused decode-aggregate micro-bench rows
(``benchmarks.fused_agg_bench``: fused-vs-decode x wire_dtype x cohort),
so the perf-trajectory document carries the fused-path headline.
"""
from __future__ import annotations

from benchmarks import fused_agg_bench
from benchmarks.common import run_algorithm, emit

SCENARIO = "cifar_like_cnn_dir0.05"


def run(quick: bool = True):
    rounds = 10 if quick else 30

    # --- Theta codec sweep (fedpac_soap uploads) -------------------------
    sweep = [("dense", None), ("lowrank_svd", 2), ("lowrank_svd", 8),
             ("power_sketch", 8), ("qblock", None),
             ("lowrank_svd+qblock", 8)]
    if quick:
        sweep = [("dense", None), ("lowrank_svd", 4), ("qblock", None),
                 ("lowrank_svd+qblock", 4)]
    base_comm = None
    rows = []
    for codec, rank in sweep:
        exp, hist, wall = run_algorithm(
            "fedpac_soap", scenario=SCENARIO, scenario_seed=7,
            rounds=rounds, local_steps=5, svd_rank=rank or 8,
            theta_codec=codec)
        comm = exp.comm_bytes_per_round()
        base_comm = base_comm or comm
        tag = f"{codec}_r{rank}" if rank else codec
        us = wall / rounds * 1e6
        emit(f"transport_theta_{tag}", us,
             f"loss={hist[-1]['test_loss']:.4f};acc={hist[-1]['test_acc']:.4f};"
             f"comm_KB={comm/1e3:.1f};x_dense={comm/base_comm:.3f}")
        rows.append({"name": f"transport_theta_{tag}", "us_per_call": us,
                     "derived": {"codec": codec, "rank": rank,
                                 "loss": float(hist[-1]["test_loss"]),
                                 "acc": float(hist[-1]["test_acc"]),
                                 "comm_bytes": int(comm),
                                 "x_dense": comm / base_comm}})

    # --- error-feedback claim (lossy delta codec) ------------------------
    # rank-1 truncation of the deltas is a strongly biased compressor:
    # without the residual carrying the rejected components, the server
    # only ever sees the top singular direction of each update.
    results = {}
    for ef in (True, False):
        exp, hist, _ = run_algorithm(
            "fedpac_soap", scenario=SCENARIO, scenario_seed=7,
            rounds=rounds, local_steps=5, svd_rank=1,
            delta_codec="lowrank_svd", error_feedback=ef)
        results[ef] = hist[-1]["test_loss"]
        emit(f"transport_delta_lowrank1_ef{int(ef)}", 0.0,
             f"loss={results[ef]:.4f};comm_KB="
             f"{exp.comm_bytes_per_round()/1e3:.1f}")
        rows.append({"name": f"transport_delta_lowrank1_ef{int(ef)}",
                     "us_per_call": 0.0,
                     "derived": {"error_feedback": ef,
                                 "loss": float(results[ef]),
                                 "comm_bytes":
                                     int(exp.comm_bytes_per_round())}})
    emit("transport_claim_ef_helps", 0.0,
         f"ef_loss={results[True]:.4f};noef_loss={results[False]:.4f};"
         f"ef_better={results[True] < results[False]}")
    rows.append({"name": "transport_claim_ef_helps", "us_per_call": 0.0,
                 "derived": {"ef_loss": float(results[True]),
                             "noef_loss": float(results[False]),
                             "ef_better":
                                 bool(results[True] < results[False])}})

    # --- fused decode-aggregate flush (Codec.accumulate) -----------------
    rows.extend(fused_agg_bench.run(quick=quick))
    return rows


if __name__ == "__main__":
    run(quick=False)
