"""Roofline report generator.

Two modes:

  (default)   reads dry-run JSONL records and renders the per-(arch x
              shape x mesh) table for EXPERIMENTS.md §Roofline:
                PYTHONPATH=src python -m benchmarks.roofline \\
                    results/dryrun_baseline.jsonl
  --kernels   *measures* the kernel triads (soap_rotate, qblock, ns_ortho,
              sophia_update, fused_agg) through the observability profiling
              hooks
              (``repro.obs.profiling``) and renders achieved GFLOP/s and
              GB/s per (kernel, impl, shape) — the measured points to place
              against the analytic roofline above:
                PYTHONPATH=src python -m benchmarks.roofline --kernels \\
                    --shapes 256x256,512x512
"""
from __future__ import annotations

import argparse
import json
import sys


def load(path):
    recs = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                recs.append(json.loads(line))
    # last record per key wins (reruns append)
    dedup = {}
    for r in recs:
        dedup[(r["arch"], r["shape"], r["mesh"], r.get("step"),
               r.get("seq_shard", False), r.get("opt"))] = r
    return list(dedup.values())


def fmt_bytes(n):
    if n is None:
        return "-"
    for unit in ["B", "KB", "MB", "GB", "TB"]:
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def what_moves(rec):
    d = rec["dominant"]
    if d == "compute":
        return "lower-precision matmuls / fewer remat recomputes"
    if d == "memory":
        if rec["shape"].startswith("decode") or rec["shape"] == "long_500k":
            return "shrink KV-cache reads (quantized cache, MLA/ring buffer)"
        return "fuse elementwise chains; cut remat traffic (seq-sharding)"
    return "overlap collectives with compute; 2D-shard to cut all-gathers"


def table(recs, mesh="pod"):
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    hdr = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dominant "
           "| MODEL_FLOPS | useful ratio | peak/dev |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3e} "
            f"| {r['t_memory']:.3e} | {r['t_collective']:.3e} "
            f"| **{r['dominant']}** | {r['model_flops_total']:.2e} "
            f"| {r['useful_flop_ratio']:.2f} "
            f"| {fmt_bytes(r['bytes_per_device']['peak'])} |")
    return "\n".join(out)


def kernel_table(records):
    hdr = ("| kernel | impl | shape | us/call | GFLOP/s | GB/s | backend |")
    sep = "|" + "---|" * 7
    out = [hdr, sep]
    for r in records:
        shape = "x".join(str(d) for d in r["shape"])
        out.append(
            f"| {r['kernel']} | {r['impl']} | {shape} "
            f"| {r['us_per_call']:.1f} | {r['gflops_s']:.2f} "
            f"| {r['gbps']:.2f} | {r['backend']} |")
    return "\n".join(out)


def run_kernels(shapes, iters=5, kernels=None):
    from repro.obs import profile_kernels
    records = profile_kernels(shapes=shapes, iters=iters, kernels=kernels)
    print(kernel_table(records))
    return records


def report(path):
    recs = load(path)
    print(table(recs, "pod"))
    print()
    print("### Per-pair bottleneck notes")
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "pod":
            continue
        print(f"- {r['arch']} x {r['shape']}: dominant={r['dominant']}; "
              f"to improve: {what_moves(r)}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("path", nargs="?", default="results/dryrun_baseline.jsonl",
                    help="dry-run JSONL records (report mode)")
    ap.add_argument("--kernels", action="store_true",
                    help="profile the kernel triads instead of reading "
                         "dry-run records")
    ap.add_argument("--shapes", default="256x256",
                    help="comma-separated NxM shapes for --kernels")
    ap.add_argument("--iters", type=int, default=5)
    args = ap.parse_args(argv)
    if args.kernels:
        shapes = tuple(tuple(int(d) for d in s.split("x"))
                       for s in args.shapes.split(","))
        run_kernels(shapes, iters=args.iters)
        return 0
    return report(args.path)


if __name__ == "__main__":
    sys.exit(main())
