"""Cohort-executor scaling sweep: vmap vs shard_map vs chunked round latency.

Times one jitted FedPAC round per (backend, cohort size S) on a small
vision problem — the speed/scale trade-off behind
``core.engine.executors``:

  vmap       fastest when the cohort fits one device;
  shard_map  shards clients over the mesh's data axes (linear speedup in S
             on multi-device meshes; on one CPU device it measures the
             shard_map overhead floor);
  chunked    bounded peak memory, wall clock ~ S/chunk_size sequential
             steps — the only backend that runs when S outgrows the device.

Emits ``exec_<backend>_S<cohort>`` rows (us per round) and returns them in
the structured ``BENCH_executor.json`` row schema
(``{"name", "us_per_call", "derived": {...}}`` — see ``repro.obs.bench``).
"""
from __future__ import annotations

import time

import numpy as np
import jax

from repro.api import build_experiment
from repro.core.engine import ExecutorConfig
from repro.fed import FedConfig
from repro.scenarios import cifar_like, materialize
from benchmarks.common import emit

BACKEND_CFGS = {
    "vmap": dict(executor="vmap"),
    "shard_map": dict(executor="shard_map"),
    "chunked": dict(executor="chunked", chunk_size=4),
}


def _time_round(exp, iters=3):
    exp.run_round()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        exp.run_round()
    jax.block_until_ready(exp.server.params)
    return (time.perf_counter() - t0) / iters * 1e6


def run(quick: bool = True):
    cohorts = [4, 8] if quick else [4, 8, 16, 32]
    n_clients = max(cohorts)
    scenario = cifar_like(model="cnn", n=600, image_size=8, n_classes=4,
                          alpha=0.3, batch=8, n_clients=n_clients)
    # materialize once and drop the eval fn: only the round is timed
    params, loss_fn, batch_fn, _ = materialize(
        scenario, seed=0, n_clients=n_clients).problem()
    results, rows = {}, []
    for backend, kw in BACKEND_CFGS.items():
        for s in cohorts:
            fed = FedConfig(algorithm="fedpac_soap", n_clients=n_clients,
                            participation=s / n_clients, rounds=4,
                            local_steps=2, **kw)
            exp = build_experiment("fedpac_soap", params=params,
                                   loss_fn=loss_fn, client_batch_fn=batch_fn,
                                   fed=fed)
            us = _time_round(exp)
            loss = float(exp.history[-1]["loss"])
            results[(backend, s)] = (us, loss)
            emit(f"exec_{backend}_S{s}", us, f"loss={loss:.4f}")
            rows.append({"name": f"exec_{backend}_S{s}", "us_per_call": us,
                         "derived": {"backend": backend, "cohort": s,
                                     "loss": loss}})
    # cross-backend agreement on the final loss (same seed, same cohorts)
    for s in cohorts:
        losses = [results[(b, s)][1] for b in BACKEND_CFGS]
        dev = max(losses) - min(losses)
        emit(f"exec_agree_S{s}", 0.0, f"max_dev={dev:.2e}")
        rows.append({"name": f"exec_agree_S{s}", "us_per_call": 0.0,
                     "derived": {"cohort": s, "max_dev": dev}})
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(quick=True)
