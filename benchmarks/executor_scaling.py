"""Cohort-executor scaling sweep: vmap vs shard_map vs chunked round latency.

Times one jitted FedPAC round per (backend, cohort size S) on a small
vision problem — the speed/scale trade-off behind
``core.engine.executors``:

  vmap       fastest when the cohort fits one device;
  shard_map  shards clients over the mesh's data axes (linear speedup in S
             on multi-device meshes; on one CPU device it measures the
             shard_map overhead floor);
  chunked    bounded peak memory, wall clock ~ S/chunk_size sequential
             steps — the only backend that runs when S outgrows the device;
  sharded    shard_map across the mesh x chunked within each shard — the
             population-scale path (10k+ cohorts with cohort-proportional
             peak memory).

The population sweep (``pop_P<population>_S<cohort>`` rows) runs the same
round over a streamed 10^6-id population: lazy ``stream_dirichlet``
partition, sparse LRU client-state store, and the ``sharded`` executor.
Each row records peak resident client-state entries against the configured
budget — the benchmark *fails* if the store ever exceeds it, so CI's quick
mode doubles as the memory-bound regression check.

Emits ``exec_*`` / ``pop_*`` rows (us per round) and returns them in the
structured ``BENCH_executor.json`` row schema
(``{"name", "us_per_call", "derived": {...}}`` — see ``repro.obs.bench``).
"""
from __future__ import annotations

import time

import numpy as np
import jax

from repro.api import build_experiment
from repro.core.engine import ExecutorConfig
from repro.fed import FedConfig
from repro.scenarios import PartitionSpec, cifar_like, materialize
from benchmarks.common import emit

BACKEND_CFGS = {
    "vmap": dict(executor="vmap"),
    "shard_map": dict(executor="shard_map"),
    "chunked": dict(executor="chunked", chunk_size=4),
}

POPULATION = 1_000_000


def _time_round(exp, iters=3):
    exp.run_round()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        exp.run_round()
    jax.block_until_ready(exp.server.params)
    return (time.perf_counter() - t0) / iters * 1e6


def _pop_rows(quick: bool):
    """Streamed-population sweep: 1M ids, sharded executor, sparse state.

    Cohort sizes are the scale axis; ``state_budget = 1.5 x cohort`` keeps
    peak client-state memory cohort-proportional while forcing LRU
    eviction + spill across rounds (fresh cohorts each round from a
    10^6-id space are disjoint with near-certainty, so two rounds overflow
    the budget by half a cohort).
    """
    import tempfile

    from repro.obs import MemorySink, attach
    from benchmarks.pipeline_bench import _phase_split

    cohorts = [256] if quick else [256, 1024, 10_000]
    spec = cifar_like(
        model="cnn", n=600, image_size=8, n_classes=4, batch=8,
        n_clients=POPULATION, name="exec_pop",
        partition=PartitionSpec("stream_dirichlet", alpha=0.3,
                                samples_per_client=32))
    scn = materialize(spec, seed=0, n_clients=POPULATION)
    rows = []
    for s in cohorts:
        budget = (3 * s) // 2
        with tempfile.TemporaryDirectory(prefix="bench_spill_") as spill:
            exp = build_experiment(
                "scaffold", scenario=scn, rounds=4, local_steps=2,
                population_size=POPULATION, cohort_size=s,
                state_budget=budget, spill_dir=spill, seed=0,
                executor="sharded", chunk_size=min(64, s))
            sink = MemorySink()
            attach(exp, sink)
            us = _time_round(exp, iters=1)
            split = _phase_split(sink, exp.server.round)
            rec = exp.history[-1]
        loss = float(rec["loss"])
        peak = int(rec["state_peak"])
        spills, restores = int(rec["state_spills"]), int(rec["state_restores"])
        if peak > budget:
            raise RuntimeError(
                f"population sweep S={s}: peak client-state entries {peak} "
                f"exceeded state_budget={budget} — the sparse store leaked")
        emit(f"pop_P{POPULATION}_S{s}", us,
             f"peak={peak}/{budget} spills={spills} loss={loss:.4f}")
        rows.append({
            "name": f"pop_P{POPULATION}_S{s}", "us_per_call": us,
            "derived": {"backend": "sharded", "population": POPULATION,
                        "cohort": s, "state_budget": budget,
                        "peak_state_entries": peak, "spills": spills,
                        "restores": restores, "loss": loss,
                        # host-phase wall split of the timed round (from
                        # round-trace spans): where a pipelined round's
                        # overlap headroom actually lives
                        "stage_s": round(split.get("stage_batches", 0.0), 4),
                        "acquire_s": round(split.get("state_acquire", 0.0),
                                           4),
                        "update_s": round(split.get("update", 0.0), 4)}})
    return rows


def run(quick: bool = True):
    cohorts = [4, 8] if quick else [4, 8, 16, 32]
    n_clients = max(cohorts)
    scenario = cifar_like(model="cnn", n=600, image_size=8, n_classes=4,
                          alpha=0.3, batch=8, n_clients=n_clients)
    # materialize once and drop the eval fn: only the round is timed
    params, loss_fn, batch_fn, _ = materialize(
        scenario, seed=0, n_clients=n_clients).problem()
    results, rows = {}, []
    for backend, kw in BACKEND_CFGS.items():
        for s in cohorts:
            fed = FedConfig(algorithm="fedpac_soap", n_clients=n_clients,
                            participation=s / n_clients, rounds=4,
                            local_steps=2, **kw)
            exp = build_experiment("fedpac_soap", params=params,
                                   loss_fn=loss_fn, client_batch_fn=batch_fn,
                                   fed=fed)
            us = _time_round(exp)
            loss = float(exp.history[-1]["loss"])
            results[(backend, s)] = (us, loss)
            emit(f"exec_{backend}_S{s}", us, f"loss={loss:.4f}")
            rows.append({"name": f"exec_{backend}_S{s}", "us_per_call": us,
                         "derived": {"backend": backend, "cohort": s,
                                     "loss": loss}})
    # cross-backend agreement on the final loss (same seed, same cohorts)
    for s in cohorts:
        losses = [results[(b, s)][1] for b in BACKEND_CFGS]
        dev = max(losses) - min(losses)
        emit(f"exec_agree_S{s}", 0.0, f"max_dev={dev:.2e}")
        rows.append({"name": f"exec_agree_S{s}", "us_per_call": 0.0,
                     "derived": {"cohort": s, "max_dev": dev}})
    rows.extend(_pop_rows(quick))
    # pipelined-vs-serial population rounds ride in the same BENCH doc:
    # the pipe_* rows are CI-pinned alongside the exec_*/pop_* rows
    from benchmarks import pipeline_bench
    rows.extend(pipeline_bench.run(quick))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(quick=True)
