"""Pipelined vs serial population rounds: the chunk-streaming round driver
(``fed.pipeline``) against the monolithic staged round, swept over cohort
size and pipeline chunk size on the streamed 10^6-id population.

Both sides run the *same* memory-bounded configuration — ``chunked``
executor, ``state_budget = 1.5 x cohort``, spill to disk — so the sweep
isolates what the pipeline actually changes: staging/restore overlap with
device compute, broadcast-filled fresh rows, write-behind spills, and the
streamed (never cohort-stacked) wire aggregation.  The ``chunked`` backend
is the apples-to-apples reference on a single-device host: the ``sharded``
backend's one-device mesh adds pure shard_map dispatch overhead per call
(see the ``exec_shard_map_*`` rows), which the pipeline would pay per
*chunk*; on a real multi-device mesh the pipeline maps its chunks through
``shard_map`` instead (``fed.pipeline._chunk_executor``).

Emits ``pipe_serial_S<cohort>`` / ``pipe_c<chunk>_S<cohort>`` rows (us per
round).  Serial rows carry the host-phase wall-time split recovered from
round-trace spans (``stage_s``/``acquire_s``/``update_s``); pipelined rows
carry the pipeline's own observability (``bubble`` — the fraction of round
wall time the host spent blocked on staging/restores — plus the
stage/restore wait split and the speedup against the same-cohort serial
row).  The rows ride inside ``BENCH_executor.json`` via the exec_scaling
job; CI pins the row names and asserts pipelined rounds are no slower
than serial and that the S>=1024 bubble fraction stays under 0.5.
"""
from __future__ import annotations

import os
import tempfile
import time

import jax

from repro.api import build_experiment
from repro.obs import MemorySink, attach
from repro.scenarios import PartitionSpec, cifar_like, materialize
from benchmarks.common import emit

POPULATION = 1_000_000
_SCN_CACHE = {}

# stager threads time-slice against XLA compute threads, so on a
# single-core host extra workers are pure contention (measured: 1 worker
# 1.8x vs 4 workers 1.5x at S=1024); multi-core hosts get the default
WORKERS = 1 if (os.cpu_count() or 1) == 1 else 4


def _scenario():
    if "scn" not in _SCN_CACHE:
        spec = cifar_like(
            model="cnn", n=600, image_size=8, n_classes=4, batch=8,
            n_clients=POPULATION, name="pipe_pop",
            partition=PartitionSpec("stream_dirichlet", alpha=0.3,
                                    samples_per_client=32))
        _SCN_CACHE["scn"] = materialize(spec, seed=0, n_clients=POPULATION)
    return _SCN_CACHE["scn"]


def _build(s, spill, **kw):
    return build_experiment(
        "scaffold", scenario=_scenario(), rounds=4, local_steps=2,
        population_size=POPULATION, cohort_size=s,
        state_budget=(3 * s) // 2, spill_dir=spill, seed=0,
        executor="chunked", chunk_size=min(64, s), **kw)


def _time_round(exp):
    """Warm (compile) round, then one timed round, wall us."""
    exp.run_round()
    t0 = time.perf_counter()
    exp.run_round()
    jax.block_until_ready(exp.server.params)
    return (time.perf_counter() - t0) * 1e6


def _phase_split(sink, rnum):
    """Sum span wall time per phase for round ``rnum``."""
    tot = {}
    for e in sink.events:
        if e.get("event") == "span" and e.get("round") == rnum:
            tot[e["phase"]] = tot.get(e["phase"], 0.0) + e["dur_s"]
    return tot


def _serial_row(s):
    with tempfile.TemporaryDirectory(prefix="pipe_bench_") as spill:
        exp = _build(s, spill)
        sink = MemorySink()
        attach(exp, sink)
        us = _time_round(exp)
        split = _phase_split(sink, exp.server.round)
        rec = exp.history[-1]
    derived = {"mode": "serial", "cohort": s,
               "stage_s": round(split.get("stage_batches", 0.0), 4),
               "acquire_s": round(split.get("state_acquire", 0.0), 4),
               "update_s": round(split.get("update", 0.0), 4),
               "loss": float(rec["loss"])}
    emit(f"pipe_serial_S{s}", us,
         f"stage={derived['stage_s']:.2f}s acquire={derived['acquire_s']:.2f}s "
         f"update={derived['update_s']:.2f}s")
    return {"name": f"pipe_serial_S{s}", "us_per_call": us,
            "derived": derived}


def _pipelined_row(s, chunk, serial_us):
    with tempfile.TemporaryDirectory(prefix="pipe_bench_") as spill:
        exp = _build(s, spill, pipeline=True, pipeline_chunk=chunk,
                     pipeline_workers=WORKERS)
        us = _time_round(exp)
        rec = exp.history[-1]
    speedup = serial_us / us
    derived = {"mode": "pipelined", "cohort": s, "chunk": chunk,
               "workers": WORKERS,
               "chunks": int(rec["pipeline_chunks"]),
               "bubble": round(float(rec["pipeline_bubble"]), 4),
               "stage_wait_s": round(float(rec["pipeline_stage_wait_s"]), 4),
               "restore_wait_s": round(float(rec["pipeline_restore_wait_s"]),
                                       4),
               "speedup_vs_serial": round(speedup, 3),
               "loss": float(rec["loss"])}
    emit(f"pipe_c{chunk}_S{s}", us,
         f"speedup={speedup:.2f}x bubble={derived['bubble']:.3f}")
    return {"name": f"pipe_c{chunk}_S{s}", "us_per_call": us,
            "derived": derived}


def run(quick: bool = True):
    # quick (the CI-pinned set) keeps one chunk size per cohort and
    # includes the S=1024 acceptance point; full sweeps the chunk axis
    sweep = ({256: [64], 1024: [128]} if quick
             else {256: [32, 64], 1024: [32, 64, 128, 256], 4096: [256]})
    rows = []
    for s, chunks in sweep.items():
        serial = _serial_row(s)
        rows.append(serial)
        for c in chunks:
            rows.append(_pipelined_row(s, c, serial["us_per_call"]))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(quick=True)
