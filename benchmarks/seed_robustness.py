"""Multi-seed robustness for the paper's headline claim (Table 1):
FedPAC_X vs Local_X under Dir(0.1) non-IID, averaged over seeds.

The single-seed quick-mode runs are noisy at CPU scale (25 rounds, 3k
samples); this check averages 3 seeds per (optimizer, algorithm) cell and
reports the mean gap — the form in which the paper's claim is testable here.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_algorithm, emit

SEEDS = (0, 1, 2)


def run(quick: bool = True, model: str = "cnn", rounds: int = 25):
    rounds = rounds if quick else 60
    gaps = {}
    for opt in ["sophia", "muon", "soap"]:
        accs = {"local": [], "fedpac": []}
        for seed in SEEDS:
            for kind in ["local", "fedpac"]:
                _, hist, wall = run_algorithm(
                    f"{kind}_{opt}", scenario=f"cifar_like_{model}",
                    scenario_seed=seed, rounds=rounds, local_steps=5,
                    seed=seed)
                accs[kind].append(hist[-1]["test_acc"])
        local = float(np.mean(accs["local"]))
        pac = float(np.mean(accs["fedpac"]))
        gaps[opt] = pac - local
        emit(f"robust_{model}_dir0.1_{opt}", 0.0,
             f"fedpac_mean={pac:.4f};local_mean={local:.4f};"
             f"gap={pac - local:+.4f};seeds={len(SEEDS)};"
             f"improves={pac >= local}")
    return gaps


if __name__ == "__main__":
    run()
