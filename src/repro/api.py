"""Top-level public API: the algorithm registry + the experiment builder.

    from repro.api import build_experiment

    exp = build_experiment("fedpac_soap", params=params, loss_fn=loss_fn,
                           client_batch_fn=batch_fn, eval_fn=eval_fn,
                           n_clients=20, participation=0.25, rounds=30)
    history = exp.run()

``build_experiment`` replaces the positional
``make_experiment(fed, params, loss_fn, client_batch_fn, eval_fn,
opt_kwargs, async_cfg)`` sprawl with a keyword builder that accepts either
a registered algorithm name (every legacy paper-table string works), or an
``AlgorithmSpec`` instance directly — including unregistered ones, so a
custom algorithm is usable the moment it is constructed.

Passing ``async_cfg`` selects the buffered-asynchronous runtime unless a
runtime is named explicitly; any ``FedConfig`` field can be given as a
keyword override.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

from repro.core.algorithms import (  # noqa: F401  (re-exported API surface)
    AlgorithmSpec, ClientStateSpec, DuplicateAlgorithmError,
    UnknownAlgorithmError, register, registered, resolve,
)
from repro.fed.base import FedExperiment, make_experiment  # noqa: F401
from repro.fed.rounds import FedConfig, FederatedExperiment
from repro.fed.async_runtime import (  # noqa: F401
    AsyncConfig, AsyncFederatedExperiment, LatencyModel,
)

__all__ = [
    "AlgorithmSpec", "AsyncConfig", "ClientStateSpec",
    "DuplicateAlgorithmError", "FedConfig", "FedExperiment", "LatencyModel",
    "UnknownAlgorithmError", "build_experiment", "make_experiment",
    "register", "registered", "resolve",
]


def build_experiment(
    algorithm: Union[str, AlgorithmSpec],
    *,
    params,
    loss_fn: Callable,
    client_batch_fn: Callable,
    eval_fn: Optional[Callable] = None,
    opt_kwargs: Optional[dict] = None,
    async_cfg: Optional[AsyncConfig] = None,
    fed: Optional[FedConfig] = None,
    **fed_overrides,
) -> FedExperiment:
    """Build the right runtime for ``algorithm`` with keyword configuration.

    algorithm: registered name (``"fedpac_soap"``, any legacy table string)
      or an ``AlgorithmSpec`` — unregistered specs work too.
    fed: optional base ``FedConfig``; ``fed_overrides`` are applied on top
      (``rounds=30, n_clients=20, runtime="async", ...``).
    async_cfg: execution-model knobs; implies ``runtime="async"`` when no
      config was passed at all — an explicit ``fed`` config or ``runtime``
      override is authoritative, and a sync one + async_cfg is an error.
    """
    spec = resolve(algorithm)
    base = fed if fed is not None else FedConfig()
    changes = dict(fed_overrides, algorithm=spec.name)
    if async_cfg is not None and fed is None and "runtime" not in \
            fed_overrides:
        changes["runtime"] = "async"
    cfg = dataclasses.replace(base, **changes)
    if cfg.runtime == "sync":
        if async_cfg is not None:
            raise ValueError(
                "async_cfg given but the config says runtime='sync' — set "
                "runtime='async' (or drop the async_cfg)")
        return FederatedExperiment(cfg, params, loss_fn, client_batch_fn,
                                   eval_fn, opt_kwargs, spec=spec)
    return AsyncFederatedExperiment(cfg, params, loss_fn, client_batch_fn,
                                    eval_fn, opt_kwargs, async_cfg=async_cfg,
                                    spec=spec)
