"""Top-level public API: two registries (algorithms x scenarios) + the one
experiment builder.

    from repro.api import build_experiment

    # declarative: a registered algorithm x a registered scenario
    exp = build_experiment("fedpac_soap", scenario="cifar_like_cnn",
                           rounds=30)
    history = exp.run()

    # or hand-rolled: the explicit problem bundle (legacy path, unchanged)
    exp = build_experiment("fedpac_soap", params=params, loss_fn=loss_fn,
                           client_batch_fn=batch_fn, eval_fn=eval_fn,
                           n_clients=20, participation=0.25, rounds=30)

``build_experiment`` accepts either a registered name or a spec instance on
*both* axes — an ``AlgorithmSpec`` / ``ScenarioSpec`` works the moment it is
constructed, registered or not.  Passing ``async_cfg`` selects the
buffered-asynchronous runtime unless a runtime is named explicitly; any
``FedConfig`` field can be given as a keyword override.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

from repro.core.algorithms import (  # noqa: F401  (re-exported API surface)
    AlgorithmSpec, ClientStateSpec, DuplicateAlgorithmError,
    UnknownAlgorithmError, register, registered, resolve,
)
from repro.scenarios import (  # noqa: F401  (re-exported API surface)
    DuplicateScenarioError, PartitionSpec, Scenario, ScenarioSpec,
    UnknownScenarioError, materialize,
)
from repro.scenarios import (
    register as register_scenario,
    registered as registered_scenarios,
    resolve as resolve_scenario,
)
from repro.fed.base import FedExperiment, make_experiment  # noqa: F401
from repro.fed.rounds import FedConfig, FederatedExperiment
from repro.fed.async_runtime import (  # noqa: F401
    AsyncConfig, AsyncFederatedExperiment, LatencyModel,
)
from repro.fed.traffic import (  # noqa: F401  (re-exported API surface)
    ChurnConfig, TrafficConfig, TrafficExperiment,
)

__all__ = [
    "AlgorithmSpec", "AsyncConfig", "ChurnConfig", "ClientStateSpec",
    "DuplicateAlgorithmError", "DuplicateScenarioError", "FedConfig",
    "FedExperiment", "LatencyModel", "PartitionSpec", "Scenario",
    "ScenarioSpec", "TrafficConfig", "TrafficExperiment",
    "UnknownAlgorithmError", "UnknownScenarioError",
    "build_experiment", "make_experiment", "materialize", "register",
    "register_scenario", "registered", "registered_scenarios", "resolve",
    "resolve_scenario",
]


def build_experiment(
    algorithm: Union[str, AlgorithmSpec],
    *,
    scenario: Optional[Union[str, ScenarioSpec, Scenario]] = None,
    scenario_seed: Optional[int] = None,
    params=None,
    loss_fn: Optional[Callable] = None,
    client_batch_fn: Optional[Callable] = None,
    eval_fn: Optional[Callable] = None,
    opt_kwargs: Optional[dict] = None,
    async_cfg: Optional[AsyncConfig] = None,
    fed: Optional[FedConfig] = None,
    population=None,
    traffic=None,
    **fed_overrides,
) -> FedExperiment:
    """Build the right runtime for ``algorithm`` on ``scenario`` (or on an
    explicit problem bundle) with keyword configuration.

    algorithm: registered name (``"fedpac_soap"``, any legacy table string)
      or an ``AlgorithmSpec`` — unregistered specs work.
    scenario: registered name (``"cifar_like_cnn"``, any catalog entry), a
      ``ScenarioSpec`` (unregistered specs work here too), or an
      already-materialized ``Scenario`` bundle (sweeps: materialize once,
      reuse across algorithms — data, partition, and jitted eval are
      shared).  Names/specs are materialized with ``scenario_seed``
      (default: the fed config's seed) and the resolved ``n_clients``;
      when the caller names no cohort size at all, the scenario's own
      ``n_clients`` becomes the config's.  A pre-materialized bundle must
      agree with the config's ``n_clients`` and ``scenario_seed``.
      Mutually exclusive with the explicit ``params``/``loss_fn``/
      ``client_batch_fn``/``eval_fn`` bundle, which keeps working
      unchanged.
    fed: optional base ``FedConfig``; ``fed_overrides`` are applied on top
      (``rounds=30, n_clients=20, runtime="async", ...``).
    async_cfg: execution-model knobs; implies ``runtime="async"`` when no
      config was passed at all — an explicit ``fed`` config or ``runtime``
      override is authoritative, and a sync one + async_cfg is an error.
    traffic: optional ``repro.fed.traffic.TrafficConfig`` — selects the
      trace-driven continuous-traffic runtime (``TrafficExperiment``):
      open-ended arrival streams, churn, budgets, anytime eval, hot-swap.
      Implies ``runtime="async"`` when no runtime is named; incompatible
      with an explicit sync runtime.
    population: optional ``repro.fed.population.ClientPopulation`` carrying
      a weighted/availability cohort sampler; requires the config's
      population knobs (``population_size``/``cohort_size``).  With
      ``population_size`` set but no object passed, the uniform streaming
      population is built from the config.  In population mode a scenario
      is materialized over the *id space* (``population_size`` clients) —
      use a lazy partition kind (``stream_dirichlet``) at 10^5+ ids.

    The materialized bundle is exposed as ``exp.scenario`` (None on the
    explicit path), including ``partition_stats`` for sweep reporting.
    """
    spec = resolve(algorithm)
    base = fed if fed is not None else FedConfig()
    changes = dict(fed_overrides, algorithm=spec.name)
    if (async_cfg is not None or traffic is not None) and fed is None \
            and "runtime" not in fed_overrides:
        changes["runtime"] = "async"

    scn = None
    if scenario is not None:
        explicit = [n for n, v in [("params", params), ("loss_fn", loss_fn),
                                   ("client_batch_fn", client_batch_fn),
                                   ("eval_fn", eval_fn)] if v is not None]
        if explicit:
            raise ValueError(
                "pass either scenario= or the explicit problem bundle, not "
                f"both (got scenario plus {', '.join(explicit)})")
        premade = isinstance(scenario, Scenario)
        scn_n_clients = (scenario.n_clients if premade
                         else resolve_scenario(scenario).n_clients)
        if fed is None and "n_clients" not in changes:
            changes["n_clients"] = scn_n_clients
    elif scenario_seed is not None:
        raise ValueError("scenario_seed only applies together with "
                         "scenario=")

    cfg = dataclasses.replace(base, **changes)
    # population mode: the scenario's client axis is the abstract id space,
    # so data partitioning spans population_size ids (sampled cohorts pull
    # their slices on demand)
    id_space = (cfg.population_size if cfg.population_active
                else cfg.n_clients)

    if scenario is not None:
        if premade:
            if scenario.n_clients != id_space:
                raise ValueError(
                    f"pre-materialized scenario {scenario.spec.name!r} was "
                    f"built for n_clients={scenario.n_clients} but the "
                    f"config wants {id_space} — re-materialize or drop "
                    "the override")
            if scenario_seed is not None and scenario_seed != scenario.seed:
                raise ValueError(
                    f"pre-materialized scenario {scenario.spec.name!r} was "
                    f"built with seed={scenario.seed} but "
                    f"scenario_seed={scenario_seed} was requested")
            scn = scenario
        else:
            seed = scenario_seed if scenario_seed is not None else cfg.seed
            scn = materialize(scenario, seed=seed, n_clients=id_space)
        params, loss_fn, client_batch_fn, eval_fn = scn.problem()
    elif params is None or loss_fn is None or client_batch_fn is None:
        raise TypeError(
            "build_experiment needs either scenario= or the explicit "
            "params/loss_fn/client_batch_fn bundle")

    if cfg.runtime == "sync":
        if async_cfg is not None:
            raise ValueError(
                "async_cfg given but the config says runtime='sync' — set "
                "runtime='async' (or drop the async_cfg)")
        if traffic is not None:
            raise ValueError(
                "traffic= given but the config says runtime='sync' — the "
                "continuous-traffic runtime is event-driven (async)")
        exp = FederatedExperiment(cfg, params, loss_fn, client_batch_fn,
                                  eval_fn, opt_kwargs, spec=spec,
                                  population=population)
    elif traffic is not None:
        from repro.fed.traffic import TrafficExperiment
        exp = TrafficExperiment(cfg, params, loss_fn, client_batch_fn,
                                eval_fn, opt_kwargs, async_cfg=async_cfg,
                                spec=spec, population=population,
                                traffic=traffic)
    else:
        exp = AsyncFederatedExperiment(cfg, params, loss_fn, client_batch_fn,
                                       eval_fn, opt_kwargs,
                                       async_cfg=async_cfg, spec=spec,
                                       population=population)
    exp.scenario = scn
    return exp
