from repro.checkpoint.store import (
    save_pytree, load_pytree, save_server_state, load_server_state,
    latest_step, CheckpointManager,
)
