"""Checkpointing: pytree <-> .npz with path-keyed entries.

Self-contained (no orbax dependency): leaves are stored under
'/'-joined tree paths, dtypes/shapes preserved exactly, atomic rename on
write.  Covers params, optimizer states (incl. None-masked leaves), and the
full federated ServerState — params + Theta + g_G + round counter +
theta_version + the functional GeometryController (adaptive beta + drift
EMA), so a restored adaptive-beta run continues from the saved controller
state instead of resetting.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.server import ServerState
from repro.core.engine import GeometryController

_NONE_SENTINEL = "__none__"


def _geom_to_meta(geom) -> Optional[dict]:
    if geom is None:
        return None
    return {"beta": float(geom.beta), "drift_ema": float(geom.drift_ema),
            "beta_max": float(geom.beta_max), "adaptive": bool(geom.adaptive),
            "ema": float(geom.ema)}


def _geom_from_meta(meta: Optional[dict]):
    if meta is None:
        return None
    return GeometryController(
        jnp.float32(meta["beta"]), jnp.float32(meta["drift_ema"]),
        beta_max=meta["beta_max"], adaptive=meta["adaptive"],
        ema=meta["ema"])


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: x is None)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path) or "__root__"
        out[key] = leaf
    return out


def save_pytree(tree, path: str):
    """Atomic save. None leaves are preserved (masked optimizer states)."""
    entries = _flatten(tree)
    arrays = {}
    meta = {"none_keys": [], "order": list(entries), "dtypes": {}}
    for k, v in entries.items():
        if v is None:
            meta["none_keys"].append(k)
            continue
        arr = np.asarray(v)
        if arr.dtype.name in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
            # npz has no native bf16/fp8: store raw bits + dtype in meta
            meta["dtypes"][k] = arr.dtype.name
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        arrays[k] = arr
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __meta__=np.frombuffer(
                json.dumps(meta).encode(), dtype=np.uint8), **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_pytree(template, path: str):
    """Load into the structure of ``template`` (shapes/dtypes validated)."""
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"]).decode())
        none_keys = set(meta["none_keys"])
        entries = _flatten(template)
        leaves = []
        import ml_dtypes
        for k, tmpl in entries.items():
            if k in none_keys:
                leaves.append(None)
                continue
            arr = z[k]
            if k in meta.get("dtypes", {}):
                arr = arr.view(getattr(ml_dtypes, meta["dtypes"][k]))
            if tmpl is not None and hasattr(tmpl, "shape"):
                assert tuple(arr.shape) == tuple(tmpl.shape), \
                    f"{k}: {arr.shape} != {tmpl.shape}"
                arr = jnp.asarray(arr).astype(tmpl.dtype)
            leaves.append(jnp.asarray(arr))
    treedef = jax.tree_util.tree_structure(
        template, is_leaf=lambda x: x is None)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_server_state(server: ServerState, directory: str, step: int,
                      telemetry: Optional[dict] = None):
    """``telemetry`` is the tracer's persistent identity
    (``repro.obs.Tracer.state()``: run_id + cumulative round/span/seq
    counters) so a restored run appends to the same JSONL trace instead of
    restarting its numbering."""
    d = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    save_pytree(server.params, os.path.join(d, "params.npz"))
    save_pytree(server.g_global, os.path.join(d, "g_global.npz"))
    if server.theta is not None:
        save_pytree(server.theta, os.path.join(d, "theta.npz"))
    with open(os.path.join(d, "meta.json"), "w") as f:
        json.dump({"round": server.round,
                   "theta_version": server.theta_version,
                   "has_theta": server.theta is not None,
                   "geom": _geom_to_meta(server.geom),
                   "telemetry": telemetry}, f)


def load_meta(directory: str, step: Optional[int] = None) -> dict:
    """The raw checkpoint meta dict (round, theta_version, geom, telemetry
    trace identity).  ``meta.get("telemetry")`` feeds
    ``repro.obs.Tracer.from_state``."""
    step = latest_step(directory) if step is None else step
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        return json.load(f)


def load_server_state(template: ServerState, directory: str,
                      step: Optional[int] = None) -> ServerState:
    step = latest_step(directory) if step is None else step
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    params = load_pytree(template.params, os.path.join(d, "params.npz"))
    gg = load_pytree(template.g_global, os.path.join(d, "g_global.npz"))
    theta = None
    if meta["has_theta"] and template.theta is not None:
        theta = load_pytree(template.theta, os.path.join(d, "theta.npz"))
    # pre-theta_version checkpoints: Theta (if any) dates from the saved round
    geom = _geom_from_meta(meta.get("geom"))
    if geom is None:
        # pre-geom checkpoints: keep the experiment's controller rather than
        # clobbering it (restores must not leave ServerState.geom None when
        # the running experiment has one)
        geom = template.geom
    return ServerState(params, theta, gg, meta["round"],
                       meta.get("theta_version", meta["round"]), geom)


def latest_step(directory: str) -> int:
    steps = [int(n.split("_")[1]) for n in os.listdir(directory)
             if n.startswith("step_")]
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    return max(steps)


class CheckpointManager:
    """Keep-last-N rotation for federated round checkpoints."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def save(self, server: ServerState, telemetry: Optional[dict] = None):
        save_server_state(server, self.directory, server.round,
                          telemetry=telemetry)
        steps = sorted(int(n.split("_")[1]) for n in os.listdir(self.directory)
                       if n.startswith("step_"))
        for s in steps[: -self.keep]:
            d = os.path.join(self.directory, f"step_{s:08d}")
            for fn in os.listdir(d):
                os.unlink(os.path.join(d, fn))
            os.rmdir(d)

    def restore(self, template: ServerState) -> ServerState:
        return load_server_state(template, self.directory)

    def restore_meta(self) -> dict:
        """Latest checkpoint's meta (incl. the ``telemetry`` trace state)."""
        return load_meta(self.directory)
