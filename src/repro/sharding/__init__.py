from repro.sharding.partitioning import (
    LogicalAxisRules,
    TRAIN_RULES,
    SERVE_RULES,
    resolve_spec,
    logical_to_sharding,
    shard_params_spec,
)
