"""Logical-axis sharding with divisibility-safe resolution.

Models annotate every parameter dimension with a *logical* axis name
("embed", "ffn", "heads", ...).  A rule set maps logical names to mesh axes.
``resolve_spec`` turns (shape, logical axes) into a ``PartitionSpec`` that is
guaranteed valid for the given mesh:

* a mesh axis is only assigned to a dim it divides evenly;
* a mesh axis is used at most once per spec;
* anything else is replicated.

This is what lets a single rule set lower every (arch x shape x mesh)
combination — e.g. GQA kv-head counts (2..8) that do not divide the 16-way
model axis simply replicate that dimension instead of failing to lower.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class LogicalAxisRules:
    """Map logical axis name -> preferred mesh axes (in priority order)."""

    rules: Mapping[str, tuple[str, ...]]

    def mesh_axes_for(self, logical: str | None) -> tuple[str, ...]:
        if logical is None:
            return ()
        return tuple(self.rules.get(logical, ()))


# Training: FSDP over "data" (first big dim of 2-D weights) x TP over "model";
# batch over pod+data.  Cross-pod weights replicated (pod = federated site).
TRAIN_RULES = LogicalAxisRules(
    {
        "batch": ("pod", "data"),
        "client": ("pod", "data"),
        "embed": ("data",),
        "ffn": ("model",),
        "qkv": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "head_dim": ("model",),
        "vocab": ("model",),
        "expert": (),
        "seq": (),
        "kv_lora": ("model",),
        "conv": (),
        "state": (),
        "codebook": (),
    }
)

# Serving with FSDP weights: 2-D shard the weights over (data, model) too —
# trades per-layer all-gathers for fitting very large models at decode
# (the qwen1.5-110b x decode_32k §Perf lever).
def _serve_fsdp_rules():
    base = dict(SERVE_RULES.rules)
    base["embed"] = ("data",)
    return LogicalAxisRules(base)


# Serving: weights stationary, tensor-parallel only; batch over pod+data.
# KV caches shard batch and (when the small GQA head counts do not divide the
# model axis) the head_dim instead — always-divisible 128-multiples.
SERVE_RULES = LogicalAxisRules(
    {
        "batch": ("pod", "data"),
        "client": ("pod", "data"),
        "embed": (),
        "ffn": ("model",),
        "qkv": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "head_dim": ("model",),
        "vocab": ("model",),
        "expert": (),
        "seq": (),
        "kv_lora": ("model",),
        "conv": (),
        "state": (),
        "codebook": (),
    }
)


def resolve_spec(
    shape: Sequence[int],
    logical_axes: Sequence[str | None],
    mesh: Mesh,
    rules: LogicalAxisRules,
) -> P:
    """Build a valid PartitionSpec for ``shape`` under ``mesh``."""
    assert len(shape) == len(logical_axes), (shape, logical_axes)
    used: set[str] = set()
    dims: list = []
    for dim, logical in zip(shape, logical_axes):
        assigned: list[str] = []
        factor = 1
        for axis in rules.mesh_axes_for(logical):
            if axis not in mesh.shape or axis in used:
                continue
            size = mesh.shape[axis]
            if dim % (factor * size) != 0:
                continue
            assigned.append(axis)
            used.add(axis)
            factor *= size
        if not assigned:
            dims.append(None)
        elif len(assigned) == 1:
            dims.append(assigned[0])
        else:
            dims.append(tuple(assigned))
    # Strip trailing Nones for cleanliness.
    while dims and dims[-1] is None:
        dims.pop()
    return P(*dims)


SERVE_FSDP_RULES = _serve_fsdp_rules()


def client_axis_spec(mesh: Mesh, preferred: Sequence[str] = ("pod", "data")):
    """Mesh axes (and leading-dim PartitionSpec) for the cohort client axis.

    Picks the subset of ``preferred`` axes present in ``mesh`` in order —
    ("pod", "data") on the production mesh, ("data",) on a host mesh — so
    the engine's shard_map executor shards clients over every federated
    data axis the mesh exposes.
    """
    axes = tuple(a for a in preferred if a in mesh.shape)
    if not axes:
        raise ValueError(
            f"mesh {dict(mesh.shape)} has none of the client axes "
            f"{tuple(preferred)}")
    return axes, P(axes if len(axes) > 1 else axes[0])


def greedy_spec(shape: Sequence[int], mesh: Mesh,
                axes_order: tuple[str, ...] = ("data", "model")) -> P:
    """Divisibility-safe generic spec for tensors without logical annotations
    (optimizer states: Kronecker factors, eigenbases, rotated moments).

    Assigns the mesh axes in ``axes_order`` to the trailing two dims
    (dim -2 <- data, dim -1 <- model) when they divide evenly; leading batch
    dims stay replicated (they are expert/stacking dims).
    """
    if len(shape) < 2:
        return P()
    dims: list = [None] * len(shape)
    targets = [len(shape) - 2, len(shape) - 1]
    for axis, d in zip(axes_order, targets):
        if axis in mesh.shape and shape[d] % mesh.shape[axis] == 0 and shape[d] > 1:
            dims[d] = axis
    while dims and dims[-1] is None:
        dims.pop()
    return P(*dims)


def logical_to_sharding(
    tree_shapes, tree_axes, mesh: Mesh, rules: LogicalAxisRules
):
    """Map pytrees of shapes + logical axes -> pytree of NamedSharding."""

    def one(shape, axes):
        return NamedSharding(mesh, resolve_spec(shape, axes, mesh, rules))

    return jax.tree.map(
        one, tree_shapes, tree_axes, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(d, int) for d in x
        )
    )


def shard_params_spec(params_shapes, params_axes, mesh: Mesh, rules: LogicalAxisRules):
    """Pytree of PartitionSpec for a params pytree.

    ``params_shapes`` leaves are jax.ShapeDtypeStruct (or arrays);
    ``params_axes`` leaves are tuples of logical names (len == rank).
    """

    def one(sds, axes):
        return resolve_spec(sds.shape, axes, mesh, rules)

    return jax.tree.map(
        one,
        params_shapes,
        params_axes,
        is_leaf=lambda x: x is None or (
            isinstance(x, tuple) and all(isinstance(d, (str, type(None))) for d in x)
        ),
    )
