"""Input/state ShapeDtypeStruct stand-ins + shardings for the dry-run.

Nothing here allocates device memory: params/optimizer states come from
``jax.eval_shape`` over the init functions, inputs are ShapeDtypeStructs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.transformer import cache_axes
from repro.sharding.partitioning import (
    TRAIN_RULES, SERVE_RULES, resolve_spec, greedy_spec,
)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def batch_spec(mesh, batch: int) -> P:
    return resolve_spec((batch,), ("batch",), mesh, TRAIN_RULES)


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def token_inputs(cfg: ModelConfig, shape: InputShape, mesh, *, rules,
                 with_labels: bool):
    """ShapeDtypeStructs for one step's data batch."""
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    bspec = resolve_spec((b, s), ("batch", "seq"), mesh, rules)
    tok_shape = (b, s, cfg.num_codebooks) if cfg.num_codebooks > 1 else (b, s)
    tok_spec = resolve_spec(tok_shape, ("batch", "seq") + (("codebook",)
                            if cfg.num_codebooks > 1 else ()), mesh, rules)
    batch = {"tokens": _sds(tok_shape, jnp.int32, mesh, tok_spec)}
    if cfg.accepts_embeds and shape.kind != "decode":
        # frontend stub: precomputed patch/frame embeddings
        espec = resolve_spec((b, s, cfg.d_model), ("batch", "seq", None),
                             mesh, rules)
        batch["embeds"] = _sds((b, s, cfg.d_model), cfg.jnp_dtype, mesh, espec)
        batch["tokens"] = None
    if with_labels:
        batch["labels"] = _sds(tok_shape, jnp.int32, mesh, tok_spec)
    return batch


def param_specs(cfg: ModelConfig, mesh, rules):
    shapes = M.param_shapes(cfg)
    axes = M.param_axes(cfg)

    def one(sds, ax):
        spec = resolve_spec(sds.shape, ax, mesh, rules)
        return _sds(sds.shape, sds.dtype, mesh, spec)

    return jax.tree.map(
        one, shapes, axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def opt_state_specs(opt, params_sds, mesh):
    """eval_shape the optimizer init and greedy-shard every leaf."""
    state = jax.eval_shape(opt.init, params_sds)

    def one(sds):
        spec = greedy_spec(sds.shape, mesh)
        return _sds(sds.shape, sds.dtype, mesh, spec)

    return jax.tree.map(one, state)


def cache_specs(cfg: ModelConfig, shape: InputShape, mesh, rules,
                ring: bool):
    caches = jax.eval_shape(
        lambda: M.init_caches(cfg, shape.global_batch, shape.seq_len,
                              ring=ring))
    axes = cache_axes(cfg)

    def one(sds, ax):
        spec = resolve_spec(sds.shape, ax, mesh, rules)
        return _sds(sds.shape, sds.dtype, mesh, spec)

    return jax.tree.map(
        one, caches, axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def like_tree_specs(tree_sds, mesh):
    """Greedy shardings for an arbitrary SDS pytree (g_global etc.)."""
    def one(sds):
        return _sds(sds.shape, sds.dtype, mesh, greedy_spec(sds.shape, mesh))
    return jax.tree.map(one, tree_sds)


def shardings_of(tree):
    return jax.tree.map(
        lambda x: x.sharding, tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
