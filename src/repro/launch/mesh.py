"""Production mesh construction (TPU v5e target).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


# Hardware constants for the roofline analysis (TPU v5e).
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
