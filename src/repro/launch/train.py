"""Single-process training driver (real execution, host-scale).

Runs FedPAC/FedSOA federated pre-training of a (reduced or paper-scale) model
on synthetic non-IID LM data across whatever devices exist.  The production
mesh path is exercised by dryrun.py; this driver actually executes.

  PYTHONPATH=src python -m repro.launch.train --arch llama-60m --reduced \
      --algorithm fedpac_soap --rounds 20
"""
from __future__ import annotations

import argparse
import json

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.data import make_lm_corpus
from repro.data.synth import lm_batches
from repro.fed import FedConfig, FederatedExperiment
from repro.models import model as M


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-60m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--algorithm", default="fedpac_soap")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--participation", type=float, default=0.5)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--hetero", type=float, default=0.8)
    ap.add_argument("--beta", type=float, default=0.5)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=5)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="append a structured JSONL round trace (spans, "
                         "metrics, telemetry) to PATH")
    args = ap.parse_args(argv)

    cfg = (configs.get_reduced(args.arch)
           if args.reduced else configs.get_config(args.arch))
    cfg = cfg.replace(dtype="float32")
    params = M.init_params(cfg, jax.random.key(args.seed))
    n_par = M.num_params(cfg)
    print(f"arch={cfg.name} params={n_par/1e6:.1f}M "
          f"algorithm={args.algorithm}")

    streams = make_lm_corpus(args.clients, 200_000, vocab=cfg.vocab_size,
                             hetero=args.hetero, seed=args.seed)
    eval_stream = np.concatenate([s[:20_000] for s in streams])
    ex, ey = lm_batches(eval_stream, seq_len=args.seq, batch=16, steps=1,
                        seed=123)
    eval_batch = {"tokens": jnp.asarray(ex[0]), "labels": jnp.asarray(ey[0])}

    def loss_fn(p, batch):
        return M.loss_fn(p, batch, cfg)

    eval_loss = jax.jit(lambda p: M.loss_fn(p, eval_batch, cfg))

    def eval_fn(p):
        return {"eval_loss": eval_loss(p)}

    def batch_fn(cid, rng):
        s = streams[cid]
        starts = rng.integers(0, len(s) - args.seq - 1, args.batch)
        idx = starts[:, None] + np.arange(args.seq + 1)
        w = s[idx]
        return {"tokens": jnp.asarray(w[:, :-1]),
                "labels": jnp.asarray(w[:, 1:])}

    fed = FedConfig(algorithm=args.algorithm, n_clients=args.clients,
                    participation=args.participation, rounds=args.rounds,
                    local_steps=args.local_steps, lr=args.lr, beta=args.beta,
                    seed=args.seed)
    exp = FederatedExperiment(fed, params, loss_fn, batch_fn, eval_fn)
    mgr = None
    if args.checkpoint_dir:
        from repro.checkpoint import CheckpointManager
        mgr = CheckpointManager(args.checkpoint_dir)
    if args.trace:
        from repro.obs import JsonlSink, Tracer
        sink = JsonlSink(args.trace, append=True)
        state = None
        if mgr:
            # resume the persisted trace identity (same run_id, continued
            # seq numbering) so restored runs append to the same trace
            try:
                state = mgr.restore_meta().get("telemetry")
            except FileNotFoundError:
                pass
        exp.tracer = Tracer.from_state(state, sinks=(sink,))
    hist = []
    for r in range(fed.rounds):
        rec = exp.run_round()
        hist.append(rec)
        exp.log_round(rec, r)
        if mgr and (r + 1) % args.checkpoint_every == 0:
            mgr.save(exp.server, telemetry=exp.tracer.state())
    print(f"final: train_loss={hist[-1]['loss']:.4f} "
          f"eval_loss={hist[-1]['eval_loss']:.4f} "
          f"comm={exp.comm_bytes_per_round()/1e6:.1f}MB/round")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(hist, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
