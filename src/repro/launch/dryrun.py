import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against the production mesh and extract roofline terms.

No device memory is allocated: params/optimizer states/caches are
ShapeDtypeStructs (eval_shape), inputs likewise.  ``compile()`` proving the
sharding story is the deliverable; memory_analysis/cost_analysis feed
EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun.jsonl
"""
import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.mesh import (
    make_production_mesh, PEAK_FLOPS_BF16, HBM_BW, ICI_BW,
)
from repro.launch import specs as S
from repro.launch import steps as ST
from repro import optim
from repro.models import model as M
from repro.sharding.partitioning import (
    TRAIN_RULES, SERVE_RULES, SERVE_FSDP_RULES,
)

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
                "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
                "u64": 8}
_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred)"
                       r"\[([0-9,]*)\]")


def collective_bytes_from_hlo(hlo: str):
    """Sum result sizes of collective ops; returns (total_bytes, per_op)."""
    per_op = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo.splitlines():
        stripped = line.strip()
        for op in COLLECTIVE_OPS:
            # match e.g. `%ag = bf16[...] all-gather(...)` incl. -start forms
            if f" {op}(" in stripped or f" {op}-start(" in stripped:
                if "=" not in stripped:
                    continue
                rhs = stripped.split("=", 1)[1]
                # result type(s): shapes before the op token
                head = rhs.split(op, 1)[0]
                nbytes = 0
                for dt, dims in _SHAPE_RE.findall(head):
                    n = 1
                    if dims:
                        for d in dims.split(","):
                            n *= int(d)
                    nbytes += n * _DTYPE_BYTES[dt]
                per_op[op] += nbytes
                break
    return sum(per_op.values()), per_op


def model_flops(cfg, shape: S.InputShape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) useful-FLOPs yardstick."""
    n_total = M.num_params(cfg)
    n_active = n_total
    if cfg.moe is not None:
        m = cfg.moe
        # routed expert params not in the top-k are inactive per token
        expert_params = 3 * cfg.d_model * m.d_ff_expert
        routed_layers = cfg.num_layers - m.first_dense_layers
        n_active -= routed_layers * (m.num_experts - m.top_k) * expert_params
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else
                                   (shape.seq_len if shape.kind == "prefill"
                                    else 1))
    mult = 6 if shape.kind == "train" else 2
    return float(mult) * n_active * tokens


def build_lowering(arch: str, shape_name: str, mesh, *, opt_name: str = "muon",
                   step_kind=None, seq_shard: bool = False, beta: float = 0.5,
                   fed_clients: int = 8, fed_local_steps: int = 2,
                   cfg=None, shape_override=None, unroll: bool = False,
                   serve_fsdp: bool = False, gg_dtype=jnp.float32,
                   state_dtype=None):
    cfg = cfg or configs.get_config(arch)
    shape = shape_override or S.INPUT_SHAPES[shape_name]
    kind = step_kind or shape.kind

    if kind in ("train", "fed_round"):
        rules = TRAIN_RULES
        lr = optim.DEFAULT_LR.get(opt_name, 1e-2)
        opt_kw = {}
        if opt_name == "soap":
            opt_kw["state_dtype"] = state_dtype or jnp.bfloat16
        elif opt_name == "muon" and state_dtype is not None:
            opt_kw["state_dtype"] = state_dtype
        opt = optim.make(opt_name, **opt_kw)
        params = S.param_specs(cfg, mesh, rules)
        batch = S.token_inputs(cfg, shape, mesh, rules=rules, with_labels=True)
        gg = S.like_tree_specs(jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, gg_dtype), params), mesh)
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        if kind == "train":
            opt_state = S.opt_state_specs(opt, params, mesh)
            step_fn = ST.make_train_step(cfg, opt, lr=lr, beta=beta,
                                         seq_shard=seq_shard, unroll=unroll,
                                         batch_axes=batch_axes)
            args = (params, opt_state, gg,
                    batch, jax.ShapeDtypeStruct((), jnp.int32))
        else:
            theta = S.like_tree_specs(
                jax.eval_shape(lambda p: opt.get_precond(opt.init(p)), params),
                mesh)
            step_fn = ST.make_fed_round_step(
                cfg, opt, lr=lr, beta=beta, clients=fed_clients,
                local_steps=fed_local_steps, seq_shard=seq_shard,
                batch_axes=batch_axes)
            args = (params, theta, gg, batch,
                    jax.ShapeDtypeStruct((2,), jnp.uint32))
    elif kind == "prefill":
        rules = SERVE_FSDP_RULES if serve_fsdp else SERVE_RULES
        params = S.param_specs(cfg, mesh, rules)
        batch = S.token_inputs(cfg, shape, mesh, rules=rules, with_labels=False)
        step_fn = ST.make_prefill_step(cfg, shape.seq_len, unroll=unroll)
        args = (params, batch)
    elif kind == "decode":
        rules = SERVE_FSDP_RULES if serve_fsdp else SERVE_RULES
        params = S.param_specs(cfg, mesh, rules)
        ring = shape.name == "long_500k"
        caches = S.cache_specs(cfg, shape, mesh, rules, ring=ring)
        tok_shape = ((shape.global_batch, 1, cfg.num_codebooks)
                     if cfg.num_codebooks > 1 else (shape.global_batch, 1))
        tokens = S._sds(tok_shape, jnp.int32, mesh,
                        S.resolve_spec(tok_shape, ("batch", "seq") +
                                       (("codebook",) if cfg.num_codebooks > 1
                                        else ()), mesh, rules))
        step_fn = ST.make_decode_step(cfg, shape.seq_len - 1, unroll=unroll)
        args = (params, tokens, caches)
    else:
        raise ValueError(kind)

    in_shardings = jax.tree.map(
        lambda x: x.sharding, args,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    with mesh:
        jitted = jax.jit(step_fn, in_shardings=in_shardings)
        lowered = jitted.lower(*args)
    return cfg, shape, lowered


def analyze(arch, shape_name, mesh_name, lowered, cfg, shape, *,
            unrolled_lowered=None):
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    n_chips = {"pod": 256, "multipod": 512}[mesh_name]
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)
    mem = compiled.memory_analysis()
    rec["bytes_per_device"] = {
        "argument": getattr(mem, "argument_size_in_bytes", None),
        "output": getattr(mem, "output_size_in_bytes", None),
        "temp": getattr(mem, "temp_size_in_bytes", None),
        "peak": getattr(mem, "peak_memory_in_bytes", None),
    }
    # FLOP/byte totals come from the *unrolled* lowering when provided: XLA's
    # cost analysis counts lax.scan (while-loop) bodies once, so the scanned
    # compile-proof module undercounts by ~num_layers.
    cost_src = unrolled_lowered if unrolled_lowered is not None else compiled
    cost = cost_src.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    # Fusion-corrected memory estimate: unoptimized HLO double-counts every
    # intermediate, so scale the *optimized* (compiled, scanned) module's
    # bytes by the loop-trip factor implied by the flops ratio.
    if unrolled_lowered is not None:
        ccost = compiled.cost_analysis()
        if isinstance(ccost, list):
            ccost = ccost[0]
        cflops = float(ccost.get("flops", 0.0)) or 1.0
        cbytes_acc = float(ccost.get("bytes accessed", 0.0))
        scale = flops / cflops
        rec["hlo_bytes_opt_est"] = cbytes_acc * scale
    else:
        rec["hlo_bytes_opt_est"] = None
    rec["hlo_flops"] = flops
    rec["hlo_bytes"] = bytes_accessed
    cbytes, per_op = collective_bytes_from_hlo(compiled.as_text())
    rec["collective_bytes"] = cbytes
    rec["collective_per_op"] = per_op
    # Roofline terms (seconds), per the spec formulas:
    #   compute    = HLO_FLOPs / (chips * peak)
    #   memory     = HLO_bytes / (chips * HBM_bw)
    #   collective = collective_bytes / (chips * link_bw)
    # (cost_analysis on the CPU backend reports whole-program totals; the
    # chips divisor distributes them, matching MODEL_FLOPS totals we verify
    # against via useful_flop_ratio.)
    rec["t_compute"] = flops / (n_chips * PEAK_FLOPS_BF16)
    rec["t_memory"] = bytes_accessed / (n_chips * HBM_BW)
    rec["t_collective"] = cbytes / (n_chips * ICI_BW)
    if rec.get("hlo_bytes_opt_est"):
        rec["t_memory_opt"] = rec["hlo_bytes_opt_est"] / (n_chips * HBM_BW)
    dom = max(("compute", "memory", "collective"),
              key=lambda k: rec[f"t_{k}"])
    rec["dominant"] = dom
    mf = model_flops(cfg, shape)
    rec["model_flops_total"] = mf
    rec["model_flops_per_chip"] = mf / n_chips
    rec["useful_flop_ratio"] = mf / flops if flops else None
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--step", default=None,
                    choices=[None, "train", "fed_round", "prefill", "decode"])
    ap.add_argument("--opt", default="muon")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--serve-fsdp", action="store_true")
    ap.add_argument("--gg-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--state-dtype", default=None,
                    choices=[None, "float32", "bfloat16"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--lower-only", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    pairs = []
    archs = configs.ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(S.INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    for a in archs:
        cfg = configs.get_config(a)
        for sh in shapes:
            if sh == "long_500k" and not cfg.supports_long_decode:
                print(f"SKIP {a} x long_500k (full attention; see DESIGN.md)")
                continue
            pairs.append((a, sh))

    out_f = open(args.out, "a") if args.out else None
    failures = 0
    for a, sh in pairs:
        for mesh_name in meshes:
            mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
            tag = f"{a} x {sh} x {mesh_name}"
            try:
                t0 = time.time()
                kw = dict(opt_name=args.opt, step_kind=args.step,
                          seq_shard=args.seq_shard,
                          serve_fsdp=args.serve_fsdp,
                          gg_dtype=getattr(jnp, args.gg_dtype),
                          state_dtype=(getattr(jnp, args.state_dtype)
                                       if args.state_dtype else None))
                cfg, shape, lowered = build_lowering(a, sh, mesh, **kw)
                lower_s = time.time() - t0
                if args.lower_only:
                    print(f"LOWER-OK {tag} ({lower_s:.0f}s)")
                    continue
                # unrolled lowering (never compiled): true FLOP/byte totals
                _, _, unrolled = build_lowering(a, sh, mesh, unroll=True,
                                                **kw)
                rec = analyze(a, sh, mesh_name, lowered, cfg, shape,
                              unrolled_lowered=unrolled)
                rec["opt"] = args.opt
                rec["step"] = args.step or shape.kind
                rec["seq_shard"] = args.seq_shard
                rec["serve_fsdp"] = args.serve_fsdp
                rec["gg_dtype"] = args.gg_dtype
                rec["state_dtype"] = args.state_dtype
                rec["lower_s"] = round(lower_s, 1)
                print(f"OK {tag}: dominant={rec['dominant']} "
                      f"t_comp={rec['t_compute']:.3e}s "
                      f"t_mem={rec['t_memory']:.3e}s "
                      f"t_coll={rec['t_collective']:.3e}s "
                      f"peak={rec['bytes_per_device']['peak']}")
                if out_f:
                    out_f.write(json.dumps(rec) + "\n")
                    out_f.flush()
            except Exception as e:  # noqa: BLE001 - report and continue
                failures += 1
                print(f"FAIL {tag}: {type(e).__name__}: {str(e)[:300]}")
    if out_f:
        out_f.close()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
