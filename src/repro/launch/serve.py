"""Serving driver: prefill + batched decode with sharded KV caches.

Executes for real on host devices with reduced configs; the production-mesh
serve path is exercised (lower+compile) by dryrun.py.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import model as M


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_reduced(args.arch)
           if args.reduced else configs.get_config(args.arch))
    cfg = cfg.replace(dtype="float32")
    key = jax.random.key(args.seed)
    params = M.init_params(cfg, key)
    max_len = args.prompt_len + args.gen

    shape = ((args.batch, args.prompt_len, cfg.num_codebooks)
             if cfg.num_codebooks > 1 else (args.batch, args.prompt_len))
    prompt = jax.random.randint(key, shape, 0, cfg.vocab_size)

    prefill = jax.jit(lambda p, b: M.prefill(p, b, cfg, max_len))
    decode = jax.jit(
        lambda p, t, c, i: M.decode_step(p, t, c, i, cfg))

    t0 = time.perf_counter()
    logits, caches = prefill(params, {"tokens": prompt})
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    def sample(logits, k):
        if args.temperature == 0.0:
            tok = jnp.argmax(logits, axis=-1)
        else:
            tok = jax.random.categorical(k, logits / args.temperature, axis=-1)
        return tok[:, None] if cfg.num_codebooks <= 1 else tok[:, None, :]

    toks = sample(logits, key)
    generated = [toks]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        key, sub = jax.random.split(key)
        logits, caches = decode(params, toks, caches,
                                jnp.int32(args.prompt_len + i))
        toks = sample(logits, sub)
        generated.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   "
          f"decode: {t_decode/(args.gen-1)*1e3:.2f} ms/token")
    print("sample token ids:", out[0, :10].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
