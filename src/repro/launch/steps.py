"""Step functions lowered by the dry-run and launched by train.py/serve.py.

train_step  — one FedPAC local step: grad -> UpdateState -> P_Theta(g) ->
              correction mix with g_G (Eq. 9).  This is what each client
              executes K times per round; lowering it exercises the paper's
              technique (preconditioner compute + optimizer sharding).
fed_round   — a full Alg. 2 round: C client groups x K local steps
              (vmap x scan) + parameter/Theta aggregation collectives.
prefill/decode — serving paths with sharded KV caches.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim.api import LocalOptimizer
from repro.core.algorithms import resolve
from repro.core.client import LocalRunConfig, client_round
from repro.core.engine import AggregationConfig, aggregate


def make_loss_fn(cfg: ModelConfig, *, remat: bool = True,
                 seq_shard: bool = False, unroll: bool = False,
                 batch_axes=("data",)):
    constraint = None
    if seq_shard:
        def constraint(x):
            # Megatron-style sequence sharding of the remat-stored layer
            # input: (B, S, D) -> batch over data(+pod), seq over model.
            return jax.lax.with_sharding_constraint(
                x, P(tuple(batch_axes), "model", None))
    def loss_fn(params, batch):
        return M.loss_fn(params, batch, cfg, remat=remat,
                         layer_constraint=constraint, unroll=unroll)
    return loss_fn


def make_train_step(cfg: ModelConfig, opt: LocalOptimizer, *, lr: float,
                    beta: float = 0.5, remat: bool = True,
                    seq_shard: bool = False, unroll: bool = False,
                    batch_axes=("data",)):
    loss_fn = make_loss_fn(cfg, remat=remat, seq_shard=seq_shard,
                           unroll=unroll, batch_axes=batch_axes)

    def train_step(params, opt_state, g_global, batch, step):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        extras = None
        if opt.needs_hessian:  # Sophia: Hutchinson diag-Hessian estimate
            from repro.core.client import hutchinson_estimate
            est = hutchinson_estimate(
                loss_fn, params, batch,
                jax.random.fold_in(jax.random.key(0), step))
            extras = {"h_est": est, "h_gate": (step % 10) == 0}
        direction, opt_state = opt.update(grads, opt_state, params, step,
                                          extras)

        def mix(d, gg, p):
            upd = (1.0 - beta) * d + beta * gg
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

        params = jax.tree.map(mix, direction, g_global, params)
        return params, opt_state, loss

    return train_step


def make_fed_round_step(cfg: ModelConfig, opt: LocalOptimizer, *, lr: float,
                        beta: float = 0.5, clients: int = 8,
                        local_steps: int = 2, remat: bool = True,
                        seq_shard: bool = False, batch_axes=("data",),
                        algorithm=None, transport=None):
    """Full FedPAC round: the global batch splits into ``clients`` cohorts of
    ``local_steps`` microbatches each; Theta/params aggregation lowers to
    all-reduces over the client (data) axis.

    ``algorithm`` (optional registered name or ``AlgorithmSpec``) supplies
    the alignment policy, the beta policy (``beta`` is filtered through
    ``spec.resolve_beta`` — a correct=False spec zeroes it, FedCM pins it),
    and per-client mixing weights; the default is the historical FedPAC
    configuration (align=True, uniform mixing, beta as given).

    ``transport`` (core.transport.Transport) routes each client group's
    delta and Theta uploads through wire-true codecs before aggregation —
    the lowering then exercises the encode/decode compute the production
    round pays.  This step is stateless, so error feedback (which needs
    per-client residual state) is rejected here."""
    spec = resolve(algorithm) if algorithm is not None else None
    align = spec.align if spec is not None else True
    if spec is not None:
        beta = spec.resolve_beta(beta)
        if beta == "auto":
            raise ValueError(
                "beta='auto' needs the GeometryController round path "
                "(fed runtimes) — pass a float beta to make_fed_round_step")
    if transport is not None and transport.feedback_active:
        raise ValueError(
            "error feedback needs per-client residual state — use the fed "
            "runtimes (build_round_fn) or pass error_feedback=False")
    loss_fn = make_loss_fn(cfg, remat=remat, seq_shard=seq_shard,
                           batch_axes=batch_axes)
    run = LocalRunConfig(lr=lr, local_steps=local_steps, beta=beta,
                         align=align)
    agg_cfg = AggregationConfig(lr=lr, local_steps=local_steps, align=align)

    def fed_round(params, theta, g_global, batch, rng):
        def split(x):  # (B, ...) -> (C, K, B/(C*K), ...)
            b = x.shape[0]
            micro = b // (clients * local_steps)
            return x.reshape(clients, local_steps, micro, *x.shape[1:])

        batches = jax.tree.map(split, batch)
        keys = jax.random.split(rng, clients)
        deltas, thetas, losses = jax.vmap(
            lambda bi, ki: client_round(loss_fn, opt, run, params, theta,
                                        g_global, bi, ki))(batches, keys)
        if transport is not None:
            deltas = jax.vmap(transport.delta.roundtrip)(deltas)
            if align:
                thetas = jax.vmap(transport.theta.roundtrip)(thetas)
        if spec is not None and spec.mixing is not None:
            weights = spec.mixing(deltas, thetas)
        else:
            weights = jnp.ones((clients,), jnp.float32)
        new_params, new_theta, new_g, _ = aggregate(
            params, theta, g_global, deltas, thetas, weights, agg_cfg)
        return new_params, new_theta, new_g, jnp.mean(losses)

    return fed_round


def make_prefill_step(cfg: ModelConfig, max_len: int, unroll: bool = False):
    def prefill_step(params, batch):
        return M.prefill(params, batch, cfg, max_len, unroll=unroll)
    return prefill_step


def make_decode_step(cfg: ModelConfig, index: int, unroll: bool = False):
    def decode_step(params, tokens, caches):
        return M.decode_step(params, tokens, caches, jnp.int32(index), cfg,
                             unroll=unroll)
    return decode_step
