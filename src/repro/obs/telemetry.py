"""Jit-pure drift telemetry: the on-device diagnostics of one server update.

``Telemetry`` is a registered pytree of scalar/vector diagnostics computed
*inside* the jitted round (sync) or flush (async) from exactly the arrays
the engine aggregates — no host callbacks, no recomputation from history.
Because both runtimes call the same ``collect`` with the same inputs, the
telemetry of a zero-staleness async flush is bitwise-identical to the sync
round's (parity-tested in ``tests/test_obs.py``, the same contract
``engine.aggregation.aggregate`` carries).

Fields:
  drift / norm_drift    preconditioner drift (Def. 1), raw and normalized
  freshness             rho = mean staleness weight (1.0 for sync rounds)
  beta / beta_next      correction strength used this round / next round
  drift_ema             the controller's smoothed drift after its update
  update_corr_cos       cos(aggregated step, -g_G): how aligned the cohort
                        update is with the correction direction it will be
                        mixed with — the paper's "corrupted descent
                        direction" made observable
  client_geom_dist      (S,) sketched ||Theta_i - mean_j Theta_j||^2 per
                        client: a JL random projection (the power_sketch
                        trick with a fixed Omega) so per-client geometry
                        distances cost O(S * d * r), not O(S * d^2)
  staleness_hist        (STALENESS_BINS,) int32 histogram of the cohort's
                        staleness (all mass in bin 0 for a sync round)
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.engine.aggregation import weighted_client_mean
from repro.utils.tree import tree_dot, tree_norm_sq

STALENESS_BINS = 8       # last bin catches s >= STALENESS_BINS - 1
SKETCH_RANK = 8
_SKETCH_KEY = 0xD81F7    # fixed: every round projects through the same Omega


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("drift", "norm_drift", "freshness", "beta", "beta_next",
                 "drift_ema", "update_corr_cos", "client_geom_dist",
                 "staleness_hist"),
    meta_fields=())
@dataclasses.dataclass(frozen=True)
class Telemetry:
    drift: jax.Array
    norm_drift: jax.Array
    freshness: jax.Array
    beta: jax.Array
    beta_next: jax.Array
    drift_ema: jax.Array
    update_corr_cos: jax.Array
    client_geom_dist: jax.Array    # (S,)
    staleness_hist: jax.Array      # (STALENESS_BINS,) int32


def staleness_histogram(staleness, bins: int = STALENESS_BINS):
    """Fixed-width int32 histogram of per-client staleness (jit-pure)."""
    s = jnp.clip(staleness.astype(jnp.int32), 0, bins - 1)
    return jnp.sum(jax.nn.one_hot(s, bins, dtype=jnp.int32), axis=0)


def client_geom_dist(thetas, s: int, rank: int = SKETCH_RANK):
    """(S,) sketched squared distance of each client's geometry to the
    cohort mean.  Leaves wider than ``rank`` are projected through a fixed
    Gaussian Omega scaled by 1/sqrt(rank), so the squared distance is an
    unbiased JL estimate of the dense one; narrow leaves are exact.
    thetas=None (first-order algorithms) reports zeros."""
    total = jnp.zeros((s,), jnp.float32)
    if thetas is None:
        return total
    for i, leaf in enumerate(jax.tree.leaves(thetas)):
        x = leaf.astype(jnp.float32).reshape(leaf.shape[0], -1)
        if x.shape[1] > rank:
            omega = jax.random.normal(
                jax.random.key(_SKETCH_KEY + i), (x.shape[1], rank),
                jnp.float32) / jnp.sqrt(jnp.float32(rank))
            x = x @ omega
        c = x - jnp.mean(x, axis=0, keepdims=True)
        total = total + jnp.sum(c * c, axis=-1)
    return total


def collect(*, deltas=None, step=None, thetas, weights, g_global, ctrl,
            new_ctrl, agg_metrics, staleness=None) -> Telemetry:
    """Assemble one round's ``Telemetry`` from the engine's own arrays.

    Call *after* ``engine.aggregate`` + ``update_controller`` with the same
    decoded ``deltas``/``thetas`` and final ``weights`` the aggregate saw,
    the pre-round controller ``ctrl`` and post-update ``new_ctrl``, and the
    aggregate's metrics dict.  The fused wire path never materializes the
    decoded delta stack — it passes the already-reduced weighted mean as
    ``step`` instead of ``deltas`` (the two are interchangeable here: the
    sync round and the async flush hand over the same reduction, keeping
    zero-staleness telemetry bitwise).  ``staleness`` is the (S,) integer
    staleness vector; None means a synchronous cohort (all zeros).
    """
    if (deltas is None) == (step is None):
        raise ValueError("pass exactly one of deltas (stacked cohort) or "
                         "step (precomputed weighted client mean)")
    w = weights.astype(jnp.float32)
    s = w.shape[0]
    if step is None:
        step = weighted_client_mean(deltas, w)
    cos = (-tree_dot(step, g_global)
           / (jnp.sqrt(tree_norm_sq(step) * tree_norm_sq(g_global)) + 1e-12))
    if staleness is None:
        staleness = jnp.zeros((s,), jnp.int32)
    return Telemetry(
        drift=agg_metrics["drift"].astype(jnp.float32),
        norm_drift=agg_metrics["norm_drift"].astype(jnp.float32),
        freshness=agg_metrics["freshness"].astype(jnp.float32),
        beta=ctrl.beta.astype(jnp.float32),
        beta_next=new_ctrl.beta.astype(jnp.float32),
        drift_ema=new_ctrl.drift_ema.astype(jnp.float32),
        update_corr_cos=cos.astype(jnp.float32),
        client_geom_dist=client_geom_dist(thetas, s),
        staleness_hist=staleness_histogram(staleness))


def telemetry_dict(t: Telemetry) -> dict:
    """Host-side view for trace events: floats + plain lists."""
    return {
        "drift": float(t.drift),
        "norm_drift": float(t.norm_drift),
        "freshness": float(t.freshness),
        "beta": float(t.beta),
        "beta_next": float(t.beta_next),
        "drift_ema": float(t.drift_ema),
        "update_corr_cos": float(t.update_corr_cos),
        "client_geom_dist": [float(x) for x in t.client_geom_dist],
        "staleness_hist": [int(x) for x in t.staleness_hist],
    }
