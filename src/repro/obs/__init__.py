"""First-class observability: jit-pure drift telemetry, round-trace spans,
pluggable sinks, kernel profiling hooks, and the BENCH_*.json perf
trajectory.

Attach a trace to any experiment (both runtimes):

    from repro.obs import JsonlSink, attach
    exp = build_experiment("fedpac_soap", scenario="cifar_like_cnn")
    attach(exp, JsonlSink("runs/trace.jsonl"))
    exp.run()

The trace then carries one ``round`` event per server update (metrics +
on-device ``Telemetry``: drift norm, beta trajectory, staleness histogram,
per-client geometry distances, update/correction alignment, wire bytes)
plus ``span`` events for each phase and explicit ``client_dropped`` events
from the async scheduler.  ``FedExperiment.log_round`` routes through the
same ``Sink`` protocol (``exp.sink``), defaulting to the legacy-bitwise
stdout formatting.
"""
from repro.obs.bench import (  # noqa: F401
    BENCH_SCHEMA_VERSION, make_bench, read_bench, validate_bench,
    write_bench,
)
from repro.obs.sinks import (  # noqa: F401
    CsvSink, JsonlSink, MemorySink, Sink, StdoutRoundSink, format_metric,
)
from repro.obs.telemetry import (  # noqa: F401
    STALENESS_BINS, Telemetry, client_geom_dist, collect,
    staleness_histogram, telemetry_dict,
)
from repro.obs.trace import (  # noqa: F401
    NULL_TRACER, PHASES, Tracer, validate_event, validate_jsonl,
)

__all__ = [
    "BENCH_SCHEMA_VERSION", "CsvSink", "JsonlSink", "MemorySink",
    "NULL_TRACER", "PHASES", "STALENESS_BINS", "Sink", "StdoutRoundSink",
    "Telemetry", "Tracer", "attach", "client_geom_dist", "collect",
    "format_metric", "make_bench", "profile_kernels", "read_bench",
    "staleness_histogram", "telemetry_dict", "validate_bench",
    "validate_event", "validate_jsonl", "write_bench",
]


def attach(exp, *sinks, run_id=None) -> Tracer:
    """Wire trace sinks into an experiment; returns the live ``Tracer``.

    ``exp`` is any ``FedExperiment``; subsequent rounds emit span/round/
    drop events into every sink.  Passing no sinks detaches (restores the
    disabled tracer)."""
    tracer = Tracer(sinks=sinks, run_id=run_id)
    exp.tracer = tracer
    return tracer


def profile_kernels(*args, **kwargs):
    """Lazy re-export of ``repro.obs.profiling.profile_kernels`` (imports
    the kernel packages only when profiling is actually requested)."""
    from repro.obs.profiling import profile_kernels as _pk
    return _pk(*args, **kwargs)
