"""Round-trace spans: a structured, simulated-time-aware event log.

A ``Tracer`` stamps every event with a run id and a monotone sequence
number and fans it out to its sinks.  Phases of a round are recorded as
*spans* carrying both wall-clock duration and (for the async runtime) the
simulated time at which the phase ran; round metrics + jit-pure
``Telemetry`` land as one ``round`` event; client dispatches that never
reach the server (dropout, over-staleness discard) are explicit
``client_dropped`` events rather than silent counter increments.

Event schema (one JSON object per line under ``JsonlSink``):

  common        event, run_id, seq
  span          phase, dur_s, round?, client_id?, chunk?, sim_time?
  round         round, metrics{...}, telemetry{...}?, sim_time?
  client_dropped  client_id, reason ("dropout"|"max_staleness"|
                  "client_left"|"algo_swap"), version, sim_time?
  client_join   client_id, sim_time?        (churn: id became active)
  client_leave  client_id, in_flight, sim_time?  (churn: id departed;
                  in_flight work, if any, is voided and later traced as a
                  client_dropped with reason "client_left")
  anytime_eval  metrics{...}, sim_time, round?   (continuous-traffic
                  online eval sampled by simulated time, fed.traffic)
  run_start     runtime, algorithm?, scenario?

A disabled tracer (no sinks) is the default on every experiment: spans
reduce to a no-op context manager and nothing is emitted, but the
round/span counters still advance so checkpoints can persist trace
continuity (``state``/``from_state`` — a restored run appends to the same
JSONL trace instead of restarting its numbering).
"""
from __future__ import annotations

import contextlib
import time
import uuid
from typing import Optional

EVENT_TYPES = ("run_start", "span", "round", "client_dropped",
               "client_join", "client_leave", "anytime_eval")
DROP_REASONS = ("dropout", "max_staleness", "client_left", "algo_swap")

# canonical phase names; the sync runtime fuses local update, wire encode
# and aggregation into one jitted call traced as a single "update" span.
# Population staging splits into "stage_batches" + "state_acquire"; the
# chunk-streaming pipeline (fed.pipeline) emits per-chunk "chunk_stage" /
# "chunk_restore" / "chunk_compute" spans (carrying a ``chunk`` index)
# and reuses "flush" for the blocking finish step.
PHASES = ("staging", "stage_batches", "state_acquire", "local_update",
          "update", "chunk_stage", "chunk_restore", "chunk_compute",
          "flush", "eval")


class Tracer:
    """Stamps, counts, and fans out trace events to sinks."""

    def __init__(self, sinks=(), run_id: Optional[str] = None, *,
                 rounds: int = 0, spans: int = 0, seq: int = 0,
                 clock=time.perf_counter):
        self.sinks = tuple(sinks)
        self.run_id = run_id if run_id is not None else uuid.uuid4().hex[:12]
        self.rounds = rounds       # cumulative round events (checkpointed)
        self.spans = spans         # cumulative spans (checkpointed)
        self.seq = seq
        self._clock = clock

    @property
    def enabled(self) -> bool:
        return bool(self.sinks)

    # ------------------------------------------------------------ emission

    def emit(self, event_type: str, **fields) -> dict:
        ev = {"event": event_type, "run_id": self.run_id, "seq": self.seq}
        ev.update(fields)
        self.seq += 1
        for s in self.sinks:
            s.emit(ev)
        return ev

    @contextlib.contextmanager
    def span(self, phase: str, *, round: Optional[int] = None,
             client_id: Optional[int] = None, chunk: Optional[int] = None,
             sim_time: Optional[float] = None):
        """Record one phase; emits a ``span`` event with the wall duration.

        Disabled tracers skip the clock reads entirely — instrumented code
        paths cost nothing when nobody is listening."""
        if not self.sinks:
            yield
            self.spans += 1
            return
        t0 = self._clock()
        try:
            yield
        finally:
            self.spans += 1
            fields = {"phase": phase, "dur_s": self._clock() - t0}
            if round is not None:
                fields["round"] = int(round)
            if client_id is not None:
                fields["client_id"] = int(client_id)
            if chunk is not None:
                fields["chunk"] = int(chunk)
            if sim_time is not None:
                fields["sim_time"] = float(sim_time)
            self.emit("span", **fields)

    def round_event(self, r: int, metrics: dict, *,
                    telemetry: Optional[dict] = None,
                    sim_time: Optional[float] = None) -> None:
        self.rounds += 1
        if not self.sinks:
            return
        fields = {"round": int(r), "metrics": metrics}
        if telemetry is not None:
            fields["telemetry"] = telemetry
        if sim_time is not None:
            fields["sim_time"] = float(sim_time)
        self.emit("round", **fields)

    def client_dropped(self, client_id: int, *, reason: str, version: int,
                       sim_time: Optional[float] = None) -> None:
        if not self.sinks:
            return
        if reason not in DROP_REASONS:
            raise ValueError(f"unknown drop reason {reason!r} "
                             f"(want one of {DROP_REASONS})")
        fields = {"client_id": int(client_id), "reason": reason,
                  "version": int(version)}
        if sim_time is not None:
            fields["sim_time"] = float(sim_time)
        self.emit("client_dropped", **fields)

    def client_join(self, client_id: int, *,
                    sim_time: Optional[float] = None) -> None:
        """Churn: ``client_id`` joined the active population."""
        if not self.sinks:
            return
        fields = {"client_id": int(client_id)}
        if sim_time is not None:
            fields["sim_time"] = float(sim_time)
        self.emit("client_join", **fields)

    def client_leave(self, client_id: int, *, in_flight: bool = False,
                     sim_time: Optional[float] = None) -> None:
        """Churn: ``client_id`` left; ``in_flight`` says whether its pending
        dispatch was voided (that work surfaces later as a
        ``client_dropped`` with reason ``"client_left"``)."""
        if not self.sinks:
            return
        fields = {"client_id": int(client_id), "in_flight": bool(in_flight)}
        if sim_time is not None:
            fields["sim_time"] = float(sim_time)
        self.emit("client_leave", **fields)

    def anytime_eval(self, metrics: dict, *, sim_time: float,
                     round: Optional[int] = None) -> None:
        """Online eval sampled by simulated time (continuous traffic)."""
        if not self.sinks:
            return
        fields = {"metrics": metrics, "sim_time": float(sim_time)}
        if round is not None:
            fields["round"] = int(round)
        self.emit("anytime_eval", **fields)

    # ------------------------------------------------------- checkpointing

    def state(self) -> dict:
        """Persistent trace identity: stash in checkpoint meta so a
        restored run appends to the same trace without renumbering."""
        return {"run_id": self.run_id, "rounds": self.rounds,
                "spans": self.spans, "seq": self.seq}

    @classmethod
    def from_state(cls, state: Optional[dict], sinks=()) -> "Tracer":
        if not state:
            return cls(sinks=sinks)
        return cls(sinks=sinks, run_id=state["run_id"],
                   rounds=state.get("rounds", 0),
                   spans=state.get("spans", 0), seq=state.get("seq", 0))


NULL_TRACER = Tracer()   # shared disabled default; counters unused


# ---------------------------------------------------------------- schema

_REQUIRED = {
    "span": ("phase", "dur_s"),
    "round": ("round", "metrics"),
    "client_dropped": ("client_id", "reason", "version"),
    "client_join": ("client_id",),
    "client_leave": ("client_id", "in_flight"),
    "anytime_eval": ("metrics", "sim_time"),
    "run_start": (),
}


def validate_event(ev: dict) -> None:
    """Raise ``ValueError`` unless ``ev`` matches the trace schema."""
    if not isinstance(ev, dict):
        raise ValueError(f"trace event must be a dict, got {type(ev)}")
    for key in ("event", "run_id", "seq"):
        if key not in ev:
            raise ValueError(f"trace event missing {key!r}: {ev}")
    kind = ev["event"]
    if kind not in EVENT_TYPES:
        raise ValueError(
            f"unknown trace event type {kind!r} (want one of {EVENT_TYPES})")
    for field in _REQUIRED[kind]:
        if field not in ev:
            raise ValueError(f"{kind} event missing {field!r}: {ev}")
    if kind == "client_dropped" and ev["reason"] not in DROP_REASONS:
        raise ValueError(f"bad drop reason {ev['reason']!r}")
    if not isinstance(ev["seq"], int):
        raise ValueError(f"seq must be an int, got {ev['seq']!r}")


def validate_jsonl(path: str) -> int:
    """Validate every line of a JSONL trace; returns the event count."""
    import json
    n = 0
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            validate_event(json.loads(line))
            n += 1
    if n == 0:
        raise ValueError(f"empty trace {path!r}")
    return n
