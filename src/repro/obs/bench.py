"""The BENCH_*.json perf-trajectory format.

Every benchmark run can publish its headline rows as a small, *stable*
JSON document (``BENCH_executor.json``, ``BENCH_transport.json``) so the
repo finally accrues a perf trajectory across PRs: same schema, same row
names, diffable numbers.  ``benchmarks/run.py`` writes them; CI asserts
they exist and validate, and uploads them as artifacts.

Schema (version 1):

  {"bench": "executor", "schema_version": 1, "unit": "us_per_call",
   "config": {"quick": true, ...},
   "rows": [{"name": "exec_vmap_S4", "us_per_call": 1234.5,
             "derived": {"loss": 0.9876}}, ...]}

Rows mirror the CSV lines the benchmark already prints — ``name`` is the
stable join key across PRs; ``derived`` holds the per-row scalars (typed,
not the string blob the CSV carries).
"""
from __future__ import annotations

import json
import os

BENCH_SCHEMA_VERSION = 1
_SCALAR = (bool, int, float, str, type(None))


def make_bench(bench: str, rows: list, *, config: dict = None) -> dict:
    doc = {"bench": str(bench), "schema_version": BENCH_SCHEMA_VERSION,
           "unit": "us_per_call", "config": dict(config or {}),
           "rows": [dict(r) for r in rows]}
    validate_bench(doc)
    return doc


def validate_bench(doc: dict) -> None:
    """Raise ``ValueError`` unless ``doc`` is a valid BENCH document."""
    if not isinstance(doc, dict):
        raise ValueError(f"BENCH doc must be a dict, got {type(doc)}")
    for key in ("bench", "schema_version", "unit", "config", "rows"):
        if key not in doc:
            raise ValueError(f"BENCH doc missing {key!r}")
    if doc["schema_version"] != BENCH_SCHEMA_VERSION:
        raise ValueError(
            f"BENCH schema_version {doc['schema_version']!r} != "
            f"{BENCH_SCHEMA_VERSION}")
    if not isinstance(doc["rows"], list) or not doc["rows"]:
        raise ValueError("BENCH rows must be a non-empty list")
    seen = set()
    for row in doc["rows"]:
        if not isinstance(row, dict) or "name" not in row \
                or "us_per_call" not in row:
            raise ValueError(f"BENCH row needs name + us_per_call: {row}")
        if not isinstance(row["name"], str):
            raise ValueError(f"BENCH row name must be a str: {row}")
        if row["name"] in seen:
            raise ValueError(f"duplicate BENCH row name {row['name']!r}")
        seen.add(row["name"])
        if not isinstance(row["us_per_call"], (int, float)) \
                or isinstance(row["us_per_call"], bool):
            raise ValueError(f"BENCH us_per_call must be numeric: {row}")
        for k, v in row.get("derived", {}).items():
            if not isinstance(v, _SCALAR):
                raise ValueError(
                    f"BENCH derived[{k!r}] must be a JSON scalar, "
                    f"got {type(v).__name__}")


def write_bench(path: str, bench: str, rows: list, *,
                config: dict = None) -> dict:
    doc = make_bench(bench, rows, config=config)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return doc


def read_bench(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    validate_bench(doc)
    return doc
