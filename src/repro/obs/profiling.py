"""Kernel profiling hooks: one timing harness over the ref/ops/kernel
triads (``soap_rotate``, ``qblock``, ``ns_ortho``, ``sophia_update``,
``fused_agg``).

Each kernel package already pairs a pure-jnp oracle (``ref``) with a
Pallas path (``ops`` dispatching to ``kernel``); this harness times both
implementations on the same inputs and emits records with analytic
FLOP/byte envelopes, so ``benchmarks/roofline.py`` can place the measured
throughput against the machine's roofline:

  {"kind": "kernel", "kernel": "soap_rotate", "impl": "ref"|"pallas",
   "shape": [m, n], "us_per_call": ..., "flops": ..., "bytes": ...,
   "gflops_s": ..., "gbps": ...}

On non-TPU hosts the Pallas path runs in interpret mode — its timings
measure the interpreter, not the kernel, and the records say so
(``interpret: true``).  The envelopes are coarse by design (matmul
2mnk FLOPs, one read+write per array): good enough to rank bound-ness,
not a substitute for a hardware profiler.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.kernels.fused_agg.ops import dequant_accumulate
from repro.kernels.ns_ortho.ops import newton_schulz
from repro.kernels.qblock.ops import quantize
from repro.kernels.soap_rotate.ops import soap_rotated_update
from repro.kernels.sophia_update.ops import sophia_update
from repro.utils import hw

KERNELS = ("soap_rotate", "qblock", "ns_ortho", "sophia_update",
           "fused_agg")
NS_STEPS = 5
FUSED_AGG_COHORT = 8   # stacked client axis for the fused_agg case


def time_fn(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Microseconds per call, compile excluded (device-synchronized)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    del out
    return (time.perf_counter() - t0) / iters * 1e6


def _mk(shape, key, n=1):
    ks = jax.random.split(jax.random.key(key), n)
    arrs = [jax.random.normal(k, shape, jnp.float32) for k in ks]
    return arrs[0] if n == 1 else arrs


def _cases(shape, block: int, interpret: bool):
    """(kernel, impl_name, jitted_fn, args, flops, bytes) per triad."""
    m, n = shape
    size = m * n
    f32 = 4
    g = _mk(shape, 0)
    out = []

    # soap_rotate: 4 (n x n)-ish matmuls + fused rotated-Adam moments
    ql, qr = _mk((m, m), 1), _mk((n, n), 2)
    mom, v = _mk(shape, 3, 2)
    flops = 2 * (m * m * n) * 2 + 2 * (m * n * n) * 2 + 12 * size
    byts = f32 * size * 8   # g, 2 rotations, m, v in/out, d
    for impl, kw in (("ref", dict(use_pallas=False)),
                     ("pallas", dict(use_pallas=True, interpret=interpret,
                                     block=block))):
        fn = jax.jit(functools.partial(soap_rotated_update, b1=0.95, b2=0.95,
                                       **kw))
        out.append(("soap_rotate", impl, fn, (g, ql, qr, mom, v),
                    flops, byts))

    # qblock: one memory-bound pass (read f32, write int8 + scales)
    qflops = 4 * size
    qbytes = f32 * size + size + f32 * (size // block + 1)
    for impl, kw in (("ref", dict(use_pallas=False)),
                     ("pallas", dict(use_pallas=True, interpret=interpret))):
        fn = jax.jit(functools.partial(quantize, block=block, **kw))
        out.append(("qblock", impl, fn, (g,), qflops, qbytes))

    # ns_ortho: NS_STEPS quintic iterations, 3 matmuls each
    nflops = NS_STEPS * (2 * m * m * n * 2 + 2 * m * m * m)
    nbytes = f32 * size * 2 * NS_STEPS * 3
    for impl, kw in (("ref", dict(use_pallas=False)),
                     ("pallas", dict(use_pallas=True, interpret=interpret))):
        fn = jax.jit(functools.partial(newton_schulz, steps=NS_STEPS, **kw))
        out.append(("ns_ortho", impl, fn, (g,), nflops, nbytes))

    # sophia_update: fused momentum/clip/precondition elementwise pass
    h = _mk(shape, 4)
    sflops = 8 * size
    sbytes = f32 * size * 5   # g, m, h in; update, m out
    for impl, kw in (("ref", dict(use_pallas=False)),
                     ("pallas", dict(use_pallas=True, interpret=interpret))):
        fn = jax.jit(functools.partial(sophia_update, **kw))
        out.append(("sophia_update", impl, fn, (g, mom, h), sflops, sbytes))

    # fused_agg: dequantize-and-accumulate B stacked int8 uploads into one
    # f32 weighted sum — streams B*size int8 + B*(size/block) f32 scales,
    # writes size f32 once (2 flops/element: scale-multiply + accumulate)
    bsz = FUSED_AGG_COHORT
    nb = max(1, size // block)
    q = jax.random.randint(jax.random.key(5), (bsz, nb, block), -127, 128,
                           jnp.int8)
    scale = jnp.abs(_mk((bsz, nb), 6)) + 1e-3
    wts = jnp.abs(_mk((bsz,), 7)) + 0.1
    aflops = 2 * bsz * nb * block
    abytes = bsz * nb * block + f32 * bsz * nb + f32 * nb * block
    for impl, kw in (("ref", dict(use_pallas=False)),
                     ("pallas", dict(use_pallas=True, interpret=interpret))):
        fn = jax.jit(functools.partial(dequant_accumulate, **kw))
        out.append(("fused_agg", impl, fn, (q, scale, wts), aflops, abytes))
    return out


def profile_kernels(shapes=((256, 256),), *, block: int = 128,
                    interpret=None, iters: int = 5,
                    kernels=None) -> list:
    """Time every triad at every shape; returns a list of records.

    ``interpret=None`` picks real Pallas kernels on TPU and the
    interpreter elsewhere (``repro.utils.hw`` — the same auto rule the
    transport uses).  ``kernels`` restricts to a subset of ``KERNELS``.
    """
    interpret = hw.resolve_interpret(interpret)
    want = set(kernels) if kernels is not None else set(KERNELS)
    unknown = want - set(KERNELS)
    if unknown:
        raise ValueError(f"unknown kernels {sorted(unknown)} "
                         f"(want a subset of {KERNELS})")
    records = []
    for shape in shapes:
        for kernel, impl, fn, args, flops, byts in _cases(
                tuple(shape), block, interpret):
            if kernel not in want:
                continue
            us = time_fn(fn, *args, iters=iters)
            sec = us / 1e6
            records.append({
                "kind": "kernel", "kernel": kernel, "impl": impl,
                "shape": list(shape), "block": block,
                "interpret": bool(interpret and impl == "pallas"),
                "backend": jax.default_backend(),
                "us_per_call": us, "flops": flops, "bytes": byts,
                "gflops_s": flops / sec / 1e9,
                "gbps": byts / sec / 1e9,
            })
    return records
