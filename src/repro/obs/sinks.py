"""Pluggable telemetry sinks: where round metrics and trace events go.

One protocol (``Sink.emit(event: dict)``) serves both the per-round metric
hook (``FedExperiment.log_round``) and the structured round-trace stream
(``obs.trace.Tracer``).  Events are plain dicts — JSON-serializable except
for the values a custom eval fn may put into round metrics, which
``JsonlSink`` coerces defensively.

  StdoutRoundSink  default ``log_round`` sink; prints round metrics with
                   exactly the legacy formatting (``format_metric``), so
                   routing logging through the protocol changes no output.
  JsonlSink        one JSON object per line, flushed per event (a crashed
                   run keeps its trace up to the last completed event).
  CsvSink          round events flattened to CSV rows (header from the
                   first event; spans/drops are skipped).
  MemorySink       in-memory list, for tests and notebook analysis.
"""
from __future__ import annotations

import json
import os
from typing import Optional


def format_metric(v):
    """4-decimal rounding for floats; everything else (ints, None, strings,
    arrays from custom eval fns) passes through untouched."""
    try:
        return round(v, 4)
    except TypeError:
        return v


class Sink:
    """``emit`` one event dict; ``close`` flushes/releases resources."""

    def emit(self, event: dict) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class StdoutRoundSink(Sink):
    """Legacy-bitwise stdout logging of round events.

    Prints ``{metric: format_metric(value)}`` for ``event="round"`` and
    ignores everything else — byte-identical to the pre-sink
    ``FedExperiment.log_round`` output, including the defensive
    non-float path.
    """

    def emit(self, event: dict) -> None:
        if event.get("event") != "round":
            return
        print({k: format_metric(v) for k, v in event["metrics"].items()})


class MemorySink(Sink):
    """Accumulates events in ``self.events`` (tests, notebooks)."""

    def __init__(self):
        self.events: list[dict] = []

    def emit(self, event: dict) -> None:
        self.events.append(dict(event))

    def rounds(self) -> list[dict]:
        return [e for e in self.events if e.get("event") == "round"]


def _jsonable(v):
    """Best-effort coercion for eval-fn values (arrays, numpy scalars)."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if hasattr(v, "tolist"):          # numpy / jax arrays and scalars
        return _jsonable(v.tolist())
    return repr(v)


class JsonlSink(Sink):
    """One event per line; opened lazily, flushed per event."""

    def __init__(self, path: str, append: bool = False):
        self.path = path
        self._mode = "a" if append else "w"
        self._f = None

    def _file(self):
        if self._f is None:
            d = os.path.dirname(os.path.abspath(self.path))
            os.makedirs(d, exist_ok=True)
            self._f = open(self.path, self._mode)
        return self._f

    def emit(self, event: dict) -> None:
        f = self._file()
        f.write(json.dumps(_jsonable(event)) + "\n")
        f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


class CsvSink(Sink):
    """Round events as CSV rows; column set fixed by the first round event.

    Scalar metric/telemetry fields become columns (telemetry vectors and
    non-round events are skipped — use ``JsonlSink`` for the full stream).
    """

    def __init__(self, path: str):
        self.path = path
        self._f = None
        self._cols: Optional[list] = None

    def _flat(self, event: dict) -> dict:
        row = {"round": event.get("round")}
        for src in ("metrics", "telemetry"):
            for k, v in (event.get(src) or {}).items():
                if isinstance(v, (bool, int, float)) or v is None:
                    row[k] = v
        return row

    def emit(self, event: dict) -> None:
        if event.get("event") != "round":
            return
        row = self._flat(event)
        if self._f is None:
            d = os.path.dirname(os.path.abspath(self.path))
            os.makedirs(d, exist_ok=True)
            self._f = open(self.path, "w")
            self._cols = list(row)
            self._f.write(",".join(self._cols) + "\n")
        vals = [row.get(c) for c in self._cols]
        self._f.write(",".join("" if v is None else str(v)
                               for v in vals) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None
