"""Pallas TPU kernels for SOAP's rotated-space Adam.

Two pieces:
  * the two-sided rotations Q_L^T G Q_R / Q_L N Q_R^T reuse the blocked
    ``matmul_fused`` kernel from kernels/ns_ortho (MXU work);
  * ``adam_moments`` — fused elementwise moment update + normalized direction
    (VPU work, single HBM pass for 3 reads / 3 writes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
SUBLANES = 8


def _adam_kernel(bc_ref, g_ref, m_ref, v_ref, n_ref, m_out, v_out, *, b1, b2,
                 eps):
    g = g_ref[...].astype(jnp.float32)
    m = b1 * m_ref[...].astype(jnp.float32) + (1 - b1) * g
    v = b2 * v_ref[...].astype(jnp.float32) + (1 - b2) * g * g
    bc1, bc2 = bc_ref[0, 0], bc_ref[0, 1]
    n_ref[...] = ((m / bc1) / (jnp.sqrt(v / bc2) + eps)).astype(n_ref.dtype)
    m_out[...] = m.astype(m_out.dtype)
    v_out[...] = v.astype(v_out.dtype)


@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps", "block",
                                             "interpret"))
def adam_moments(g, m, v, *, b1: float = 0.95, b2: float = 0.95,
                 eps: float = 1e-8, block: int = 1024, step=None,
                 interpret: bool = False):
    """Fused rotated-space Adam moments. Returns (n, m', v') as f32.

    ``step`` enables bias correction with t = step + 1, matching
    ``optim.soap``'s warm-restarted local steps; None reproduces the raw
    uncorrected direction.  It may be a traced scalar (the local-step scan
    carry): the correction factors ride in as a scalar operand, so no
    per-step recompilation."""
    shape = g.shape
    n_el = g.size
    width = SUBLANES * LANES
    rows = -(-n_el // width)
    pad = rows * width - n_el

    def prep(x):
        flat = x.reshape(-1).astype(jnp.float32)
        if pad:
            flat = jnp.pad(flat, (0, pad))
        return flat.reshape(rows, width)

    gp, mp, vp = prep(g), prep(m), prep(v)
    bm = min(block // LANES, rows)
    grid_rows = -(-rows // bm)
    if rows % bm:
        extra = grid_rows * bm - rows
        gp, mp, vp = (jnp.pad(x, ((0, extra), (0, 0))) for x in (gp, mp, vp))

    if step is None:
        bc = jnp.ones((1, 2), jnp.float32)
    else:
        t = jnp.asarray(step, jnp.float32) + 1.0
        bc = jnp.stack([1.0 - b1 ** t, 1.0 - b2 ** t]).reshape(1, 2)
    kern = functools.partial(_adam_kernel, b1=b1, b2=b2, eps=eps)
    n_out, m_new, v_new = pl.pallas_call(
        kern,
        grid=(grid_rows,),
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0))]
        + [pl.BlockSpec((bm, width), lambda i: (i, 0))] * 3,
        out_specs=[pl.BlockSpec((bm, width), lambda i: (i, 0))] * 3,
        out_shape=[jax.ShapeDtypeStruct(gp.shape, jnp.float32)] * 3,
        interpret=interpret,
    )(bc, gp, mp, vp)

    def post(x):
        return x.reshape(-1)[:n_el].reshape(shape)

    return post(n_out), post(m_new), post(v_new)
