"""Jitted wrapper: full SOAP rotated-Adam step composed from Pallas pieces.

Rotations run on the MXU via the blocked matmul kernel; the moment update is
one fused VPU pass.  ``use_pallas=False`` falls back to the jnp oracle.
"""
from __future__ import annotations

from repro.kernels.ns_ortho.kernel import matmul_fused
from repro.kernels.soap_rotate import ref
from repro.kernels.soap_rotate.kernel import adam_moments


def soap_rotated_update(g, ql, qr, m, v, *, b1: float = 0.95,
                        b2: float = 0.95, eps: float = 1e-8, step=None,
                        use_pallas: bool = False, interpret: bool = True,
                        block: int = 128):
    if not use_pallas:
        return ref.soap_rotated_update(g, ql, qr, m, v, b1=b1, b2=b2,
                                       eps=eps, step=step)
    kw = dict(bm=block, bk=block, bn=block, interpret=interpret)
    g32 = g.astype(ql.dtype)
    g_rot = matmul_fused(matmul_fused(ql.T, g32, **kw), qr, **kw)
    n, m_new, v_new = adam_moments(g_rot, m, v, b1=b1, b2=b2, eps=eps,
                                   step=step, interpret=interpret)
    d = matmul_fused(matmul_fused(ql, n, **kw), qr.T, **kw)
    return d, m_new, v_new
