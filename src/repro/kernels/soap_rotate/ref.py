"""Pure-jnp oracle for SOAP's rotated-space Adam step (Alg. 4 lines 13-21).

Given gradient G, eigenbases (Q_L, Q_R), rotated moments (M, V):
  G'  = Q_L^T G Q_R
  M'  = b1 M + (1-b1) G'
  V'  = b2 V + (1-b2) G'**2
  N   = M'' / (sqrt(V'') + eps)   with M''/V'' the bias-corrected moments
        when ``step`` is given (t = step + 1), else the raw M'/V'
  D   = Q_L N Q_R^T
Returns (D, M', V').
"""
from __future__ import annotations

import jax.numpy as jnp


def soap_rotated_update(g, ql, qr, m, v, *, b1: float = 0.95,
                        b2: float = 0.95, eps: float = 1e-8, step=None):
    gf = g.astype(jnp.float32)
    g_rot = ql.T @ gf @ qr
    m_new = b1 * m + (1 - b1) * g_rot
    v_new = b2 * v + (1 - b2) * g_rot * g_rot
    if step is None:
        n = m_new / (jnp.sqrt(v_new) + eps)
    else:
        t = jnp.asarray(step, jnp.float32) + 1.0
        n = (m_new / (1 - b1 ** t)) / (jnp.sqrt(v_new / (1 - b2 ** t)) + eps)
    d = ql @ n @ qr.T
    return d, m_new, v_new
