"""Jitted wrapper for blockwise int8 quantization: Pallas on TPU
(interpret mode for CPU validation) or the pure-jnp oracle."""
from __future__ import annotations

from repro.kernels.qblock import ref
from repro.kernels.qblock.kernel import quantize as _pallas

dequantize = ref.dequantize


def quantize(x, *, block: int = 128, eps: float = 1e-12,
             use_pallas: bool = False, interpret: bool = True):
    if use_pallas:
        return _pallas(x, block=block, eps=eps, interpret=interpret)
    return ref.quantize(x, block=block, eps=eps)
