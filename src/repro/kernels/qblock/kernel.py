"""Pallas TPU kernel: fused blockwise int8 quantization.

One grid row processes a (bm, block) tile of blocks: rowwise abs-max,
scale, divide, round, cast — a single HBM read of the f32 input and a
single write of the int8 values + f32 scales (vs four passes for the
unfused jnp version).  ``block`` must be a multiple of 128 (VPU lanes);
bm is a multiple of 32 so the int8 output respects its (32, 128) min
tile.  The last partial tile is handled by zero-padding outside the
kernel — zero blocks quantize to scale=eps, q=0, so padding is exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
INT8_SUBLANES = 32


def _qblock_kernel(x_ref, q_ref, s_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, eps)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit,
                   static_argnames=("block", "eps", "bm", "interpret"))
def quantize(x, *, block: int = 128, eps: float = 1e-12,
             bm: int = INT8_SUBLANES, interpret: bool = False):
    """Blockwise int8 quantize; returns (q (nb, block) int8, scale (nb,))."""
    if block % LANES:
        raise ValueError(
            f"block must be a multiple of {LANES} (VPU lane width), "
            f"got {block}")
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.size
    nb = -(-n // block)
    grid_rows = -(-nb // bm)
    total = grid_rows * bm * block
    if total - n:
        flat = jnp.pad(flat, (0, total - n))
    xb = flat.reshape(grid_rows * bm, block)

    kern = functools.partial(_qblock_kernel, eps=eps)
    q, s = pl.pallas_call(
        kern,
        grid=(grid_rows,),
        in_specs=[pl.BlockSpec((bm, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, block), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct(xb.shape, jnp.int8),
                   jax.ShapeDtypeStruct((xb.shape[0], 1), jnp.float32)],
        interpret=interpret,
    )(xb)
    return q[:nb], s[:nb, 0]
