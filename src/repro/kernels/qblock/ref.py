"""Pure-jnp oracle for blockwise int8 quantization (qblock codec).

A flat array is split into blocks of ``block`` elements; each block ships
one f32 scale and ``block`` int8 values:

  scale_b = max(|x_b|) / 127          (floored at eps so zero blocks work)
  q_b     = clip(round(x_b / scale_b), -127, 127)

Dequantization is ``q_b * scale_b``; the per-element error is bounded by
scale_b / 2 (round-to-nearest).  The op is a single memory-bound pass over
the data — the TPU version is the Pallas kernel in kernel.py (one HBM
round-trip, rowwise max + scale + cast fused).
"""
from __future__ import annotations

import math

import jax.numpy as jnp


def quantize(x, *, block: int = 128, eps: float = 1e-12):
    """Blockwise int8 quantization of any-shape ``x``.

    Returns (q, scale): q int8 (nblocks, block) — zero-padded to a whole
    number of blocks — and scale f32 (nblocks,).
    """
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.size
    nb = -(-n // block)
    pad = nb * block - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    xb = flat.reshape(nb, block)
    amax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, eps)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize(q, scale, shape, dtype=jnp.float32):
    """Inverse of ``quantize``: (nblocks, block) int8 + (nblocks,) scales
    back to ``shape`` (padding trimmed)."""
    xb = q.astype(jnp.float32) * scale[:, None]
    n = math.prod(shape)
    return xb.reshape(-1)[:n].reshape(shape).astype(dtype)
