"""Pure-jnp oracle for the fused Sophia update (Alg. 8 lines 7/17-18).

Given gradient g, momentum m, Hessian-diag EMA h:
  m' = b1 m + (1 - b1) g
  d  = clip(m' / max(h, eps), -rho, rho)
Returns (d, m').  One fused pass — the op is purely memory-bound, which is
exactly why it is a Pallas kernel on TPU (single HBM round-trip instead of
four).
"""
from __future__ import annotations

import jax.numpy as jnp


def sophia_update(g, m, h, *, b1: float = 0.9, rho: float = 0.05,
                  eps: float = 1e-12):
    gf = g.astype(jnp.float32)
    m_new = b1 * m.astype(jnp.float32) + (1.0 - b1) * gf
    d = jnp.clip(m_new / jnp.maximum(h.astype(jnp.float32), eps), -rho, rho)
    return d, m_new
