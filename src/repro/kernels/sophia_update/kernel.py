"""Pallas TPU kernel: fused Sophia momentum + clipped preconditioned update.

Memory-bound elementwise op: reads (g, m, h), writes (d, m') in one pass.
Arrays are flattened and tiled to (8, 128)-multiple VMEM blocks (VPU lane
layout); the last partial tile is handled by zero-padding outside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
SUBLANES = 8


def _sophia_kernel(g_ref, m_ref, h_ref, d_ref, m_out_ref, *, b1, rho, eps):
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)
    m_new = b1 * m + (1.0 - b1) * g
    d = jnp.clip(m_new / jnp.maximum(h, eps), -rho, rho)
    d_ref[...] = d.astype(d_ref.dtype)
    m_out_ref[...] = m_new.astype(m_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("b1", "rho", "eps", "block",
                                             "interpret"))
def sophia_update(g, m, h, *, b1: float = 0.9, rho: float = 0.05,
                  eps: float = 1e-12, block: int = 1024,
                  interpret: bool = False):
    """Fused Sophia direction. Any-shape inputs; returns (d, m') f32."""
    shape = g.shape
    n = g.size
    width = SUBLANES * LANES
    rows = -(-n // width)
    pad = rows * width - n

    def prep(x):
        flat = x.reshape(-1).astype(jnp.float32)
        if pad:
            flat = jnp.pad(flat, (0, pad))
        return flat.reshape(rows, width)

    gp, mp, hp = prep(g), prep(m), prep(h)
    bm = min(block // LANES, rows)
    grid_rows = -(-rows // bm)
    if rows % bm:
        extra = grid_rows * bm - rows
        gp = jnp.pad(gp, ((0, extra), (0, 0)))
        mp = jnp.pad(mp, ((0, extra), (0, 0)))
        hp = jnp.pad(hp, ((0, extra), (0, 0)))

    kern = functools.partial(_sophia_kernel, b1=b1, rho=rho, eps=eps)
    d, m_new = pl.pallas_call(
        kern,
        grid=(grid_rows,),
        in_specs=[pl.BlockSpec((bm, width), lambda i: (i, 0))] * 3,
        out_specs=[pl.BlockSpec((bm, width), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct(gp.shape, jnp.float32)] * 2,
        interpret=interpret,
    )(gp, mp, hp)
    d = d.reshape(-1)[:n].reshape(shape)
    m_new = m_new.reshape(-1)[:n].reshape(shape)
    return d, m_new
