"""Jitted wrapper for the fused Sophia update: Pallas on TPU (interpret mode
for CPU validation) or the pure-jnp oracle."""
from __future__ import annotations

from repro.kernels.sophia_update import ref
from repro.kernels.sophia_update.kernel import sophia_update as _pallas


def sophia_update(g, m, h, *, b1: float = 0.9, rho: float = 0.05,
                  eps: float = 1e-12, use_pallas: bool = False,
                  interpret: bool = True):
    if use_pallas:
        return _pallas(g, m, h, b1=b1, rho=rho, eps=eps, interpret=interpret)
    return ref.sophia_update(g, m, h, b1=b1, rho=rho, eps=eps)
