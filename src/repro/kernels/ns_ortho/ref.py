"""Pure-jnp oracle for Newton–Schulz orthogonalization (Muon's hot-spot).

Quintic iteration from the Muon reference implementation:
  X <- a X + (b A + c A^2) X,  A = X X^T
coefficients (3.4445, -4.7750, 2.0315); input pre-scaled by Frobenius norm.
"""
from __future__ import annotations

import jax.numpy as jnp

NS_COEFFS = (3.4445, -4.7750, 2.0315)


def ns_iteration(x, coeffs=NS_COEFFS):
    """One quintic Newton–Schulz step. x: (m, n) with m <= n."""
    a, b, c = coeffs
    xf = x.astype(jnp.float32)
    aa = xf @ xf.T
    bb = b * aa + c * (aa @ aa)
    return (a * xf + bb @ xf).astype(x.dtype)


def newton_schulz(g, steps: int = 5, eps: float = 1e-7):
    """Orthogonalize g: (m, n). Returns approx orthogonal factor of g."""
    transpose = g.shape[0] > g.shape[1]
    x = g.T if transpose else g
    x = x / (jnp.linalg.norm(x) + eps)
    for _ in range(steps):
        x = ns_iteration(x)
    return x.T if transpose else x
