"""Jitted wrapper: Newton–Schulz orthogonalization via the Pallas matmul.

``newton_schulz(g, use_pallas=...)`` dispatches between the Pallas kernel
(TPU target; interpret mode on CPU for validation) and the pure-jnp oracle.
The default is the jnp path on CPU hosts, so optimizers transparently use the
same API everywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ns_ortho import ref
from repro.kernels.ns_ortho.kernel import matmul_fused

NS_COEFFS = ref.NS_COEFFS


def ns_iteration_pallas(x, *, interpret: bool = True, block: int = 128):
    """One quintic NS step via three fused Pallas matmuls. x: (m,n), m<=n."""
    a, b, c = NS_COEFFS
    kw = dict(bm=block, bk=block, bn=block, interpret=interpret)
    xt = x.T
    A = matmul_fused(x, xt, **kw)                       # X X^T
    B = matmul_fused(A, A, aux=A, alpha=c, beta=b, **kw)  # c A^2 + b A
    return matmul_fused(B, x, aux=x, alpha=1.0, beta=a, **kw)  # B X + a X


def newton_schulz_pallas(g, steps: int = 5, eps: float = 1e-7, *,
                         interpret: bool = True, block: int = 128):
    transpose = g.shape[0] > g.shape[1]
    x = g.T if transpose else g
    x = x / (jnp.linalg.norm(x) + eps)
    for _ in range(steps):
        x = ns_iteration_pallas(x, interpret=interpret, block=block)
    return x.T if transpose else x


def newton_schulz(g, steps: int = 5, eps: float = 1e-7, *,
                  use_pallas: bool = False, interpret: bool = True):
    """Batched-aware NS orthogonalization; 3-D inputs vmap over dim 0."""
    fn = (functools.partial(newton_schulz_pallas, steps=steps, eps=eps,
                            interpret=interpret)
          if use_pallas else
          functools.partial(ref.newton_schulz, steps=steps, eps=eps))
    if g.ndim == 3:  # (experts, m, n)
        return jax.vmap(fn)(g)
    return fn(g)
