"""Pallas TPU kernel: blocked matmul with fused scale/add epilogue.

Building block for the Newton–Schulz quintic iteration
  A = X X^T;  B = b A + c A A;  Y = a X + B X
Each product is one ``matmul_fused`` call whose epilogue folds the scalar
combination into the final K-step, so the `b*A + ...` / `a*X + ...` terms cost
no extra HBM round-trips.

Tiling: (bm, bk) x (bk, bn) blocks staged in VMEM, f32 accumulator scratch,
MXU-aligned 128-multiples by default.  Grid order (m, n, k), k innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel_noaux(lhs_ref, rhs_ref, out_ref, acc_ref, *, alpha, beta, k_steps):
    _body(lhs_ref, rhs_ref, None, out_ref, acc_ref, alpha, beta, k_steps)


def _kernel_aux(lhs_ref, rhs_ref, aux_ref, out_ref, acc_ref, *, alpha, beta,
                k_steps):
    _body(lhs_ref, rhs_ref, aux_ref, out_ref, acc_ref, alpha, beta, k_steps)


def _body(lhs_ref, rhs_ref, aux_ref, out_ref, acc_ref, alpha, beta, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        lhs_ref[...].astype(jnp.float32),
        rhs_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        res = alpha * acc_ref[...]
        if aux_ref is not None:
            res = res + beta * aux_ref[...].astype(jnp.float32)
        out_ref[...] = res.astype(out_ref.dtype)


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit, static_argnames=("alpha", "beta", "bm", "bk", "bn", "interpret"))
def matmul_fused(lhs, rhs, aux=None, *, alpha: float = 1.0, beta: float = 0.0,
                 bm: int = 128, bk: int = 128, bn: int = 128,
                 interpret: bool = False):
    """alpha * (lhs @ rhs) + beta * aux via a blocked Pallas kernel.

    lhs: (m, k), rhs: (k, n), aux: (m, n) or None.  Inputs are zero-padded to
    tile multiples and the result sliced back, so arbitrary shapes work.
    """
    m, k = lhs.shape
    k2, n = rhs.shape
    assert k == k2, (lhs.shape, rhs.shape)
    bm_, bk_, bn_ = min(bm, m), min(bk, k), min(bn, n)
    lhs_p = _pad_to(_pad_to(lhs, bm_, 0), bk_, 1)
    rhs_p = _pad_to(_pad_to(rhs, bk_, 0), bn_, 1)
    mp, kp = lhs_p.shape
    np_ = rhs_p.shape[1]
    k_steps = kp // bk_
    grid = (mp // bm_, np_ // bn_, k_steps)

    in_specs = [
        pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
    ]
    operands = [lhs_p, rhs_p]
    if aux is not None:
        aux_p = _pad_to(_pad_to(aux, bm_, 0), bn_, 1)
        in_specs.append(pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)))
        operands.append(aux_p)
        kern = functools.partial(_kernel_aux, alpha=alpha, beta=beta,
                                 k_steps=k_steps)
    else:
        kern = functools.partial(_kernel_noaux, alpha=alpha, beta=beta,
                                 k_steps=k_steps)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), lhs.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[:m, :n]
