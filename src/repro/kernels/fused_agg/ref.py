"""Pure-jnp oracles for the fused decode-aggregate pass.

Each function computes sum_i w_i * decode(enc_i) for one wire format
without materializing the (B, ...) decoded cohort:

  dequant_accumulate   qblock int8 blocks: the per-block scale and the
                       client weight fold into one multiplier per block,
                       so dequantization and the weighted reduction are a
                       single pass over the int8 buffer
  lowrank_accumulate   U·diag(s)·Vᵀ factors: (client, rank) merge into one
                       contraction axis — a (m, B·r) x (B·r, n) GEMM —
                       so the dense per-client outer products never exist
  sketch_accumulate    power_sketch Q·B factors, same merged GEMM
"""
from __future__ import annotations

import jax.numpy as jnp


def dequant_accumulate(q, scale, weights):
    """sum_i w_i * (q_i * scale_i) over the client axis.

    q: (B, nb, block) int8 (zero-padded to whole blocks), scale: (B, nb)
    f32, weights: (B,) -> (nb, block) f32.  Padding blocks carry q=0 so
    they contribute nothing regardless of their scale.
    """
    ws = weights.astype(jnp.float32)[:, None] * scale.astype(jnp.float32)
    return jnp.einsum("bn,bnk->nk", ws, q.astype(jnp.float32))


def _merged_gemm(lhs, rhs):
    """sum_i lhs_i @ rhs_i as one batched GEMM over a merged (B*r) axis.

    lhs: (B, *batch, m, r), rhs: (B, *batch, r, n) -> (*batch, m, n).
    """
    b, r = lhs.shape[0], lhs.shape[-1]
    lm = jnp.moveaxis(lhs, 0, -2)                      # (*batch, m, B, r)
    lm = lm.reshape(*lm.shape[:-2], b * r)             # (*batch, m, B*r)
    rm = jnp.moveaxis(rhs, 0, -3)                      # (*batch, B, r, n)
    rm = rm.reshape(*rm.shape[:-3], b * r, rm.shape[-1])
    return lm @ rm


def lowrank_accumulate(u, s, vt, weights):
    """sum_i w_i * U_i diag(s_i) V_iᵀ.  u: (B, *batch, m, r),
    s: (B, *batch, r), vt: (B, *batch, r, n), weights: (B,)."""
    ws = s.astype(jnp.float32) * weights.astype(jnp.float32).reshape(
        (-1,) + (1,) * (s.ndim - 1))
    us = u.astype(jnp.float32) * ws[..., None, :]
    return _merged_gemm(us, vt.astype(jnp.float32))


def sketch_accumulate(q, b, weights):
    """sum_i w_i * Q_i B_i.  q: (B, *batch, m, r), b: (B, *batch, r, n)."""
    qw = q.astype(jnp.float32) * weights.astype(jnp.float32).reshape(
        (-1,) + (1,) * (q.ndim - 1))
    return _merged_gemm(qw, b.astype(jnp.float32))
