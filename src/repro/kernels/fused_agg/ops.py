"""Jitted wrappers for the fused decode-aggregate pass.

``dequant_accumulate`` dispatches between the Pallas kernel (TPU;
interpret mode for CPU validation) and the pure-jnp oracle; the low-rank
and sketch accumulators are MXU-bound merged GEMMs where XLA's own
lowering is already the right kernel, so they alias the reference.
Defaults of ``None`` resolve through the shared backend auto rule
(``repro.utils.hw``): real kernels on TPU, reference/interpreter
elsewhere.
"""
from __future__ import annotations

from repro.kernels.fused_agg import ref
from repro.kernels.fused_agg.kernel import dequant_accumulate as _pallas
from repro.utils import hw

lowrank_accumulate = ref.lowrank_accumulate
sketch_accumulate = ref.sketch_accumulate


def dequant_accumulate(q, scale, weights, *, use_pallas=None,
                       interpret=None):
    """sum_i w_i * (q_i * scale_i) over the client axis (see ref)."""
    if hw.resolve_use_pallas(use_pallas):
        return _pallas(q, scale, weights,
                       interpret=hw.resolve_interpret(interpret))
    return ref.dequant_accumulate(q, scale, weights)
