"""Fused decode-aggregate kernels for the wire-native server flush.

Accumulates a stacked cohort of encoded uploads directly into the running
weighted sum sum_i w_i * decode(msg_i) — the decoded per-client dense
trees never exist.  ``dequant_accumulate`` (qblock int8 blocks, Pallas
kernel) folds the per-block scales into the w_i multiply; the low-rank /
sketch accumulators contract the factors through one merged GEMM.
"""
