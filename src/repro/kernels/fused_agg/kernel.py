"""Pallas TPU kernel: fused dequantize-accumulate of a stacked int8 buffer.

The grid is (grid_rows, B) with the client axis innermost, so each output
tile of the running weighted sum stays resident in VMEM while the kernel
streams every client's int8 blocks through it exactly once — one HBM read
of the quantized cohort, one write of the f32 sum, never a decoded
per-client tensor.  The per-block scale and the client weight are folded
into a single multiplier (computed outside the kernel, B*nb floats) so the
inner loop is one int8->f32 cast, one multiply, one add per element.

bm is a multiple of 32 so the int8 input respects its (32, 128) min tile;
``block`` must be a multiple of 128 (VPU lanes).  Partial tiles are
zero-padded outside the kernel — zero blocks accumulate nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
INT8_SUBLANES = 32


def _fused_agg_kernel(ws_ref, q_ref, out_ref):
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    q = q_ref[0].astype(jnp.float32)        # (bm, block)
    out_ref[...] += ws_ref[0] * q           # ws (bm, 1) broadcasts per block


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def dequant_accumulate(q, scale, weights, *, bm: int = INT8_SUBLANES,
                       interpret: bool = False):
    """sum_i w_i * (q_i * scale_i): (B, nb, block) int8 + (B, nb) scales +
    (B,) weights -> (nb, block) f32."""
    n_clients, nb, block = q.shape
    if block % LANES:
        raise ValueError(
            f"block must be a multiple of {LANES} (VPU lane width), "
            f"got {block}")
    grid_rows = -(-nb // bm)
    nbp = grid_rows * bm
    if nbp - nb:
        q = jnp.pad(q, ((0, 0), (0, nbp - nb), (0, 0)))
        scale = jnp.pad(scale, ((0, 0), (0, nbp - nb)))
    ws = (weights.astype(jnp.float32)[:, None]
          * scale.astype(jnp.float32))[..., None]       # (B, nbp, 1)

    out = pl.pallas_call(
        _fused_agg_kernel,
        grid=(grid_rows, n_clients),
        in_specs=[pl.BlockSpec((1, bm, 1), lambda i, b: (b, i, 0)),
                  pl.BlockSpec((1, bm, block), lambda i, b: (b, i, 0))],
        out_specs=pl.BlockSpec((bm, block), lambda i, b: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbp, block), jnp.float32),
        interpret=interpret,
    )(ws, q)
    return out[:nb]
