"""Pallas TPU kernels for the optimizer hot-spots the paper exercises.

Each kernel package has:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jitted wrapper with use_pallas/interpret dispatch
  ref.py    — pure-jnp oracle the tests assert against

ns_ortho      : blocked matmul + fused NS-quintic epilogue (Muon, MXU-bound)
sophia_update : fused momentum/clip/precondition pass (memory-bound)
soap_rotate   : two-sided eigenbasis rotation + fused rotated Adam
qblock        : fused blockwise int8 quantization (wire codec, memory-bound)
fused_agg     : fused dequantize-accumulate server flush (memory-bound)
"""
