"""Architecture registry: 10 assigned architectures + the paper's own models."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, reduced

# arch id -> module name (dashes are not importable)
_ARCHS = {
    "starcoder2-3b": "starcoder2_3b",
    "smollm-360m": "smollm_360m",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "musicgen-medium": "musicgen_medium",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "chatglm3-6b": "chatglm3_6b",
    "mixtral-8x22b": "mixtral_8x22b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "qwen1.5-110b": "qwen1_5_110b",
    # paper's own experimental models
    "llama-60m": "llama_60m",
    "llama-130m": "llama_130m",
    "llama-350m": "llama_350m",
}

ASSIGNED = list(_ARCHS)[:10]
ALL = list(_ARCHS)


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_ARCHS[name]}")
    return mod.CONFIG


def get_reduced(name: str, **kw) -> ModelConfig:
    return reduced(get_config(name), **kw)
