"""Falcon-Mamba-7B [arXiv:2410.05355] — pure Mamba-1 SSM, attention-free.

64 layers, d_model=4096 (d_inner=8192), ssm_state=16, conv=4, d_ff=0 (the
Mamba block subsumes the MLP). O(1) state -> long_500k decode runs.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    num_layers=64,
    d_model=4096,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    block_pattern=("mamba",),
    mlp_type="none",
    norm_type="rms",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, scan_chunk=128),
    tie_embeddings=False,
    dtype="bfloat16",
    source="arXiv:2410.05355",
)
