"""ChatGLM3-6B [arXiv:2406.12793] — GQA(kv=2), 2d/partial RoPE (fraction 0.5), QKV bias."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    num_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    block_pattern=("attn",),
    mlp_type="swiglu",
    norm_type="rms",
    rope_theta=1e4,
    rope_fraction=0.5,
    qkv_bias=True,
    tie_embeddings=False,
    dtype="bfloat16",
    source="arXiv:2406.12793",
)
