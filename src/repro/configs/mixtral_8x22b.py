"""Mixtral-8x22B [arXiv:2401.04088] — MoE 8 experts top-2, GQA(kv=8), SWA.

Sliding-window attention (window 4096) bounds the decode cache, so the
long_500k decode shape runs with a ring-buffer KV cache.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    num_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    block_pattern=("swa",),
    window=4096,
    mlp_type="swiglu",
    norm_type="rms",
    rope_theta=1e6,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16384),
    tie_embeddings=False,
    dtype="bfloat16",
    source="arXiv:2401.04088",
)
