"""LLaMA-130M — the paper's C4 federated pre-training model (Table 3)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-130m",
    num_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=2048,
    vocab_size=32000,
    block_pattern=("attn",),
    mlp_type="swiglu",
    norm_type="rms",
    tie_embeddings=True,
    dtype="float32",
    source="arXiv:2302.13971 (scaled)",
)
