"""RecurrentGemma-2B [arXiv:2402.19427] — RG-LRU + local attention, 1:2 ratio.

Block pattern (rglru, rglru, local_attn); local window 2048; MQA (kv=1);
GeGLU MLP; head_dim 256. O(1) recurrent state + window-bounded attention
cache -> long_500k decode runs.
"""
from repro.models.config import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    num_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local_attn"),
    window=2048,
    mlp_type="geglu",
    norm_type="rms",
    rope_theta=1e4,
    rglru=RGLRUConfig(lru_width=2560, d_conv=4),
    tie_embeddings=True,
    dtype="bfloat16",
    source="arXiv:2402.19427",
)
