"""MusicGen-medium decoder [arXiv:2306.05284] — decoder-only over EnCodec tokens.

4 parallel codebooks (vocab 2048 each); the EnCodec conv codec + delay-pattern
interleaver is a data-pipeline stub — the backbone consumes summed codebook
embeddings and emits per-codebook logits (B, S, 4, 2048). MHA (kv=24 == heads).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    num_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    block_pattern=("attn",),
    mlp_type="gelu",
    norm_type="layer",
    rope_theta=1e4,
    num_codebooks=4,
    accepts_embeds=True,
    tie_embeddings=False,
    dtype="bfloat16",
    source="arXiv:2306.05284",
)
