"""Qwen1.5-110B [hf:Qwen/Qwen1.5-0.5B family] — dense GQA(kv=8), QKV bias."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    num_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    block_pattern=("attn",),
    mlp_type="swiglu",
    norm_type="rms",
    rope_theta=1e6,
    qkv_bias=True,
    tie_embeddings=False,
    dtype="bfloat16",
    source="hf:Qwen/Qwen1.5-0.5B",
)
