"""LLaMA-60M — the paper's C4 federated pre-training model (Table 3)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-60m",
    num_layers=8,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=1376,
    vocab_size=32000,
    block_pattern=("attn",),
    mlp_type="swiglu",
    norm_type="rms",
    tie_embeddings=True,
    dtype="float32",
    source="arXiv:2302.13971 (scaled)",
)
