"""DeepSeek-V2-236B [arXiv:2405.04434] — MLA (kv_lora=512) + MoE 160e top-6, 2 shared.

Per the assignment: d_ff=1536 is the routed-expert intermediate size; layer 0
is a dense FFN (DeepSeek-V2 convention). MLA decode cache stores only the
compressed (c_kv, k_rope) latents.
"""
from repro.models.config import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    num_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=192,  # qk_nope(128) + qk_rope(64); v_head_dim=128
    d_ff=1536,
    vocab_size=102400,
    block_pattern=("attn",),
    mlp_type="swiglu",
    norm_type="rms",
    rope_theta=1e4,
    moe=MoEConfig(num_experts=160, top_k=6, d_ff_expert=1536,
                  num_shared_experts=2, first_dense_layers=1),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    tie_embeddings=False,
    dtype="bfloat16",
    source="arXiv:2405.04434",
)
