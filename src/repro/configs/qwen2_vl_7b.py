"""Qwen2-VL-7B backbone [arXiv:2409.12191] — M-RoPE, GQA(kv=4), QKV bias.

Vision frontend (ViT + projector) is stubbed per assignment: input_specs
provides precomputed patch embeddings (B, S, D) via the ``embeds`` entry.
M-RoPE sections (t, h, w) = (16, 24, 24) over head_dim/2 = 64.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    num_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    block_pattern=("attn",),
    mlp_type="swiglu",
    norm_type="rms",
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    qkv_bias=True,
    accepts_embeds=True,
    tie_embeddings=False,
    dtype="bfloat16",
    source="arXiv:2409.12191",
)
