"""StarCoder2-3B [arXiv:2402.19173] — dense, GQA(kv=2), RoPE, GELU MLP, LayerNorm, biases."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    num_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    block_pattern=("attn",),
    mlp_type="gelu",
    norm_type="layer",
    rope_theta=1e5,
    qkv_bias=True,
    tie_embeddings=True,
    dtype="bfloat16",
    source="arXiv:2402.19173",
)
