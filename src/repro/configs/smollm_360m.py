"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-135M family] — llama-arch small, GQA(kv=5)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    num_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    block_pattern=("attn",),
    mlp_type="swiglu",
    norm_type="rms",
    rope_theta=1e4,
    tie_embeddings=True,
    dtype="bfloat16",
    source="hf:HuggingFaceTB/SmolLM-135M",
)
