"""LLaMA-350M — the paper's C4 federated pre-training model (Table 3)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-350m",
    num_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2736,
    vocab_size=32000,
    block_pattern=("attn",),
    mlp_type="swiglu",
    norm_type="rms",
    tie_embeddings=True,
    dtype="float32",
    source="arXiv:2302.13971 (scaled)",
)
