"""Buffered-asynchronous federated runtime with staleness-aware FedPAC.

Subsystem layout:
  latency.py     client latency/availability models (distributions, dropout,
                 persistent heterogeneous speeds)
  scheduler.py   event-driven simulated-time scheduler (bounded concurrency,
                 deterministic per seed)
  staleness.py   staleness-decay weight functions w(s)
  buffer.py      FedBuff-style buffered server flush + staleness-aware
                 FedPAC Alignment (AsyncConfig, jitted aggregate)
  experiment.py  AsyncFederatedExperiment — drop-in FedExperiment
"""
from repro.fed.async_runtime.latency import LatencyModel
from repro.fed.async_runtime.scheduler import SimScheduler, Completion
from repro.fed.async_runtime.staleness import make_staleness_weight
from repro.fed.async_runtime.buffer import AsyncConfig, make_async_aggregate_fn
from repro.fed.async_runtime.experiment import AsyncFederatedExperiment
