"""Buffered-asynchronous server: FedBuff-style flush with staleness-aware
FedPAC geometry handling.

The server holds version v and a buffer; client results (delta_i, Theta_i)
trained from version v_i accumulate until ``buffer_size`` arrive, then one
flush advances the model.  With staleness s_i = v - v_i and decay weights
w_i = w(s_i) in (0, 1]:

  params  x^{v+1} = x^v + server_lr * (1/B) sum_i w_i Delta_i
          (unnormalized FedBuff step: a stale buffer moves the model less)
  g_G     fresh estimate g_B = -(sum_i w_i Delta_i / sum_i w_i) / (K eta),
          mixed as g^{v+1} = (1 - rho) g^v + rho g_B,  rho = mean_i w_i
  Theta   Theta_B = sum_i w_i Theta_i / sum_i w_i,
          Theta^{v+1} = (1 - rho) Theta^v + rho Theta_B

rho (the buffer "freshness") -> 1 recovers the synchronous Alg. 2 update
exactly; a stale buffer drags the global geometry only part-way toward the
arriving (outdated) client preconditioners — the staleness-aware Alignment.
The flush is one jitted call over the stacked (B, ...) buffer.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.drift import drift_metric
from repro.core.server import weighted_client_mean, normalized_client_mean
from repro.fed.async_runtime.latency import LatencyModel
from repro.utils.tree import tree_norm_sq


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Execution-model knobs of the buffered-asynchronous runtime."""
    buffer_size: int = 4           # flush after this many client reports
    concurrency: Optional[int] = None  # in-flight clients; None -> from
                                       # FedConfig.participation (>= buffer);
                                       # always clamped into [1, n_clients]
    staleness_mode: str = "poly"   # none | poly | hinge (staleness.py)
    staleness_alpha: float = 0.5   # w_i = 1/(1+s_i)^alpha for "poly"
    hinge_threshold: int = 2
    max_staleness: Optional[int] = None  # discard results staler than this
    latency: LatencyModel = dataclasses.field(default_factory=LatencyModel)

    def resolve_concurrency(self, n_clients: int, participation: float) -> int:
        c = self.concurrency
        if c is None:
            c = max(self.buffer_size,
                    int(round(n_clients * participation)))
        return max(1, min(c, n_clients))


def make_async_aggregate_fn(*, lr: float, local_steps: int,
                            server_lr: float = 1.0, jit: bool = True):
    """Returns flush(params, theta, g_global, deltas, thetas, weights)
    -> (params', theta', g_global', metrics); stacked (B, ...) buffer."""

    def flush(params, theta, g_global, deltas, thetas, weights):
        w = weights.astype(jnp.float32)
        rho = jnp.mean(w)                       # buffer freshness in (0, 1]
        step = weighted_client_mean(deltas, w)  # (1/B) sum w_i Delta_i
        new_params = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32)
                          + server_lr * d).astype(p.dtype), params, step)
        g_batch = jax.tree.map(
            lambda d: -d / (local_steps * lr),
            normalized_client_mean(deltas, w))
        new_g = jax.tree.map(lambda old, gb: (1.0 - rho) * old + rho * gb,
                             g_global, g_batch)
        theta_batch = normalized_client_mean(thetas, w)
        new_theta = jax.tree.map(
            lambda old, tb: ((1.0 - rho) * old.astype(jnp.float32)
                             + rho * tb).astype(old.dtype),
            theta, theta_batch)
        drift = drift_metric(thetas)
        norm_drift = drift / (tree_norm_sq(theta_batch) + 1e-12)
        metrics = {"loss": jnp.zeros(()),  # filled by the driver
                   "drift": drift, "norm_drift": norm_drift,
                   "freshness": rho}
        return new_params, new_theta, new_g, metrics

    return jax.jit(flush) if jit else flush
