"""Buffered-asynchronous server: FedBuff-style flush with staleness-aware
FedPAC geometry handling.

The server holds version v and a buffer; client results (delta_i, Theta_i)
trained from version v_i accumulate until ``buffer_size`` arrive, then one
flush advances the model.  The flush itself is one call into the unified
round engine (``core.engine.aggregate``) with staleness-decay weights
w_i = w(v - v_i) in (0, 1]: the parameter step shrinks with staleness
(unnormalized FedBuff mean), while g_G and Theta are freshness-mixed with
rho = mean_i w_i — rho -> 1 recovers the synchronous Alg. 2 update
*bitwise* (tested in tests/test_engine.py), and a stale buffer drags the
global geometry only part-way toward the arriving (outdated) client
preconditioners.  The drift-adaptive ``GeometryController`` update happens
inside the same jitted flush, with beta additionally backed off by rho.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.engine import (
    AggregationConfig, aggregate, aggregate_wire, update_controller,
)
from repro.core.transport import wire_bytes
from repro.fed.async_runtime.latency import LatencyModel


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Execution-model knobs of the buffered-asynchronous runtime."""
    buffer_size: int = 4           # flush after this many client reports
    concurrency: Optional[int] = None  # in-flight clients; None -> from
                                       # FedConfig.participation (>= buffer);
                                       # always clamped into [1, n_clients]
    staleness_mode: str = "poly"   # none | poly | hinge (staleness.py)
    staleness_alpha: float = 0.5   # w_i = 1/(1+s_i)^alpha for "poly"
    hinge_threshold: int = 2
    max_staleness: Optional[int] = None  # discard results staler than this
    latency: LatencyModel = dataclasses.field(default_factory=LatencyModel)

    def __post_init__(self):
        if self.buffer_size < 1:
            raise ValueError(
                f"buffer_size must be >= 1, got {self.buffer_size}")
        if self.concurrency is not None and self.concurrency < 1:
            raise ValueError(
                f"concurrency must be >= 1, got {self.concurrency}")
        if self.max_staleness is not None and self.max_staleness < 0:
            raise ValueError(
                f"max_staleness must be >= 0, got {self.max_staleness}")

    def resolve_concurrency(self, n_clients: int, participation: float) -> int:
        c = self.concurrency
        if c is None:
            c = max(self.buffer_size,
                    int(round(n_clients * participation)))
        c = max(1, min(c, n_clients))
        if self.buffer_size > c:
            raise ValueError(
                f"buffer_size={self.buffer_size} exceeds the resolved "
                f"concurrency {c} (n_clients={n_clients}, "
                f"participation={participation}): the buffer could only "
                "fill from already-delivered stragglers — raise "
                "concurrency/participation or shrink buffer_size")
        return c


def make_async_aggregate_fn(*, lr: float, local_steps: int,
                            server_lr: float = 1.0, align: bool = True,
                            mixing=None, transport=None, wire_cell=None,
                            jit: bool = True, telemetry: bool = False):
    """Returns flush(params, theta, g_global, ctrl, deltas, thetas, weights,
    staleness=None) -> (params', theta', g_global', ctrl', metrics);
    stacked (B, ...) buffer.  One engine aggregate + one controller step,
    jitted together.

    With ``transport`` (core.transport.Transport) the buffer entries are
    stacked *wire messages* — deltas always, thetas too when ``align``.
    Without a ``mixing`` hook the flush is *fused*: ``aggregate_wire``
    reduces the encoded uploads straight into the weighted sums
    (``Codec.accumulate``) and the decoded (B, ...) cohort stack never
    materializes; with ``mixing`` (which consumes decoded cohorts) the
    decode-then-aggregate fallback runs.  Byte accounting is static
    shape math captured at trace time into the caller's ``wire_cell``
    dict as the exact host-side total (key "total") plus the cohort size
    (key "cohort") — no truncating per-client division.  Without a
    transport the entries are dense trees (legacy path, kept for the
    bitwise-equivalence tests).

    ``mixing`` is an optional AlgorithmSpec hook ``(deltas, thetas) ->
    (B,)`` (e.g. preconditioned mixing); its weights multiply the
    staleness-decay weights, so a stale *and* sharp-curvature client is
    damped by both policies.

    ``telemetry=True`` runs the jit-pure ``repro.obs.telemetry.collect``
    inside the flush (the identical call the sync round makes, so
    zero-staleness telemetry matches the sync round's bitwise) and returns
    it under ``metrics["telemetry"]``; ``staleness`` is the buffer's (B,)
    integer staleness vector (None means all-fresh)."""
    cfg = AggregationConfig(lr=lr, local_steps=local_steps,
                            server_lr=server_lr, align=align)

    fused = transport is not None and mixing is None

    def flush(params, theta, g_global, ctrl, deltas, thetas, weights,
              staleness=None):
        step = None
        if transport is not None:
            b = jax.tree.leaves(weights)[0].shape[0]
            up_bytes = wire_bytes(deltas)
            if align:
                up_bytes += wire_bytes(thetas)
            if wire_cell is not None:
                wire_cell["total"] = up_bytes
                wire_cell["cohort"] = b
        if fused:
            new_params, new_theta, new_g, agg, aux = aggregate_wire(
                params, theta, g_global, deltas, weights, cfg, transport,
                tmsgs=thetas if align else None,
                thetas=None if align else thetas,
                need_thetas=telemetry)
            deltas, thetas, step = None, aux["thetas"], aux["step"]
        else:
            if transport is not None:
                deltas = jax.vmap(transport.delta.decode)(deltas)
                if align:
                    thetas = jax.vmap(transport.theta.decode)(thetas)
            if mixing is not None:
                weights = weights * mixing(deltas, thetas)
            new_params, new_theta, new_g, agg = aggregate(
                params, theta, g_global, deltas, thetas, weights, cfg)
        # drift-adaptive rule, additionally backed off by the staleness of
        # the g_G estimate the next cohort will correct toward
        new_ctrl = update_controller(ctrl, agg["norm_drift"],
                                     agg["freshness"])
        metrics = dict(agg, loss=jnp.zeros(()),  # loss filled by the driver
                       beta=ctrl.beta)
        if telemetry:
            from repro.obs import telemetry as obs_telemetry
            metrics["telemetry"] = obs_telemetry.collect(
                deltas=deltas, step=step, thetas=thetas, weights=weights,
                g_global=g_global, ctrl=ctrl, new_ctrl=new_ctrl,
                agg_metrics=agg, staleness=staleness)
        return new_params, new_theta, new_g, new_ctrl, metrics

    return jax.jit(flush) if jit else flush
