"""Staleness-decay weight functions for buffered-asynchronous aggregation.

A client result that trained from server version v and arrives at version
v' has staleness s = v' - v (>= 0).  Its delta and uploaded Theta are scaled
by w(s) in (0, 1] before aggregation:

  none   w(s) = 1                      (naive async — FedBuff without decay)
  poly   w(s) = 1 / (1 + s)^alpha      (FedBuff / FedAsync polynomial decay)
  hinge  w(s) = 1 if s <= t else 1/(1 + s - t)   (grace window of t versions)
"""
from __future__ import annotations

from typing import Callable


def make_staleness_weight(mode: str = "poly", alpha: float = 0.5,
                          hinge_threshold: int = 2) -> Callable[[float], float]:
    if mode in ("none", "const"):
        return lambda s: 1.0
    if mode == "poly":
        return lambda s: float((1.0 + s) ** -alpha)
    if mode == "hinge":
        t = hinge_threshold
        return lambda s: 1.0 if s <= t else float(1.0 / (1.0 + s - t))
    raise ValueError(f"unknown staleness mode {mode!r} "
                     "(want 'none'|'poly'|'hinge')")
