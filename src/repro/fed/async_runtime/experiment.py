"""``AsyncFederatedExperiment`` — the buffered-asynchronous execution model
for every stateless-client ``AlgorithmSpec`` the registry knows.

Drop-in interchangeable with the synchronous ``FederatedExperiment`` via the
shared ``fed.base.FedExperiment`` interface: one ``run_round()`` is one
buffer flush (one server version).  Per client, local training runs at
*dispatch* under the then-current server snapshot (params, Theta^v, g_G^v)
— semantically the client downloaded version v — and the result is delivered
by the simulated-time scheduler after the client's sampled latency, possibly
several versions later.  Staleness-aware FedPAC then decays each arrival's
delta and Theta by w(s_i) before Alignment/Correction (see buffer.py).

The local update and all algorithm policy (beta pinning, upload codec,
mixing weights, comm accounting) come from the resolved ``AlgorithmSpec`` —
the same spec the sync runtime consumes.  Algorithms that declare lock-step
per-client persistent state (``spec.client_state``, e.g. SCAFFOLD) are
rejected generically: buffered execution has no lock-step state exchange.

The flush and the drift-adaptive beta update both run through the unified
round engine, so the adaptive controller (``ServerState.geom``) is the same
functional state the sync runtime evolves — a checkpoint taken under one
runtime restores under the other.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import init_server, zero_theta
from repro.core.algorithms import (
    AlgorithmSpec, EF_STATE, make_local_update, resolve,
)
from repro.core.client import LocalRunConfig
from repro.core.engine import BETA_MAX_AUTO, advance_server, make_controller
from repro.core.transport import encode_with_feedback
from repro.fed.base import FedExperiment
from repro.fed.rounds import FedConfig, resolve_lr
from repro.fed.staging import stage_client_batches
from repro.fed.async_runtime.buffer import AsyncConfig, make_async_aggregate_fn
from repro.fed.async_runtime.scheduler import SimScheduler
from repro.fed.async_runtime.staleness import make_staleness_weight


class AsyncFederatedExperiment(FedExperiment):
    """Buffered-asynchronous federated runtime (FedBuff execution model)."""

    def __init__(self, fed: FedConfig, params, loss_fn: Callable,
                 client_batch_fn: Callable, eval_fn: Optional[Callable] = None,
                 opt_kwargs: Optional[dict] = None,
                 async_cfg: Optional[AsyncConfig] = None,
                 spec: Optional[AlgorithmSpec] = None,
                 population: Optional[object] = None):
        super().__init__(fed)
        from repro.fed.population import resolve_population
        self.population = resolve_population(fed, population)
        self.acfg = async_cfg or AsyncConfig()
        self.loss_fn = loss_fn
        self.client_batch_fn = client_batch_fn
        self.eval_fn = eval_fn

        self._bind_spec(spec if spec is not None else fed.algorithm,
                        params, opt_kwargs)

        beta = self.spec.resolve_beta(fed.beta)
        ctrl = make_controller(beta, correct=self.spec.correct,
                               beta_max=BETA_MAX_AUTO)
        self._weight_fn = make_staleness_weight(
            self.acfg.staleness_mode, self.acfg.staleness_alpha,
            self.acfg.hinge_threshold)

        self.server = init_server(params, self.opt, geom=ctrl)
        if self.population is not None:
            # participation fractions don't scale to 10^6-id spaces: the
            # in-flight pool sizes from cohort_size (or the explicit knob)
            concurrency = self.acfg.concurrency
            if concurrency is None:
                concurrency = max(self.acfg.buffer_size, fed.cohort_size)
            concurrency = max(1, min(concurrency, self.population.size))
            if self.acfg.buffer_size > concurrency:
                raise ValueError(
                    f"buffer_size={self.acfg.buffer_size} exceeds the "
                    f"population-mode concurrency {concurrency} — raise "
                    "AsyncConfig.concurrency or cohort_size")
        else:
            concurrency = self.acfg.resolve_concurrency(fed.n_clients,
                                                        fed.participation)
        self.scheduler = SimScheduler(self.acfg.latency, fed.n_clients,
                                      concurrency, seed=fed.seed,
                                      population=self.population)
        # batches/keys draw from a separate stream so the simulated event
        # order is invariant to how many batch samples a client consumes.
        self.rng = np.random.default_rng(fed.seed + 1)
        self.total_dropped = 0
        self.total_discarded = 0
        # flushes normally eval; the traffic runtime turns this off when it
        # samples anytime eval on its own simulated-time grid instead
        self._flush_eval = True

    # ------------------------------------------------------------ algorithm

    def _bind_spec(self, spec, params, opt_kwargs: Optional[dict]) -> None:
        """Resolve ``spec`` and (re)build everything derived from it: the
        optimizer, lr, transport, jitted local round, jitted flush, and the
        EF residual machinery.  Called once at construction — and again by
        the continuous-traffic hot-swap, which rebinds a new algorithm
        mid-stream while keeping the server geometry warm."""
        fed = self.fed
        self.spec = resolve(spec)
        if self.spec.client_state is not None:
            raise ValueError(
                f"algorithm {self.spec.name!r} declares lock-step per-client "
                "persistent state, which buffered-asynchronous execution "
                "cannot exchange — use the synchronous runtime")
        self.opt = self.spec.make_optimizer(**(opt_kwargs or {}))
        self.align = self.spec.align
        self.lr = resolve_lr(fed, self.spec)

        run = LocalRunConfig(lr=self.lr, local_steps=fed.local_steps,
                             beta=0.0, hessian_freq=fed.hessian_freq,
                             align=self.align)
        local_fn = make_local_update(self.spec, self.loss_fn, self.opt, run)

        # client-side wire encoding happens inside the jitted local round:
        # the buffer then holds wire messages, not dense trees (a real
        # memory win for compressed codecs on large buffers)
        self.transport = fed.make_transport(self.spec)
        self._ef = self.transport.feedback_active
        align = self.align

        def local(p, theta, g, batches, key, beta_in, residual=None):
            delta, theta_out, _, loss = local_fn(
                p, theta, g, beta=beta_in, view=None, batch_i=batches,
                key_i=key)
            # the decoded tree is discarded: the buffer holds wire form
            # only, and the flush decodes the whole stacked buffer once
            dmsg, _, new_residual = encode_with_feedback(
                self.transport.delta, delta, residual)
            tmsg = (self.transport.theta.encode(theta_out) if align
                    else theta_out)
            return dmsg, tmsg, new_residual, loss

        self._local_fn = jax.jit(local)
        self._wire_cell = {}
        self._flush_fn = make_async_aggregate_fn(
            lr=self.lr, local_steps=fed.local_steps, server_lr=fed.server_lr,
            align=self.align, mixing=self.spec.mixing,
            transport=self.transport, wire_cell=self._wire_cell,
            telemetry=True)
        # EF residuals use the same ClientStateSpec protocol as the sync
        # runtime, driven per dispatch (a client's own state is not
        # lock-step: it reads/writes it when *it* trains).  The scatter is
        # jitted with the stacked state donated so updating one client's
        # row is an in-place dynamic-update-slice, not an O(N x |params|)
        # copy per dispatch.  In population mode the residuals live in the
        # budgeted sparse store (cold rows spill through the checkpoint
        # store) and the jitted gather/scatter address *slots*; the store
        # maps global ids to slots per dispatch.
        self._ef_store = None
        self._ef_state = None
        if self._ef and self.population is not None:
            from repro.fed.population import make_client_store
            self._ef_store = make_client_store(
                EF_STATE, params, fed.population_size,
                budget=fed.resolve_state_budget(), spill_dir=fed.spill_dir)
        elif self._ef:
            self._ef_state = EF_STATE.init(params, fed.n_clients)
        if self._ef:
            self._ef_scatter = jax.jit(
                lambda s, cid, u: EF_STATE.server_update(
                    s, cid[None], jax.tree.map(lambda x: x[None], u), None),
                donate_argnums=0)
            # a discarded (over-stale) arrival never reaches the server:
            # fold its decoded content back into the residual so the
            # components are delayed, not silently lost
            self._ef_restore = jax.jit(
                lambda s, cid, msg: jax.tree.map(
                    lambda a, d: a.at[cid].add(d.astype(jnp.float32)),
                    s, self.transport.delta.decode(msg)),
                donate_argnums=0)
        self._theta0 = zero_theta(self.opt, params) if self.align else None

    # ------------------------------------------------------------ clients

    def _client_payload(self, cid: int):
        """Train client ``cid`` on the current server snapshot (dispatch).

        The payload holds *wire messages* — delta (error-compensated for
        lossy codecs) and, for aligned algorithms, Theta — exactly what
        the client would put on the network.  ``cid`` is a stable global
        id in population mode; its batches and PRNG key derive from
        ``fold_in(seed, cid)`` salted by the client's own dispatch count,
        and its EF residual row is addressed through the sparse store."""
        t = self.tracer
        pop = self.population
        with t.span("staging", client_id=cid, sim_time=self.scheduler.now):
            if pop is not None:
                from repro.fed.population import (
                    stage_client_population_batches,
                )
                salt = self.scheduler.dispatch_salt(cid)
                batches = stage_client_population_batches(
                    self.client_batch_fn, pop, cid, self.fed.local_steps,
                    salt=salt)
                key = pop.client_key(cid, salt=salt)
            else:
                batches = stage_client_batches(self.client_batch_fn, cid,
                                               self.fed.local_steps, self.rng)
                key = jax.random.key(int(self.rng.integers(0, 2**31)))
        theta = self.server.theta if self.server.theta is not None \
            else self._theta0
        slot = cid
        if self._ef_store is not None:
            slot = int(self._ef_store.acquire([cid])[0])
            residual = EF_STATE.client_view(self._ef_store.state, slot)
        elif self._ef:
            residual = EF_STATE.client_view(self._ef_state, cid)
        else:
            residual = None
        with t.span("local_update", client_id=cid,
                    sim_time=self.scheduler.now):
            dmsg, tmsg, new_residual, loss = self._local_fn(
                self.server.params, theta, self.server.g_global, batches, key,
                self.server.geom.beta, residual)
            if t.enabled:
                jax.block_until_ready(loss)
        if self._ef_store is not None:
            self._ef_store.state = self._ef_scatter(
                self._ef_store.state, jnp.asarray(slot), new_residual)
        elif self._ef:
            self._ef_state = self._ef_scatter(
                self._ef_state, jnp.asarray(cid), new_residual)
        return {"delta": dmsg, "theta": tmsg, "loss": loss}

    # ------------------------------------------------------------ loop

    def run_round(self):
        """Collect ``buffer_size`` usable client reports, then flush."""
        acf, sched, t = self.acfg, self.scheduler, self.tracer
        version = self.server.round
        sched.fill(version, self._client_payload)
        buffered, stale, weights = [], [], []
        dropped = discarded = 0
        events_budget = 100 * acf.buffer_size + 100
        while len(buffered) < acf.buffer_size:
            events_budget -= 1
            if events_budget <= 0:
                raise RuntimeError(
                    "buffer starved: dropout/max_staleness reject every "
                    "arrival — loosen AsyncConfig")
            ev = sched.next_completion()
            # replacement trains from the *current* server state
            sched.fill(version, self._client_payload)
            if ev.dropped:
                # a dispatch that never reports back is an explicit trace
                # event, not a silent counter bump
                dropped += 1
                t.client_dropped(ev.client_id, reason="dropout",
                                 version=ev.version, sim_time=ev.time)
                continue
            s = version - ev.version
            if acf.max_staleness is not None and s > acf.max_staleness:
                discarded += 1
                t.client_dropped(ev.client_id, reason="max_staleness",
                                 version=ev.version, sim_time=ev.time)
                self._discard_restore(ev)
                continue
            buffered.append(ev)
            stale.append(s)
            weights.append(self._weight_fn(s))

        return self._flush_buffer(buffered, stale, weights,
                                  dropped=dropped, discarded=discarded)

    def _discard_restore(self, ev) -> None:
        """An arrival whose work will never reach the server (over-stale,
        voided by churn, or orphaned by a hot-swap): restore its decoded
        delta into the client's EF residual so compression error is
        delayed, never lost.  No-op for feedback-free transports."""
        if self._ef_store is not None:
            # re-acquire: the row may have been evicted (and spilled)
            # while this result was in flight
            slot = int(self._ef_store.acquire([ev.client_id])[0])
            self._ef_store.state = self._ef_restore(
                self._ef_store.state, jnp.asarray(slot),
                ev.payload["delta"])
        elif self._ef:
            # the residual was committed at dispatch assuming this upload
            # would be aggregated — fold the discarded components back
            self._ef_state = self._ef_restore(
                self._ef_state, jnp.asarray(ev.client_id),
                ev.payload["delta"])

    def _flush_buffer(self, buffered, stale, weights, *,
                      dropped: int = 0, discarded: int = 0) -> dict:
        """Aggregate a full buffer into one server version: the jitted
        decode-aggregate flush, ``advance_server``, and the round record
        (history + trace).  Shared by the round-shaped loop above and the
        continuous-traffic runtime's policy-driven flushes."""
        sched, t = self.scheduler, self.tracer
        rnum = self.server.round + 1   # the round this flush produces

        with t.span("flush", round=rnum, sim_time=sched.now):
            # stack the buffered wire messages client-axis-first; the jitted
            # flush decodes them right before aggregation
            deltas = jax.tree.map(lambda *xs: jnp.stack(xs),
                                  *[ev.payload["delta"] for ev in buffered])
            thetas = jax.tree.map(lambda *xs: jnp.stack(xs),
                                  *[ev.payload["theta"] for ev in buffered])
            w = jnp.asarray(weights, jnp.float32)
            theta_ref = self.server.theta if self.server.theta is not None \
                else self._theta0
            p, th, g, ctrl, metrics = self._flush_fn(
                self.server.params, theta_ref, self.server.g_global,
                self.server.geom, deltas, thetas, w,
                jnp.asarray(stale, jnp.int32))
            if t.enabled:
                jax.block_until_ready(metrics)
        self.server = advance_server(self.server, p, th if self.align else
                                     None, g, geom=ctrl, aligned=self.align)

        self.total_dropped += dropped
        self.total_discarded += discarded
        tele = metrics.pop("telemetry", None)
        self.last_telemetry = tele
        rec = {k: float(v) for k, v in metrics.items()}
        if "total" in self._wire_cell:
            # trace-time capture: exact host ints, not lossy f32 scalars.
            # upload_bytes stays the per-client figure the history always
            # reported (exact for homogeneous cohorts); the untruncated
            # total and cohort size ride along for heterogeneous audits.
            total = int(self._wire_cell["total"])
            cohort = int(self._wire_cell["cohort"])
            rec["upload_bytes"] = float(total // cohort)
            rec["upload_total_bytes"] = float(total)
            rec["cohort_size"] = float(cohort)
        rec.update({
            "loss": float(np.mean([float(ev.payload["loss"])
                                   for ev in buffered])),
            "staleness": float(np.mean(stale)),
            "max_staleness": float(np.max(stale)),
            "sim_time": float(sched.now),
            "dropped": float(dropped),
            "discarded": float(discarded),
        })
        rec["round"] = self.server.round
        if self._ef_store is not None:
            rec.update(state_resident=self._ef_store.resident,
                       state_peak=self._ef_store.peak_resident,
                       state_spills=self._ef_store.spills,
                       state_restores=self._ef_store.restores)
        if self.eval_fn is not None and self._flush_eval:
            with t.span("eval", round=rnum, sim_time=sched.now):
                rec.update({k: float(v) for k, v in
                            self.eval_fn(self.server.params).items()})
        if t.enabled:
            from repro.obs.telemetry import telemetry_dict
            t.round_event(rec["round"], rec, sim_time=float(sched.now),
                          telemetry=telemetry_dict(tele) if tele is not None
                          else None)
        self.history.append(rec)
        return rec

    # ------------------------------------------------------------ accounting

    def comm_bytes_per_round(self) -> int:
        return self.transport.round_bytes(
            self.server.params,
            self.server.theta if self.spec.align else None)
