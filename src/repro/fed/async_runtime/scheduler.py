"""Event-driven simulated-time scheduler for asynchronous federated rounds.

Clients are dispatched into a bounded in-flight pool (``concurrency``); each
dispatch draws a completion time from the ``LatencyModel`` and is pushed onto
a min-heap keyed by (time, seq).  ``next_completion()`` pops the earliest
event and advances the simulated clock.  Because every draw comes from one
seeded ``np.random.Generator`` and ties break on the monotone dispatch
sequence number, the event order is fully deterministic per seed — the
property the runtime tests pin down.

Population mode (a ``fed.population.ClientPopulation`` passed in): client
ids are stable *global* ids drawn from the abstract id space, never a dense
0..N-1 enumeration.  Per-client randomness derives from the id itself —
persistent speed via ``LatencyModel.client_speed(seed, cid)``, per-dispatch
latency/dropout from ``SeedSequence((seed, tag, cid, dispatch_index))`` —
so one client's realizations are invariant to population size, to who else
is in flight, and to event interleaving.  Only the *selection* of which
idle client to dispatch consumes the shared scheduler generator.  The
legacy dense branch (``population=None``) is byte-identical to before.

The scheduler is payload-agnostic: the experiment attaches whatever the
"client" computed at dispatch time (its trained delta/Theta under the
then-current server state) and reads it back on completion, which is exactly
the semantics of a client downloading version v, training, and reporting
back later.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Optional

import numpy as np

from repro.fed.async_runtime.latency import LatencyModel

# domain-separation tag for per-dispatch latency/dropout streams
_DISPATCH_TAG = 0xD15


@dataclasses.dataclass(order=True)
class Completion:
    """A client report-back event in simulated time."""
    time: float
    seq: int                   # dispatch order; deterministic tie-break
    client_id: int = dataclasses.field(compare=False)
    version: int = dataclasses.field(compare=False)   # server version at dispatch
    dropped: bool = dataclasses.field(compare=False, default=False)
    payload: Any = dataclasses.field(compare=False, default=None)


class SimScheduler:
    """Bounded-concurrency client pool over simulated time."""

    def __init__(self, latency: LatencyModel, n_clients: int,
                 concurrency: int, seed: int = 0, population=None):
        self.population = population
        pool = n_clients if population is None else population.size
        if concurrency > pool:
            raise ValueError(
                f"concurrency {concurrency} exceeds the client pool {pool}")
        self.latency = latency
        self.n_clients = n_clients
        self.concurrency = concurrency
        self.rng = np.random.default_rng(seed)
        self._seed = int(seed)
        if population is None:
            self.speeds = latency.client_speeds(n_clients, self.rng)
        else:
            self.speeds = None               # derived per id, cached sparse
            self._speed_cache: dict = {}
            self._dispatch_counts: dict = {}
        self.now = 0.0
        self._seq = 0
        self._heap: list[Completion] = []
        self._in_flight: set[int] = set()

    # ------------------------------------------------------------ dispatch

    def idle_clients(self) -> np.ndarray:
        if self.population is not None:
            raise RuntimeError(
                "population mode has no dense idle list — idle clients are "
                "rejection-sampled from the id space (fill/sample_dispatch)")
        return np.array([c for c in range(self.n_clients)
                         if c not in self._in_flight])

    def dispatch_salt(self, client_id: int) -> int:
        """The dispatch index of ``client_id``'s in-progress (or most
        recent) dispatch — the salt its payload staging must reuse so a
        client's training stream is tied to (id, dispatch), not to global
        event order."""
        return self._dispatch_counts.get(int(client_id), 1) - 1

    def dispatch(self, client_id: int, version: int,
                 payload_fn: Optional[Callable[[int], Any]] = None):
        """Dispatch one client; its result is due after the sampled latency.

        Dropout is drawn *before* ``payload_fn`` runs so a client fated to
        drop never pays for local training — only its simulated time."""
        if client_id in self._in_flight:
            raise ValueError(f"client {client_id} already in flight")
        if self.population is None:
            lat = self.latency.sample_latency(self.speeds[client_id],
                                              self.rng)
            dropped = self.latency.sample_dropout(self.rng)
        else:
            cid = int(client_id)
            salt = self._dispatch_counts.get(cid, 0)
            self._dispatch_counts[cid] = salt + 1
            speed = self._speed_cache.get(cid)
            if speed is None:
                speed = self.latency.client_speed(self._seed, cid)
                self._speed_cache[cid] = speed
            rng = np.random.default_rng(np.random.SeedSequence(
                (self._seed, _DISPATCH_TAG, cid, salt)))
            lat = self.latency.sample_latency(speed, rng)
            dropped = self.latency.sample_dropout(rng)
        payload = payload_fn(client_id) \
            if (payload_fn is not None and not dropped) else None
        ev = Completion(self.now + lat, self._seq, int(client_id),
                        int(version), dropped, payload)
        self._seq += 1
        self._in_flight.add(int(client_id))
        heapq.heappush(self._heap, ev)
        return ev

    def fill(self, version: int,
             payload_fn: Optional[Callable[[int], Any]] = None):
        """Dispatch uniformly-sampled idle clients until the pool is full."""
        started = []
        while len(self._in_flight) < self.concurrency:
            if self.population is None:
                idle = self.idle_clients()
                cid = int(self.rng.choice(idle))
            else:
                cid = self.population.sample_dispatch(
                    self.rng, exclude=self._in_flight, t=self.now)
            started.append(self.dispatch(cid, version, payload_fn))
        return started

    # ------------------------------------------------------------ completion

    def in_flight(self) -> int:
        return len(self._in_flight)

    def next_completion(self) -> Completion:
        if not self._heap:
            raise RuntimeError("no clients in flight")
        ev = heapq.heappop(self._heap)
        self.now = ev.time
        self._in_flight.discard(ev.client_id)
        return ev
