"""Event-driven simulated-time scheduler for asynchronous federated rounds.

Clients are dispatched into a bounded in-flight pool (``concurrency``); each
dispatch draws a completion time from the ``LatencyModel`` and is pushed onto
a min-heap keyed by (time, seq).  ``next_completion()`` pops the earliest
event and advances the simulated clock.  Because every draw comes from one
seeded ``np.random.Generator`` and ties break on the monotone dispatch
sequence number, the event order is fully deterministic per seed — the
property the runtime tests pin down.

All per-client bookkeeping (persistent speeds, dispatch counts, the
in-flight set) is *sparse* — dicts and sets keyed by global client id, no
``n_clients``-sized arrays — so the id space can grow, shrink, or churn
(clients joining and leaving mid-stream, ``fed.traffic``) without the
scheduler ever enumerating it.  The legacy dense branch
(``population=None``) still draws its persistent speeds in one eager batch
from the shared generator, so its event stream stays byte-identical to the
historical dense-array implementation (golden-tested).

Population mode (a ``fed.population.ClientPopulation`` passed in): client
ids are stable *global* ids drawn from the abstract id space, never a dense
0..N-1 enumeration.  Per-client randomness derives from the id itself —
persistent speed via ``LatencyModel.client_speed(seed, cid)``, per-dispatch
latency/dropout from ``SeedSequence((seed, tag, cid, dispatch_index))`` —
so one client's realizations are invariant to population size, to who else
is in flight, and to event interleaving.  Only the *selection* of which
idle client to dispatch consumes the shared scheduler generator.

The scheduler is payload-agnostic: the experiment attaches whatever the
"client" computed at dispatch time (its trained delta/Theta under the
then-current server state) and reads it back on completion, which is exactly
the semantics of a client downloading version v, training, and reporting
back later.  For churn, an in-flight dispatch can be *voided*
(``void(cid)``): the completion still pops (its simulated time passes) but
``consume_voided`` flags it so the experiment discards the work with a
traced reason instead of aggregating it.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Optional

import numpy as np

from repro.fed.async_runtime.latency import LatencyModel

# domain-separation tag for per-dispatch latency/dropout streams
_DISPATCH_TAG = 0xD15


@dataclasses.dataclass(order=True)
class Completion:
    """A client report-back event in simulated time."""
    time: float
    seq: int                   # dispatch order; deterministic tie-break
    client_id: int = dataclasses.field(compare=False)
    version: int = dataclasses.field(compare=False)   # server version at dispatch
    dropped: bool = dataclasses.field(compare=False, default=False)
    payload: Any = dataclasses.field(compare=False, default=None)


class SimScheduler:
    """Bounded-concurrency client pool over simulated time."""

    def __init__(self, latency: LatencyModel, n_clients: int,
                 concurrency: int, seed: int = 0, population=None):
        self.population = population
        pool = n_clients if population is None else population.size
        if concurrency > pool:
            raise ValueError(
                f"concurrency {concurrency} exceeds the client pool {pool}")
        self.latency = latency
        self.n_clients = n_clients
        self.concurrency = concurrency
        self.rng = np.random.default_rng(seed)
        self._seed = int(seed)
        # sparse per-client bookkeeping, shared by both modes: speeds,
        # dispatch counts, and in-flight membership keyed by global id
        self._speed_of: dict = {}
        self._dispatch_counts: dict = {}
        if population is None:
            # the dense path's persistent speeds are still one eager batched
            # draw from the shared generator (the historical rng stream the
            # golden trace test pins), dict-ified afterwards
            speeds = latency.client_speeds(n_clients, self.rng)
            self._speed_of = {c: float(speeds[c]) for c in range(n_clients)}
        self.now = 0.0
        self._seq = 0
        self._heap: list[Completion] = []
        self._in_flight: set[int] = set()
        self._live_seq: dict = {}      # cid -> seq of its in-flight dispatch
        self._voided: set[int] = set()  # dispatch seqs cancelled by churn

    # ------------------------------------------------------------ dispatch

    def idle_clients(self) -> np.ndarray:
        if self.population is not None:
            raise RuntimeError(
                "population mode has no dense idle list — idle clients are "
                "rejection-sampled from the id space (fill/sample_dispatch)")
        return np.array([c for c in range(self.n_clients)
                         if c not in self._in_flight])

    def dispatch_salt(self, client_id: int) -> int:
        """The dispatch index of ``client_id``'s in-progress (or most
        recent) dispatch — the salt its payload staging must reuse so a
        client's training stream is tied to (id, dispatch), not to global
        event order."""
        return self._dispatch_counts.get(int(client_id), 1) - 1

    def dispatch(self, client_id: int, version: int,
                 payload_fn: Optional[Callable[[int], Any]] = None):
        """Dispatch one client; its result is due after the sampled latency.

        Dropout is drawn *before* ``payload_fn`` runs so a client fated to
        drop never pays for local training — only its simulated time."""
        cid = int(client_id)
        if cid in self._in_flight:
            raise ValueError(f"client {cid} already in flight")
        salt = self._dispatch_counts.get(cid, 0)
        self._dispatch_counts[cid] = salt + 1
        if self.population is None:
            lat = self.latency.sample_latency(self._speed_of[cid], self.rng)
            dropped = self.latency.sample_dropout(self.rng)
        else:
            speed = self._speed_of.get(cid)
            if speed is None:
                speed = self.latency.client_speed(self._seed, cid)
                self._speed_of[cid] = speed
            rng = np.random.default_rng(np.random.SeedSequence(
                (self._seed, _DISPATCH_TAG, cid, salt)))
            lat = self.latency.sample_latency(speed, rng)
            dropped = self.latency.sample_dropout(rng)
        payload = payload_fn(cid) \
            if (payload_fn is not None and not dropped) else None
        ev = Completion(self.now + lat, self._seq, cid,
                        int(version), dropped, payload)
        self._live_seq[cid] = self._seq
        self._seq += 1
        self._in_flight.add(cid)
        heapq.heappush(self._heap, ev)
        return ev

    def dispatch_one(self, version: int,
                     payload_fn: Optional[Callable[[int], Any]] = None):
        """Dispatch one uniformly-sampled idle client (the selection code
        path ``fill`` loops over) — the open-loop arrival hook: one client
        arrives *now*, whoever it turns out to be."""
        if len(self._in_flight) >= self.concurrency:
            raise RuntimeError(
                f"in-flight pool is full ({self.concurrency}) — an arrival "
                "must wait for a completion before it can dispatch")
        if self.population is None:
            idle = self.idle_clients()
            cid = int(self.rng.choice(idle))
        else:
            cid = self.population.sample_dispatch(
                self.rng, exclude=self._in_flight, t=self.now)
        return self.dispatch(cid, version, payload_fn)

    def fill(self, version: int,
             payload_fn: Optional[Callable[[int], Any]] = None):
        """Dispatch uniformly-sampled idle clients until the pool is full."""
        started = []
        while len(self._in_flight) < self.concurrency:
            started.append(self.dispatch_one(version, payload_fn))
        return started

    # ------------------------------------------------------------ completion

    def in_flight(self) -> int:
        return len(self._in_flight)

    def peek_time(self) -> Optional[float]:
        """Simulated time of the earliest pending completion (None when no
        client is in flight) — how the traffic runtime interleaves
        completions with its own control events."""
        return self._heap[0].time if self._heap else None

    def next_completion(self) -> Completion:
        if not self._heap:
            raise RuntimeError("no clients in flight")
        ev = heapq.heappop(self._heap)
        self.now = ev.time
        self._in_flight.discard(ev.client_id)
        if self._live_seq.get(ev.client_id) == ev.seq:
            del self._live_seq[ev.client_id]
        return ev

    # ------------------------------------------------------------ churn

    def void(self, client_id: int) -> Optional[int]:
        """Cancel ``client_id``'s in-flight dispatch (the client left, or
        the algorithm it trained under was swapped out).  The completion
        event stays in the heap — simulated time still passes — but
        ``consume_voided`` will flag it so the caller discards the payload.
        Returns the voided dispatch seq, or None if nothing was in flight."""
        seq = self._live_seq.get(int(client_id))
        if seq is None:
            return None
        self._voided.add(seq)
        return seq

    def consume_voided(self, ev: Completion) -> bool:
        """True iff ``ev`` was voided after dispatch; consumes the mark."""
        if ev.seq in self._voided:
            self._voided.discard(ev.seq)
            return True
        return False

    # --------------------------------------------------------- checkpointing

    def state(self) -> dict:
        """Scalar scheduler state for mid-stream checkpointing.  The heap's
        payload-carrying events are serialized by the experiment (they hold
        device arrays); everything else — the clock, the shared generator,
        and the sparse per-client dicts — round-trips here.  Persistent
        speeds are *not* saved: the dense batch draw replays identically at
        construction and population speeds re-derive from ids."""
        return {
            "now": float(self.now), "seq": int(self._seq),
            "rng": self.rng.bit_generator.state,
            "dispatch_counts": {str(k): int(v)
                                for k, v in self._dispatch_counts.items()},
            "live_seq": {str(k): int(v)
                         for k, v in self._live_seq.items()},
            "voided": sorted(int(s) for s in self._voided),
        }

    def restore_events(self, events) -> None:
        """Re-seat deserialized in-flight ``Completion`` events (the
        payload-carrying half of a checkpoint, saved by the experiment)
        after ``load_state`` has restored the scalar half."""
        self._heap = list(events)
        heapq.heapify(self._heap)
        self._in_flight = {ev.client_id for ev in self._heap}

    def load_state(self, state: dict) -> None:
        self.now = float(state["now"])
        self._seq = int(state["seq"])
        self.rng.bit_generator.state = state["rng"]
        self._dispatch_counts = {int(k): int(v)
                                 for k, v in state["dispatch_counts"].items()}
        self._live_seq = {int(k): int(v)
                          for k, v in state["live_seq"].items()}
        self._voided = set(int(s) for s in state["voided"])
