"""Event-driven simulated-time scheduler for asynchronous federated rounds.

Clients are dispatched into a bounded in-flight pool (``concurrency``); each
dispatch draws a completion time from the ``LatencyModel`` and is pushed onto
a min-heap keyed by (time, seq).  ``next_completion()`` pops the earliest
event and advances the simulated clock.  Because every draw comes from one
seeded ``np.random.Generator`` and ties break on the monotone dispatch
sequence number, the event order is fully deterministic per seed — the
property the runtime tests pin down.

The scheduler is payload-agnostic: the experiment attaches whatever the
"client" computed at dispatch time (its trained delta/Theta under the
then-current server state) and reads it back on completion, which is exactly
the semantics of a client downloading version v, training, and reporting
back later.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Optional

import numpy as np

from repro.fed.async_runtime.latency import LatencyModel


@dataclasses.dataclass(order=True)
class Completion:
    """A client report-back event in simulated time."""
    time: float
    seq: int                   # dispatch order; deterministic tie-break
    client_id: int = dataclasses.field(compare=False)
    version: int = dataclasses.field(compare=False)   # server version at dispatch
    dropped: bool = dataclasses.field(compare=False, default=False)
    payload: Any = dataclasses.field(compare=False, default=None)


class SimScheduler:
    """Bounded-concurrency client pool over simulated time."""

    def __init__(self, latency: LatencyModel, n_clients: int,
                 concurrency: int, seed: int = 0):
        if concurrency > n_clients:
            raise ValueError(
                f"concurrency {concurrency} exceeds n_clients {n_clients}")
        self.latency = latency
        self.n_clients = n_clients
        self.concurrency = concurrency
        self.rng = np.random.default_rng(seed)
        self.speeds = latency.client_speeds(n_clients, self.rng)
        self.now = 0.0
        self._seq = 0
        self._heap: list[Completion] = []
        self._in_flight: set[int] = set()

    # ------------------------------------------------------------ dispatch

    def idle_clients(self) -> np.ndarray:
        return np.array([c for c in range(self.n_clients)
                         if c not in self._in_flight])

    def dispatch(self, client_id: int, version: int,
                 payload_fn: Optional[Callable[[int], Any]] = None):
        """Dispatch one client; its result is due after the sampled latency.

        Dropout is drawn *before* ``payload_fn`` runs so a client fated to
        drop never pays for local training — only its simulated time."""
        if client_id in self._in_flight:
            raise ValueError(f"client {client_id} already in flight")
        lat = self.latency.sample_latency(self.speeds[client_id], self.rng)
        dropped = self.latency.sample_dropout(self.rng)
        payload = payload_fn(client_id) \
            if (payload_fn is not None and not dropped) else None
        ev = Completion(self.now + lat, self._seq, int(client_id),
                        int(version), dropped, payload)
        self._seq += 1
        self._in_flight.add(int(client_id))
        heapq.heappush(self._heap, ev)
        return ev

    def fill(self, version: int,
             payload_fn: Optional[Callable[[int], Any]] = None):
        """Dispatch uniformly-sampled idle clients until the pool is full."""
        started = []
        while len(self._in_flight) < self.concurrency:
            idle = self.idle_clients()
            cid = int(self.rng.choice(idle))
            started.append(self.dispatch(cid, version, payload_fn))
        return started

    # ------------------------------------------------------------ completion

    def in_flight(self) -> int:
        return len(self._in_flight)

    def next_completion(self) -> Completion:
        if not self._heap:
            raise RuntimeError("no clients in flight")
        ev = heapq.heappop(self._heap)
        self.now = ev.time
        self._in_flight.discard(ev.client_id)
        return ev
