"""Client latency / availability models for the simulated-time scheduler.

Two-level heterogeneity, matching production FL traces:
  * persistent per-client speed: each client draws a lognormal multiplier
    with sigma = ``heterogeneity`` once (slow phones stay slow);
  * per-round jitter: every dispatch draws a fresh latency from
    ``distribution`` scaled by the client's speed.
``dropout`` is the probability a dispatched client never reports back (the
simulated wall-clock is still spent).  All draws come from the scheduler's
seeded ``np.random.Generator``, so event order is deterministic per seed.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# domain-separation tag for per-id derived speeds (population mode)
_SPEED_TAG = 0x5BEED


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    distribution: str = "lognormal"   # lognormal | exponential | uniform | pareto
    mean_latency: float = 1.0         # seconds of simulated time
    jitter: float = 0.25              # per-draw spread (sigma / half-width)
    heterogeneity: float = 0.0        # sigma of persistent per-client speed
    dropout: float = 0.0              # P(result never arrives)
    pareto_shape: float = 2.5

    def client_speeds(self, n_clients: int, rng: np.random.Generator):
        """Persistent per-client latency multipliers (1.0 when homogeneous)."""
        if self.heterogeneity <= 0.0:
            return np.ones(n_clients)
        # median-1 lognormal: half the fleet faster, half slower
        return np.exp(rng.normal(0.0, self.heterogeneity, size=n_clients))

    def client_speed(self, seed: int, client_id: int) -> float:
        """One client's persistent speed, derived from its global id alone
        (population mode): ``SeedSequence((seed, tag, client_id))`` — the
        same multiplier whether the id space holds 10^2 or 10^6 clients,
        with no dense speeds array."""
        if self.heterogeneity <= 0.0:
            return 1.0
        rng = np.random.default_rng(
            np.random.SeedSequence((int(seed), _SPEED_TAG, int(client_id))))
        return float(np.exp(rng.normal(0.0, self.heterogeneity)))

    def sample_latency(self, speed: float, rng: np.random.Generator) -> float:
        d = self.distribution
        if d == "lognormal":
            base = self.mean_latency * np.exp(
                rng.normal(0.0, self.jitter) - 0.5 * self.jitter**2)
        elif d == "exponential":
            base = rng.exponential(self.mean_latency)
        elif d == "uniform":
            half = self.jitter * self.mean_latency
            base = rng.uniform(self.mean_latency - half,
                               self.mean_latency + half)
        elif d == "pareto":
            a = self.pareto_shape
            base = self.mean_latency * (a - 1.0) / a * (1.0 + rng.pareto(a))
        else:
            raise ValueError(f"unknown latency distribution {d!r}")
        return float(max(base * speed, 1e-9))

    def sample_dropout(self, rng: np.random.Generator) -> bool:
        return bool(self.dropout > 0.0 and rng.uniform() < self.dropout)
