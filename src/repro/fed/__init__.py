from repro.core.algorithms import (
    AlgorithmSpec, ClientStateSpec, register, registered, resolve,
)
from repro.core.scaffold import ScaffoldState
from repro.fed.base import FedExperiment, make_experiment
from repro.fed.rounds import FedConfig, FederatedExperiment, parse_algorithm
from repro.fed.staging import stage_client_batches, stage_cohort_batches
from repro.fed.async_runtime import (
    AsyncConfig, AsyncFederatedExperiment, LatencyModel,
)
from repro.fed.population import (
    AvailabilitySampler, ClientPopulation, ClientStateStore,
    DenseClientStore, UniformSampler, WeightedSampler, make_client_store,
    make_population, stage_population_batches,
)
from repro.fed.traffic import (
    BurstyRate, ChurnConfig, ConstantRate, DiurnalRate, PiecewiseRate,
    TrafficConfig, TrafficExperiment,
)
