from repro.fed.base import FedExperiment, make_experiment
from repro.fed.rounds import FedConfig, FederatedExperiment, parse_algorithm
from repro.fed.scaffold import make_scaffold_round_fn, ScaffoldState
from repro.fed.staging import stage_client_batches, stage_cohort_batches
from repro.fed.async_runtime import (
    AsyncConfig, AsyncFederatedExperiment, LatencyModel,
)
