from repro.fed.rounds import FedConfig, FederatedExperiment, parse_algorithm
from repro.fed.scaffold import make_scaffold_round_fn, ScaffoldState
