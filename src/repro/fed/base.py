"""Shared federated-experiment interface.

``FedExperiment`` is the runtime-agnostic contract that both the lock-step
synchronous runtime (``fed.rounds.FederatedExperiment``) and the buffered
asynchronous runtime (``fed.async_runtime.AsyncFederatedExperiment``)
implement, so benchmarks and examples can swap execution models without
touching algorithm code.  One ``run_round()`` is one server model update —
a communication round in the sync runtime, a buffer flush in the async one.

The base class owns the config/rounds contract: subclasses call
``super().__init__(fed)`` with any config exposing an integer ``rounds``
attribute (``FedConfig`` in-tree), which also initializes ``history``.
Round logging goes through the single overridable ``log_round`` hook,
which routes through the observability sink protocol (``repro.obs``):
``self.sink`` receives one ``round`` event per logged round, defaulting to
``StdoutRoundSink`` — byte-identical to the legacy print formatting.
``self.tracer`` is the round-trace span recorder (disabled until sinks are
attached via ``repro.obs.attach``).

``make_experiment`` picks the runtime from ``FedConfig.runtime`` — it is
the legacy positional constructor; prefer ``repro.api.build_experiment``.
"""
from __future__ import annotations

import abc
from typing import Optional

from repro.obs.sinks import StdoutRoundSink
from repro.obs.sinks import format_metric as _format_metric
from repro.obs.trace import Tracer


class FedExperiment(abc.ABC):
    """Drives server model updates for any algorithm over client datasets.

    Contract declared here (not ad hoc in subclasses):
      fed      — the experiment config; must expose an int ``rounds``
      history  — list of per-round metric dicts, appended by run_round()
      scenario — the materialized ``repro.scenarios.Scenario`` bundle when
                 the experiment was built from a declarative scenario
                 (``build_experiment(..., scenario=...)``); None otherwise
      sink     — ``repro.obs.Sink`` receiving ``log_round`` round events
                 (default: legacy-bitwise stdout formatting)
      tracer   — ``repro.obs.Tracer`` for span/round/drop trace events;
                 disabled (no sinks) unless ``repro.obs.attach``-ed
      last_telemetry — the most recent jit-pure ``Telemetry`` pytree
                 (None before the first round)
    """

    fed: "FedConfig"     # noqa: F821 — any config with an int .rounds
    history: list
    scenario = None      # set by repro.api.build_experiment

    def __init__(self, fed):
        rounds = getattr(fed, "rounds", None)
        if not isinstance(rounds, int) or isinstance(rounds, bool):
            raise TypeError(
                "FedExperiment config must expose an integer 'rounds' "
                f"attribute (got {type(fed).__name__} with "
                f"rounds={rounds!r}) — pass a FedConfig or a compatible "
                "config object")
        self.fed = fed
        self.history = []
        self.sink = StdoutRoundSink()
        self.tracer = Tracer()       # disabled until obs.attach()
        self.last_telemetry = None

    @abc.abstractmethod
    def run_round(self) -> dict:
        """Advance the server by one model update; returns the metrics row."""

    @abc.abstractmethod
    def comm_bytes_per_round(self) -> int:
        """Per-client upload bytes for one round (Table 6 accounting)."""

    # 4-decimal rounding for floats; everything else (ints, None, strings,
    # arrays from custom eval fns) passes through untouched.
    format_metric = staticmethod(_format_metric)

    def log_round(self, rec: dict, r: int) -> None:
        """Per-round logging hook; routes through ``self.sink`` (override
        either this hook or the sink to redirect metrics).  The emitted
        event mirrors the tracer's ``round`` events minus the trace-stream
        sequencing (logging and tracing are independent channels)."""
        self.sink.emit({"event": "round", "run_id": self.tracer.run_id,
                        "round": r, "metrics": rec})

    def run(self, rounds: Optional[int] = None, log_every: int = 0):
        """Run ``rounds`` model updates (default: ``self.fed.rounds``)."""
        for r in range(rounds if rounds is not None else self.fed.rounds):
            rec = self.run_round()
            if log_every and (r % log_every == 0):
                self.log_round(rec, r)
        return self.history


def make_experiment(fed, params, loss_fn, client_batch_fn, eval_fn=None,
                    opt_kwargs=None, async_cfg=None) -> FedExperiment:
    """Instantiate the runtime named by ``fed.runtime`` ("sync" | "async").

    Legacy positional entry point; ``repro.api.build_experiment`` is the
    keyword builder that also accepts ``AlgorithmSpec`` values directly.
    """
    if fed.runtime == "sync":
        if async_cfg is not None:
            raise ValueError(
                "async_cfg given but fed.runtime='sync' — set "
                "FedConfig(runtime='async') or drop the async_cfg")
        from repro.fed.rounds import FederatedExperiment
        return FederatedExperiment(fed, params, loss_fn, client_batch_fn,
                                   eval_fn, opt_kwargs)
    if fed.runtime == "async":
        from repro.fed.async_runtime import AsyncFederatedExperiment
        return AsyncFederatedExperiment(fed, params, loss_fn, client_batch_fn,
                                        eval_fn, opt_kwargs,
                                        async_cfg=async_cfg)
    raise ValueError(f"unknown runtime {fed.runtime!r} (want 'sync'|'async')")
