"""Shared federated-experiment interface.

``FedExperiment`` is the runtime-agnostic contract that both the lock-step
synchronous runtime (``fed.rounds.FederatedExperiment``) and the buffered
asynchronous runtime (``fed.async_runtime.AsyncFederatedExperiment``)
implement, so benchmarks and examples can swap execution models without
touching algorithm code.  One ``run_round()`` is one server model update —
a communication round in the sync runtime, a buffer flush in the async one.

The base class owns the config/rounds contract: subclasses call
``super().__init__(fed)`` with any config exposing an integer ``rounds``
attribute (``FedConfig`` in-tree), which also initializes ``history``.
Round logging goes through the single overridable ``log_round`` hook.

``make_experiment`` picks the runtime from ``FedConfig.runtime`` — it is
the legacy positional constructor; prefer ``repro.api.build_experiment``.
"""
from __future__ import annotations

import abc
from typing import Optional


class FedExperiment(abc.ABC):
    """Drives server model updates for any algorithm over client datasets.

    Contract declared here (not ad hoc in subclasses):
      fed      — the experiment config; must expose an int ``rounds``
      history  — list of per-round metric dicts, appended by run_round()
      scenario — the materialized ``repro.scenarios.Scenario`` bundle when
                 the experiment was built from a declarative scenario
                 (``build_experiment(..., scenario=...)``); None otherwise
    """

    fed: "FedConfig"     # noqa: F821 — any config with an int .rounds
    history: list
    scenario = None      # set by repro.api.build_experiment

    def __init__(self, fed):
        rounds = getattr(fed, "rounds", None)
        if not isinstance(rounds, int) or isinstance(rounds, bool):
            raise TypeError(
                "FedExperiment config must expose an integer 'rounds' "
                f"attribute (got {type(fed).__name__} with "
                f"rounds={rounds!r}) — pass a FedConfig or a compatible "
                "config object")
        self.fed = fed
        self.history = []

    @abc.abstractmethod
    def run_round(self) -> dict:
        """Advance the server by one model update; returns the metrics row."""

    @abc.abstractmethod
    def comm_bytes_per_round(self) -> int:
        """Per-client upload bytes for one round (Table 6 accounting)."""

    @staticmethod
    def format_metric(v):
        """4-decimal rounding for floats; everything else (ints, None,
        strings, arrays from custom eval fns) passes through untouched."""
        try:
            return round(v, 4)
        except TypeError:
            return v

    def log_round(self, rec: dict, r: int) -> None:
        """Per-round logging hook; override to route metrics elsewhere."""
        print({k: self.format_metric(v) for k, v in rec.items()})

    def run(self, rounds: Optional[int] = None, log_every: int = 0):
        """Run ``rounds`` model updates (default: ``self.fed.rounds``)."""
        for r in range(rounds if rounds is not None else self.fed.rounds):
            rec = self.run_round()
            if log_every and (r % log_every == 0):
                self.log_round(rec, r)
        return self.history


def make_experiment(fed, params, loss_fn, client_batch_fn, eval_fn=None,
                    opt_kwargs=None, async_cfg=None) -> FedExperiment:
    """Instantiate the runtime named by ``fed.runtime`` ("sync" | "async").

    Legacy positional entry point; ``repro.api.build_experiment`` is the
    keyword builder that also accepts ``AlgorithmSpec`` values directly.
    """
    if fed.runtime == "sync":
        if async_cfg is not None:
            raise ValueError(
                "async_cfg given but fed.runtime='sync' — set "
                "FedConfig(runtime='async') or drop the async_cfg")
        from repro.fed.rounds import FederatedExperiment
        return FederatedExperiment(fed, params, loss_fn, client_batch_fn,
                                   eval_fn, opt_kwargs)
    if fed.runtime == "async":
        from repro.fed.async_runtime import AsyncFederatedExperiment
        return AsyncFederatedExperiment(fed, params, loss_fn, client_batch_fn,
                                        eval_fn, opt_kwargs,
                                        async_cfg=async_cfg)
    raise ValueError(f"unknown runtime {fed.runtime!r} (want 'sync'|'async')")
