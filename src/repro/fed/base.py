"""Shared federated-experiment interface.

``FedExperiment`` is the runtime-agnostic contract that both the lock-step
synchronous runtime (``fed.rounds.FederatedExperiment``) and the buffered
asynchronous runtime (``fed.async_runtime.AsyncFederatedExperiment``)
implement, so benchmarks and examples can swap execution models without
touching algorithm code.  One ``run_round()`` is one server model update —
a communication round in the sync runtime, a buffer flush in the async one.

``make_experiment`` picks the runtime from ``FedConfig.runtime``.
"""
from __future__ import annotations

import abc
from typing import Optional


class FedExperiment(abc.ABC):
    """Drives server model updates for any algorithm over client datasets."""

    history: list

    @abc.abstractmethod
    def run_round(self) -> dict:
        """Advance the server by one model update; returns the metrics row."""

    @abc.abstractmethod
    def comm_bytes_per_round(self) -> int:
        """Per-client upload bytes for one round (Table 6 accounting)."""

    def run(self, rounds: Optional[int] = None, log_every: int = 0):
        for r in range(rounds if rounds is not None else self.fed.rounds):
            rec = self.run_round()
            if log_every and (r % log_every == 0):
                print({k: round(v, 4) for k, v in rec.items()})
        return self.history


def make_experiment(fed, params, loss_fn, client_batch_fn, eval_fn=None,
                    opt_kwargs=None, async_cfg=None) -> FedExperiment:
    """Instantiate the runtime named by ``fed.runtime`` ("sync" | "async")."""
    if fed.runtime == "sync":
        if async_cfg is not None:
            raise ValueError(
                "async_cfg given but fed.runtime='sync' — set "
                "FedConfig(runtime='async') or drop the async_cfg")
        from repro.fed.rounds import FederatedExperiment
        return FederatedExperiment(fed, params, loss_fn, client_batch_fn,
                                   eval_fn, opt_kwargs)
    if fed.runtime == "async":
        from repro.fed.async_runtime import AsyncFederatedExperiment
        return AsyncFederatedExperiment(fed, params, loss_fn, client_batch_fn,
                                        eval_fn, opt_kwargs,
                                        async_cfg=async_cfg)
    raise ValueError(f"unknown runtime {fed.runtime!r} (want 'sync'|'async')")
