"""Sparse client-state store: the ``ClientStateSpec`` protocol, lazily.

The engine keeps per-client persistent state *stacked* with a leading
client axis so cohorts gather/scatter it inside jit.  At population scale
that axis cannot be the population: a million SCAFFOLD variates would dwarf
the model.  The store keeps the stacked axis sized to a fixed ``budget`` of
*slots* and maintains the client-id -> slot mapping host-side:

* a client's state **materializes on first selection** (fresh rows are the
  spec's zero-init),
* hot clients stay resident (LRU on every selection),
* cold entries **spill** to the checkpoint store (``save_pytree`` /
  ``load_pytree`` — atomic .npz with exact dtypes, bf16 included) and are
  restored bit-exactly when the client is drawn again.

``acquire(cohort_ids)`` returns the cohort's *slot* indices — what the
round_fn scatters by — after evicting/restoring as needed.  Numerics are
untouched: gather/scatter by slot never mixes rows, fresh rows equal the
dense path's zero-init, and a spill→restore round-trip is byte-identical
(the bitwise sparse-vs-dense tests pin this for SCAFFOLD + error-feedback
composition on both runtimes).

Algorithm semantics stay population-true: ``server_update`` still receives
``n_clients = population_size`` (SCAFFOLD's ``S/N`` uses the real N), and
shared globals (``c_global``) live resident in the stacked state — only
private rows (declared via ``ClientStateSpec.client_export/client_import``)
travel to disk.

Streaming extensions (the chunk pipeline, ``fed.pipeline``)
-----------------------------------------------------------

``acquire(ids, defer_restore=True)`` assigns slots but *defers* row
materialization: the missing clients park in a pending set the caller
drains chunk-by-chunk with ``collect_pending`` (one batched host buffer
per chunk — fresh rows broadcast-filled, restored rows grafted in place).
Around it:

* evictions within one acquire batch into a single *group* .npz (one
  batched export gather + one file) written **behind** the round by the
  store's I/O workers (``enable_async_io``) — the synchronous per-client
  save leaves the critical path;
* ``prefetch(ids)`` warms upcoming chunks' spill archives into a host
  cache from the same workers;
* rows whose group save is still in flight restore straight from the
  in-memory export (never from a half-written file), so the spill →
  restore round-trip stays byte-identical.

The classic eager ``acquire`` path is untouched — serial rounds keep
their exact per-client spill/restore behavior and file layout.
"""
from __future__ import annotations

import os
import tempfile
import threading
from collections import OrderedDict
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint.store import load_pytree, save_pytree
from repro.core.algorithms import (
    ClientStateSpec, state_export, state_import, state_import_many,
)


class DenseClientStore:
    """Budget covers the whole population: slots are client ids, no
    spilling.  The legacy dense-list behavior as a store — and the golden
    reference the sparse store is tested bitwise against."""

    def __init__(self, proto: ClientStateSpec, params, population_size: int):
        self.proto = proto
        self.budget = int(population_size)
        self.population_size = int(population_size)
        self.state = proto.init(params, population_size)
        # zero-init template: what evict_client resets a departed row to
        self._fresh = state_export(proto, proto.init(params, 1), 0)
        self.spills = 0
        self.restores = 0
        self._touched: set = set()

    @property
    def resident(self) -> int:
        return len(self._touched)

    @property
    def peak_resident(self) -> int:
        return len(self._touched)

    def acquire(self, ids, defer_restore: bool = False) -> np.ndarray:
        del defer_restore      # every row is always resident: nothing pends
        ids = np.asarray(ids, np.int64)
        self._touched.update(int(c) for c in ids)
        return ids

    # streaming no-ops: the dense store has nothing to restore or spill
    def enable_async_io(self, workers: int = 2):
        return self

    def prefetch(self, ids) -> None:
        pass

    def collect_pending(self, ids):
        return None

    def flush_io(self) -> None:
        pass

    def evict_client(self, cid: int) -> bool:
        """Churn departure: forget ``cid``'s persistent state.  Dense slots
        are client ids, so the row is reset to the spec's zero-init — a
        rejoining client starts fresh, exactly like a never-seen one."""
        cid = int(cid)
        if cid not in self._touched:
            return False
        self._touched.discard(cid)
        self.state = state_import(self.proto, self.state, cid, self._fresh)
        return True


class _Done:
    """Resolved-future stand-in for the synchronous (no-worker) I/O path."""

    def __init__(self, value=None):
        self._value = value

    def result(self):
        return self._value


class ClientStateStore:
    """LRU-budgeted sparse store over a ``budget``-slot stacked state."""

    def __init__(self, proto: ClientStateSpec, params, population_size: int,
                 budget: int, spill_dir: Optional[str] = None):
        if budget < 1:
            raise ValueError(f"state budget must be >= 1, got {budget}")
        if budget > population_size:
            raise ValueError(
                f"state budget {budget} exceeds population {population_size}"
                " (use DenseClientStore / make_client_store)")
        self.proto = proto
        self.budget = int(budget)
        self.population_size = int(population_size)
        self.state = proto.init(params, budget)
        # the zero-init row: scatter target for first-time clients and the
        # load_pytree shape/dtype template for restores
        self._fresh = state_export(proto, proto.init(params, 1), 0)
        if spill_dir is None:
            spill_dir = tempfile.mkdtemp(prefix="repro_client_spill_")
        self.spill_dir = spill_dir
        os.makedirs(self.spill_dir, exist_ok=True)
        self._slot_of: "OrderedDict[int, int]" = OrderedDict()  # LRU order
        self._free = list(range(budget - 1, -1, -1))
        self._spilled: set = set()          # per-client .npz (eager path)
        self.spills = 0
        self.restores = 0
        self.peak_resident = 0
        # ---- streaming state (deferred acquire / write-behind groups)
        self._io = None                     # ThreadPoolExecutor when enabled
        self._io_lock = threading.Lock()
        self._pending: "OrderedDict[int, int]" = OrderedDict()  # cid -> slot
        self._group_of: dict = {}           # cid -> (path, row index)
        self._group_live: dict = {}         # path -> set of unrestored cids
        self._group_rows: dict = {}         # path -> row count (template)
        self._inflight: dict = {}           # cid -> (path, stacked rows, idx)
        self._save_futs: dict = {}          # path -> save future
        self._archive_futs: dict = {}       # path -> prefetch-load future
        self._archive_cache: dict = {}      # path -> host row-stack tree
        self._row_futs: dict = {}           # cid -> per-client load future
        self._cleanup_futs: list = []
        self._group_seq = 0
        self._fresh_host = None             # lazy np view of self._fresh

    # ------------------------------------------------------------- plumbing

    @property
    def resident(self) -> int:
        return len(self._slot_of)

    def _spill_path(self, cid: int) -> str:
        return os.path.join(self.spill_dir, f"client_{cid:012d}.npz")

    def _evict_one(self, protected: set) -> int:
        """Spill the least-recently-used client not in the incoming cohort;
        returns its freed slot."""
        for cid in self._slot_of:          # OrderedDict: LRU first
            if cid not in protected:
                slot = self._slot_of.pop(cid)
                save_pytree(state_export(self.proto, self.state, slot),
                            self._spill_path(cid))
                self._spilled.add(cid)
                self.spills += 1
                return slot
        raise RuntimeError(
            f"cannot evict: all {self.budget} resident clients are in the "
            "incoming cohort (state budget must be >= cohort size)")

    # -------------------------------------------------------------- acquire

    def acquire(self, ids, defer_restore: bool = False) -> np.ndarray:
        """Slot indices for a cohort of global client ids, materializing/
        restoring rows as needed.  The round_fn gathers views and scatters
        updates by these slots; the mapping persists until eviction.

        ``defer_restore=True`` (the chunk pipeline) assigns slots without
        touching ``self.state``: missing rows pend until the caller drains
        them chunk-wise with ``collect_pending`` and grafts them itself;
        evictions batch into one write-behind group spill."""
        ids = np.asarray(ids, np.int64)
        if len(ids) > self.budget:
            raise ValueError(
                f"cohort of {len(ids)} exceeds the state budget "
                f"{self.budget}: every cohort member needs a resident slot")
        incoming = {int(c) for c in ids}
        if len(incoming) != len(ids):
            raise ValueError("acquire wants distinct client ids")
        if defer_restore:
            return self._acquire_deferred(ids, incoming)
        slots = np.empty(len(ids), np.int64)
        # two-pass: collect every missing client's (slot, row), then graft
        # them in ONE batched scatter — per-client functional .at[].set
        # would copy the whole budget-sized state once per miss
        # (O(cohort x budget) per acquire).  Evictions during collection
        # only ever export previous residents (incoming ids are protected),
        # whose rows in self.state are untouched until the final scatter.
        miss_slots, miss_rows = [], []
        for i, cid in enumerate(int(c) for c in ids):
            if cid in self._slot_of:
                self._slot_of.move_to_end(cid)      # touch
                slots[i] = self._slot_of[cid]
                continue
            slot = self._free.pop() if self._free else \
                self._evict_one(incoming)
            if cid in self._spilled:
                row = load_pytree(self._fresh, self._spill_path(cid))
                self._spilled.discard(cid)
                os.unlink(self._spill_path(cid))
                self.restores += 1
            elif cid in self._group_of:
                # spilled by a pipelined round's group file: restore from
                # the archive (or the still-in-flight in-memory export)
                row = self._row_from_group(cid)
                self.restores += 1
            else:
                row = self._fresh               # first selection: zero-init
            miss_slots.append(slot)
            miss_rows.append(row)
            self._slot_of[cid] = slot
            slots[i] = slot
        if miss_slots:
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *miss_rows)
            self.state = state_import_many(
                self.proto, self.state, np.asarray(miss_slots, np.int64),
                stacked)
        self.peak_resident = max(self.peak_resident, len(self._slot_of))
        return slots

    # ----------------------------------------------- streaming: deferred

    def enable_async_io(self, workers: int = 2):
        """Run spill writes and restore reads on background threads.
        Without this every streaming I/O hook runs synchronously (correct,
        just not overlapped)."""
        if self._io is None and workers > 0:
            from concurrent.futures import ThreadPoolExecutor
            self._io = ThreadPoolExecutor(
                max_workers=int(workers),
                thread_name_prefix="repro-state-io")
        return self

    def _submit(self, fn, *args):
        if self._io is None:
            return _Done(fn(*args))
        return self._io.submit(fn, *args)

    def _acquire_deferred(self, ids, incoming) -> np.ndarray:
        if self._pending:
            raise RuntimeError(
                "acquire(defer_restore=True) with rows still pending — "
                "drain the previous cohort with collect_pending first")
        slots = np.empty(len(ids), np.int64)
        missing = []                        # (position, cid)
        for i, cid in enumerate(int(c) for c in ids):
            if cid in self._slot_of:
                self._slot_of.move_to_end(cid)      # touch
                slots[i] = self._slot_of[cid]
            else:
                missing.append((i, cid))
        evicted = []                        # (cid, slot) this acquire spills
        for i, cid in missing:
            if self._free:
                slot = self._free.pop()
            else:
                vcid, slot = self._evict_candidate(incoming)
                evicted.append((vcid, slot))
            self._slot_of[cid] = slot
            self._pending[cid] = slot
            slots[i] = slot
        if evicted:
            self._spill_group(evicted)
        self.peak_resident = max(self.peak_resident, len(self._slot_of))
        return slots

    def _evict_candidate(self, protected: set):
        """Pop the LRU resident not in the incoming cohort (no I/O here —
        the caller batches the group spill)."""
        for cid in self._slot_of:
            if cid not in protected:
                return cid, self._slot_of.pop(cid)
        raise RuntimeError(
            f"cannot evict: all {self.budget} resident clients are in the "
            "incoming cohort (state budget must be >= cohort size)")

    def _spill_group(self, evicted) -> None:
        """One batched export of every slot this acquire evicts + one
        write-behind .npz for the whole group."""
        cids = [c for c, _ in evicted]
        slots = jnp.asarray(np.asarray([s for _, s in evicted], np.int64))
        # one batched gather instead of per-client state_export slices
        rows = jax.vmap(
            lambda s: state_export(self.proto, self.state, s))(slots)
        path = os.path.join(self.spill_dir,
                            f"group_{self._group_seq:08d}.npz")
        self._group_seq += 1
        self._group_live[path] = set(cids)
        self._group_rows[path] = len(cids)
        with self._io_lock:
            for idx, cid in enumerate(cids):
                self._group_of[cid] = (path, idx)
                self._inflight[cid] = (path, rows, idx)
        self.spills += len(cids)

        def _save():
            host = jax.tree.map(np.asarray, rows)
            save_pytree(host, path)
            with self._io_lock:
                for cid in cids:
                    entry = self._inflight.get(cid)
                    if entry is not None and entry[0] == path:
                        del self._inflight[cid]

        self._save_futs[path] = self._submit(_save)

    def _group_template(self, path: str):
        k = self._group_rows[path]
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((k, *np.shape(x)),
                                           jnp.dtype(x.dtype)), self._fresh)

    def _load_group(self, path: str):
        return jax.tree.map(np.asarray,
                            load_pytree(self._group_template(path), path))

    def _archive(self, path: str):
        """The host row-stack of a group file, from the prefetch cache or a
        synchronous load (waiting out an in-flight save first)."""
        fut = self._archive_futs.pop(path, None)
        if fut is not None:
            self._archive_cache[path] = fut.result()
        arch = self._archive_cache.get(path)
        if arch is None:
            save_fut = self._save_futs.get(path)
            if save_fut is not None:
                save_fut.result()
            arch = self._load_group(path)
            self._archive_cache[path] = arch
        return arch

    def _row_from_group(self, cid: int):
        """One client's spilled row out of its group (in-flight export,
        prefetched archive, or a synchronous file read)."""
        path, idx = self._group_of.pop(cid)
        with self._io_lock:
            entry = self._inflight.pop(cid, None)
        if entry is not None and entry[0] == path:
            row = jax.tree.map(lambda x: np.asarray(x[idx]), entry[1])
        else:
            row = jax.tree.map(lambda x: x[idx], self._archive(path))
        live = self._group_live[path]
        live.discard(cid)
        if not live:
            self._drop_group(path)
        return row

    def _drop_group(self, path: str) -> None:
        """Every row of the group restored (or re-spilled elsewhere): delete
        the file once its write has finished."""
        self._group_live.pop(path, None)
        self._group_rows.pop(path, None)
        self._archive_cache.pop(path, None)
        self._archive_futs.pop(path, None)
        save_fut = self._save_futs.pop(path, None)

        def _rm():
            if save_fut is not None:
                save_fut.result()
            try:
                os.unlink(path)
            except FileNotFoundError:
                pass

        self._cleanup_futs.append(self._submit(_rm))

    def prefetch(self, ids) -> None:
        """Warm the restore path for an upcoming chunk: group archives (and
        legacy per-client spills) load into the host cache on the I/O
        workers while the current chunk computes."""
        paths = set()
        for cid in (int(c) for c in np.asarray(ids).ravel()):
            if cid not in self._pending:
                continue
            if cid in self._group_of:
                path = self._group_of[cid][0]
                with self._io_lock:
                    in_mem = cid in self._inflight
                if not in_mem and path not in self._archive_cache \
                        and path not in self._archive_futs:
                    paths.add(path)
            elif cid in self._spilled and cid not in self._row_futs:
                self._row_futs[cid] = self._submit(
                    load_pytree, self._fresh, self._spill_path(cid))
        for path in paths:
            save_fut = self._save_futs.get(path)

            def _load(path=path, save_fut=save_fut):
                if save_fut is not None:
                    save_fut.result()      # never read a half-written file
                return self._load_group(path)

            self._archive_futs[path] = self._submit(_load)

    def collect_pending(self, ids):
        """Drain this chunk's pending rows: returns ``(slots, rows)`` —
        stacked host rows aligned with the slot array, fresh rows
        broadcast-filled — or None when every chunk member was already
        resident.  The caller grafts them with ``state_import_many`` and
        owns the resulting state (the store's ``self.state`` is not
        touched)."""
        sel = [int(c) for c in np.asarray(ids).ravel()
               if int(c) in self._pending]
        if not sel:
            return None
        slots = np.asarray([self._pending.pop(c) for c in sel], np.int64)
        if self._fresh_host is None:
            self._fresh_host = jax.tree.map(np.asarray, self._fresh)
        k = len(sel)
        bufs = jax.tree.map(
            lambda f: np.empty((k, *f.shape), f.dtype), self._fresh_host)
        fresh_pos = []
        for i, cid in enumerate(sel):
            if cid in self._group_of:
                row = self._row_from_group(cid)
                self.restores += 1
            elif cid in self._spilled:
                fut = self._row_futs.pop(cid, None)
                row = (fut.result() if fut is not None else
                       load_pytree(self._fresh, self._spill_path(cid)))
                self._spilled.discard(cid)
                os.unlink(self._spill_path(cid))
                self.restores += 1
            else:
                fresh_pos.append(i)         # zero-init: broadcast below
                continue
            jax.tree.map(
                lambda b, r: b.__setitem__(i, np.asarray(r)), bufs, row)
        if fresh_pos:
            pos = np.asarray(fresh_pos, np.int64)
            # ONE broadcast assignment per leaf — never k stacked copies
            # of the fresh row
            jax.tree.map(
                lambda b, f: b.__setitem__(pos, f), bufs, self._fresh_host)
        return slots, bufs

    def flush_io(self) -> None:
        """Block until every write-behind spill (and queued cleanup) has
        hit disk — checkpoint/shutdown barrier."""
        for fut in list(self._save_futs.values()):
            fut.result()
        for fut in self._cleanup_futs:
            fut.result()
        self._cleanup_futs = []

    # ----------------------------------------------------------------- churn

    def evict_client(self, cid: int) -> bool:
        """Churn departure: drop ``cid``'s persistent state wherever it
        lives — resident slot (freed; the stale row is only ever overwritten
        by the next acquire's graft), per-client spill file (unlinked), or
        group archive row (unlinked from the group, which is deleted once
        empty).  Returns whether the client had any state to forget."""
        cid = int(cid)
        if cid in self._pending:
            raise RuntimeError(
                f"evict_client({cid}) with its deferred acquire still "
                "pending — drain collect_pending first")
        had = False
        if cid in self._slot_of:
            self._free.append(self._slot_of.pop(cid))
            had = True
        if cid in self._spilled:
            self._spilled.discard(cid)
            fut = self._row_futs.pop(cid, None)
            if fut is not None:
                fut.result()
            try:
                os.unlink(self._spill_path(cid))
            except FileNotFoundError:
                pass
            had = True
        if cid in self._group_of:
            path, _ = self._group_of.pop(cid)
            with self._io_lock:
                self._inflight.pop(cid, None)
            live = self._group_live.get(path)
            if live is not None:
                live.discard(cid)
                if not live:
                    self._drop_group(path)
            had = True
        return had


def make_client_store(proto: Optional[ClientStateSpec], params,
                      population_size: int, budget: Optional[int] = None,
                      spill_dir: Optional[str] = None):
    """The store a run needs: ``None`` for stateless algorithms, dense when
    the budget covers the population (no spill machinery in the loop),
    sparse-LRU otherwise."""
    if proto is None:
        return None
    if budget is None or budget >= population_size:
        return DenseClientStore(proto, params, population_size)
    return ClientStateStore(proto, params, population_size, budget,
                            spill_dir=spill_dir)
