"""Sparse client-state store: the ``ClientStateSpec`` protocol, lazily.

The engine keeps per-client persistent state *stacked* with a leading
client axis so cohorts gather/scatter it inside jit.  At population scale
that axis cannot be the population: a million SCAFFOLD variates would dwarf
the model.  The store keeps the stacked axis sized to a fixed ``budget`` of
*slots* and maintains the client-id -> slot mapping host-side:

* a client's state **materializes on first selection** (fresh rows are the
  spec's zero-init),
* hot clients stay resident (LRU on every selection),
* cold entries **spill** to the checkpoint store (``save_pytree`` /
  ``load_pytree`` — atomic .npz with exact dtypes, bf16 included) and are
  restored bit-exactly when the client is drawn again.

``acquire(cohort_ids)`` returns the cohort's *slot* indices — what the
round_fn scatters by — after evicting/restoring as needed.  Numerics are
untouched: gather/scatter by slot never mixes rows, fresh rows equal the
dense path's zero-init, and a spill→restore round-trip is byte-identical
(the bitwise sparse-vs-dense tests pin this for SCAFFOLD + error-feedback
composition on both runtimes).

Algorithm semantics stay population-true: ``server_update`` still receives
``n_clients = population_size`` (SCAFFOLD's ``S/N`` uses the real N), and
shared globals (``c_global``) live resident in the stacked state — only
private rows (declared via ``ClientStateSpec.client_export/client_import``)
travel to disk.
"""
from __future__ import annotations

import os
import tempfile
from collections import OrderedDict
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint.store import load_pytree, save_pytree
from repro.core.algorithms import (
    ClientStateSpec, state_export, state_import_many,
)


class DenseClientStore:
    """Budget covers the whole population: slots are client ids, no
    spilling.  The legacy dense-list behavior as a store — and the golden
    reference the sparse store is tested bitwise against."""

    def __init__(self, proto: ClientStateSpec, params, population_size: int):
        self.proto = proto
        self.budget = int(population_size)
        self.population_size = int(population_size)
        self.state = proto.init(params, population_size)
        self.spills = 0
        self.restores = 0
        self._touched: set = set()

    @property
    def resident(self) -> int:
        return len(self._touched)

    @property
    def peak_resident(self) -> int:
        return len(self._touched)

    def acquire(self, ids) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        self._touched.update(int(c) for c in ids)
        return ids


class ClientStateStore:
    """LRU-budgeted sparse store over a ``budget``-slot stacked state."""

    def __init__(self, proto: ClientStateSpec, params, population_size: int,
                 budget: int, spill_dir: Optional[str] = None):
        if budget < 1:
            raise ValueError(f"state budget must be >= 1, got {budget}")
        if budget > population_size:
            raise ValueError(
                f"state budget {budget} exceeds population {population_size}"
                " (use DenseClientStore / make_client_store)")
        self.proto = proto
        self.budget = int(budget)
        self.population_size = int(population_size)
        self.state = proto.init(params, budget)
        # the zero-init row: scatter target for first-time clients and the
        # load_pytree shape/dtype template for restores
        self._fresh = state_export(proto, proto.init(params, 1), 0)
        if spill_dir is None:
            spill_dir = tempfile.mkdtemp(prefix="repro_client_spill_")
        self.spill_dir = spill_dir
        os.makedirs(self.spill_dir, exist_ok=True)
        self._slot_of: "OrderedDict[int, int]" = OrderedDict()  # LRU order
        self._free = list(range(budget - 1, -1, -1))
        self._spilled: set = set()
        self.spills = 0
        self.restores = 0
        self.peak_resident = 0

    # ------------------------------------------------------------- plumbing

    @property
    def resident(self) -> int:
        return len(self._slot_of)

    def _spill_path(self, cid: int) -> str:
        return os.path.join(self.spill_dir, f"client_{cid:012d}.npz")

    def _evict_one(self, protected: set) -> int:
        """Spill the least-recently-used client not in the incoming cohort;
        returns its freed slot."""
        for cid in self._slot_of:          # OrderedDict: LRU first
            if cid not in protected:
                slot = self._slot_of.pop(cid)
                save_pytree(state_export(self.proto, self.state, slot),
                            self._spill_path(cid))
                self._spilled.add(cid)
                self.spills += 1
                return slot
        raise RuntimeError(
            f"cannot evict: all {self.budget} resident clients are in the "
            "incoming cohort (state budget must be >= cohort size)")

    # -------------------------------------------------------------- acquire

    def acquire(self, ids) -> np.ndarray:
        """Slot indices for a cohort of global client ids, materializing/
        restoring rows as needed.  The round_fn gathers views and scatters
        updates by these slots; the mapping persists until eviction."""
        ids = np.asarray(ids, np.int64)
        if len(ids) > self.budget:
            raise ValueError(
                f"cohort of {len(ids)} exceeds the state budget "
                f"{self.budget}: every cohort member needs a resident slot")
        incoming = {int(c) for c in ids}
        if len(incoming) != len(ids):
            raise ValueError("acquire wants distinct client ids")
        slots = np.empty(len(ids), np.int64)
        # two-pass: collect every missing client's (slot, row), then graft
        # them in ONE batched scatter — per-client functional .at[].set
        # would copy the whole budget-sized state once per miss
        # (O(cohort x budget) per acquire).  Evictions during collection
        # only ever export previous residents (incoming ids are protected),
        # whose rows in self.state are untouched until the final scatter.
        miss_slots, miss_rows = [], []
        for i, cid in enumerate(int(c) for c in ids):
            if cid in self._slot_of:
                self._slot_of.move_to_end(cid)      # touch
                slots[i] = self._slot_of[cid]
                continue
            slot = self._free.pop() if self._free else \
                self._evict_one(incoming)
            if cid in self._spilled:
                row = load_pytree(self._fresh, self._spill_path(cid))
                self._spilled.discard(cid)
                os.unlink(self._spill_path(cid))
                self.restores += 1
            else:
                row = self._fresh               # first selection: zero-init
            miss_slots.append(slot)
            miss_rows.append(row)
            self._slot_of[cid] = slot
            slots[i] = slot
        if miss_slots:
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *miss_rows)
            self.state = state_import_many(
                self.proto, self.state, np.asarray(miss_slots, np.int64),
                stacked)
        self.peak_resident = max(self.peak_resident, len(self._slot_of))
        return slots


def make_client_store(proto: Optional[ClientStateSpec], params,
                      population_size: int, budget: Optional[int] = None,
                      spill_dir: Optional[str] = None):
    """The store a run needs: ``None`` for stateless algorithms, dense when
    the budget covers the population (no spill machinery in the loop),
    sparse-LRU otherwise."""
    if proto is None:
        return None
    if budget is None or budget >= population_size:
        return DenseClientStore(proto, params, population_size)
    return ClientStateStore(proto, params, population_size, budget,
                            spill_dir=spill_dir)
