"""Million-client population layer: streaming cohorts over an abstract
client-id space, sparse per-client state with LRU spill through the
checkpoint store, and on-demand batch staging — population size becomes a
real config knob (``FedConfig.population_size`` / ``cohort_size`` /
``state_budget``) whose cost scales with the cohort, not the id space."""
from repro.fed.population.directory import (
    AvailabilitySampler, ClientPopulation, SAMPLERS, UniformSampler,
    WeightedSampler, hourly_availability, load_hourly_trace,
    make_population, resolve_population,
)
from repro.fed.population.state import (
    ClientStateStore, DenseClientStore, make_client_store,
)
from repro.fed.population.batches import (
    stage_client_population_batches, stage_population_batches,
)

__all__ = [
    "AvailabilitySampler", "ClientPopulation", "SAMPLERS", "UniformSampler",
    "WeightedSampler", "hourly_availability", "load_hourly_trace",
    "make_population", "resolve_population",
    "ClientStateStore",
    "DenseClientStore", "make_client_store",
    "stage_client_population_batches", "stage_population_batches",
]
