"""On-demand batch staging for sampled cohorts.

The dense runtimes stage batches with the experiment's shared generator —
fine when every client exists up front, wrong at population scale where a
client's data stream must not depend on who else was sampled or when.
Here each client's staging generator derives from the population's
``SeedSequence((seed, client_id, salt))`` stream (``ClientPopulation.
client_rng``), so staging the same client with the same salt yields the
same batches whether the population holds 10^2 or 10^6 ids, and whatever
cohort it rode in.

Only the sampled cohort is ever staged: peak memory is (S, K, ...) —
cohort-proportional, never population-proportional.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.fed.staging import _stack_steps, _stacker


def stage_population_batches(client_batch_fn, population, cohort,
                             local_steps: int, salt: int = 0):
    """A cohort's batches, (S, K, ...) stacked, each client drawing from its
    own fold_in-derived generator.  ``salt`` separates rounds (sync: the
    round index; async: the client's dispatch count)."""
    per_client = [
        _stack_steps(client_batch_fn, int(cid), local_steps,
                     population.client_rng(int(cid), salt))
        for cid in cohort]
    stack = _stacker(per_client[0])
    stacked = jax.tree.map(lambda *xs: stack(xs), *per_client)
    return jax.tree.map(jnp.asarray, stacked)


def stage_client_population_batches(client_batch_fn, population, cid: int,
                                    local_steps: int, salt: int = 0):
    """One client's (K, ...) batches from its own derived generator (the
    async runtime stages per-dispatch, not per-cohort)."""
    return jax.tree.map(
        jnp.asarray,
        _stack_steps(client_batch_fn, int(cid), local_steps,
                     population.client_rng(int(cid), salt)))
