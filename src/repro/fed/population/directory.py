"""Client directory over an abstract id space — no dense per-client lists.

A ``ClientPopulation`` is the id space ``[0, size)`` plus a streaming
``CohortSampler``: cohorts are *drawn*, never enumerated, so a 10^6-client
population costs O(cohort) work and memory per round, not O(population).

Every per-client draw — local-update PRNG keys, batch-staging generators,
latency/dropout realizations — derives from ``fold_in(seed, client_id)``
(jax keys) or the ``SeedSequence((seed, tag, client_id, salt))`` analog
(numpy generators).  Two consequences the tests pin down:

* a fixed cohort's round is **invariant to population size** — growing the
  id space from 10^2 to 10^6 does not perturb a single client's draws;
* draws are independent of **materialization order** — whether a client's
  state was resident, spilled, or never touched cannot shift its stream.

Cohort draws themselves are seeded per ``(seed, round)`` so the schedule of
cohorts is reproducible without any cross-round RNG threading.

The legacy dense-list path (``FedConfig.population_size is None``) does not
run through this module: it keeps the experiment's shared generator and its
historical draw order bitwise-intact.
"""
from __future__ import annotations

import os
from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp

# domain-separation tags for the SeedSequence streams (arbitrary, fixed)
_COHORT_TAG = 0xC0607
_CLIENT_TAG = 0xC11E57
_MAX_REJECT_ROUNDS = 64


def _distinct_uniform(rng: np.random.Generator, size: int, k: int,
                      exclude=frozenset()) -> np.ndarray:
    """``k`` distinct ids from ``[0, size)`` minus ``exclude`` in O(k) memory.

    Small id spaces take the exact permutation route; large ones
    rejection-sample (the regime where k << size, so collisions are rare).
    """
    avail = size - len(exclude)
    if k > avail:
        raise ValueError(
            f"cannot draw {k} distinct clients from an id space of {size} "
            f"with {len(exclude)} excluded")
    if size <= max(4 * k, 1024) + len(exclude):
        pool = np.arange(size)
        if exclude:
            pool = pool[~np.isin(pool, np.fromiter(exclude, np.int64,
                                                   len(exclude)))]
        return rng.permutation(pool)[:k]
    chosen: list = []
    seen = set(exclude)
    for _ in range(_MAX_REJECT_ROUNDS):
        draw = rng.integers(0, size, size=2 * (k - len(chosen)) + 8)
        for cid in draw:
            c = int(cid)
            if c not in seen:
                seen.add(c)
                chosen.append(c)
                if len(chosen) == k:
                    return np.asarray(chosen, np.int64)
    raise RuntimeError(    # pragma: no cover — k << size makes this unreachable
        f"rejection sampling failed to find {k} distinct ids in {size}")


class UniformSampler:
    """Uniform cohort draws without replacement, streaming."""

    def sample(self, rng: np.random.Generator, size: int, k: int, *,
               t: float = 0) -> np.ndarray:
        del t
        return _distinct_uniform(rng, size, k)


class WeightedSampler:
    """Weight-proportional cohorts via Gumbel top-k over a candidate pool.

    ``weight_fn(ids) -> (len(ids),) nonnegative weights`` is evaluated only
    on sampled candidates, never on the full population.  Id spaces small
    enough to enumerate (<= ``exact_below``) are sampled exactly; larger
    ones draw a uniform candidate pool of ``oversample * k`` ids first, so
    the draw is weight-proportional *within the pool* — an approximation
    whose bias shrinks as ``oversample`` grows.
    """

    def __init__(self, weight_fn: Callable[[np.ndarray], np.ndarray],
                 oversample: int = 16, exact_below: int = 65536):
        if oversample < 2:
            raise ValueError(f"oversample must be >= 2, got {oversample}")
        self.weight_fn = weight_fn
        self.oversample = int(oversample)
        self.exact_below = int(exact_below)

    def sample(self, rng: np.random.Generator, size: int, k: int, *,
               t: float = 0) -> np.ndarray:
        del t
        if k > size:
            raise ValueError(f"cohort {k} exceeds population {size}")
        if size <= max(self.exact_below, self.oversample * k):
            cand = np.arange(size)
        else:
            cand = _distinct_uniform(rng, size, self.oversample * k)
        w = np.asarray(self.weight_fn(cand), np.float64)
        if w.shape != cand.shape:
            raise ValueError(
                f"weight_fn returned shape {w.shape} for {cand.shape} ids")
        if np.any(w < 0) or not np.any(w > 0):
            raise ValueError("weights must be nonnegative with at least "
                             f"{k} strictly positive entries")
        if int(np.sum(w > 0)) < k:
            raise ValueError(
                f"only {int(np.sum(w > 0))} candidates have positive weight "
                f"but the cohort needs {k}")
        # Gumbel top-k == sequential weighted sampling without replacement
        with np.errstate(divide="ignore"):
            keys = np.where(w > 0, np.log(w), -np.inf) + rng.gumbel(
                size=w.shape)
        return cand[np.argsort(-keys, kind="stable")[:k]].astype(np.int64)


def _mix_u01(ids: np.ndarray, hour: int) -> np.ndarray:
    """Deterministic per-(id, hour) uniforms in [0, 1) — a cheap integer
    hash (splitmix-style multiply/xor), invariant to population size and
    to evaluation order, so fractional availability tables resolve to a
    stable per-client on/off decision each hour."""
    x = (np.asarray(ids, np.uint64) * np.uint64(0x9E3779B97F4A7C15)
         + np.uint64(hour) * np.uint64(0xBF58476D1CE4E5B9))
    x ^= x >> np.uint64(31)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(29)
    return (x >> np.uint64(11)).astype(np.float64) / float(1 << 53)


def load_hourly_trace(path: str) -> np.ndarray:
    """Load an empirical per-hour availability table from a trace file:
    ``.npy``/``.npz`` (first array) or a text/CSV table of numbers.  Rows
    are hours; an optional second axis is the timezone/device bucket."""
    p = str(path)
    if p.endswith(".npy"):
        return np.load(p)
    if p.endswith(".npz"):
        with np.load(p) as z:
            return z[z.files[0]]
    return np.loadtxt(p, delimiter="," if p.endswith(".csv") else None)


def hourly_availability(table, *, hour_unit: float = 1.0,
                        ) -> Callable[[np.ndarray, float], np.ndarray]:
    """An ``available_fn(ids, t)`` from an empirical per-hour table (e.g.
    device-usage fractions measured from a real fleet).

    ``table`` is ``(H,)`` or ``(H, B)`` — a str/PathLike loads through
    ``load_hourly_trace``.  Hour ``floor(t / hour_unit) % H`` indexes the
    first axis (the table wraps, i.e. it is one diurnal/weekly cycle):

    * ``(H, B)`` boolean/0-1 masks: client ``id`` belongs to timezone
      bucket ``id % B`` and is available iff ``table[hour, id % B]``;
    * ``(H,)`` fractions in [0, 1]: each client resolves the fraction with
      its own deterministic per-(id, hour) uniform, so an 0.3 hour keeps
      ~30% of the fleet online — the *same* 30% every time that hour is
      asked about.
    """
    if isinstance(table, (str, os.PathLike)):
        table = load_hourly_trace(table)
    table = np.asarray(table)
    if table.ndim not in (1, 2) or table.shape[0] < 1:
        raise ValueError(
            f"hourly table must be (H,) or (H, B) with H >= 1, "
            f"got shape {table.shape}")
    if hour_unit <= 0:
        raise ValueError(f"hour_unit must be > 0, got {hour_unit}")
    if table.ndim == 1 and (table.min() < 0 or table.max() > 1):
        raise ValueError(
            "fractional (H,) availability values must lie in [0, 1], "
            f"got range [{table.min()}, {table.max()}]")
    hours = table.shape[0]

    def available_fn(ids: np.ndarray, t: float) -> np.ndarray:
        ids = np.asarray(ids)
        hour = int(np.floor(float(t) / hour_unit)) % hours
        if table.ndim == 2:
            return np.asarray(table[hour, ids % table.shape[1]], bool)
        return _mix_u01(ids, hour) < float(table[hour])

    return available_fn


class AvailabilitySampler:
    """Cohorts restricted to an availability trace.

    ``available_fn(ids, t) -> bool mask`` answers which of the candidate ids
    are online at time ``t`` (the round index in the sync runtime, the
    simulated clock in the async one) — e.g. diurnal cycles as a function of
    ``client_id % timezone_buckets``.  Candidates are streamed uniformly and
    filtered; a trace too sparse to fill the cohort raises instead of
    spinning.  ``from_hourly`` builds the mask from an empirical per-hour
    availability array (trace-file-driven device-usage data) instead of a
    synthetic callable.
    """

    def __init__(self, available_fn: Callable[[np.ndarray, float], np.ndarray],
                 max_rounds: int = _MAX_REJECT_ROUNDS):
        self.available_fn = available_fn
        self.max_rounds = int(max_rounds)

    @classmethod
    def from_hourly(cls, table, *, hour_unit: float = 1.0,
                    max_rounds: int = _MAX_REJECT_ROUNDS
                    ) -> "AvailabilitySampler":
        """Sampler over an empirical per-hour availability table (array,
        or a trace file path — see ``hourly_availability``)."""
        return cls(hourly_availability(table, hour_unit=hour_unit),
                   max_rounds=max_rounds)

    def sample(self, rng: np.random.Generator, size: int, k: int, *,
               t: float = 0) -> np.ndarray:
        if k > size:
            raise ValueError(f"cohort {k} exceeds population {size}")
        chosen: list = []
        seen: set = set()
        for _ in range(self.max_rounds):
            cand = _distinct_uniform(rng, size, min(size - len(seen), 2 * k),
                                     exclude=seen)
            seen.update(int(c) for c in cand)
            mask = np.asarray(self.available_fn(cand, t), bool)
            chosen.extend(int(c) for c in cand[mask])
            if len(chosen) >= k:
                return np.asarray(chosen[:k], np.int64)
            if len(seen) >= size:
                break
        raise RuntimeError(
            f"availability trace too sparse at t={t}: found {len(chosen)} "
            f"available clients of the {k} needed (population {size})")


# config-string-constructible samplers; weighted/availability need callables,
# so they are only reachable by passing a ClientPopulation object explicitly
SAMPLERS = {"uniform": UniformSampler}


class ClientPopulation:
    """An abstract client-id space ``[0, size)`` with streaming cohorts."""

    def __init__(self, size: int, *, seed: int = 0,
                 sampler: Optional[object] = None):
        if size < 1:
            raise ValueError(f"population size must be >= 1, got {size}")
        self.size = int(size)
        self.seed = int(seed)
        self.sampler = sampler if sampler is not None else UniformSampler()
        self._base_key = jax.random.key(self.seed)

    # ------------------------------------------------------------ cohorts

    def sample_cohort(self, round_index: int, cohort_size: int) -> np.ndarray:
        """One round's cohort: distinct global ids, seeded per (seed, round).

        Reproducible in isolation — no generator is threaded between rounds,
        so round r's cohort is the same whether rounds 0..r-1 ran or not.
        """
        rng = np.random.default_rng(
            np.random.SeedSequence((self.seed, _COHORT_TAG,
                                    int(round_index))))
        ids = np.asarray(self.sampler.sample(rng, self.size,
                                             int(cohort_size),
                                             t=int(round_index)), np.int64)
        self._check_ids(ids, cohort_size)
        return ids

    def sample_dispatch(self, rng: np.random.Generator, exclude=frozenset(),
                        t: float = 0) -> int:
        """One client for an async dispatch slot, skipping in-flight ids."""
        for _ in range(_MAX_REJECT_ROUNDS * 16):
            ids = self.sampler.sample(rng, self.size, 1, t=t)
            if int(ids[0]) not in exclude:
                return int(ids[0])
        raise RuntimeError(
            f"could not draw an idle client: {len(exclude)} of {self.size} "
            "ids are in flight and the sampler keeps returning them")

    def _check_ids(self, ids: np.ndarray, k: int) -> None:
        if len(ids) != k or len(np.unique(ids)) != k:
            raise ValueError(
                f"sampler returned {len(ids)} ids "
                f"({len(np.unique(ids))} distinct) for cohort size {k}")
        if ids.size and (ids.min() < 0 or ids.max() >= self.size):
            raise ValueError(
                f"sampler returned ids outside [0, {self.size}): "
                f"[{ids.min()}, {ids.max()}]")

    # --------------------------------------------------- per-client streams

    def _check_id(self, client_id: int) -> int:
        cid = int(client_id)
        if not 0 <= cid < self.size:
            raise ValueError(
                f"client id {cid} outside id space [0, {self.size})")
        return cid

    def client_rng(self, client_id: int, salt: int = 0) -> np.random.Generator:
        """A numpy generator owned by ``client_id`` alone (host-side draws:
        batch sampling, latency realizations).  ``salt`` separates uses
        within one client — the round index (sync) or the client's dispatch
        count (async)."""
        return np.random.default_rng(
            np.random.SeedSequence((self.seed, _CLIENT_TAG,
                                    self._check_id(client_id), int(salt))))

    def client_key(self, client_id: int, salt: int = 0):
        """The jax analog: ``fold_in(fold_in(key(seed), client_id), salt)``."""
        return jax.random.fold_in(
            jax.random.fold_in(self._base_key, self._check_id(client_id)),
            int(salt))

    def cohort_keys(self, cohort, salt: int = 0):
        """Stacked (S,) per-client keys for a whole cohort (one device op)."""
        ids = jnp.asarray(np.asarray(cohort))
        return jax.vmap(
            lambda c: jax.random.fold_in(
                jax.random.fold_in(self._base_key, c), salt))(ids)

    def __repr__(self):
        return (f"ClientPopulation(size={self.size}, seed={self.seed}, "
                f"sampler={type(self.sampler).__name__})")


def make_population(fed) -> ClientPopulation:
    """Build the population a config describes (``population_size``,
    ``cohort_sampler``, ``seed``).  Richer samplers (weighted, availability
    traces) carry callables a config string cannot, so they are passed as
    ready ``ClientPopulation`` objects instead."""
    if getattr(fed, "population_size", None) is None:
        raise ValueError("make_population needs a config with "
                         "population_size set")
    name = getattr(fed, "cohort_sampler", "uniform")
    if name not in SAMPLERS:
        raise ValueError(
            f"unknown cohort_sampler {name!r} (config strings support "
            f"{sorted(SAMPLERS)}; pass a ClientPopulation for weighted/"
            "availability sampling)")
    return ClientPopulation(fed.population_size, seed=fed.seed,
                            sampler=SAMPLERS[name]())


def resolve_population(fed, population=None) -> Optional[ClientPopulation]:
    """Both runtimes' population plumbing: None unless the config activates
    population mode; an explicitly-passed ``ClientPopulation`` (the only way
    to carry weighted/availability samplers) must agree with the config's
    sizing knobs."""
    if population is None:
        if not getattr(fed, "population_active", False):
            return None
        return make_population(fed)
    if not getattr(fed, "population_active", False):
        raise ValueError(
            "a ClientPopulation was passed but population_size is not set — "
            "population mode needs the FedConfig knobs (population_size, "
            "cohort_size) for validation and sizing")
    if population.size != fed.population_size:
        raise ValueError(
            f"population.size {population.size} != fed.population_size "
            f"{fed.population_size}")
    return population
