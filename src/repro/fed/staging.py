"""Batch staging shared by the sync and async runtimes.

``client_batch_fn(cid, rng)`` yields one local minibatch; staging stacks the
K per-step batches (and, for a synchronous cohort, the S clients) into
leading (S, K, ...) axes with as few device transfers as possible:

  * batch fn yields host (numpy) arrays -> stack entirely on host with
    ``np.stack`` and do a *single* device transfer per leaf;
  * batch fn yields device (jax) arrays -> stack on device with
    ``jnp.stack``; pulling them back to host first would add S*K
    device-to-host copies just to save the stack.

Reusable host buffers (``StagingBuffers``) take the host path one step
further: the (S, K, ...) per-leaf arrays are allocated once and refilled
in place every round, so steady-state staging does zero large host
allocations.  The chunk-streaming pipeline (``fed.pipeline``) stages into
these buffers row-by-row from a background thread pool.

Thread-safety contract
----------------------

Under the background stager a ``client_batch_fn`` may be called from
worker threads, concurrently for different clients.  A fn is safe to call
concurrently iff it is a pure function of ``(cid, rng)`` — it must not
mutate shared Python state (the rng passed in is private to the client).
Mark such fns with ``mark_thread_safe``; the built-in scenario batch fns
are marked.  Unmarked fns are *serialized* through a module lock — always
correct, just without intra-chunk staging parallelism.
"""
from __future__ import annotations

import threading

import numpy as np
import jax
import jax.numpy as jnp

_UNSAFE_FN_LOCK = threading.Lock()


def mark_thread_safe(fn):
    """Declare ``fn`` safe for concurrent calls (a pure function of its
    arguments).  Returns ``fn`` so it works as a decorator."""
    fn._repro_thread_safe = True
    return fn


def is_thread_safe(fn) -> bool:
    return bool(getattr(fn, "_repro_thread_safe", False))


def serialized_unless_thread_safe(fn):
    """Call-through wrapper enforcing the staging contract: unmarked fns
    run under a module-wide lock so concurrent stager workers cannot
    corrupt shared state they might mutate."""
    if is_thread_safe(fn):
        return fn

    def locked(*a, **kw):
        with _UNSAFE_FN_LOCK:
            return fn(*a, **kw)
    return locked


def _stacker(tree):
    """np.stack when every leaf is host-side, else jnp.stack."""
    on_host = all(isinstance(leaf, np.ndarray) or np.isscalar(leaf)
                  for leaf in jax.tree.leaves(tree))
    return np.stack if on_host else jnp.stack


def _stack_steps(client_batch_fn, cid: int, local_steps: int, rng):
    """One client's K per-step batches stacked to a (K, ...) pytree."""
    steps = [client_batch_fn(int(cid), rng) for _ in range(local_steps)]
    stack = _stacker(steps[0])
    return jax.tree.map(lambda *xs: stack(xs), *steps)


def stage_client_batches(client_batch_fn, cid: int, local_steps: int, rng):
    """One client's round of batches, stacked to leading (K, ...) axes."""
    return jax.tree.map(
        jnp.asarray, _stack_steps(client_batch_fn, cid, local_steps, rng))


# ---------------------------------------------------------- host buffers

class StagingBuffers:
    """Preallocated, reusable (S, K, ...) host buffers for batch staging.

    One buffer tree per requested ``(tag, s)`` key, allocated lazily from
    the first staged client's leaf shapes/dtypes and refilled in place on
    every later round — steady-state staging allocates nothing large.
    Rows are written independently (``fill_row``), so disjoint clients can
    be filled from concurrent stager workers.
    """

    def __init__(self):
        self._bufs: dict = {}
        # concurrent stager workers race on lazy allocation: without the
        # lock two callers could each build a tree and fill different ones
        self._lock = threading.Lock()

    def get(self, key, s: int, template):
        """The (S, ...) buffer tree for ``(key, s)``; ``template`` is one
        client's stacked (K, ...) pytree (host or device leaves)."""
        with self._lock:
            buf = self._bufs.get((key, s))
            if buf is None:
                buf = jax.tree.map(
                    lambda x: np.empty((s, *np.shape(x)),
                                       dtype=np.asarray(x).dtype), template)
                self._bufs[(key, s)] = buf
        return buf

    def peek(self, key, s: int):
        """The already-allocated buffer tree for ``(key, s)`` (KeyError if
        no client was staged into it yet)."""
        with self._lock:
            return self._bufs[(key, s)]

    @staticmethod
    def fill_row(buf, i: int, row):
        """Write one client's (K, ...) pytree into row ``i`` in place."""
        jax.tree.map(lambda b, r: b.__setitem__(i, np.asarray(r)), buf, row)


def stage_cohort_batches(client_batch_fn, cohort, local_steps: int, rng,
                         buffers: StagingBuffers | None = None):
    """A cohort's batches, stacked to leading (S, K, ...) axes.

    With ``buffers``, host-side batch fns refill a persistent buffer tree
    instead of re-allocating a fresh ``np.stack`` per round (values are
    identical — same rows, one device upload per leaf either way).
    Device-side batch fns keep the ``jnp.stack`` path: their leaves are
    already on device and a host bounce would add S*K transfers.
    """
    per_client = [_stack_steps(client_batch_fn, cid, local_steps, rng)
                  for cid in cohort]
    stack = _stacker(per_client[0])
    if buffers is not None and stack is np.stack:
        buf = buffers.get("cohort", len(per_client), per_client[0])
        for i, row in enumerate(per_client):
            StagingBuffers.fill_row(buf, i, row)
        return jax.tree.map(jnp.asarray, buf)
    stacked = jax.tree.map(lambda *xs: stack(xs), *per_client)
    return jax.tree.map(jnp.asarray, stacked)
