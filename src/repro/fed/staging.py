"""Batch staging shared by the sync and async runtimes.

``client_batch_fn(cid, rng)`` yields one local minibatch; staging stacks the
K per-step batches (and, for a synchronous cohort, the S clients) into
leading (S, K, ...) axes with as few device transfers as possible:

  * batch fn yields host (numpy) arrays -> stack entirely on host with
    ``np.stack`` and do a *single* device transfer per leaf;
  * batch fn yields device (jax) arrays -> stack on device with
    ``jnp.stack``; pulling them back to host first would add S*K
    device-to-host copies just to save the stack.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def _stacker(tree):
    """np.stack when every leaf is host-side, else jnp.stack."""
    on_host = all(isinstance(leaf, np.ndarray) or np.isscalar(leaf)
                  for leaf in jax.tree.leaves(tree))
    return np.stack if on_host else jnp.stack


def _stack_steps(client_batch_fn, cid: int, local_steps: int, rng):
    """One client's K per-step batches stacked to a (K, ...) pytree."""
    steps = [client_batch_fn(int(cid), rng) for _ in range(local_steps)]
    stack = _stacker(steps[0])
    return jax.tree.map(lambda *xs: stack(xs), *steps)


def stage_client_batches(client_batch_fn, cid: int, local_steps: int, rng):
    """One client's round of batches, stacked to leading (K, ...) axes."""
    return jax.tree.map(
        jnp.asarray, _stack_steps(client_batch_fn, cid, local_steps, rng))


def stage_cohort_batches(client_batch_fn, cohort, local_steps: int, rng):
    """A cohort's batches, stacked to leading (S, K, ...) axes."""
    per_client = [_stack_steps(client_batch_fn, cid, local_steps, rng)
                  for cid in cohort]
    stack = _stacker(per_client[0])
    stacked = jax.tree.map(lambda *xs: stack(xs), *per_client)
    return jax.tree.map(jnp.asarray, stacked)
