"""Synchronous federated runtime: client sampling, batch staging, round loop.

Supports every algorithm in the paper's tables:
  fedavg                         SGD locally, parameter averaging
  scaffold                       control variates (fed/scaffold.py)
  fedcm                          client momentum == correction-only + SGD
  local_{adamw,sophia,muon,soap} FedSOA (Alg. 1) with that optimizer
  fedpac_{sophia,muon,soap}      FedPAC (Alg. 2)
  + component ablations (align_only / correct_only) and _light (SVD upload)

The runtime is a thin driver over the unified round engine
(``core.engine``): it samples cohorts and stages batches; the round itself
is the engine's executor + aggregate + geometry controller.  The buffered-
asynchronous execution model of the same algorithms lives in
``fed.async_runtime``; both implement ``fed.base.FedExperiment``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro import optim
from repro.core import (
    make_round_fn, init_server, make_svd_codec, round_comm_bytes,
)
from repro.core.engine import (
    BETA_MAX_AUTO, ExecutorConfig, make_controller,
)
from repro.fed.base import FedExperiment
from repro.fed.scaffold import make_scaffold_round_fn, ScaffoldState
from repro.fed.staging import stage_cohort_batches

RUNTIMES = ("sync", "async")


@dataclasses.dataclass
class FedConfig:
    algorithm: str = "fedpac_soap"
    n_clients: int = 20
    participation: float = 0.2     # fraction sampled per round
    rounds: int = 20
    local_steps: int = 10          # K
    batch_size: int = 16
    lr: Optional[float] = None     # default: paper's per-optimizer lr
    beta: Union[float, str] = 0.5  # FedPAC correction strength (or "auto")
    hessian_freq: int = 10
    svd_rank: int = 8              # for *_light variants
    seed: int = 0
    server_lr: float = 1.0
    runtime: str = "sync"          # "sync" | "async" (fed.base.make_experiment)
    executor: str = "vmap"         # cohort executor: vmap|shard_map|chunked
    chunk_size: int = 8            # for executor="chunked"

    def __post_init__(self):
        if not (0.0 < self.participation <= 1.0):
            raise ValueError(
                f"participation must be in (0, 1], got {self.participation}")
        if self.runtime not in RUNTIMES:
            raise ValueError(
                f"unknown runtime {self.runtime!r} (want one of {RUNTIMES})")
        self.executor_config()   # ExecutorConfig validates backend/chunk_size
        if self.n_clients < 1:
            raise ValueError(f"n_clients must be >= 1, got {self.n_clients}")
        if self.local_steps < 1:
            raise ValueError(
                f"local_steps must be >= 1, got {self.local_steps}")
        if isinstance(self.beta, str) and self.beta != "auto":
            raise ValueError(
                f"beta must be a float or 'auto', got {self.beta!r}")

    def executor_config(self) -> ExecutorConfig:
        return ExecutorConfig(backend=self.executor,
                              chunk_size=self.chunk_size)


_KNOWN_OPTS = ("adamw", "sophia", "muon", "soap", "sgd")


def parse_algorithm(name: str):
    """-> (optimizer_name, align, correct, light)."""
    light = name.endswith("_light")
    if light:
        name = name[: -len("_light")]
    if name == "fedavg":
        return "sgd", False, False, light
    if name == "scaffold":
        return "scaffold", False, False, light
    if name == "fedcm":
        return "sgd", False, True, light
    kind, _, opt_name = name.partition("_")
    flags = {"local": (False, False), "fedpac": (True, True),
             "align": (True, False), "correct": (False, True)}
    if kind in ("align", "correct"):     # align_only_soap / correct_only_muon
        opt_name = name.split("_")[-1]
    if kind not in flags:
        raise ValueError(
            f"unknown algorithm {name!r}: expected fedavg|scaffold|fedcm or "
            "local_|fedpac_|align_only_|correct_only_<optimizer>")
    if opt_name not in _KNOWN_OPTS:
        raise ValueError(
            f"unknown optimizer {opt_name!r} in algorithm {name!r} "
            f"(want one of {_KNOWN_OPTS})")
    align, correct = flags[kind]
    return opt_name, align, correct, light


def resolve_lr(fed: FedConfig, opt_name: str) -> float:
    """Explicit fed.lr wins — including falsy values like 0.0."""
    if fed.lr is not None:
        return fed.lr
    return optim.DEFAULT_LR.get(opt_name, 1e-2)


def resolve_beta(fed: FedConfig, correct: bool):
    """-> (static_beta, adaptive): the one beta rule for both runtimes.

    No correction => 0; FedCM pins beta to its (1 - alpha) = 0.9;
    beta="auto" starts at 0 and is driven by measured drift each round."""
    if not correct:
        return 0.0, False
    if fed.algorithm == "fedcm":
        return 0.9, False
    if fed.beta == "auto":
        return 0.0, True
    return float(fed.beta), False


class FederatedExperiment(FedExperiment):
    """Drives R lock-step communication rounds over client datasets.

    ``client_batch_fn(client_id, rng) -> batch pytree`` supplies one local
    minibatch; batches for a round are stacked to (S, K, ...).
    """

    def __init__(self, fed: FedConfig, params, loss_fn: Callable,
                 client_batch_fn: Callable, eval_fn: Optional[Callable] = None,
                 opt_kwargs: Optional[dict] = None):
        self.fed = fed
        self.loss_fn = loss_fn
        self.client_batch_fn = client_batch_fn
        self.eval_fn = eval_fn
        self.rng = np.random.default_rng(fed.seed)

        opt_name, align, correct, light = parse_algorithm(fed.algorithm)
        self.is_scaffold = opt_name == "scaffold"
        lr = resolve_lr(fed, opt_name)
        self.lr = lr
        executor = fed.executor_config()
        if self.is_scaffold:
            self.opt = optim.make("sgd")
            self.round_fn = make_scaffold_round_fn(
                loss_fn, lr=lr, local_steps=fed.local_steps,
                n_clients=fed.n_clients, server_lr=fed.server_lr,
                executor=executor)
            self.scaffold_state = ScaffoldState.init(params, fed.n_clients)
            geom = make_controller(0.0, correct=False)
        else:
            self.opt = optim.make(opt_name, **(opt_kwargs or {}))
            static_beta, adaptive = resolve_beta(fed, correct)
            beta = "auto" if adaptive else static_beta
            geom = make_controller(beta, correct=correct,
                                   beta_max=BETA_MAX_AUTO)
            codec = make_svd_codec(fed.svd_rank) if light else None
            self.round_fn = make_round_fn(
                loss_fn, self.opt, lr=lr, local_steps=fed.local_steps,
                beta=beta, align=align, correct=correct,
                hessian_freq=fed.hessian_freq, server_lr=fed.server_lr,
                compress_fn=codec, executor=executor)
        self.server = init_server(params, self.opt, geom=geom)
        self.align = align
        self.history: list[dict] = []

    # ------------------------------------------------------------ staging

    def _sample_cohort(self):
        s = max(1, int(round(self.fed.n_clients * self.fed.participation)))
        return self.rng.choice(self.fed.n_clients, size=s, replace=False)

    def _stage_batches(self, cohort):
        """Stack per-client, per-step batches -> leading (S, K, ...) axes."""
        return stage_cohort_batches(self.client_batch_fn, cohort,
                                    self.fed.local_steps, self.rng)

    # ------------------------------------------------------------ loop

    def run_round(self):
        cohort = self._sample_cohort()
        batches = self._stage_batches(cohort)
        key = jax.random.key(int(self.rng.integers(0, 2**31)))
        if self.is_scaffold:
            self.server, self.scaffold_state, metrics = self.round_fn(
                self.server, self.scaffold_state, jnp.asarray(cohort), batches,
                key)
        else:
            self.server, metrics = self.round_fn(self.server, batches, key)
        rec = {k: float(v) for k, v in metrics.items()}
        rec["round"] = self.server.round
        if self.eval_fn is not None:
            rec.update({k: float(v) for k, v in
                        self.eval_fn(self.server.params).items()})
        self.history.append(rec)
        return rec

    # ------------------------------------------------------------ accounting

    def comm_bytes_per_round(self) -> int:
        theta = self.server.theta if self.align else None
        _, _, _, light = parse_algorithm(self.fed.algorithm)
        return round_comm_bytes(
            self.server.params, theta,
            compressed_rank=self.fed.svd_rank if light else None)
