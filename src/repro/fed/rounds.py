"""Synchronous federated runtime: client sampling, batch staging, round loop.

Algorithms are first-class ``AlgorithmSpec`` values resolved from the
registry (``core.algorithms``) — the legacy strings from the paper's tables
all resolve there:

  fedavg                         SGD locally, parameter averaging
  scaffold                       control variates (core/scaffold.py)
  fedcm                          client momentum == correction-only + SGD
  local_{adamw,sophia,muon,soap} FedSOA (Alg. 1) with that optimizer
  fedpac_{sophia,muon,soap}      FedPAC (Alg. 2)
  fedpm_{sophia,muon,soap}       preconditioned mixing (core/fedpm.py)
  + component ablations (align_only / correct_only) and _light (SVD upload)

The runtime is a thin driver over the unified round engine
(``core.engine``): it samples cohorts and stages batches; the round itself
is the spec-built uniform driver (``core.algorithms.build_round_fn``) —
one signature for every algorithm, per-client persistent state (SCAFFOLD's
control variates) included.  The buffered-asynchronous execution model of
the same specs lives in ``fed.async_runtime``; both implement
``fed.base.FedExperiment``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro import optim
from repro.core import init_server
from repro.core.algorithms import (
    AlgorithmSpec, build_round_fn, init_round_client_state, resolve,
)
from repro.core.engine import BETA_MAX_AUTO, ExecutorConfig, make_controller
from repro.core.transport import (
    Transport, validate_codec_spec, validate_wire_dtype,
)
from repro.fed.base import FedExperiment
from repro.utils import hw
from repro.fed.staging import StagingBuffers, stage_cohort_batches

RUNTIMES = ("sync", "async")


@dataclasses.dataclass
class FedConfig:
    algorithm: str = "fedpac_soap"
    n_clients: int = 20
    participation: float = 0.2     # fraction sampled per round
    rounds: int = 20
    local_steps: int = 10          # K
    batch_size: int = 16
    lr: Optional[float] = None     # default: paper's per-optimizer lr
    beta: Union[float, str] = 0.5  # FedPAC correction strength (or "auto")
    hessian_freq: int = 10
    svd_rank: int = 8              # low-rank codec rank (*_light variants)
    seed: int = 0
    server_lr: float = 1.0
    runtime: str = "sync"          # "sync" | "async" (fed.base.make_experiment)
    executor: str = "vmap"         # cohort executor:
    #                                vmap|shard_map|chunked|sharded
    chunk_size: int = 8            # for executor="chunked"/"sharded"
    # ---- population scale-out (fed.population). None -> legacy dense path
    # (n_clients dense lists, shared-RNG draw order preserved bitwise).
    population_size: Optional[int] = None  # abstract client-id space size
    cohort_size: Optional[int] = None      # clients per round (required
    #                                        when population_size is set)
    state_budget: Optional[int] = None     # resident client-state slots;
    #                                        None -> min(pop, 4 * cohort)
    cohort_sampler: str = "uniform"        # population cohort sampler name
    spill_dir: Optional[str] = None        # cold-state spill dir (None ->
    #                                        a fresh temp dir)
    # geometry transport (core.transport): None inherits the spec's declared
    # codec specs (upload / delta_upload); strings may chain with "+"
    theta_codec: Optional[str] = None
    delta_codec: Optional[str] = None
    error_feedback: bool = True    # EF residuals for lossy delta codecs
    qblock_size: int = 128         # qblock codec: elements per scale
    sketch_iters: int = 2          # power_sketch subspace iterations
    use_pallas: Optional[bool] = None  # Pallas wire kernels; None -> auto
                                       # (real kernels on TPU, off elsewhere)
    wire_dtype: str = "f32"        # wire payload dtype: "f32" (native,
                                   # lossless) | "bf16" (half-width uploads)
    # ---- chunk-streaming pipelined rounds (fed.pipeline): overlap host
    # staging + state I/O with device compute.  Population + sync only.
    pipeline: bool = False
    pipeline_chunk: int = 128      # clients per pipeline chunk
    pipeline_workers: int = 4      # background stager threads

    def __post_init__(self):
        if not (0.0 < self.participation <= 1.0):
            raise ValueError(
                f"participation must be in (0, 1], got {self.participation}")
        if self.runtime not in RUNTIMES:
            raise ValueError(
                f"unknown runtime {self.runtime!r} (want one of {RUNTIMES})")
        self.executor_config()   # ExecutorConfig validates backend/chunk_size
        if self.n_clients < 1:
            raise ValueError(f"n_clients must be >= 1, got {self.n_clients}")
        if self.local_steps < 1:
            raise ValueError(
                f"local_steps must be >= 1, got {self.local_steps}")
        if self.hessian_freq < 1:
            raise ValueError(
                f"hessian_freq must be >= 1, got {self.hessian_freq}")
        if isinstance(self.beta, str) and self.beta != "auto":
            raise ValueError(
                f"beta must be a float or 'auto', got {self.beta!r}")
        for codec_spec in (self.theta_codec, self.delta_codec):
            if codec_spec is not None:
                validate_codec_spec(codec_spec)  # UnknownCodecError early
        if self.svd_rank < 1:
            raise ValueError(f"svd_rank must be >= 1, got {self.svd_rank}")
        if self.qblock_size < 1:
            raise ValueError(
                f"qblock_size must be >= 1, got {self.qblock_size}")
        if hw.resolve_use_pallas(self.use_pallas) and self.qblock_size % 128:
            raise ValueError(
                f"qblock_size must be a multiple of 128 (VPU lane width) "
                f"when Pallas kernels are enabled, got {self.qblock_size}")
        validate_wire_dtype(self.wire_dtype)
        if self.sketch_iters < 0:
            raise ValueError(
                f"sketch_iters must be >= 0, got {self.sketch_iters}")
        if self.pipeline_chunk < 1:
            raise ValueError(
                f"pipeline_chunk must be >= 1, got {self.pipeline_chunk}")
        if self.pipeline_workers < 1:
            raise ValueError(
                f"pipeline_workers must be >= 1, got "
                f"{self.pipeline_workers}")
        self._validate_population()
        if self.pipeline:
            if not self.population_active:
                raise ValueError(
                    "pipeline=True requires population mode (the chunked "
                    "cohort stream and sparse state store) — set "
                    "population_size/cohort_size as well")
            if self.runtime != "sync":
                raise ValueError(
                    "pipeline=True is a sync-runtime feature (the async "
                    "runtime already overlaps dispatches); use "
                    "runtime='sync'")

    def _validate_population(self):
        if self.population_size is None:
            pop_only = {"cohort_size": self.cohort_size,
                        "state_budget": self.state_budget,
                        "spill_dir": self.spill_dir}
            stray = [k for k, v in pop_only.items() if v is not None]
            if self.cohort_sampler != "uniform":
                stray.append("cohort_sampler")
            if stray:
                raise ValueError(
                    f"{', '.join(sorted(stray))} only apply to population "
                    "mode — set population_size as well")
            return
        if self.population_size < 1:
            raise ValueError(
                f"population_size must be >= 1, got {self.population_size}")
        if self.cohort_size is None:
            raise ValueError(
                "population mode needs an explicit cohort_size "
                "(participation fractions don't scale to 10^6-id spaces)")
        if not 1 <= self.cohort_size <= self.population_size:
            raise ValueError(
                f"cohort_size must be in [1, population_size="
                f"{self.population_size}], got {self.cohort_size}")
        if self.state_budget is not None and \
                self.state_budget < self.cohort_size:
            raise ValueError(
                f"state_budget {self.state_budget} < cohort_size "
                f"{self.cohort_size}: every cohort member needs a resident "
                "state slot")
        from repro.fed.population.directory import SAMPLERS
        if self.cohort_sampler not in SAMPLERS:
            raise ValueError(
                f"unknown cohort_sampler {self.cohort_sampler!r} (config "
                f"strings support {sorted(SAMPLERS)}; pass a "
                "ClientPopulation for weighted/availability sampling)")

    @property
    def population_active(self) -> bool:
        return self.population_size is not None

    def resolve_state_budget(self) -> int:
        """Resident client-state slots: explicit budget, else enough for a
        few cohorts of churn without population-proportional memory."""
        if self.state_budget is not None:
            return self.state_budget
        return min(self.population_size, 4 * self.cohort_size)

    def executor_config(self) -> ExecutorConfig:
        return ExecutorConfig(backend=self.executor,
                              chunk_size=self.chunk_size)

    def make_transport(self, spec: AlgorithmSpec) -> Transport:
        """Resolve the wire policy for ``spec`` under this config."""
        return spec.make_transport(
            rank=self.svd_rank, block=self.qblock_size,
            sketch_iters=self.sketch_iters,
            delta_codec=self.delta_codec, theta_codec=self.theta_codec,
            error_feedback=self.error_feedback, use_pallas=self.use_pallas,
            wire_dtype=self.wire_dtype)


def parse_algorithm(name: str):
    """Legacy flag-tuple view of an algorithm string.

    -> (optimizer_name, align, correct, light).  Deprecated: strings now
    resolve to registered ``AlgorithmSpec`` values (``core.algorithms``);
    this shim survives for callers that still want the PR-2-era tuple.
    Prefer ``repro.core.algorithms.resolve(name)`` — the spec additionally
    carries the beta policy, upload codec, client-state protocol, and
    mixing hook that this tuple cannot express.
    """
    spec = resolve(name)
    return spec.optimizer, spec.align, spec.correct, spec.upload == "svd"


def resolve_lr(fed: FedConfig, spec_or_opt: Union[AlgorithmSpec, str]
               ) -> float:
    """Explicit fed.lr wins — including falsy values like 0.0 — then the
    spec's declared default_lr, then the optimizer's paper-table default."""
    if fed.lr is not None:
        return fed.lr
    if isinstance(spec_or_opt, AlgorithmSpec):
        if spec_or_opt.default_lr is not None:
            return spec_or_opt.default_lr
        spec_or_opt = spec_or_opt.optimizer
    return optim.DEFAULT_LR.get(spec_or_opt, 1e-2)


class FederatedExperiment(FedExperiment):
    """Drives R lock-step communication rounds over client datasets.

    ``client_batch_fn(client_id, rng) -> batch pytree`` supplies one local
    minibatch; batches for a round are stacked to (S, K, ...).

    ``spec`` (optional) supplies the algorithm directly — an unregistered
    ``AlgorithmSpec`` works; ``fed.algorithm`` is only consulted when it is
    None.  The spec is resolved once here and reused for the round fn, the
    optimizer, and comm accounting.

    Population mode (``fed.population_size`` set, optionally with an
    explicit ``population=`` carrying a weighted/availability sampler):
    cohorts stream from the abstract id space, every per-client draw
    derives from ``fold_in(seed, client_id)`` (round salt separates
    rounds), per-client state lives in a budgeted sparse store
    (``fed.population.make_client_store``) whose cold rows spill through
    the checkpoint store, and the round_fn receives *slot* indices plus
    pre-derived stacked keys.  The legacy path (``population_size=None``)
    keeps its shared-generator draw order bitwise-intact.
    """

    def __init__(self, fed: FedConfig, params, loss_fn: Callable,
                 client_batch_fn: Callable, eval_fn: Optional[Callable] = None,
                 opt_kwargs: Optional[dict] = None,
                 spec: Optional[AlgorithmSpec] = None,
                 population: Optional[object] = None):
        super().__init__(fed)
        self.spec = resolve(spec if spec is not None else fed.algorithm)
        self.loss_fn = loss_fn
        self.client_batch_fn = client_batch_fn
        self.eval_fn = eval_fn
        self.rng = np.random.default_rng(fed.seed)

        self.population = self._resolve_population(population)
        n_for_state = (fed.population_size if self.population is not None
                       else fed.n_clients)
        self.opt = self.spec.make_optimizer(**(opt_kwargs or {}))
        self.lr = resolve_lr(fed, self.spec)
        beta = self.spec.resolve_beta(fed.beta)
        self.transport = fed.make_transport(self.spec)
        self.round_fn = build_round_fn(
            self.spec, loss_fn, self.opt, lr=self.lr,
            local_steps=fed.local_steps, beta=beta,
            hessian_freq=fed.hessian_freq, server_lr=fed.server_lr,
            transport=self.transport,
            executor=fed.executor_config(), n_clients=n_for_state,
            telemetry=True)
        geom = make_controller(beta, correct=self.spec.correct,
                               beta_max=BETA_MAX_AUTO)
        self.server = init_server(params, self.opt, geom=geom)
        if self.population is not None:
            from repro.core.algorithms import round_client_state_spec
            from repro.fed.population import make_client_store
            self.state_store = make_client_store(
                round_client_state_spec(self.spec, self.transport), params,
                fed.population_size, budget=fed.resolve_state_budget(),
                spill_dir=fed.spill_dir)
            self.client_state = (self.state_store.state
                                 if self.state_store is not None else None)
        else:
            self.state_store = None
            self.client_state = init_round_client_state(
                self.spec, self.transport, params, fed.n_clients)
        # persistent host staging buffers: host-side batch fns refill the
        # same (S, K, ...) arrays every round instead of re-allocating
        self._staging_buffers = StagingBuffers()
        self.pipeline = None
        if fed.pipeline:
            if self.spec.mixing is not None:
                import warnings
                warnings.warn(
                    f"algorithm {self.spec.name!r} has a mixing hook, "
                    "which needs the decoded cohort stack; pipeline=True "
                    "falls back to the serial round", RuntimeWarning,
                    stacklevel=2)
            else:
                from repro.fed.pipeline import RoundPipeline
                self.pipeline = RoundPipeline(self)

    def _resolve_population(self, population):
        from repro.fed.population import resolve_population
        return resolve_population(self.fed, population)

    # ------------------------------------------------------------ staging

    def _sample_cohort(self):
        s = max(1, int(round(self.fed.n_clients * self.fed.participation)))
        return self.rng.choice(self.fed.n_clients, size=s, replace=False)

    def _stage_batches(self, cohort):
        """Stack per-client, per-step batches -> leading (S, K, ...) axes."""
        return stage_cohort_batches(self.client_batch_fn, cohort,
                                    self.fed.local_steps, self.rng,
                                    buffers=self._staging_buffers)

    def _stage_population(self, round_index: int):
        """One population round's inputs: streamed cohort, fold_in-derived
        batches and stacked keys (round_index as the salt), and the cohort's
        state-store *slots* (acquire materializes/restores rows).  The
        host-phase split ("stage_batches" vs "state_acquire" spans) is what
        the executor benchmarks read back to attribute serial round time."""
        from repro.fed.population import stage_population_batches
        t = self.tracer
        pop = self.population
        cohort = pop.sample_cohort(round_index, self.fed.cohort_size)
        with t.span("stage_batches", round=round_index + 1):
            batches = stage_population_batches(
                self.client_batch_fn, pop, cohort, self.fed.local_steps,
                salt=round_index)
        keys = pop.cohort_keys(cohort, salt=round_index)
        with t.span("state_acquire", round=round_index + 1):
            slots = (self.state_store.acquire(cohort)
                     if self.state_store is not None else cohort)
        return slots, batches, keys

    # ------------------------------------------------------------ loop

    def run_round(self):
        t = self.tracer
        rnum = self.server.round + 1   # the round this update produces
        if self.pipeline is not None:
            # chunk-streaming pipelined round: staging/restores/compute
            # interleave per chunk (fed.pipeline emits its own spans) and
            # the driver advances server/client_state itself
            metrics = self.pipeline.run_round()
        else:
            with t.span("staging", round=rnum):
                if self.population is not None:
                    slots, batches, key = self._stage_population(rnum - 1)
                else:
                    cohort = self._sample_cohort()
                    batches = self._stage_batches(cohort)
                    key = jax.random.key(int(self.rng.integers(0, 2**31)))
                    slots = cohort
            # one jitted call fuses local update + wire encode +
            # aggregation; the span blocks on the result only when someone
            # is tracing
            with t.span("update", round=rnum):
                cstate = (self.state_store.state
                          if self.state_store is not None
                          else self.client_state)
                self.server, self.client_state, metrics = self.round_fn(
                    self.server, cstate, jnp.asarray(slots), batches, key)
                if self.state_store is not None:
                    self.state_store.state = self.client_state
                if t.enabled:
                    jax.block_until_ready(metrics)
        tele = metrics.pop("telemetry", None)
        self.last_telemetry = tele
        rec = {k: float(v) for k, v in metrics.items()}
        rec["round"] = self.server.round
        if self.state_store is not None:
            rec.update(state_resident=self.state_store.resident,
                       state_peak=self.state_store.peak_resident,
                       state_spills=self.state_store.spills,
                       state_restores=self.state_store.restores)
        if self.eval_fn is not None:
            with t.span("eval", round=rnum):
                rec.update({k: float(v) for k, v in
                            self.eval_fn(self.server.params).items()})
        if t.enabled:
            from repro.obs.telemetry import telemetry_dict
            t.round_event(rec["round"], rec,
                          telemetry=telemetry_dict(tele) if tele is not None
                          else None)
        self.history.append(rec)
        return rec

    # ------------------------------------------------------------ accounting

    def comm_bytes_per_round(self) -> int:
        return self.transport.round_bytes(
            self.server.params,
            self.server.theta if self.spec.align else None)
