"""SCAFFOLD (Karimireddy et al. 2020) — first-order control-variate baseline.

Per-client control variate c_i and server control c; local step
  x <- x - lr (g - c_i + c)
Option-II update  c_i' = c_i - c + (x0 - xK)/(K lr);
server: c <- c + (S/N) mean_i (c_i' - c_i).

Persistent per-client state is kept stacked (N, ...) so cohorts index it with
a gather — the state lives sharded over the mesh in distributed runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.server import ServerState


@dataclasses.dataclass
class ScaffoldState:
    c_global: Any          # pytree like params (f32)
    c_clients: Any         # pytree with leading N axis

    @staticmethod
    def init(params, n_clients: int):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        stacked = jax.tree.map(
            lambda p: jnp.zeros((n_clients, *p.shape), jnp.float32), params)
        return ScaffoldState(zeros, stacked)


def make_scaffold_round_fn(loss_fn, *, lr: float, local_steps: int,
                           n_clients: int, server_lr: float = 1.0):
    @jax.jit
    def round_fn(params, c_global, c_clients, cohort, batches, rng):
        def one_client(cid, batch_i):
            c_i = jax.tree.map(lambda c: c[cid], c_clients)

            def step(x, batch):
                g = jax.grad(loss_fn)(x, batch)

                def upd(p, gg, ci, c):
                    d = gg.astype(jnp.float32) - ci + c
                    return (p.astype(jnp.float32) - lr * d).astype(p.dtype)

                x = jax.tree.map(upd, x, g, c_i, c_global)
                return x, loss_fn(x, batch)

            x_final, losses = jax.lax.scan(step, params, batch_i)
            delta = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                x_final, params)
            # Option II control-variate refresh
            c_i_new = jax.tree.map(
                lambda ci, c, d: ci - c - d / (local_steps * lr),
                c_i, c_global, delta)
            c_diff = jax.tree.map(lambda a, b: a - b, c_i_new, c_i)
            return delta, c_i_new, c_diff, jnp.mean(losses)

        deltas, c_i_new, c_diffs, losses = jax.vmap(one_client)(
            cohort, batches)
        mean_delta = jax.tree.map(lambda d: jnp.mean(d, axis=0), deltas)
        new_params = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) + server_lr * d).astype(p.dtype),
            params, mean_delta)
        s = cohort.shape[0]
        new_c_global = jax.tree.map(
            lambda c, cd: c + (s / n_clients) * jnp.mean(cd, axis=0),
            c_global, c_diffs)
        new_c_clients = jax.tree.map(
            lambda all_c, upd: all_c.at[cohort].set(upd), c_clients, c_i_new)
        g_global = jax.tree.map(lambda d: -d / (local_steps * lr), mean_delta)
        return (new_params, new_c_global, new_c_clients, g_global,
                jnp.mean(losses))

    def driver(server: ServerState, state: ScaffoldState, cohort, batches,
               rng):
        p, cg, cc, g, loss = round_fn(server.params, state.c_global,
                                      state.c_clients, cohort, batches, rng)
        new_server = ServerState(p, None, g, server.round + 1)
        return new_server, ScaffoldState(cg, cc), {
            "loss": loss, "drift": jnp.zeros(())}

    return driver
