"""SCAFFOLD (Karimireddy et al. 2020) — first-order control-variate baseline.

Per-client control variate c_i and server control c; local step
  x <- x - lr (g - c_i + c)
Option-II update  c_i' = c_i - c + (x0 - xK)/(K lr);
server: c <- c + (S/N) mean_i (c_i' - c_i).

The parameter/g_G server update delegates to the unified round engine
(``core.engine.aggregate``); only the control-variate bookkeeping is
SCAFFOLD-specific.  Persistent per-client state is kept stacked (N, ...) so
cohorts index it with a gather — the state lives sharded over the mesh in
distributed runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.server import ServerState
from repro.core.engine import (
    AggregationConfig, ExecutorConfig, advance_server, aggregate,
    make_cohort_executor,
)


@dataclasses.dataclass
class ScaffoldState:
    c_global: Any          # pytree like params (f32)
    c_clients: Any         # pytree with leading N axis

    @staticmethod
    def init(params, n_clients: int):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        stacked = jax.tree.map(
            lambda p: jnp.zeros((n_clients, *p.shape), jnp.float32), params)
        return ScaffoldState(zeros, stacked)


def make_scaffold_round_fn(loss_fn, *, lr: float, local_steps: int,
                           n_clients: int, server_lr: float = 1.0,
                           executor: Optional[ExecutorConfig] = None):
    agg_cfg = AggregationConfig(lr=lr, local_steps=local_steps,
                                server_lr=server_lr, align=False)
    cohort_exec = make_cohort_executor(executor)

    @jax.jit
    def round_fn(params, g_global, c_global, c_clients, cohort, batches):
        def one_client(cid, batch_i):
            c_i = jax.tree.map(lambda c: c[cid], c_clients)

            def step(x, batch):
                g = jax.grad(loss_fn)(x, batch)

                def upd(p, gg, ci, c):
                    d = gg.astype(jnp.float32) - ci + c
                    return (p.astype(jnp.float32) - lr * d).astype(p.dtype)

                x = jax.tree.map(upd, x, g, c_i, c_global)
                return x, loss_fn(x, batch)

            x_final, losses = jax.lax.scan(step, params, batch_i)
            delta = jax.tree.map(
                lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                x_final, params)
            # Option II control-variate refresh
            c_i_new = jax.tree.map(
                lambda ci, c, d: ci - c - d / (local_steps * lr),
                c_i, c_global, delta)
            c_diff = jax.tree.map(lambda a, b: a - b, c_i_new, c_i)
            return delta, c_i_new, c_diff, jnp.mean(losses)

        deltas, c_i_new, c_diffs, losses = cohort_exec(
            one_client, cohort, batches)
        s = cohort.shape[0]
        weights = jnp.ones((s,), jnp.float32)
        new_params, _, new_g, _ = aggregate(
            params, None, g_global, deltas, None, weights, agg_cfg)
        new_c_global = jax.tree.map(
            lambda c, cd: c + (s / n_clients) * jnp.mean(cd, axis=0),
            c_global, c_diffs)
        new_c_clients = jax.tree.map(
            lambda all_c, upd: all_c.at[cohort].set(upd), c_clients, c_i_new)
        return (new_params, new_c_global, new_c_clients, new_g,
                jnp.mean(losses))

    def driver(server: ServerState, state: ScaffoldState, cohort, batches,
               rng):
        p, cg, cc, g, loss = round_fn(server.params, server.g_global,
                                      state.c_global, state.c_clients,
                                      cohort, batches)
        new_server = advance_server(server, p, None, g, aligned=False)
        return new_server, ScaffoldState(cg, cc), {
            "loss": loss, "drift": jnp.zeros(())}

    return driver
