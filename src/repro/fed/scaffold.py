"""Compat shim: SCAFFOLD moved to ``repro.core.scaffold``.

The algorithm is now a registered ``AlgorithmSpec`` whose control variates
are declared per-client state flowing through the engine's one round path —
there is no SCAFFOLD-specific round function or runtime fork anymore.
Importing this module (or ``repro.fed``) keeps ``ScaffoldState`` importable
from its historical location.
"""
from repro.core.scaffold import (  # noqa: F401
    SCAFFOLD_SPEC, ScaffoldState, make_scaffold_local_update,
)
