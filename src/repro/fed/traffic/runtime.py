"""``TrafficExperiment`` — trace-driven continuous-traffic execution.

Where the round-shaped async runtime asks "collect ``buffer_size`` reports,
flush, repeat N times", this runtime replays an open-ended **arrival
trace**: clients arrive at simulated times drawn from an
:class:`~repro.fed.traffic.traces.ArrivalProcess`, train under the server
snapshot current at their arrival, and report back after their sampled
latency.  The stream runs under *budgets* — simulated seconds and/or
wall-clock seconds — instead of a round count, and progress is measured by
**anytime eval**: the server model evaluated on a fixed simulated-time
grid, independent of when flushes happen (``eval_history``).

One event loop merges five simulated-time streams, tie-broken by a fixed
priority so the order is deterministic per seed:

  completion < arrival < churn < anytime-eval < flush-tick < algo-swap

* **completion** — the scheduler heap pops a client report; it joins the
  aggregation buffer (or is dropped/discarded/voided, each a traced
  event).  Under the ``"count"`` buffer policy a full buffer flushes
  immediately (FedBuff semantics); under ``"interval"`` the buffer waits
  for the periodic flush tick.
* **arrival** — one client is admitted into the bounded in-flight pool;
  if the pool is full the arrival queues (``backlog``) and admits at the
  next free slot, modelling an admission queue in front of the trainer
  fleet.  A *saturating* trace (``ConstantRate(rate=inf)``) skips the
  queue entirely: the pool is refilled the instant a slot frees, in the
  exact event order of the legacy round-shaped runtime — a zero-churn
  saturating trace with the ``"count"`` policy reproduces the round-shaped
  async run metric-for-metric (parity-tested).
* **churn** — ids join/leave the population (:class:`Membership`);
  departures evict persistent client state and void in-flight work.
* **swap** — the live algorithm is hot-swapped mid-stream
  (``fed.traffic.hotswap``) with warm-started geometry.

Mid-stream checkpointing (``save_checkpoint``/``load_checkpoint``) writes
the server through ``checkpoint.store`` (tracer identity included), the
scalar stream state (clocks, every rng, membership, control-event
timeline) as JSON, and the payload-carrying events (in-flight heap +
aggregation buffer wire messages) as a pickled host-array blob — a restore
in a fresh process replays the exact trailing event stream.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import pickle
import time
from typing import Optional, Union

import numpy as np
import jax

from repro.checkpoint.store import (
    load_meta, load_pytree, load_server_state, save_pytree,
    save_server_state,
)
from repro.core.algorithms import EF_STATE
from repro.fed.async_runtime.experiment import AsyncFederatedExperiment
from repro.fed.async_runtime.scheduler import Completion
from repro.fed.traffic.traces import (
    ArrivalProcess, ChurnConfig, ConstantRate, Membership, TRACES,
    make_trace,
)

_INF = float("inf")

# deterministic tie-break when several streams land on one simulated instant
_PRIO = {"completion": 0, "arrival": 1, "churn": 2, "eval": 3,
         "flush": 4, "swap": 5}

BUFFER_POLICIES = ("count", "interval")


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Continuous-traffic knobs (composes with ``FedConfig``/``AsyncConfig``).

    trace           arrival process: a catalog name (``TRACES``) or a
                    ready-made ``ArrivalProcess`` instance
    trace_kwargs    kwargs for the named trace (rate, base, period, ...)
    churn           ``ChurnConfig`` for join/leave dynamics (None: static)
    buffer_policy   "count" — flush when ``AsyncConfig.buffer_size`` reports
                    are buffered (FedBuff); "interval" — flush every
                    ``flush_interval`` simulated seconds, whatever arrived
    flush_interval  period of the "interval" policy (simulated seconds)
    eval_every      anytime-eval period in simulated seconds (None: eval
                    only at flushes, the round-shaped behavior)
    sim_budget      default simulated-seconds budget for ``run_stream``
    wall_budget     default wall-clock-seconds budget for ``run_stream``
    swap_to         algorithm name to hot-swap to mid-stream (optional)
    swap_at         simulated time of the swap (required with swap_to)
    seed            trace/churn stream seed (None: derives from fed.seed)
    """
    trace: Union[str, ArrivalProcess] = "constant"
    trace_kwargs: Optional[dict] = None
    churn: Optional[ChurnConfig] = None
    buffer_policy: str = "count"
    flush_interval: Optional[float] = None
    eval_every: Optional[float] = None
    sim_budget: Optional[float] = None
    wall_budget: Optional[float] = None
    swap_to: Optional[str] = None
    swap_at: Optional[float] = None
    seed: Optional[int] = None

    def __post_init__(self):
        if isinstance(self.trace, str) and self.trace not in TRACES:
            raise ValueError(
                f"unknown trace {self.trace!r} (want one of {TRACES} "
                "or an ArrivalProcess instance)")
        if self.buffer_policy not in BUFFER_POLICIES:
            raise ValueError(
                f"unknown buffer_policy {self.buffer_policy!r} "
                f"(want one of {BUFFER_POLICIES})")
        if self.buffer_policy == "interval" and \
                not (self.flush_interval and self.flush_interval > 0):
            raise ValueError(
                "buffer_policy='interval' needs flush_interval > 0")
        if self.eval_every is not None and self.eval_every <= 0:
            raise ValueError(f"eval_every must be > 0, got {self.eval_every}")
        if (self.swap_to is None) != (self.swap_at is None):
            raise ValueError("swap_to and swap_at come together")


class TrafficExperiment(AsyncFederatedExperiment):
    """Open-ended event-stream runtime over the buffered-async engine."""

    def __init__(self, fed, params, loss_fn, client_batch_fn, eval_fn=None,
                 opt_kwargs=None, async_cfg=None, spec=None, population=None,
                 traffic: Optional[TrafficConfig] = None):
        super().__init__(fed, params, loss_fn, client_batch_fn, eval_fn,
                         opt_kwargs, async_cfg, spec, population)
        self.tcfg = traffic if traffic is not None else TrafficConfig()
        tcfg = self.tcfg
        self._opt_kwargs = opt_kwargs
        seed = tcfg.seed if tcfg.seed is not None else fed.seed + 2

        if isinstance(tcfg.trace, ArrivalProcess):
            self.trace = tcfg.trace
        else:
            self.trace = make_trace(tcfg.trace, seed=seed,
                                    **(tcfg.trace_kwargs or {}))
        self._saturating = isinstance(self.trace, ConstantRate) \
            and self.trace.saturating

        pool = self.population.size if self.population is not None \
            else fed.n_clients
        self.membership: Optional[Membership] = None
        if tcfg.churn is not None and tcfg.churn.active:
            if self._saturating:
                raise ValueError(
                    "churn needs an open-loop arrival trace — a saturating "
                    "(rate=inf) trace is the closed-loop legacy regime")
            self.membership = Membership(
                pool, dataclasses.replace(
                    tcfg.churn, seed=tcfg.churn.seed
                    if tcfg.churn.seed else seed + 1))

        if tcfg.eval_every is not None:
            # anytime eval owns the grid; flushes stop evaluating
            self._flush_eval = False

        # open-ended stream state
        self.sim_now = 0.0
        self.backlog = 0                 # arrivals waiting for a pool slot
        self.flushes = 0
        self.eval_history: list = []
        self._buffered: list = []
        self._stale: list = []
        self._weights: list = []
        self._dropped_acc = 0
        self._discarded_acc = 0
        self._void_reason: dict = {}     # dispatch seq -> drop reason
        self._started = False
        self._next_arrival_t = _INF
        self._next_churn = (_INF, None)
        self._next_eval_t = tcfg.eval_every if tcfg.eval_every else _INF
        self._next_flush_t = tcfg.flush_interval \
            if tcfg.buffer_policy == "interval" else _INF
        self._swap_t = tcfg.swap_at if tcfg.swap_to is not None else _INF

    # ------------------------------------------------------------ stream

    def _ensure_started(self) -> None:
        if self._started:
            return
        self._started = True
        if self._saturating:
            # closed loop: the pool starts full, exactly like round 0 of
            # the legacy runtime
            self.scheduler.fill(self.server.round, self._client_payload)
        else:
            self._next_arrival_t = self.trace.next_arrival(self.sim_now)
        if self.membership is not None:
            self._next_churn = self.membership.next_event(self.sim_now)

    def _peek_next(self):
        """``(time, priority, kind)`` of the earliest pending event."""
        self._ensure_started()
        tc = self.scheduler.peek_time()
        cands = [(self._next_arrival_t, _PRIO["arrival"], "arrival"),
                 (self._next_churn[0], _PRIO["churn"], "churn"),
                 (self._next_eval_t, _PRIO["eval"], "eval"),
                 (self._next_flush_t, _PRIO["flush"], "flush"),
                 (self._swap_t, _PRIO["swap"], "swap")]
        if tc is not None:
            cands.append((tc, _PRIO["completion"], "completion"))
        t, prio, kind = min(cands)
        return (None if math.isinf(t) else (t, prio, kind))

    def _dispatch(self, version: int) -> bool:
        """Admit one arrival: membership-aware selection under churn,
        otherwise the scheduler's uniform idle draw.  False when churn
        left no idle active candidate (the arrival stays queued)."""
        sched = self.scheduler
        if self.membership is not None:
            cid = self.membership.sample_dispatch(
                sched.rng, exclude=sched._in_flight)
            if cid is None:
                return False
            sched.dispatch(cid, version, self._client_payload)
        else:
            sched.dispatch_one(version, self._client_payload)
        return True

    def _step(self) -> Optional[dict]:
        """Process exactly one event; returns the flush record if this
        event produced a server update, else None."""
        nxt = self._peek_next()
        if nxt is None:
            raise RuntimeError(
                "traffic stream is drained: no completion, arrival, churn, "
                "eval, flush, or swap event is pending")
        t, _, kind = nxt
        self.sim_now = t
        return getattr(self, f"_on_{kind}")(t)

    def _on_completion(self, t: float) -> Optional[dict]:
        acf, sched, tr = self.acfg, self.scheduler, self.tracer
        version = self.server.round
        ev = sched.next_completion()
        if self._saturating:
            # legacy order: the replacement dispatches (at the pre-flush
            # version) before the event is processed
            sched.fill(version, self._client_payload)
        elif self.backlog > 0 and sched.in_flight() < sched.concurrency:
            if self._dispatch(version):
                self.backlog -= 1
        if sched.consume_voided(ev):
            reason = self._void_reason.pop(ev.seq, "client_left")
            self._discarded_acc += 1
            tr.client_dropped(ev.client_id, reason=reason,
                              version=ev.version, sim_time=ev.time)
            # no EF restore: a departed client's residual was evicted, and
            # a swapped-out algorithm's wire format no longer decodes
            return None
        if ev.dropped:
            self._dropped_acc += 1
            tr.client_dropped(ev.client_id, reason="dropout",
                              version=ev.version, sim_time=ev.time)
            return None
        s = version - ev.version
        if acf.max_staleness is not None and s > acf.max_staleness:
            self._discarded_acc += 1
            tr.client_dropped(ev.client_id, reason="max_staleness",
                              version=ev.version, sim_time=ev.time)
            self._discard_restore(ev)
            return None
        self._buffered.append(ev)
        self._stale.append(s)
        self._weights.append(self._weight_fn(s))
        if self.tcfg.buffer_policy == "count" \
                and len(self._buffered) >= acf.buffer_size:
            return self._do_flush()
        return None

    def _on_arrival(self, t: float) -> None:
        self.trace.notify_arrival(t)
        if self.scheduler.in_flight() >= self.scheduler.concurrency \
                or not self._dispatch(self.server.round):
            self.backlog += 1
        self._next_arrival_t = self.trace.next_arrival(t)

    def _on_churn(self, t: float) -> None:
        mem, sched, tr = self.membership, self.scheduler, self.tracer
        kind = self._next_churn[1]
        if kind == "join":
            cid = mem.sample_join()
            if cid is not None:
                tr.client_join(cid, sim_time=t)
                # a join can unblock queued arrivals starved of candidates
                while self.backlog > 0 \
                        and sched.in_flight() < sched.concurrency \
                        and self._dispatch(self.server.round):
                    self.backlog -= 1
        else:
            cid = mem.sample_leave()
            if cid is not None:
                seq = sched.void(cid)
                if seq is not None:
                    self._void_reason[seq] = "client_left"
                tr.client_leave(cid, in_flight=seq is not None, sim_time=t)
                self._evict_state(cid)
        self._next_churn = mem.next_event(t)

    def _evict_state(self, cid: int) -> None:
        """A departure forgets the client's persistent server-side rows."""
        if self._ef_store is not None:
            self._ef_store.evict_client(cid)
        elif self._ef_state is not None:
            import jax.numpy as jnp
            self._ef_state = jax.tree.map(
                lambda a: a.at[cid].set(jnp.zeros_like(a[cid])),
                self._ef_state)

    def _on_eval(self, t: float) -> None:
        if self.eval_fn is None:
            raise ValueError("eval_every set but the experiment has no "
                             "eval_fn")
        with self.tracer.span("eval", round=self.server.round, sim_time=t):
            metrics = {k: float(v)
                       for k, v in self.eval_fn(self.server.params).items()}
        rec = {"sim_time": float(t), "round": int(self.server.round),
               **metrics}
        self.eval_history.append(rec)
        self.tracer.anytime_eval(metrics, sim_time=t,
                                 round=self.server.round)
        self._next_eval_t += self.tcfg.eval_every

    def _on_flush(self, t: float) -> Optional[dict]:
        self._next_flush_t += self.tcfg.flush_interval
        if not self._buffered:
            return None              # nothing arrived this interval
        return self._do_flush()

    def _on_swap(self, t: float) -> None:
        from repro.fed.traffic.hotswap import apply_swap
        apply_swap(self, self.tcfg.swap_to, opt_kwargs=self._opt_kwargs,
                   sim_time=t)
        self._swap_t = _INF

    def _do_flush(self) -> dict:
        # the server clock is the stream clock (an interval flush fires
        # between completions; its record stamps the tick time)
        self.scheduler.now = max(self.scheduler.now, self.sim_now)
        buffered, stale, weights = \
            self._buffered, self._stale, self._weights
        self._buffered, self._stale, self._weights = [], [], []
        dropped, self._dropped_acc = self._dropped_acc, 0
        discarded, self._discarded_acc = self._discarded_acc, 0
        rec = self._flush_buffer(buffered, stale, weights,
                                 dropped=dropped, discarded=discarded)
        self.flushes += 1
        return rec

    def discard_buffer(self, *, reason: str = "algo_swap") -> int:
        """Drop every buffered report (traced per client); the hot-swap
        uses this so stale-format wire messages never reach the new
        aggregator.  Returns how many were discarded."""
        n = len(self._buffered)
        for ev in self._buffered:
            self._discarded_acc += 1
            self.tracer.client_dropped(ev.client_id, reason=reason,
                                       version=ev.version,
                                       sim_time=self.sim_now)
        self._buffered, self._stale, self._weights = [], [], []
        return n

    # ------------------------------------------------------------ driving

    def run_round(self) -> dict:
        """One server update: process events until a flush happens (the
        ``FedExperiment`` contract — lets round-shaped tooling drive a
        traffic stream unchanged)."""
        while True:
            rec = self._step()
            if rec is not None:
                return rec

    def run_stream(self, sim_budget: Optional[float] = None,
                   wall_budget: Optional[float] = None,
                   max_flushes: Optional[int] = None) -> dict:
        """Replay the trace until a budget trips; returns a summary.

        ``sim_budget`` bounds the *simulated* clock (events past it stay
        pending — a later call resumes them), ``wall_budget`` the host
        wall-clock, ``max_flushes`` the number of server updates.  Budgets
        default to the config's; at least one must be set."""
        tcfg = self.tcfg
        sim_budget = sim_budget if sim_budget is not None else tcfg.sim_budget
        wall_budget = wall_budget if wall_budget is not None \
            else tcfg.wall_budget
        if sim_budget is None and wall_budget is None and max_flushes is None:
            raise ValueError("run_stream needs a sim_budget, wall_budget, "
                             "or max_flushes — open-ended otherwise")
        flushes0 = self.flushes
        t0 = time.perf_counter()
        while True:
            if max_flushes is not None \
                    and self.flushes - flushes0 >= max_flushes:
                break
            if wall_budget is not None \
                    and time.perf_counter() - t0 >= wall_budget:
                break
            nxt = self._peek_next()
            if nxt is None:
                break
            if sim_budget is not None and nxt[0] > sim_budget:
                self.sim_now = float(sim_budget)
                break
            self._step()
        return {
            "flushes": self.flushes - flushes0,
            "sim_time": float(self.sim_now),
            "wall_s": time.perf_counter() - t0,
            "evals": len(self.eval_history),
            "backlog": int(self.backlog),
            "dropped": int(self.total_dropped),
            "discarded": int(self.total_discarded),
            "joins": self.membership.joins if self.membership else 0,
            "leaves": self.membership.leaves if self.membership else 0,
            "active": (self.membership.n_active if self.membership
                       else (self.population.size if self.population
                             is not None else self.fed.n_clients)),
        }

    # ------------------------------------------------------- checkpointing

    def save_checkpoint(self, directory: str, step: Optional[int] = None
                        ) -> str:
        """Mid-stream checkpoint: server (+ tracer identity) through the
        checkpoint store, scalar stream state as JSON, payload-carrying
        events (in-flight heap + aggregation buffer) as a host-array
        pickle.  Returns the step directory."""
        from repro.fed.population.state import ClientStateStore
        if isinstance(self._ef_store, ClientStateStore):
            raise NotImplementedError(
                "mid-stream checkpointing under a budgeted sparse EF store "
                "is not supported — raise the state budget so the store is "
                "dense, or use a feedback-free transport")
        step = self.flushes if step is None else int(step)
        save_server_state(self.server, directory, step,
                          telemetry=self.tracer.state())
        d = os.path.join(directory, f"step_{step:08d}")
        state = {
            "sim_now": float(self.sim_now),
            "backlog": int(self.backlog),
            "flushes": int(self.flushes),
            "started": bool(self._started),
            "scheduler": self.scheduler.state(),
            "trace": self.trace.state(),
            "membership": self.membership.state() if self.membership
            else None,
            "batches_rng": self.rng.bit_generator.state,
            "next_arrival_t": self._next_arrival_t,
            "next_churn": [self._next_churn[0], self._next_churn[1]],
            "next_eval_t": self._next_eval_t,
            "next_flush_t": self._next_flush_t,
            "swap_t": self._swap_t,
            "void_reason": {str(k): v
                            for k, v in self._void_reason.items()},
            "total_dropped": int(self.total_dropped),
            "total_discarded": int(self.total_discarded),
            "dropped_acc": int(self._dropped_acc),
            "discarded_acc": int(self._discarded_acc),
            "stale": [int(s) for s in self._stale],
            "weights": [float(w) for w in self._weights],
            "history": self.history,
            "eval_history": self.eval_history,
        }
        with open(os.path.join(d, "traffic.json"), "w") as f:
            json.dump(state, f)
        to_host = lambda tree: jax.tree.map(np.asarray, tree)  # noqa: E731
        events = {
            "heap": [(ev.time, ev.seq, ev.client_id, ev.version, ev.dropped,
                      to_host(ev.payload))
                     for ev in self.scheduler._heap],
            "buffered": [(ev.time, ev.seq, ev.client_id, ev.version,
                          ev.dropped, to_host(ev.payload))
                         for ev in self._buffered],
        }
        with open(os.path.join(d, "traffic_events.pkl"), "wb") as f:
            pickle.dump(events, f)
        if self._ef_state is not None:
            save_pytree(self._ef_state, os.path.join(d, "ef_state.npz"))
        return d

    def load_checkpoint(self, directory: str, step: Optional[int] = None
                        ) -> None:
        """Restore a ``save_checkpoint`` into this (identically
        constructed) experiment — fresh process included.  Everything the
        constructor randomized is overwritten from the checkpoint."""
        meta = load_meta(directory, step)
        template = self.server
        if meta.get("has_theta") and template.theta is None \
                and self._theta0 is not None:
            # a freshly built experiment has theta=None until its first
            # flush; template with the zero Theta so the saved one loads
            template = dataclasses.replace(template, theta=self._theta0)
        self.server = load_server_state(template, directory, step)
        from repro.obs.trace import Tracer
        self.tracer = Tracer.from_state(meta.get("telemetry"),
                                        sinks=self.tracer.sinks)
        if step is None:
            from repro.checkpoint.store import latest_step
            step = latest_step(directory)
        d = os.path.join(directory, f"step_{step:08d}")
        with open(os.path.join(d, "traffic.json")) as f:
            state = json.load(f)
        self.sim_now = float(state["sim_now"])
        self.backlog = int(state["backlog"])
        self.flushes = int(state["flushes"])
        self._started = bool(state["started"])
        self.scheduler.load_state(state["scheduler"])
        self.trace.load_state(state["trace"])
        if state["membership"] is not None:
            if self.membership is None:
                raise ValueError(
                    "checkpoint has churn membership but this experiment "
                    "was built without a ChurnConfig")
            self.membership.load_state(state["membership"])
        self.rng.bit_generator.state = state["batches_rng"]
        self._next_arrival_t = float(state["next_arrival_t"])
        t, kind = state["next_churn"]
        self._next_churn = (float(t), kind)
        self._next_eval_t = float(state["next_eval_t"])
        self._next_flush_t = float(state["next_flush_t"])
        self._swap_t = float(state["swap_t"])
        self._void_reason = {int(k): v
                             for k, v in state["void_reason"].items()}
        self.total_dropped = int(state["total_dropped"])
        self.total_discarded = int(state["total_discarded"])
        self._dropped_acc = int(state["dropped_acc"])
        self._discarded_acc = int(state["discarded_acc"])
        self._stale = [int(s) for s in state["stale"]]
        self._weights = [float(w) for w in state["weights"]]
        self.history = list(state["history"])
        self.eval_history = list(state["eval_history"])
        with open(os.path.join(d, "traffic_events.pkl"), "rb") as f:
            events = pickle.load(f)
        self.scheduler.restore_events(
            [Completion(t_, seq, cid, ver, drp, payload)
             for t_, seq, cid, ver, drp, payload in events["heap"]])
        self._buffered = [Completion(t_, seq, cid, ver, drp, payload)
                          for t_, seq, cid, ver, drp, payload
                          in events["buffered"]]
        ef_path = os.path.join(d, "ef_state.npz")
        if self._ef_state is not None:
            self._ef_state = load_pytree(self._ef_state, ef_path)
        if self.population is not None and self._ef_state is None \
                and self._ef_store is None and os.path.exists(ef_path):
            raise ValueError("checkpoint carries an EF state this "
                             "experiment does not use")
