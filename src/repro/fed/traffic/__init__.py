"""Trace-driven continuous-traffic runtime (``repro.fed.traffic``).

Replaces "run N rounds" with "replay an arrival trace": open-ended client
event streams with churn, wall-clock/simulated-time budgets, anytime eval,
mid-stream checkpoint/rollback, and live algorithm hot-swap.  See
``runtime.TrafficExperiment`` for the execution model and ``traces`` for
the arrival/churn catalog.
"""
from repro.fed.traffic.traces import (           # noqa: F401
    ArrivalProcess, BurstyRate, ChurnConfig, ConstantRate, DiurnalRate,
    Membership, PiecewiseRate, TRACES, make_trace,
)
from repro.fed.traffic.runtime import (          # noqa: F401
    BUFFER_POLICIES, TrafficConfig, TrafficExperiment,
)
from repro.fed.traffic.hotswap import (          # noqa: F401
    apply_swap, run_ab, time_to_quality,
)
