"""Arrival-rate processes and churn models for continuous-traffic replay.

An :class:`ArrivalProcess` answers one question — *when does the next
client arrive?* — via ``next_arrival(t)``.  Arrivals are simulated-time
points at which one client is dispatched into the scheduler's bounded
in-flight pool (if the pool is full the arrival is deferred until a
completion frees a slot, modelling an admission queue).  All processes are
Poisson-thinned from a rate function ``rate(t)`` with a per-process
``np.random.Generator`` that is **separate** from the scheduler's selection
stream, so swapping trace shapes never perturbs which clients are chosen
at a given arrival instant.

Catalog
-------

* :class:`ConstantRate` — homogeneous Poisson at ``rate`` arrivals per
  simulated second.  ``rate=float('inf')`` is the *saturating* regime: the
  pool is refilled the instant a slot frees, which reproduces the legacy
  round-shaped async runtime exactly (the parity test pins this).
* :class:`DiurnalRate` — sinusoidal day/night cycle,
  ``base * (1 + amplitude * sin(2*pi*(t/period + phase)))``.
* :class:`BurstyRate` — self-exciting Hawkes process (Ogata thinning):
  every arrival bumps the intensity by ``jump``, decaying at ``decay``.
* :class:`PiecewiseRate` — rate replayed from an empirical array (one
  entry per ``bin_width`` seconds of trace), the "replay a measured
  traffic trace" hook.

Each process checkpoints with ``state()/load_state()`` (its rng bit
generator plus any scalar intensity state) so a mid-stream restore
continues the exact arrival sequence.

Churn
-----

:class:`ChurnConfig` + :class:`Membership` model clients joining and
leaving the population as two independent Poisson streams over the id
space.  A departure evicts the client's persistent state (LRU slot /
spill file) and voids its in-flight work — the completion still pops (its
simulated time passes) but the payload is discarded with a traced
``client_dropped`` reason ``"client_left"``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

_HUGE = float("inf")


class ArrivalProcess:
    """Base: thinning over ``rate(t)`` bounded by ``rate_bound(t, ...)``."""

    #: processes whose intensity depends on past arrivals (Hawkes) need to
    #: be told when an arrival was *accepted*; the runtime calls this.
    self_exciting = False

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(int(seed))

    def rate(self, t: float) -> float:            # pragma: no cover
        raise NotImplementedError

    def rate_bound(self, t: float) -> float:
        """An upper bound on ``rate`` over ``[t, inf)`` — the thinning
        envelope.  Defaults to a constant global bound."""
        raise NotImplementedError

    def next_arrival(self, t: float) -> float:
        """Simulated time of the first arrival strictly after ``t``."""
        lam_max = float(self.rate_bound(t))
        if lam_max <= 0.0:
            return _HUGE
        if math.isinf(lam_max):
            return t                   # saturating: an arrival is always due
        while True:
            t = t + self.rng.exponential(1.0 / lam_max)
            # one uniform per candidate keeps the stream aligned even when
            # rate == bound (constant rate): accept-with-prob-1 still draws
            u = self.rng.uniform()
            lam = float(self.rate(t))
            if u * lam_max <= lam:
                return t
            lam_max = float(self.rate_bound(t))

    def notify_arrival(self, t: float) -> None:
        """Hook for self-exciting processes; default is a no-op."""

    # ------------------------------------------------------- checkpointing

    def state(self) -> dict:
        return {"rng": self.rng.bit_generator.state}

    def load_state(self, state: dict) -> None:
        self.rng.bit_generator.state = state["rng"]


class ConstantRate(ArrivalProcess):
    """Homogeneous Poisson arrivals; ``rate=inf`` saturates the pool."""

    def __init__(self, rate: float, seed: int = 0):
        super().__init__(seed)
        if not (rate > 0.0):
            raise ValueError(f"arrival rate must be > 0, got {rate}")
        self._rate = float(rate)

    @property
    def saturating(self) -> bool:
        return math.isinf(self._rate)

    def rate(self, t: float) -> float:
        return self._rate

    def rate_bound(self, t: float) -> float:
        return self._rate


class DiurnalRate(ArrivalProcess):
    """Sinusoidal day/night cycle around a base rate."""

    def __init__(self, base: float, amplitude: float = 0.8,
                 period: float = 24.0, phase: float = 0.0, seed: int = 0):
        super().__init__(seed)
        if base <= 0 or not math.isfinite(base):
            raise ValueError(f"base rate must be finite > 0, got {base}")
        if not 0.0 <= amplitude <= 1.0:
            raise ValueError(
                f"amplitude must be in [0, 1] (rate stays >= 0), "
                f"got {amplitude}")
        if period <= 0:
            raise ValueError(f"period must be > 0, got {period}")
        self.base = float(base)
        self.amplitude = float(amplitude)
        self.period = float(period)
        self.phase = float(phase)

    def rate(self, t: float) -> float:
        return self.base * (1.0 + self.amplitude * math.sin(
            2.0 * math.pi * (t / self.period + self.phase)))

    def rate_bound(self, t: float) -> float:
        return self.base * (1.0 + self.amplitude)


class BurstyRate(ArrivalProcess):
    """Self-exciting Hawkes process: bursts feed on themselves.

    Intensity ``lam(t) = base + excitation * exp(-decay * (t - t_last))``
    where every accepted arrival adds ``jump`` to the excitation.  The
    stationarity condition ``jump / decay < 1`` is enforced so the process
    cannot run away.
    """

    self_exciting = True

    def __init__(self, base: float, jump: float = 0.5, decay: float = 1.0,
                 seed: int = 0):
        super().__init__(seed)
        if base <= 0 or not math.isfinite(base):
            raise ValueError(f"base rate must be finite > 0, got {base}")
        if jump < 0 or decay <= 0:
            raise ValueError(f"need jump >= 0, decay > 0 "
                             f"(got jump={jump}, decay={decay})")
        if jump / decay >= 1.0:
            raise ValueError(
                f"non-stationary Hawkes: jump/decay = {jump / decay:.3f} "
                ">= 1 (each arrival spawns >= 1 expected child)")
        self.base = float(base)
        self.jump = float(jump)
        self.decay = float(decay)
        self._excitation = 0.0         # excess intensity at _last_t
        self._last_t = 0.0

    def _excitation_at(self, t: float) -> float:
        return self._excitation * math.exp(
            -self.decay * max(0.0, t - self._last_t))

    def rate(self, t: float) -> float:
        return self.base + self._excitation_at(t)

    def rate_bound(self, t: float) -> float:
        # intensity only decays between arrivals: current value bounds it
        return self.base + self._excitation_at(t)

    def notify_arrival(self, t: float) -> None:
        self._excitation = self._excitation_at(t) + self.jump
        self._last_t = float(t)

    def state(self) -> dict:
        return {"rng": self.rng.bit_generator.state,
                "excitation": self._excitation, "last_t": self._last_t}

    def load_state(self, state: dict) -> None:
        self.rng.bit_generator.state = state["rng"]
        self._excitation = float(state["excitation"])
        self._last_t = float(state["last_t"])


class PiecewiseRate(ArrivalProcess):
    """Rate replayed from an empirical array — one entry per ``bin_width``
    simulated seconds.  Past the last bin the trace wraps (``cycle=True``,
    the default) or holds its final value."""

    def __init__(self, rates, bin_width: float = 1.0, cycle: bool = True,
                 seed: int = 0):
        super().__init__(seed)
        rates = np.asarray(rates, np.float64).ravel()
        if rates.size == 0 or rates.min() < 0 or not np.isfinite(rates).all():
            raise ValueError("rates must be a non-empty array of finite "
                             "values >= 0")
        if rates.max() <= 0:
            raise ValueError("rates are identically zero: no arrivals ever")
        if bin_width <= 0:
            raise ValueError(f"bin_width must be > 0, got {bin_width}")
        self.rates = rates
        self.bin_width = float(bin_width)
        self.cycle = bool(cycle)

    def rate(self, t: float) -> float:
        i = int(math.floor(float(t) / self.bin_width))
        n = self.rates.size
        i = i % n if self.cycle else min(max(i, 0), n - 1)
        return float(self.rates[i])

    def rate_bound(self, t: float) -> float:
        if self.cycle:
            return float(self.rates.max())
        i = int(math.floor(float(t) / self.bin_width))
        i = min(max(i, 0), self.rates.size - 1)
        return float(self.rates[i:].max())


TRACES = ("constant", "diurnal", "bursty", "piecewise")


def make_trace(kind: str, seed: int = 0, **kwargs) -> ArrivalProcess:
    """Trace factory by name — what configs and the bench sweep use."""
    if kind == "constant":
        return ConstantRate(seed=seed, **kwargs)
    if kind == "diurnal":
        return DiurnalRate(seed=seed, **kwargs)
    if kind == "bursty":
        return BurstyRate(seed=seed, **kwargs)
    if kind == "piecewise":
        return PiecewiseRate(seed=seed, **kwargs)
    raise ValueError(f"unknown trace kind {kind!r} (want one of {TRACES})")


# ---------------------------------------------------------------- churn


@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    """Client join/leave dynamics over the population id space.

    ``join_rate`` / ``leave_rate`` are Poisson rates (events per simulated
    second); ``initial_active`` caps how many ids start active (None =
    whole population).  Zero rates with ``initial_active=None`` is the
    no-churn degenerate case (every id always active)."""
    join_rate: float = 0.0
    leave_rate: float = 0.0
    initial_active: Optional[int] = None
    seed: int = 0

    def __post_init__(self):
        if self.join_rate < 0 or self.leave_rate < 0:
            raise ValueError("churn rates must be >= 0")

    @property
    def active(self) -> bool:
        return (self.join_rate > 0 or self.leave_rate > 0
                or self.initial_active is not None)


class Membership:
    """The active subset of a population id space under churn.

    Joins draw uniformly from the inactive ids, leaves uniformly from the
    active ones; both consume this object's own generator so churn noise
    never shifts the scheduler's selection or latency streams.  The whole
    structure (sets + rng) round-trips through ``state()/load_state``.
    """

    def __init__(self, population_size: int, churn: ChurnConfig):
        self.population_size = int(population_size)
        self.churn = churn
        self.rng = np.random.default_rng(int(churn.seed))
        n0 = population_size if churn.initial_active is None \
            else min(int(churn.initial_active), population_size)
        if n0 < 1:
            raise ValueError(f"initial_active must be >= 1, got {n0}")
        if n0 == population_size:
            active = np.arange(population_size, dtype=np.int64)
        else:
            active = self.rng.choice(population_size, size=n0, replace=False)
        self._active: set = {int(c) for c in active}
        self.joins = 0
        self.leaves = 0

    @property
    def n_active(self) -> int:
        return len(self._active)

    def is_active(self, cid: int) -> bool:
        return int(cid) in self._active

    def active_ids(self) -> np.ndarray:
        return np.fromiter(sorted(self._active), np.int64,
                           count=len(self._active))

    def next_event(self, t: float):
        """``(time, kind)`` of the next churn event after ``t`` — kind is
        ``"join"`` or ``"leave"`` — or ``(inf, None)`` without churn rates.
        Competing exponentials: one draw for the merged stream, one to
        attribute it."""
        jr = self.churn.join_rate if len(self._active) < \
            self.population_size else 0.0
        lr = self.churn.leave_rate if len(self._active) > 1 else 0.0
        total = jr + lr
        if total <= 0:
            return _HUGE, None
        dt = self.rng.exponential(1.0 / total)
        kind = "join" if self.rng.uniform() * total < jr else "leave"
        return t + dt, kind

    def sample_join(self) -> Optional[int]:
        """Activate one uniformly-drawn inactive id (None if all active)."""
        n_inactive = self.population_size - len(self._active)
        if n_inactive <= 0:
            return None
        # rank-based draw over the complement — no dense materialization
        k = int(self.rng.integers(n_inactive))
        cid = self._kth_inactive(k)
        self._active.add(cid)
        self.joins += 1
        return cid

    def _kth_inactive(self, k: int) -> int:
        act = sorted(self._active)
        lo = 0
        for a in act:
            gap = a - lo              # inactive ids in [lo, a)
            if k < gap:
                return lo + k
            k -= gap
            lo = a + 1
        return lo + k

    def sample_leave(self) -> Optional[int]:
        """Deactivate one uniformly-drawn active id (None if <= 1 left)."""
        if len(self._active) <= 1:
            return None
        ids = self.active_ids()
        cid = int(ids[self.rng.integers(len(ids))])
        self._active.discard(cid)
        self.leaves += 1
        return cid

    def sample_dispatch(self, rng: np.random.Generator,
                        exclude: set) -> Optional[int]:
        """One uniformly-drawn active id outside ``exclude`` — consumes the
        *scheduler's* generator (selection stream), not the churn one.
        None when churn shrank the idle active set to nothing (the arrival
        queues until a join or a completion frees a candidate)."""
        ids = self.active_ids()
        if exclude:
            ids = ids[~np.isin(ids, np.fromiter(
                (int(c) for c in exclude), np.int64, count=len(exclude)))]
        if len(ids) == 0:
            return None
        return int(ids[rng.integers(len(ids))])

    # ------------------------------------------------------- checkpointing

    def state(self) -> dict:
        return {"active": [int(c) for c in sorted(self._active)],
                "rng": self.rng.bit_generator.state,
                "joins": self.joins, "leaves": self.leaves}

    def load_state(self, state: dict) -> None:
        self._active = {int(c) for c in state["active"]}
        self.rng.bit_generator.state = state["rng"]
        self.joins = int(state["joins"])
        self.leaves = int(state["leaves"])
