"""Mid-stream algorithm hot-swap and A/B trace replay.

``apply_swap`` rebinds a live :class:`TrafficExperiment` to a new
``AlgorithmSpec`` without stopping the stream: in-flight work trained
under the old algorithm is voided (its wire format no longer decodes) and
the aggregation buffer is discarded — both surface as traced
``client_dropped`` events with reason ``"algo_swap"`` — while the server
keeps its parameters and its **warm-started geometry** (the adaptive-beta
``GeometryController`` state carries over, so the new algorithm inherits
the drift estimate instead of relearning it).  The global preconditioner
reference Theta survives only when the new optimizer's preconditioner has
the identical tree structure and shapes; otherwise it restarts cold.

``run_ab`` replays one traffic trace against two independent experiments
(A/B): built with the same seeds they see the *same arrival stream* —
identical arrival times, client selections, latencies, and dropout fates —
so any divergence in their eval trajectories is attributable to the
algorithms, not the traffic.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax


def _same_structure(a, b) -> bool:
    if jax.tree.structure(a) != jax.tree.structure(b):
        return False
    return all(tuple(getattr(x, "shape", ())) == tuple(getattr(y, "shape", ()))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def apply_swap(exp, new_spec, *, opt_kwargs: Optional[dict] = None,
               sim_time: Optional[float] = None) -> None:
    """Swap the live algorithm of a running ``TrafficExperiment``.

    Voids every in-flight dispatch, discards the buffer (all traced with
    reason ``"algo_swap"``), rebinds the spec/optimizer/transport/jitted
    paths, and rebuilds the server state keeping params + g_global +
    geometry warm."""
    sched, t = exp.scheduler, exp.tracer
    for cid in list(sched._live_seq):
        seq = sched.void(cid)
        if seq is not None:
            exp._void_reason[seq] = "algo_swap"
    exp.discard_buffer(reason="algo_swap")

    old = exp.server
    exp._bind_spec(new_spec, old.params, opt_kwargs)
    theta = None
    if exp.align and old.theta is not None \
            and _same_structure(old.theta, exp._theta0):
        theta = old.theta            # same preconditioner geometry: keep it
    exp.server = dataclasses.replace(
        old, theta=theta,
        theta_version=old.theta_version if theta is not None else old.round)
    if t.enabled:
        t.emit("run_start", runtime="traffic",
               algorithm=exp.spec.name, swapped=True,
               sim_time=float(sim_time if sim_time is not None
                              else exp.sim_now))


def run_ab(exp_a, exp_b, *, sim_budget: Optional[float] = None,
           wall_budget: Optional[float] = None,
           max_flushes: Optional[int] = None) -> dict:
    """Replay one trace against two experiments under the same budgets.

    Build both with the same ``FedConfig.seed`` and trace config so their
    arrival streams coincide; one may carry a ``swap_to``/``swap_at`` for
    the mid-stream-swap arm.  Returns both summaries + eval histories."""
    sa = exp_a.run_stream(sim_budget=sim_budget, wall_budget=wall_budget,
                          max_flushes=max_flushes)
    sb = exp_b.run_stream(sim_budget=sim_budget, wall_budget=wall_budget,
                          max_flushes=max_flushes)
    return {"a": sa, "b": sb,
            "eval_a": list(exp_a.eval_history),
            "eval_b": list(exp_b.eval_history)}


def time_to_quality(eval_history, metric: str, target: float,
                    higher_is_better: bool = True) -> Optional[float]:
    """First simulated time at which ``metric`` crosses ``target`` in an
    anytime-eval history — the continuous-traffic headline number.  None
    if the target was never reached."""
    for rec in eval_history:
        v = rec.get(metric)
        if v is None:
            continue
        if (v >= target) if higher_is_better else (v <= target):
            return float(rec["sim_time"])
    return None
