"""Chunk-streaming pipelined population rounds.

The monolithic population round serializes three phases: stage the whole
cohort's batches on host, restore/materialize every cold state row, then
launch one device program over the full (S, K, ...) stack.  This module
splits the cohort into deterministic ordered chunks and turns the round
into a software pipeline:

  * a background stager (thread pool) fills chunk i+1's batches into
    preallocated double-buffered host arrays (``StagingBuffers``) while
    chunk i's device program runs (JAX async dispatch — the chunk call
    returns before the device finishes);
  * the sparse state store prefetches chunk i+1's cold rows
    (``ClientStateStore.prefetch``) on its I/O workers and spills evicted
    rows write-behind, so restores are host-cache hits by the time a chunk
    needs them;
  * each chunk's wire uploads fold into the running f32 weighted sums
    (``engine.stream_chunk``, backed by the carry-accepting
    ``Codec.accumulate``) and one jitted ``finish_stream`` applies the
    Alg. 2 tail — the full-cohort wire stack never materializes, so peak
    memory is chunk-proportional.

Parity is exact by construction, not approximate: a single-chunk pipeline
(``pipeline_chunk >= cohort_size``) folds with ``carry=None`` and
``exact=True``, which routes through the very same contraction order as
the legacy fused round — bitwise-identical, jitted-vs-jitted.  Multi-chunk
streams are bitwise-reproducible for a fixed chunk size and identical
across stager worker counts (each client's batches derive from its own
``(seed, client_id, salt)`` stream and land in its own buffer row).

Client-state semantics under chunking: chunks read the *round-start*
state (plus their own restored rows) and write into a separate
``write_state`` — chunk boundaries are not extra communication rounds.
Chunks own disjoint slot sets, so per-chunk ``server_update`` scatters
never collide, and evolving shared globals (SCAFFOLD's ``c_global`` sum)
telescope to the cohort total.  ``write_state``, the stream carry, and the
running loss are *donated* back to each chunk step, so the round updates
them in place instead of copying per chunk.

The pipeline is a population-mode, sync-runtime feature behind
``FedConfig.pipeline``; algorithms with a ``mixing`` hook need the decoded
cohort stack and keep the legacy serial round (``fed.rounds`` warns and
falls back).
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import transport as T
from repro.core.algorithms import (
    make_local_update, make_wire_client_step, round_client_state_spec,
    state_import_many, zero_theta,
)
from repro.core.client import LocalRunConfig
from repro.core.engine import (
    AggregationConfig, BETA_MAX_AUTO, ExecutorConfig, advance_server,
    finish_stream, make_cohort_executor, make_controller, stream_chunk,
    update_controller,
)
from repro.fed.staging import (
    StagingBuffers, _stack_steps, serialized_unless_thread_safe,
)

_BUF = "pipe"   # StagingBuffers tag; keyed with the parity -> two trees


def _chunk_executor(cfg: ExecutorConfig):
    """The per-chunk executor for a config: the chunk IS the memory bound,
    so the scanning backends collapse to one vmap over the chunk; the
    sharded backends keep their mesh (a chunk spreads over devices)."""
    if cfg.backend in ("vmap", "chunked"):
        return make_cohort_executor(ExecutorConfig(backend="vmap"))
    return make_cohort_executor(
        dataclasses.replace(cfg, backend="shard_map"))


class RoundPipeline:
    """Chunk-streaming round driver bound to one ``FederatedExperiment``.

    Built by the experiment when ``fed.pipeline`` is set; ``run_round()``
    replaces the monolithic round-fn call and returns the same metrics
    dict plus pipeline observability: ``pipeline_bubble`` (fraction of the
    round wall time the host spent *blocked* waiting on staging/restores
    — the pipeline's figure of merit), chunk count, and the stage/restore
    wait split.
    """

    def __init__(self, exp):
        fed = exp.fed
        spec = exp.spec
        if not fed.population_active:
            raise ValueError("RoundPipeline requires population mode")
        if spec.mixing is not None:
            raise ValueError(
                f"algorithm {spec.name!r} has a mixing hook (needs the "
                "decoded cohort stack); the chunk-streaming pipeline "
                "cannot serve it — use the serial round")
        self.exp = exp
        self.fed = fed
        self.spec = spec
        self.opt = exp.opt
        self.transport = exp.transport
        self.cohort_size = fed.cohort_size
        self.chunk = max(1, min(fed.pipeline_chunk, fed.cohort_size))
        self.bounds = tuple(
            (a, min(a + self.chunk, self.cohort_size))
            for a in range(0, self.cohort_size, self.chunk))
        self.exact = len(self.bounds) == 1
        self.workers = fed.pipeline_workers
        self.local_steps = fed.local_steps
        self.n_clients = fed.population_size
        self.encode_theta = spec.align     # transport is always present here
        self.state_proto = round_client_state_spec(spec, exp.transport)

        beta = spec.resolve_beta(fed.beta)
        self.default_ctrl = make_controller(beta, correct=spec.correct,
                                            beta_max=BETA_MAX_AUTO)
        run = LocalRunConfig(lr=exp.lr, local_steps=fed.local_steps,
                             beta=0.0, hessian_freq=fed.hessian_freq,
                             align=spec.align)
        self.agg_cfg = AggregationConfig(lr=exp.lr,
                                         local_steps=fed.local_steps,
                                         server_lr=fed.server_lr,
                                         align=spec.align)
        local_fn = make_local_update(spec, exp.loss_fn, exp.opt, run)
        self.client_step = make_wire_client_step(
            spec, local_fn, exp.transport, self.state_proto, fused=True)
        self.chunk_exec = _chunk_executor(fed.executor_config())

        self.batch_fn = serialized_unless_thread_safe(exp.client_batch_fn)
        self.stager = ThreadPoolExecutor(max_workers=self.workers,
                                         thread_name_prefix="repro-stager")
        self.sbufs = StagingBuffers()
        if exp.state_store is not None:
            exp.state_store.enable_async_io(workers=2)

        # wire accounting: static shape math captured at trace time, keyed
        # by chunk length (the tail chunk is its own program)
        self._wire_cell: dict = {}
        # first chunk: write_state still aliases the store's live buffers
        # (read_state == write_state == round-start state), so nothing is
        # donated; later chunks own their write_state/carry/loss buffers
        # (every in-tree server_update scatters or recomputes each leaf,
        # so chunk 1's outputs share no buffer with the live store) and
        # donate them back for in-place reuse
        self._first = jax.jit(self._chunk_first)
        self._next = jax.jit(self._chunk_next, donate_argnums=(5, 6, 7))
        # the finish step runs once per round and folds the carry into
        # scalars + params-sized outputs; donating it would only save one
        # small copy while warning about the unusable theta_usum leaves
        self._finish = jax.jit(self._finish_impl)

    # ------------------------------------------------------------ jit steps

    def _chunk_body(self, params, theta, g_global, beta, read_state,
                    write_state, carry, loss_sum, slots, pend, batches,
                    keys):
        proto = self.state_proto
        if proto is not None and pend is not None:
            # graft this chunk's restored rows into BOTH states: reads see
            # them (client_view) and server_updates that leave a row
            # partially untouched must not lose them.  The read graft is
            # *internal* to this chunk's program — chunks own disjoint
            # slot sets, so no later chunk ever reads these rows, and the
            # round-start ``read_state`` never round-trips through jit
            # (returning it would copy the whole budget-sized state every
            # chunk; the write graft rides the donated buffer instead).
            pslots, rows = pend
            read_state = state_import_many(proto, read_state, pslots, rows)
            write_state = state_import_many(proto, write_state, pslots,
                                            rows)

        def one_client(cid, batch_i, key_i):
            return self.client_step(params, theta, g_global, beta,
                                    read_state, cid, batch_i, key_i)

        dmsgs, tmsgs, outs, losses = self.chunk_exec(
            one_client, slots, batches, keys)
        b = losses.shape[0]
        up = T.wire_bytes(dmsgs)
        if self.encode_theta:
            up += T.wire_bytes(tmsgs)
        self._wire_cell[int(b)] = up
        w = jnp.ones((b,), jnp.float32)
        carry = stream_chunk(carry, dmsgs, w, self.transport,
                             tmsgs=tmsgs if self.encode_theta else None,
                             thetas=None if self.encode_theta else tmsgs,
                             exact=self.exact)
        ls = jnp.sum(losses)
        loss_sum = ls if loss_sum is None else loss_sum + ls
        if proto is not None:
            write_state = proto.server_update(write_state, slots, outs,
                                              self.n_clients)
        return write_state, carry, loss_sum

    def _chunk_first(self, params, theta, g_global, beta, read_state,
                     write_state, slots, pend, batches, keys):
        return self._chunk_body(params, theta, g_global, beta, read_state,
                                write_state, None, None, slots, pend,
                                batches, keys)

    def _chunk_next(self, params, theta, g_global, beta, read_state,
                    write_state, carry, loss_sum, slots, pend, batches,
                    keys):
        return self._chunk_body(params, theta, g_global, beta, read_state,
                                write_state, carry, loss_sum, slots, pend,
                                batches, keys)

    def _finish_impl(self, params, theta, g_global, ctrl, carry, loss_sum):
        p, th, g, metrics, _aux = finish_stream(
            params, theta, g_global, carry, self.cohort_size, self.agg_cfg)
        new_ctrl = update_controller(ctrl, metrics["norm_drift"],
                                     metrics["freshness"])
        metrics = dict(metrics, loss=loss_sum / self.cohort_size,
                       beta=ctrl.beta)
        return p, th, g, new_ctrl, metrics

    # ------------------------------------------------------------- staging

    def _submit_stage(self, cohort, bounds, parity, salt):
        """Fan one chunk's clients out over the stager pool: round-robin
        slices write disjoint buffer rows, so completion order cannot
        change the staged values (worker-count determinism)."""
        a, b = bounds
        ids = [int(c) for c in cohort[a:b]]
        n = b - a
        n_tasks = max(1, min(self.workers, n))
        futs = []
        for w in range(n_tasks):
            offs = list(range(w, n, n_tasks))
            futs.append(self.stager.submit(
                self._stage_slice, [ids[o] for o in offs], offs, parity,
                n, salt))
        return futs

    def _stage_slice(self, ids, offs, parity, n, salt):
        pop = self.exp.population
        for cid, off in zip(ids, offs):
            row = _stack_steps(self.batch_fn, cid, self.local_steps,
                               pop.client_rng(cid, salt))
            buf = self.sbufs.get((_BUF, parity), n, row)
            StagingBuffers.fill_row(buf, off, row)

    def _finish_stage(self, futs, parity, n):
        for f in futs:
            f.result()               # propagate stager exceptions
        return jax.tree.map(jnp.asarray, self.sbufs.peek((_BUF, parity), n))

    @staticmethod
    def _pad_pend(pslots, rows, n):
        """Pad a chunk's pending (slots, rows) to the chunk length so every
        pending-count compiles to ONE program: padding replicates row 0,
        and duplicate scatter indices carrying identical rows are a
        well-defined no-op on the result."""
        k = len(pslots)
        if k < n:
            reps = np.concatenate(
                [np.arange(k, dtype=np.int64), np.zeros(n - k, np.int64)])
            pslots = np.asarray(pslots)[reps]
            rows = jax.tree.map(lambda x: np.asarray(x)[reps], rows)
        return jnp.asarray(np.asarray(pslots)), jax.tree.map(jnp.asarray,
                                                             rows)

    # ------------------------------------------------------------ the round

    def run_round(self) -> dict:
        """One pipelined round; advances the experiment's server/state and
        returns the metrics dict (same keys as the serial round, plus the
        ``pipeline_*`` observability fields)."""
        exp = self.exp
        t = exp.tracer
        pop = exp.population
        store = exp.state_store
        rnum = exp.server.round + 1
        ridx = rnum - 1                 # staging salt, as in the serial path
        S = self.cohort_size
        t_round = time.perf_counter()

        with t.span("staging", round=rnum):
            cohort = pop.sample_cohort(ridx, S)
            with t.span("state_acquire", round=rnum):
                slots = (store.acquire(cohort, defer_restore=True)
                         if store is not None else np.asarray(cohort))
            keys = pop.cohort_keys(cohort, salt=ridx)

        server = exp.server
        ctrl = (server.geom if server.geom is not None
                else self.default_ctrl)
        theta = server.theta
        if self.spec.align and theta is None:
            # round 0: no reference yet -> align to the fresh (zero) state
            theta = zero_theta(self.opt, server.params)
        params, g_global = server.params, server.g_global

        read_state = store.state if store is not None else None
        write_state = read_state
        carry = loss_sum = None
        stage_wait = restore_wait = 0.0

        stage_futs = {0: self._submit_stage(cohort, self.bounds[0], 0,
                                            ridx)}
        if store is not None:
            a0, b0 = self.bounds[0]
            store.prefetch(cohort[a0:b0])

        for ci, (a, b) in enumerate(self.bounds):
            if ci + 1 < len(self.bounds):
                # chunk i+1 stages and prefetches while chunk i computes
                stage_futs[ci + 1] = self._submit_stage(
                    cohort, self.bounds[ci + 1], (ci + 1) % 2, ridx)
                if store is not None:
                    na, nb = self.bounds[ci + 1]
                    store.prefetch(cohort[na:nb])
            tw = time.perf_counter()
            with t.span("chunk_stage", round=rnum, chunk=ci):
                batches = self._finish_stage(stage_futs.pop(ci), ci % 2,
                                             b - a)
            stage_wait += time.perf_counter() - tw
            pend = None
            tw = time.perf_counter()
            if store is not None:
                with t.span("chunk_restore", round=rnum, chunk=ci):
                    got = store.collect_pending(cohort[a:b])
                    if got is not None:
                        pend = self._pad_pend(*got, b - a)
            restore_wait += time.perf_counter() - tw
            chunk_slots = jnp.asarray(slots[a:b])
            chunk_keys = keys[a:b]
            # async dispatch: the span times the *launch*; device work
            # overlaps the next chunk's staging and the flush span blocks
            with t.span("chunk_compute", round=rnum, chunk=ci):
                if carry is None:
                    write_state, carry, loss_sum = self._first(
                        params, theta, g_global, ctrl.beta, read_state,
                        write_state, chunk_slots, pend, batches,
                        chunk_keys)
                else:
                    write_state, carry, loss_sum = self._next(
                        params, theta, g_global, ctrl.beta, read_state,
                        write_state, carry, loss_sum, chunk_slots, pend,
                        batches, chunk_keys)

        with t.span("flush", round=rnum):
            p, th, g, new_ctrl, metrics = self._finish(
                params, theta, g_global, ctrl, carry, loss_sum)
            jax.block_until_ready(p)

        if store is not None:
            store.state = write_state
            store.flush_io()
        exp.client_state = write_state
        exp.server = advance_server(server, p, th, g, geom=new_ctrl,
                                    aligned=self.spec.align)

        total_bytes = sum(self._wire_cell[b - a] for a, b in self.bounds)
        wall = time.perf_counter() - t_round
        bubble = (stage_wait + restore_wait) / max(wall, 1e-9)
        return dict(metrics,
                    upload_bytes=total_bytes // S,
                    upload_total_bytes=total_bytes, cohort_size=S,
                    pipeline_chunks=len(self.bounds),
                    pipeline_chunk_size=self.chunk,
                    pipeline_bubble=bubble,
                    pipeline_stage_wait_s=stage_wait,
                    pipeline_restore_wait_s=restore_wait)
