"""First-class algorithm API: the ``AlgorithmSpec`` registry and the one
uniform round path every algorithm runs through.

An algorithm is *data*, not a string: a frozen ``AlgorithmSpec`` declaring
its local optimizer, alignment/correction policy, beta policy (including
FedCM's pinned beta — the rule lives with the algorithm, not in runtime
branches), upload codec, per-client persistent state, aggregation mixing
weights, and comm accounting.  Both runtimes consume specs through one
driver signature

    round_fn(server, client_state, cohort, batches, rng)
        -> (server, client_state, metrics)

so SCAFFOLD's control variates (``core.scaffold``) and the FedPM-style
preconditioned-mixing aggregation (``core.fedpm``) flow through exactly the
same engine path as FedPAC — no special-cased forks, no dual signatures.

Registering a new algorithm takes ~10 lines and zero runtime changes::

    from repro.core.algorithms import AlgorithmSpec, register
    register(AlgorithmSpec(name="my_alg", optimizer="soap",
                           align=True, correct=True))

Legacy strings (``fedpac_soap_light``, ...) keep working: ``resolve`` maps
every name from the paper's tables onto a registered spec (``*_light`` is a
derived variant with the SVD upload codec).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core.client import LocalRunConfig, client_round
from repro.core.engine import (
    AggregationConfig, BETA_MAX_AUTO, ExecutorConfig, advance_server,
    aggregate, aggregate_wire, make_cohort_executor, make_controller,
    update_controller,
)
from repro.core.server import ServerState
from repro.core import transport as T
from repro.optim.api import LocalOptimizer
from repro.utils import hw


class UnknownAlgorithmError(ValueError):
    """Name resolves to no registered ``AlgorithmSpec``."""


class DuplicateAlgorithmError(ValueError):
    """``register`` called twice for the same name without overwrite."""


@dataclasses.dataclass(frozen=True)
class ClientStateSpec:
    """Unified per-client persistent-state protocol.

    Algorithms that carry state across rounds (SCAFFOLD's control variates)
    declare it here; the engine threads it through the one round path.
    State is kept *stacked* with a leading (N,) client axis so cohorts
    gather it inside jit and it shards over the mesh in distributed runs.

      init(params, n_clients)              -> stacked state pytree
      client_view(state, cid)              -> what one client reads
      server_update(state, cohort, outs,
                    n_clients)             -> new state (scatter + globals)

    ``outs`` is the cohort-stacked third element of the local update's
    return value (None for stateless algorithms).

    ``client_export``/``client_import`` are the sparse-population spill
    hooks: export one client's *private row* out of the stacked state /
    graft a row back in.  They default to the generic stacked-leaf slice
    (``leaf[cid]`` / ``leaf.at[cid].set(row)``), which is correct whenever
    every leaf carries the leading (N,) client axis (error-feedback
    residuals do).  States that mix per-client rows with shared globals
    (SCAFFOLD's ``c_global``) must override them so only the private part
    travels to the checkpoint store — use the module helpers
    ``state_export``/``state_import`` rather than calling these directly.
    """
    init: Callable[[Any, int], Any]
    client_view: Callable[[Any, Any], Any]
    server_update: Callable[[Any, Any, Any, int], Any]
    client_export: Optional[Callable[[Any, int], Any]] = None
    client_import: Optional[Callable[[Any, int, Any], Any]] = None
    # batched import: graft many rows (stacked along a leading axis aligned
    # with the id array) in ONE scatter.  Functional per-client .at[].set
    # copies the whole stacked state each call — O(cohort x budget) per
    # acquire — so the population store always imports through
    # ``state_import_many``; override this alongside ``client_import``
    client_import_many: Optional[Callable[[Any, Any, Any], Any]] = None


@dataclasses.dataclass(frozen=True)
class AlgorithmSpec:
    """One federated algorithm, declaratively.

    local_update: factory ``(spec, loss_fn, opt, run) -> local_fn`` with
      ``local_fn(params, theta, g_global, *, beta, view, batch_i, key_i)
      -> (delta, theta_out_or_None, client_out_or_None, loss)``;
      None selects the standard ``core.client.client_round`` path.
    mixing: optional per-client aggregation weights
      ``(deltas, thetas) -> (S,)`` fed into the engine's weighted delta
      mean (e.g. ``engine.aggregation.precond_mixing_weights``).
    pinned_beta: algorithm-mandated correction strength overriding the
      user's ``FedConfig.beta`` (FedCM's (1 - alpha) = 0.9).
    """
    name: str
    optimizer: str = "sgd"
    align: bool = False
    correct: bool = False
    pinned_beta: Optional[float] = None
    upload: str = "dense"               # Theta codec spec (transport registry;
    #                                     "svd" is the legacy lowrank alias)
    delta_upload: str = "dense"         # delta codec spec (transport registry)
    local_update: Optional[Callable] = None
    client_state: Optional[ClientStateSpec] = None
    mixing: Optional[Callable] = None
    default_lr: Optional[float] = None  # overrides the optimizer's table lr
    description: str = ""

    def __post_init__(self):
        T.validate_codec_spec(self.upload)
        T.validate_codec_spec(self.delta_upload)

    # ------------------------------------------------------------ policies

    def resolve_beta(self, requested: Union[float, str]):
        """The one beta rule: no correction => 0; pinned (FedCM and its
        variants) wins; "auto" passes through to the adaptive controller."""
        if not self.correct:
            return 0.0
        if self.pinned_beta is not None:
            return float(self.pinned_beta)
        if requested == "auto":
            return "auto"
        return float(requested)

    def make_optimizer(self, **opt_kwargs) -> LocalOptimizer:
        return optim.make(self.optimizer, **opt_kwargs)

    def make_transport(self, *, rank: int = 8, block: int = 128,
                       sketch_iters: int = 2, delta_codec=None,
                       theta_codec=None, error_feedback: bool = True,
                       use_pallas: Optional[bool] = None,
                       interpret: Optional[bool] = None,
                       wire_dtype: str = "f32") -> T.Transport:
        """Resolve this spec's wire policy (``delta_codec``/``theta_codec``
        override the spec's declared codec specs, e.g. from FedConfig).
        ``use_pallas=None``/``interpret=None`` resolve through the shared
        backend auto rule (``repro.utils.hw``): real Pallas kernels on
        TPU, the jnp reference/interpreter everywhere else.
        ``wire_dtype`` caps floating payload dtypes on the wire
        ("f32" native | "bf16")."""
        cfg = T.TransportConfig(rank=rank, block=block,
                                sketch_iters=sketch_iters,
                                use_pallas=hw.resolve_use_pallas(use_pallas),
                                interpret=hw.resolve_interpret(interpret),
                                wire_dtype=wire_dtype)
        return T.Transport(
            delta=T.resolve_codec(
                self.delta_upload if delta_codec is None else delta_codec,
                cfg),
            theta=T.resolve_codec(
                self.upload if theta_codec is None else theta_codec, cfg),
            error_feedback=error_feedback)

    def init_client_state(self, params, n_clients: int):
        """Fresh persistent state (None for stateless algorithms)."""
        if self.client_state is None:
            return None
        return self.client_state.init(params, n_clients)

    def comm_bytes(self, params, theta, *, svd_rank: Optional[int] = None
                   ) -> int:
        """Per-client upload bytes for one round (Table 6 accounting).

        Deprecated shim: measured from the wire messages this spec's
        default transport encodes (``transport.wire_bytes``)."""
        transport = self.make_transport(rank=svd_rank or 8)
        return transport.round_bytes(params, theta if self.align else None)

    # ------------------------------------------------------------ variants

    def light(self) -> "AlgorithmSpec":
        """Derived ``<name>_light`` variant: rank-r SVD Theta upload."""
        return dataclasses.replace(self, name=f"{self.name}_light",
                                   upload="svd")


# ----------------------------------------------------------------- registry

_REGISTRY: dict[str, AlgorithmSpec] = {}
_BUILTINS_LOADED = False


def _ensure_builtins():
    """Import the modules that register built-in specs (idempotent).

    SCAFFOLD and FedPM live in their own modules and self-register on
    import; loading them lazily keeps this module import-cycle-free.
    """
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    from repro.core import scaffold, fedpm  # noqa: F401  (self-registering)
    _BUILTINS_LOADED = True  # only after the imports succeed: a transient
    #                          failure must not poison the registry


def register(spec: AlgorithmSpec, *, overwrite: bool = False) -> AlgorithmSpec:
    """Add ``spec`` to the registry; returns it for chaining."""
    if not isinstance(spec, AlgorithmSpec):
        raise TypeError(f"register wants an AlgorithmSpec, got {type(spec)}")
    if spec.optimizer not in optim.available():
        raise ValueError(
            f"spec {spec.name!r} names unknown optimizer {spec.optimizer!r} "
            f"(want one of {optim.available()})")
    if spec.name in _REGISTRY and not overwrite:
        raise DuplicateAlgorithmError(
            f"algorithm {spec.name!r} is already registered "
            "(pass overwrite=True to replace it)")
    _REGISTRY[spec.name] = spec
    return spec


def registered() -> tuple:
    """Sorted names of all registered algorithms."""
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def get(name: str) -> AlgorithmSpec:
    _ensure_builtins()
    if name in _REGISTRY:
        return _REGISTRY[name]
    if name.endswith("_light"):
        base = name[: -len("_light")]
        if base in _REGISTRY:
            return _REGISTRY[base].light()
    raise UnknownAlgorithmError(
        f"unknown algorithm {name!r}: registered specs are "
        f"{', '.join(registered())} (append '_light' for the rank-r SVD "
        "Theta upload); add new ones via repro.core.algorithms.register")


def resolve(spec_or_name: Union[str, AlgorithmSpec]) -> AlgorithmSpec:
    """Spec passes through; strings (incl. every legacy paper-table name)
    resolve against the registry."""
    if isinstance(spec_or_name, AlgorithmSpec):
        return spec_or_name
    return get(str(spec_or_name))


# -------------------------------------------------------- uniform round path

def zero_theta(opt: LocalOptimizer, params):
    """Fresh (zero) preconditioner pytree for ``opt`` on ``params``.

    Round 0 has no global reference yet; both runtimes align to this."""
    state = jax.eval_shape(opt.init, params)
    theta_shape = jax.eval_shape(lambda s: opt.get_precond(s), state)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), theta_shape)


def make_local_update(spec: AlgorithmSpec, loss_fn: Callable,
                      opt: LocalOptimizer, run: LocalRunConfig) -> Callable:
    """The spec's local update; defaults to the standard ``client_round``."""
    if spec.local_update is not None:
        return spec.local_update(spec, loss_fn, opt, run)

    def local_fn(params, theta, g_global, *, beta, view, batch_i, key_i):
        del view  # stateless
        delta, theta_out, loss = client_round(
            loss_fn, opt, run, params, theta, g_global, batch_i, key_i,
            beta=beta)
        return delta, theta_out, None, loss

    return local_fn


def make_wire_client_step(spec: AlgorithmSpec, local_fn: Callable,
                          transport: Optional[T.Transport],
                          state_proto: Optional[ClientStateSpec], *,
                          fused: bool) -> Callable:
    """One client's round, from state view to wire message.

    ``client_step(params, theta, g_global, beta, cstate, cid, batch_i,
    key_i) -> (dchan, tmsg, out, loss)`` — the body ``build_round_fn``
    vmaps over the cohort, factored out so the chunk-streaming pipeline
    (``fed.pipeline``) traces the *identical* per-client computation
    (parity between the two paths is bitwise, not just numeric).

    The client-side encode is the wire boundary: what leaves the client IS
    the wire msg.  The fused server path reduces wire messages directly,
    so the decoded tree stays a client-local transient (it still forms the
    EF residual); only the decode-then-aggregate fallback (``fused=False``,
    mixing hooks) ships it server-side alongside the message.
    """
    ef_active = transport is not None and transport.feedback_active
    has_algo_state = spec.client_state is not None
    encode_theta = transport is not None and spec.align

    def client_step(params, theta, g_global, beta, cstate, cid, batch_i,
                    key_i):
        view = (state_proto.client_view(cstate, cid)
                if state_proto is not None else None)
        if ef_active:
            algo_view, residual = view if has_algo_state else (None, view)
        else:
            algo_view, residual = view, None
        delta, theta_out, algo_out, loss = local_fn(
            params, theta, g_global, beta=beta, view=algo_view,
            batch_i=batch_i, key_i=key_i)
        if transport is None:
            return delta, theta_out, algo_out, loss
        dmsg, decoded, new_residual = T.encode_with_feedback(
            transport.delta, delta, residual)
        dchan = (dmsg, decoded) if (ef_active and not fused) else dmsg
        tmsg = (transport.theta.encode(theta_out) if encode_theta
                else theta_out)
        if ef_active:
            out = ((algo_out, new_residual) if has_algo_state
                   else new_residual)
        else:
            out = algo_out
        return dchan, tmsg, out, loss

    return client_step


def state_export(proto: ClientStateSpec, state, cid):
    """One client's private state row (the unit the sparse population store
    spills to the checkpoint store).  Generic stacked-leaf slice unless the
    spec overrides ``client_export``."""
    if proto.client_export is not None:
        return proto.client_export(state, cid)
    return jax.tree.map(lambda x: x[cid], state)


def state_import(proto: ClientStateSpec, state, cid, row):
    """Graft a private row (from ``state_export`` or a spill file) back into
    the stacked state at ``cid``."""
    if proto.client_import is not None:
        return proto.client_import(state, cid, row)
    return jax.tree.map(lambda x, r: x.at[cid].set(r), state, row)


def state_import_many(proto: ClientStateSpec, state, cids, rows):
    """Graft many private rows in one scatter (``rows`` stacked along a
    leading axis aligned with ``cids``).

    This is the population store's import path: a single functional
    ``.at[ids].set`` costs one full-state copy total, where per-client
    ``state_import`` would copy the whole stacked state once *per client*
    (O(cohort x budget) — quadratic in the cohort when the budget tracks
    it).  Values are identical to sequential imports at distinct ids.
    Specs that override ``client_import`` without a batched variant fall
    back to the sequential path."""
    if proto.client_import_many is not None:
        return proto.client_import_many(state, cids, rows)
    if proto.client_import is not None:
        # sequential fallback: host ids only (specs that want jit-traced
        # grafts — the pipeline's in-step restore — override
        # ``client_import_many``)
        for i, cid in enumerate(np.asarray(cids)):
            state = proto.client_import(
                state, int(cid), jax.tree.map(lambda x: x[i], rows))
        return state
    ids = jnp.asarray(cids)   # may be traced: the pipeline grafts in-jit
    return jax.tree.map(lambda x, r: x.at[ids].set(r), state, rows)


# error-feedback residuals, declared through the same per-client state
# protocol as algorithm state (SCAFFOLD's variates): the engine gathers the
# cohort's residuals inside jit and scatters the refreshed ones back.
EF_STATE = ClientStateSpec(init=T.ef_init, client_view=T.ef_view,
                           server_update=lambda s, cohort, outs, n:
                           T.ef_scatter(s, cohort, outs))


def _compose_state_specs(algo: ClientStateSpec,
                         ef: ClientStateSpec) -> ClientStateSpec:
    """Pair algorithm state with transport (EF) state: one protocol, two
    independently-threaded slots."""
    return ClientStateSpec(
        init=lambda p, n: (algo.init(p, n), ef.init(p, n)),
        client_view=lambda s, cid: (algo.client_view(s[0], cid),
                                    ef.client_view(s[1], cid)),
        server_update=lambda s, cohort, outs, n: (
            algo.server_update(s[0], cohort, outs[0], n),
            ef.server_update(s[1], cohort, outs[1], n)),
        client_export=lambda s, cid: (state_export(algo, s[0], cid),
                                      state_export(ef, s[1], cid)),
        client_import=lambda s, cid, row: (
            state_import(algo, s[0], cid, row[0]),
            state_import(ef, s[1], cid, row[1])),
        client_import_many=lambda s, cids, rows: (
            state_import_many(algo, s[0], cids, rows[0]),
            state_import_many(ef, s[1], cids, rows[1])))


def round_client_state_spec(spec: AlgorithmSpec,
                            transport: Optional[T.Transport] = None
                            ) -> Optional[ClientStateSpec]:
    """The full per-client state protocol of one run: the algorithm's
    declared state, the transport's error-feedback residuals (lossy delta
    codec only), their composition, or None."""
    ef = EF_STATE if (transport is not None
                      and transport.feedback_active) else None
    algo = spec.client_state
    if ef is None:
        return algo
    if algo is None:
        return ef
    return _compose_state_specs(algo, ef)


def init_round_client_state(spec: AlgorithmSpec, transport, params,
                            n_clients: int):
    """Fresh state matching ``round_client_state_spec`` (None if stateless)."""
    proto = round_client_state_spec(spec, transport)
    return proto.init(params, n_clients) if proto is not None else None


def build_round_fn(
    spec: AlgorithmSpec,
    loss_fn: Callable,
    opt: LocalOptimizer,
    *,
    lr: float,
    local_steps: int,
    beta: Union[float, str] = 0.5,
    hessian_freq: int = 10,
    server_lr: float = 1.0,
    compress_fn: Optional[Callable] = None,
    transport: Optional[T.Transport] = None,
    beta_max: float = BETA_MAX_AUTO,
    drift_ema: float = 1.0,
    executor: Optional[ExecutorConfig] = None,
    n_clients: Optional[int] = None,
    jit: bool = True,
    telemetry: bool = False,
):
    """The one round implementation, for every registered algorithm.

    Returns ``driver(server, client_state, cohort, batches, rng) ->
    (server, client_state, metrics)`` — the uniform signature both runtimes
    use (``client_state`` is None for stateless algorithms).  batches carry
    leading (S, K, ...) axes; ``cohort`` is the (S,) array of client ids
    (persistent state is gathered/scattered by it inside jit).

    ``transport`` routes the uploads through wire-true codecs: each client
    encodes its delta (error-compensated for lossy codecs) and, for
    aligned algorithms, its Theta; the server runs the *fused* flush
    (``engine.aggregate_wire``) — encoded uploads accumulate straight into
    the weighted sums via ``Codec.accumulate``, never materializing the
    decoded per-client stack — and reports the measured ``upload_bytes``.
    Algorithms with a ``mixing`` hook (which consumes the decoded cohort)
    fall back to decode-then-``aggregate``.  ``compress_fn`` is the legacy
    stacked Theta round-trip (exclusive with ``transport``); None for both
    is the plain dense path.

    ``telemetry=True`` additionally computes the jit-pure ``Telemetry``
    diagnostics (``repro.obs.telemetry``) inside the round and returns the
    pytree under ``metrics["telemetry"]`` — the same ``collect`` the async
    flush runs, so sync and zero-staleness-async telemetry agree bitwise.
    """
    if transport is not None and compress_fn is not None:
        raise ValueError("pass either transport or the legacy compress_fn, "
                         "not both")
    state_proto = round_client_state_spec(spec, transport)
    ef_active = transport is not None and transport.feedback_active
    has_algo_state = spec.client_state is not None
    if state_proto is not None and n_clients is None:
        raise ValueError(
            f"algorithm {spec.name!r} carries per-client state "
            f"({'error-feedback residuals' if not has_algo_state else 'declared algorithm state'}); "
            "build_round_fn needs n_clients")
    encode_theta = transport is not None and spec.align
    # the fused wire path needs no decoded cohort; mixing hooks consume
    # the decoded stacks, so they keep the decode-then-aggregate path
    fused = transport is not None and spec.mixing is None
    default_ctrl = make_controller(beta, correct=spec.correct,
                                   beta_max=beta_max, ema=drift_ema)
    run = LocalRunConfig(lr=lr, local_steps=local_steps, beta=0.0,
                         hessian_freq=hessian_freq, align=spec.align)
    agg_cfg = AggregationConfig(lr=lr, local_steps=local_steps,
                                server_lr=server_lr, align=spec.align)
    cohort_exec = make_cohort_executor(executor)
    local_fn = make_local_update(spec, loss_fn, opt, run)
    client_step = make_wire_client_step(spec, local_fn, transport,
                                        state_proto, fused=fused)
    # wire accounting is static shape math: captured at trace time and
    # reported host-side as an exact int (f32 metrics would round above
    # 2^24 bytes)
    wire_cell = {}

    def round_fn(params, theta, g_global, ctrl, cstate, cohort, batches, rng):
        s = jax.tree.leaves(batches)[0].shape[0]
        # rng is either one round key (legacy: split S ways) or an already
        # stacked (S,) vector of per-client fold_in-derived keys (population
        # runs, where a client's stream must not depend on cohort makeup).
        # Typed keys make this a static trace-time branch: scalar key
        # ndim == 0, stacked ndim == 1.
        if jnp.issubdtype(rng.dtype, jax.dtypes.prng_key) and rng.ndim == 1:
            keys = rng
        else:
            keys = jax.random.split(rng, s)

        def one_client(cid, batch_i, key_i):
            return client_step(params, theta, g_global, ctrl.beta, cstate,
                               cid, batch_i, key_i)

        deltas, thetas, outs, losses = cohort_exec(
            one_client, cohort, batches, keys)
        step = None
        weights = jnp.ones((s,), jnp.float32)
        if fused:
            # fused wire path: the stacked messages reduce straight into
            # the weighted sums (Codec.accumulate); byte counts are static
            # shape math over those same structures, recorded as the exact
            # total + cohort size (no truncating division)
            up_bytes = T.wire_bytes(deltas)
            if encode_theta:
                up_bytes += T.wire_bytes(thetas)
            wire_cell["total"] = up_bytes
            wire_cell["cohort"] = s
            new_params, new_theta, new_g, agg, aux = aggregate_wire(
                params, theta, g_global, deltas, weights, agg_cfg,
                transport, tmsgs=thetas if encode_theta else None,
                thetas=None if encode_theta else thetas,
                need_thetas=telemetry)
            deltas, thetas, step = None, aux["thetas"], aux["step"]
        else:
            if transport is not None:
                # decode-then-aggregate fallback: mixing hooks consume the
                # decoded cohort, so it must materialize here
                if ef_active:
                    dmsgs, deltas = deltas
                    up_bytes = T.wire_bytes(dmsgs)
                else:
                    up_bytes = T.wire_bytes(deltas)
                    deltas = jax.vmap(transport.delta.decode)(deltas)
                if encode_theta:
                    up_bytes += T.wire_bytes(thetas)
                    thetas = jax.vmap(transport.theta.decode)(thetas)
                wire_cell["total"] = up_bytes
                wire_cell["cohort"] = s
            elif compress_fn is not None and thetas is not None:
                # legacy path: clients upload compressed Theta; server
                # aggregates the decoded reconstruction (Table 6 trade-off)
                thetas = compress_fn(thetas)
            if spec.mixing is not None:
                weights = spec.mixing(deltas, thetas)
            new_params, new_theta, new_g, agg = aggregate(
                params, theta, g_global, deltas, thetas, weights, agg_cfg)
        new_cstate = (state_proto.server_update(cstate, cohort, outs,
                                                n_clients)
                      if state_proto is not None else cstate)
        new_ctrl = update_controller(ctrl, agg["norm_drift"],
                                     agg["freshness"])
        metrics = dict(agg, loss=jnp.mean(losses), beta=ctrl.beta)
        if telemetry:
            from repro.obs import telemetry as obs_telemetry
            metrics["telemetry"] = obs_telemetry.collect(
                deltas=deltas, step=step, thetas=thetas, weights=weights,
                g_global=g_global, ctrl=ctrl, new_ctrl=new_ctrl,
                agg_metrics=agg)
        return new_params, new_theta, new_g, new_ctrl, new_cstate, metrics

    if jit:
        round_fn = jax.jit(round_fn)

    def driver(server: ServerState, cstate, cohort, batches, rng):
        ctrl = server.geom if server.geom is not None else default_ctrl
        theta = server.theta
        if spec.align and theta is None:
            # round 0: no reference yet -> align to the fresh (zero) state.
            theta = zero_theta(opt, server.params)
        p, th, g, new_ctrl, new_cstate, metrics = round_fn(
            server.params, theta, server.g_global, ctrl, cstate, cohort,
            batches, rng)
        if transport is not None:
            # exact host-side ints captured at trace time (never lossy f32
            # device scalars); upload_bytes keeps its historical per-client
            # meaning while the untruncated total rides along
            total, cohort = wire_cell["total"], wire_cell["cohort"]
            metrics = dict(metrics, upload_bytes=total // cohort,
                           upload_total_bytes=total, cohort_size=cohort)
        new_server = advance_server(server, p, th, g, geom=new_ctrl,
                                    aligned=spec.align)
        return new_server, new_cstate, metrics

    return driver


# ------------------------------------------------------- built-in algorithms

def _register_stateless_builtins():
    register(AlgorithmSpec(
        name="fedavg", optimizer="sgd",
        description="SGD locally, parameter averaging"))
    register(AlgorithmSpec(
        name="fedcm", optimizer="sgd", correct=True, pinned_beta=0.9,
        description="client momentum: correction-only SGD, beta pinned to "
                    "(1 - alpha) = 0.9"))
    for opt_name in optim.available():
        register(AlgorithmSpec(
            name=f"local_{opt_name}", optimizer=opt_name,
            description=f"FedSOA (Alg. 1) with {opt_name}: fresh local "
                        "state each round, parameter averaging"))
        register(AlgorithmSpec(
            name=f"fedpac_{opt_name}", optimizer=opt_name, align=True,
            correct=True,
            description=f"FedPAC (Alg. 2) with {opt_name}: preconditioner "
                        "Alignment + direction Correction"))
        register(AlgorithmSpec(
            name=f"align_only_{opt_name}", optimizer=opt_name, align=True,
            description="Table 5 ablation: Alignment without Correction"))
        register(AlgorithmSpec(
            name=f"correct_only_{opt_name}", optimizer=opt_name,
            correct=True,
            description="Table 5 ablation: Correction without Alignment"))


_register_stateless_builtins()
