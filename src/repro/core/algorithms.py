"""First-class algorithm API: the ``AlgorithmSpec`` registry and the one
uniform round path every algorithm runs through.

An algorithm is *data*, not a string: a frozen ``AlgorithmSpec`` declaring
its local optimizer, alignment/correction policy, beta policy (including
FedCM's pinned beta — the rule lives with the algorithm, not in runtime
branches), upload codec, per-client persistent state, aggregation mixing
weights, and comm accounting.  Both runtimes consume specs through one
driver signature

    round_fn(server, client_state, cohort, batches, rng)
        -> (server, client_state, metrics)

so SCAFFOLD's control variates (``core.scaffold``) and the FedPM-style
preconditioned-mixing aggregation (``core.fedpm``) flow through exactly the
same engine path as FedPAC — no special-cased forks, no dual signatures.

Registering a new algorithm takes ~10 lines and zero runtime changes::

    from repro.core.algorithms import AlgorithmSpec, register
    register(AlgorithmSpec(name="my_alg", optimizer="soap",
                           align=True, correct=True))

Legacy strings (``fedpac_soap_light``, ...) keep working: ``resolve`` maps
every name from the paper's tables onto a registered spec (``*_light`` is a
derived variant with the SVD upload codec).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro import optim
from repro.core.client import LocalRunConfig, client_round
from repro.core.compression import make_svd_codec, round_comm_bytes
from repro.core.engine import (
    AggregationConfig, BETA_MAX_AUTO, ExecutorConfig, advance_server,
    aggregate, make_cohort_executor, make_controller, update_controller,
)
from repro.core.server import ServerState
from repro.optim.api import LocalOptimizer

UPLOADS = ("dense", "svd")


class UnknownAlgorithmError(ValueError):
    """Name resolves to no registered ``AlgorithmSpec``."""


class DuplicateAlgorithmError(ValueError):
    """``register`` called twice for the same name without overwrite."""


@dataclasses.dataclass(frozen=True)
class ClientStateSpec:
    """Unified per-client persistent-state protocol.

    Algorithms that carry state across rounds (SCAFFOLD's control variates)
    declare it here; the engine threads it through the one round path.
    State is kept *stacked* with a leading (N,) client axis so cohorts
    gather it inside jit and it shards over the mesh in distributed runs.

      init(params, n_clients)              -> stacked state pytree
      client_view(state, cid)              -> what one client reads
      server_update(state, cohort, outs,
                    n_clients)             -> new state (scatter + globals)

    ``outs`` is the cohort-stacked third element of the local update's
    return value (None for stateless algorithms).
    """
    init: Callable[[Any, int], Any]
    client_view: Callable[[Any, Any], Any]
    server_update: Callable[[Any, Any, Any, int], Any]


@dataclasses.dataclass(frozen=True)
class AlgorithmSpec:
    """One federated algorithm, declaratively.

    local_update: factory ``(spec, loss_fn, opt, run) -> local_fn`` with
      ``local_fn(params, theta, g_global, *, beta, view, batch_i, key_i)
      -> (delta, theta_out_or_None, client_out_or_None, loss)``;
      None selects the standard ``core.client.client_round`` path.
    mixing: optional per-client aggregation weights
      ``(deltas, thetas) -> (S,)`` fed into the engine's weighted delta
      mean (e.g. ``engine.aggregation.precond_mixing_weights``).
    pinned_beta: algorithm-mandated correction strength overriding the
      user's ``FedConfig.beta`` (FedCM's (1 - alpha) = 0.9).
    """
    name: str
    optimizer: str = "sgd"
    align: bool = False
    correct: bool = False
    pinned_beta: Optional[float] = None
    upload: str = "dense"               # "dense" | "svd" (*_light variants)
    local_update: Optional[Callable] = None
    client_state: Optional[ClientStateSpec] = None
    mixing: Optional[Callable] = None
    default_lr: Optional[float] = None  # overrides the optimizer's table lr
    description: str = ""

    def __post_init__(self):
        if self.upload not in UPLOADS:
            raise ValueError(
                f"unknown upload codec {self.upload!r} "
                f"(want one of {UPLOADS})")

    # ------------------------------------------------------------ policies

    def resolve_beta(self, requested: Union[float, str]):
        """The one beta rule: no correction => 0; pinned (FedCM and its
        variants) wins; "auto" passes through to the adaptive controller."""
        if not self.correct:
            return 0.0
        if self.pinned_beta is not None:
            return float(self.pinned_beta)
        if requested == "auto":
            return "auto"
        return float(requested)

    def make_optimizer(self, **opt_kwargs) -> LocalOptimizer:
        return optim.make(self.optimizer, **opt_kwargs)

    def make_codec(self, svd_rank: int) -> Optional[Callable]:
        """Upload codec for Theta (None: dense upload)."""
        return make_svd_codec(svd_rank) if self.upload == "svd" else None

    def init_client_state(self, params, n_clients: int):
        """Fresh persistent state (None for stateless algorithms)."""
        if self.client_state is None:
            return None
        return self.client_state.init(params, n_clients)

    def comm_bytes(self, params, theta, *, svd_rank: Optional[int] = None
                   ) -> int:
        """Per-client upload bytes for one round (Table 6 accounting)."""
        return round_comm_bytes(
            params, theta if self.align else None,
            compressed_rank=svd_rank if self.upload == "svd" else None)

    # ------------------------------------------------------------ variants

    def light(self) -> "AlgorithmSpec":
        """Derived ``<name>_light`` variant: rank-r SVD Theta upload."""
        return dataclasses.replace(self, name=f"{self.name}_light",
                                   upload="svd")


# ----------------------------------------------------------------- registry

_REGISTRY: dict[str, AlgorithmSpec] = {}
_BUILTINS_LOADED = False


def _ensure_builtins():
    """Import the modules that register built-in specs (idempotent).

    SCAFFOLD and FedPM live in their own modules and self-register on
    import; loading them lazily keeps this module import-cycle-free.
    """
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    from repro.core import scaffold, fedpm  # noqa: F401  (self-registering)
    _BUILTINS_LOADED = True  # only after the imports succeed: a transient
    #                          failure must not poison the registry


def register(spec: AlgorithmSpec, *, overwrite: bool = False) -> AlgorithmSpec:
    """Add ``spec`` to the registry; returns it for chaining."""
    if not isinstance(spec, AlgorithmSpec):
        raise TypeError(f"register wants an AlgorithmSpec, got {type(spec)}")
    if spec.optimizer not in optim.available():
        raise ValueError(
            f"spec {spec.name!r} names unknown optimizer {spec.optimizer!r} "
            f"(want one of {optim.available()})")
    if spec.name in _REGISTRY and not overwrite:
        raise DuplicateAlgorithmError(
            f"algorithm {spec.name!r} is already registered "
            "(pass overwrite=True to replace it)")
    _REGISTRY[spec.name] = spec
    return spec


def registered() -> tuple:
    """Sorted names of all registered algorithms."""
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def get(name: str) -> AlgorithmSpec:
    _ensure_builtins()
    if name in _REGISTRY:
        return _REGISTRY[name]
    if name.endswith("_light"):
        base = name[: -len("_light")]
        if base in _REGISTRY:
            return _REGISTRY[base].light()
    raise UnknownAlgorithmError(
        f"unknown algorithm {name!r}: registered specs are "
        f"{', '.join(registered())} (append '_light' for the rank-r SVD "
        "Theta upload); add new ones via repro.core.algorithms.register")


def resolve(spec_or_name: Union[str, AlgorithmSpec]) -> AlgorithmSpec:
    """Spec passes through; strings (incl. every legacy paper-table name)
    resolve against the registry."""
    if isinstance(spec_or_name, AlgorithmSpec):
        return spec_or_name
    return get(str(spec_or_name))


# -------------------------------------------------------- uniform round path

def zero_theta(opt: LocalOptimizer, params):
    """Fresh (zero) preconditioner pytree for ``opt`` on ``params``.

    Round 0 has no global reference yet; both runtimes align to this."""
    state = jax.eval_shape(opt.init, params)
    theta_shape = jax.eval_shape(lambda s: opt.get_precond(s), state)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), theta_shape)


def make_local_update(spec: AlgorithmSpec, loss_fn: Callable,
                      opt: LocalOptimizer, run: LocalRunConfig) -> Callable:
    """The spec's local update; defaults to the standard ``client_round``."""
    if spec.local_update is not None:
        return spec.local_update(spec, loss_fn, opt, run)

    def local_fn(params, theta, g_global, *, beta, view, batch_i, key_i):
        del view  # stateless
        delta, theta_out, loss = client_round(
            loss_fn, opt, run, params, theta, g_global, batch_i, key_i,
            beta=beta)
        return delta, theta_out, None, loss

    return local_fn


def build_round_fn(
    spec: AlgorithmSpec,
    loss_fn: Callable,
    opt: LocalOptimizer,
    *,
    lr: float,
    local_steps: int,
    beta: Union[float, str] = 0.5,
    hessian_freq: int = 10,
    server_lr: float = 1.0,
    compress_fn: Optional[Callable] = None,
    beta_max: float = BETA_MAX_AUTO,
    drift_ema: float = 1.0,
    executor: Optional[ExecutorConfig] = None,
    n_clients: Optional[int] = None,
    jit: bool = True,
):
    """The one round implementation, for every registered algorithm.

    Returns ``driver(server, client_state, cohort, batches, rng) ->
    (server, client_state, metrics)`` — the uniform signature both runtimes
    use (``client_state`` is None for stateless algorithms).  batches carry
    leading (S, K, ...) axes; ``cohort`` is the (S,) array of client ids
    (persistent state is gathered/scattered by it inside jit).
    """
    state_proto = spec.client_state
    if state_proto is not None and n_clients is None:
        raise ValueError(
            f"algorithm {spec.name!r} declares per-client state; "
            "build_round_fn needs n_clients")
    default_ctrl = make_controller(beta, correct=spec.correct,
                                   beta_max=beta_max, ema=drift_ema)
    run = LocalRunConfig(lr=lr, local_steps=local_steps, beta=0.0,
                         hessian_freq=hessian_freq, align=spec.align)
    agg_cfg = AggregationConfig(lr=lr, local_steps=local_steps,
                                server_lr=server_lr, align=spec.align)
    cohort_exec = make_cohort_executor(executor)
    local_fn = make_local_update(spec, loss_fn, opt, run)

    def round_fn(params, theta, g_global, ctrl, cstate, cohort, batches, rng):
        s = jax.tree.leaves(batches)[0].shape[0]
        keys = jax.random.split(rng, s)

        def one_client(cid, batch_i, key_i):
            view = (state_proto.client_view(cstate, cid)
                    if state_proto is not None else None)
            return local_fn(params, theta, g_global, beta=ctrl.beta,
                            view=view, batch_i=batch_i, key_i=key_i)

        deltas, thetas, outs, losses = cohort_exec(
            one_client, cohort, batches, keys)
        if compress_fn is not None and thetas is not None:
            # Clients upload compressed Theta; server aggregates the decoded
            # reconstruction (accuracy/bandwidth trade-off of Table 6).
            thetas = compress_fn(thetas)
        if spec.mixing is not None:
            weights = spec.mixing(deltas, thetas)
        else:
            weights = jnp.ones((s,), jnp.float32)
        new_params, new_theta, new_g, agg = aggregate(
            params, theta, g_global, deltas, thetas, weights, agg_cfg)
        new_cstate = (state_proto.server_update(cstate, cohort, outs,
                                                n_clients)
                      if state_proto is not None else cstate)
        new_ctrl = update_controller(ctrl, agg["norm_drift"],
                                     agg["freshness"])
        metrics = dict(agg, loss=jnp.mean(losses), beta=ctrl.beta)
        return new_params, new_theta, new_g, new_ctrl, new_cstate, metrics

    if jit:
        round_fn = jax.jit(round_fn)

    def driver(server: ServerState, cstate, cohort, batches, rng):
        ctrl = server.geom if server.geom is not None else default_ctrl
        theta = server.theta
        if spec.align and theta is None:
            # round 0: no reference yet -> align to the fresh (zero) state.
            theta = zero_theta(opt, server.params)
        p, th, g, new_ctrl, new_cstate, metrics = round_fn(
            server.params, theta, server.g_global, ctrl, cstate, cohort,
            batches, rng)
        new_server = advance_server(server, p, th, g, geom=new_ctrl,
                                    aligned=spec.align)
        return new_server, new_cstate, metrics

    return driver


# ------------------------------------------------------- built-in algorithms

def _register_stateless_builtins():
    register(AlgorithmSpec(
        name="fedavg", optimizer="sgd",
        description="SGD locally, parameter averaging"))
    register(AlgorithmSpec(
        name="fedcm", optimizer="sgd", correct=True, pinned_beta=0.9,
        description="client momentum: correction-only SGD, beta pinned to "
                    "(1 - alpha) = 0.9"))
    for opt_name in optim.available():
        register(AlgorithmSpec(
            name=f"local_{opt_name}", optimizer=opt_name,
            description=f"FedSOA (Alg. 1) with {opt_name}: fresh local "
                        "state each round, parameter averaging"))
        register(AlgorithmSpec(
            name=f"fedpac_{opt_name}", optimizer=opt_name, align=True,
            correct=True,
            description=f"FedPAC (Alg. 2) with {opt_name}: preconditioner "
                        "Alignment + direction Correction"))
        register(AlgorithmSpec(
            name=f"align_only_{opt_name}", optimizer=opt_name, align=True,
            description="Table 5 ablation: Alignment without Correction"))
        register(AlgorithmSpec(
            name=f"correct_only_{opt_name}", optimizer=opt_name,
            correct=True,
            description="Table 5 ablation: Correction without Alignment"))


_register_stateless_builtins()
