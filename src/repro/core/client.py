"""Client-side local training: K preconditioned steps with optional
FedPAC correction (Eq. 9) — the shared engine for FedSOA and FedPAC.

All of this is jit/vmap-friendly: one client's round is a ``lax.scan`` over K
steps; the cohort is a ``vmap`` over the client axis (sharded over the mesh's
"data"/"pod" axes by the launcher).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim.api import LocalOptimizer


@dataclasses.dataclass(frozen=True)
class LocalRunConfig:
    lr: float
    local_steps: int           # K
    beta: float = 0.0          # correction strength (Eq. 9); 0 => no correction
    hessian_freq: int = 10     # Sophia's f_h
    align: bool = True         # warm-start Theta from the global reference

    def __post_init__(self):
        # validate eagerly: hessian_freq=0 would only surface as a cryptic
        # `k % 0` ZeroDivisionError deep inside the jitted scan below
        if self.local_steps < 1:
            raise ValueError(
                f"local_steps must be >= 1, got {self.local_steps}")
        if self.hessian_freq < 1:
            raise ValueError(
                f"hessian_freq must be >= 1 (step k refreshes the Hutchinson "
                f"estimate when k % hessian_freq == 0), got "
                f"{self.hessian_freq}")


def hutchinson_estimate(loss_fn, params, batch, key):
    """u * (H u) with Rademacher u (Pearlmutter HVP via jvp-of-grad)."""
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(key, len(leaves))
    u = jax.tree.unflatten(
        treedef,
        [jax.random.rademacher(k, l.shape).astype(jnp.float32)
         for k, l in zip(keys, leaves)])
    g_fn = lambda p: jax.grad(loss_fn)(p, batch)
    _, hvp = jax.jvp(g_fn, (params,), (jax.tree.map(
        lambda uu, p: uu.astype(p.dtype), u, params),))
    return jax.tree.map(lambda uu, hh: uu * hh.astype(jnp.float32), u, hvp)


def client_round(
    loss_fn: Callable,
    opt: LocalOptimizer,
    run: LocalRunConfig,
    x0,
    theta,            # global preconditioner reference (or None / zeros-like)
    g_global,         # estimated global direction g_G^r (params-like)
    batches,          # pytree with leading (K, ...) axis
    rng,
    beta=None,        # runtime override (drift-adaptive beta); None -> run.beta
):
    """One client's round. Returns (delta_x, theta_final, mean_loss)."""
    beta = run.beta if beta is None else beta
    opt_state = opt.init(x0)
    if run.align and theta is not None:
        opt_state = opt.set_precond(opt_state, theta)

    def step(carry, inp):
        x, st, k = carry
        batch, key = inp
        loss, grads = jax.value_and_grad(loss_fn)(x, batch)
        extras = None
        if opt.needs_hessian:
            gate = (k % run.hessian_freq) == 0
            est = jax.lax.cond(
                gate,
                lambda: hutchinson_estimate(loss_fn, x, batch, key),
                lambda: jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), x),
            )
            extras = {"h_est": est, "h_gate": gate}
        direction, st = opt.update(grads, st, x, k, extras)
        # Eq. 9: x <- x - lr [ (1-beta) P_Theta(g) + beta g_G ]
        def mix(d, gg, p):
            upd = (1.0 - beta) * d + beta * gg
            return (p.astype(jnp.float32) - run.lr * upd).astype(p.dtype)
        x = jax.tree.map(mix, direction, g_global, x)
        return (x, st, k + 1), loss

    keys = jax.random.split(rng, run.local_steps)
    (x_final, opt_state, _), losses = jax.lax.scan(
        step, (x0, opt_state, jnp.int32(0)), (batches, keys))
    delta = jax.tree.map(lambda a, b: (a.astype(jnp.float32)
                                       - b.astype(jnp.float32)), x_final, x0)
    return delta, opt.get_precond(opt_state), jnp.mean(losses)
