"""SCAFFOLD (Karimireddy et al. 2020) — first-order control-variate baseline,
expressed through the unified client-state protocol.

Per-client control variate c_i and server control c; local step
  x <- x - lr (g - c_i + c)
Option-II update  c_i' = c_i - c + (x0 - xK)/(K lr);
server: c <- c + (S/N) mean_i (c_i' - c_i).

There is no SCAFFOLD round function anymore: the algorithm is an
``AlgorithmSpec`` whose ``local_update`` runs the control-variate steps and
whose ``ClientStateSpec`` declares (c, {c_i}) as persistent per-client state
— the engine's one round path gathers the cohort's variates inside jit,
aggregates deltas through the same ``core.engine.aggregate`` as every other
algorithm, and scatters the refreshed variates back.  State is kept stacked
(N, ...) so it lives sharded over the mesh in distributed runs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms import AlgorithmSpec, ClientStateSpec, register


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("c_global", "c_clients"), meta_fields=())
@dataclasses.dataclass(frozen=True)
class ScaffoldState:
    c_global: Any          # pytree like params (f32)
    c_clients: Any         # pytree with leading N axis

    @staticmethod
    def init(params, n_clients: int):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        stacked = jax.tree.map(
            lambda p: jnp.zeros((n_clients, *p.shape), jnp.float32), params)
        return ScaffoldState(zeros, stacked)


def _client_view(state: ScaffoldState, cid):
    """One client's read: the global control + its own variate."""
    return state.c_global, jax.tree.map(lambda c: c[cid], state.c_clients)


def _client_export(state: ScaffoldState, cid):
    """Spill hook: only the client's own variate is private state.
    ``c_global`` is server-owned and stays resident in the store."""
    return jax.tree.map(lambda c: c[cid], state.c_clients)


def _client_import(state: ScaffoldState, cid, row):
    return ScaffoldState(
        state.c_global,
        jax.tree.map(lambda c, r: c.at[cid].set(r), state.c_clients, row))


def _client_import_many(state: ScaffoldState, cids, rows):
    """Batched graft: one scatter into c_clients for a whole cohort.
    ``cids`` may be a traced array (the pipeline grafts inside jit)."""
    ids = jnp.asarray(cids)
    return ScaffoldState(
        state.c_global,
        jax.tree.map(lambda c, r: c.at[ids].set(r), state.c_clients, rows))


def _server_update(state: ScaffoldState, cohort, outs, n_clients: int):
    """Option-II server bookkeeping: scatter refreshed variates, move c."""
    c_i_new, c_diffs = outs
    s = cohort.shape[0]
    new_c_global = jax.tree.map(
        lambda c, cd: c + (s / n_clients) * jnp.mean(cd, axis=0),
        state.c_global, c_diffs)
    new_c_clients = jax.tree.map(
        lambda all_c, upd: all_c.at[cohort].set(upd),
        state.c_clients, c_i_new)
    return ScaffoldState(new_c_global, new_c_clients)


def make_scaffold_local_update(spec, loss_fn, opt, run):
    """K control-variate SGD steps; returns (delta, None, (c_i', dc), loss)."""
    del spec, opt
    lr, local_steps = run.lr, run.local_steps

    def local_fn(params, theta, g_global, *, beta, view, batch_i, key_i):
        del theta, g_global, beta, key_i  # first-order, uncorrected
        c_global, c_i = view

        def step(x, batch):
            g = jax.grad(loss_fn)(x, batch)

            def upd(p, gg, ci, c):
                d = gg.astype(jnp.float32) - ci + c
                return (p.astype(jnp.float32) - lr * d).astype(p.dtype)

            x = jax.tree.map(upd, x, g, c_i, c_global)
            return x, loss_fn(x, batch)

        x_final, losses = jax.lax.scan(step, params, batch_i)
        delta = jax.tree.map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
            x_final, params)
        # Option II control-variate refresh
        c_i_new = jax.tree.map(
            lambda ci, c, d: ci - c - d / (local_steps * lr),
            c_i, c_global, delta)
        c_diff = jax.tree.map(lambda a, b: a - b, c_i_new, c_i)
        return delta, None, (c_i_new, c_diff), jnp.mean(losses)

    return local_fn


SCAFFOLD_SPEC = register(AlgorithmSpec(
    name="scaffold", optimizer="sgd",
    local_update=make_scaffold_local_update,
    client_state=ClientStateSpec(init=ScaffoldState.init,
                                 client_view=_client_view,
                                 server_update=_server_update,
                                 client_export=_client_export,
                                 client_import=_client_import,
                                 client_import_many=_client_import_many),
    # historical default: the legacy parser's "scaffold" token bypassed the
    # SGD table lr (0.1) and fell back to 1e-2 — kept to preserve numerics
    default_lr=1e-2,
    description="control variates (Karimireddy et al. 2020); lock-step "
                "per-client state => synchronous runtime only"))
