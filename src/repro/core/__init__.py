"""FedPAC core: the paper's contribution as composable JAX modules."""
from repro.core.client import LocalRunConfig, client_round, hutchinson_estimate
from repro.core.server import ServerState, init_server
from repro.core.engine import (
    AggregationConfig, BETA_MAX_AUTO, ExecutorConfig, GeometryController,
    advance_server, aggregate, aggregate_round, auto_controller,
    fixed_controller, make_cohort_executor, make_controller,
    normalized_client_mean, precond_mixing_weights, update_controller,
    weighted_client_mean,
)
from repro.core.algorithms import (
    AlgorithmSpec, ClientStateSpec, DuplicateAlgorithmError, EF_STATE,
    UnknownAlgorithmError, build_round_fn, init_round_client_state,
    make_local_update, register, registered, resolve,
    round_client_state_spec, zero_theta,
)
from repro.core.transport import (
    Codec, Transport, TransportConfig, UnknownCodecError, WireMsg,
    registered_codecs, resolve_codec, wire_bytes,
)
from repro.core.scaffold import ScaffoldState
from repro.core.fedpac import make_round_fn
from repro.core.fedsoa import make_fedsoa_round_fn, make_variant_round_fn, VARIANTS
from repro.core import fedpm  # registers the preconditioned-mixing specs
from repro.core.drift import drift_metric, drift_per_layer, spectral_drift
from repro.core.compression import (
    make_svd_codec, svd_truncate, round_comm_bytes, compressed_bytes,
)
