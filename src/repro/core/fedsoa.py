"""FedSOA (Alg. 1): the naive second-order FL baseline.

Clients run the second-order optimizer locally from a *fresh* state each
round (line 3: Theta_i^{r,0} <- 0) and the server averages parameters only.
This is `Local Sophia/SOAP/Muon` in the paper's tables — the configuration
whose preconditioner drift FedPAC is built to fix.
"""
from __future__ import annotations

from typing import Callable

from repro.core.fedpac import make_round_fn
from repro.optim.api import LocalOptimizer


def make_fedsoa_round_fn(loss_fn: Callable, opt: LocalOptimizer, *, lr: float,
                         local_steps: int, hessian_freq: int = 10,
                         server_lr: float = 1.0, jit: bool = True):
    return make_round_fn(
        loss_fn, opt, lr=lr, local_steps=local_steps,
        beta=0.0, align=False, correct=False,
        hessian_freq=hessian_freq, server_lr=server_lr, jit=jit)


VARIANTS = {
    # name -> (align, correct)  — Table 5 component ablation
    "fedsoa": (False, False),
    "align_only": (True, False),
    "correct_only": (False, True),
    "fedpac": (True, True),
}


def make_variant_round_fn(variant: str, loss_fn, opt, **kw):
    align, correct = VARIANTS[variant]
    return make_round_fn(loss_fn, opt, align=align, correct=correct, **kw)
