"""Pluggable cohort executors: how one round's S clients map onto devices.

``make_cohort_executor`` returns ``run(one_client, *stacked_args)`` where
``one_client(batch_i, key_i, ...)`` is a single client's round and every
arg carries a leading (S,) client axis.  Three backends:

  vmap       one fused batched program — the default, fastest when the whole
             cohort fits one device's memory;
  shard_map  shards the client axis over the mesh's ("pod","data") axes
             (``sharding.partitioning.client_axis_spec``), realizing the
             paper's linear speedup in S: each device group trains S/n
             clients and the engine's aggregation means lower to
             all-reduces;
  chunked    sequential ``lax.map`` over cohort chunks of ``chunk_size``,
             so cohorts larger than device memory still run (peak memory
             scales with the chunk, wall clock with S/chunk_size);
  sharded    shard_map over the mesh *with the chunked body inside each
             shard*: the population-scale path. A 10k cohort splits S/n
             ways across device groups and each group scans its slice in
             ``chunk_size`` pieces, so peak memory per device is
             chunk-proportional while throughput still scales with the
             mesh.

All backends produce numerically equivalent stacked outputs (tested); pick
by cohort size vs device budget — ``benchmarks/executor_scaling.py`` sweeps
the trade-off.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

BACKENDS = ("vmap", "shard_map", "chunked", "sharded")


@dataclasses.dataclass(frozen=True)
class ExecutorConfig:
    backend: str = "vmap"
    chunk_size: int = 8                  # chunked: clients per scan step
    mesh: Optional[Any] = None           # shard_map: None -> all local devices
    client_axes: tuple = ("pod", "data")  # mesh axes to shard clients over

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown executor backend {self.backend!r} "
                f"(want one of {BACKENDS})")
        if self.chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {self.chunk_size}")


def _leading_dim(args) -> int:
    return jax.tree.leaves(args)[0].shape[0]


@functools.lru_cache(maxsize=None)
def _default_mesh():
    # the local device set is fixed for the process lifetime, so the mesh
    # is too — rebuilding it per executor call only burned host time
    return jax.make_mesh((len(jax.devices()),), ("data",))


def _chunked_run(one_client, chunk_size: int, *args):
    """Bounded-memory sequential ``lax.map`` over cohort slices.

    A cohort that is not a chunk multiple pads with replicas of its first
    rows (pad < c <= s always holds) and the padded outputs are dropped,
    so every cohort size runs through ONE compiled chunk body — the old
    separate vmap tail compiled a fresh program for every distinct
    remainder shape."""
    s = _leading_dim(args)
    c = min(chunk_size, s)
    n = -(-s // c)
    pad = n * c - s
    if pad:
        args = jax.tree.map(
            lambda x: jnp.concatenate([x, x[:pad]], axis=0), args)
    chunks = jax.tree.map(lambda x: x.reshape(n, c, *x.shape[1:]), args)
    out = jax.lax.map(lambda a: jax.vmap(one_client)(*a), chunks)
    out = jax.tree.map(lambda x: x.reshape(n * c, *x.shape[2:]), out)
    if pad:
        out = jax.tree.map(lambda x: x[:s], out)
    return out


def _make_shard_runner(cfg: ExecutorConfig, shard_body_of):
    """shard_map plumbing shared by the ``shard_map`` and ``sharded``
    backends; ``shard_body_of(one_client)`` is what runs on each device
    group's slice of the client axis."""
    from repro.sharding.partitioning import client_axis_spec

    def run(one_client, *args):
        mesh = cfg.mesh if cfg.mesh is not None else _default_mesh()
        axes, spec = client_axis_spec(mesh, preferred=cfg.client_axes)
        n = math.prod(mesh.shape[a] for a in axes)
        s = _leading_dim(args)
        if s % n != 0:
            raise ValueError(
                f"cohort size {s} not divisible by the client-axis "
                f"extent {n} (mesh axes {axes}) — pad the cohort or "
                f"use the 'chunked' executor")
        return shard_map(shard_body_of(one_client), mesh=mesh,
                         in_specs=(spec,) * len(args), out_specs=spec,
                         check_rep=False)(*args)
    return run


def make_cohort_executor(cfg: Optional[ExecutorConfig] = None):
    cfg = cfg or ExecutorConfig()

    if cfg.backend == "vmap":
        def run(one_client, *args):
            return jax.vmap(one_client)(*args)
        return run

    if cfg.backend == "shard_map":
        return _make_shard_runner(
            cfg, lambda one_client: lambda *a: jax.vmap(one_client)(*a))

    if cfg.backend == "sharded":
        # population-scale path: each device group scans its cohort slice in
        # chunk_size pieces — peak memory ~ chunk, throughput ~ mesh
        return _make_shard_runner(
            cfg, lambda one_client:
            lambda *a: _chunked_run(one_client, cfg.chunk_size, *a))

    # chunked: bounded-memory sequential scan over cohort slices
    def run(one_client, *args):
        return _chunked_run(one_client, cfg.chunk_size, *args)
    return run
