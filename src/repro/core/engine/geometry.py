"""Functional geometry controller: adaptive correction strength as jit-pure
state carried inside ``ServerState``.

Replaces the old mutable ``beta_cell`` dict that lived Python-side in the
sync driver: invisible to jit, lost on checkpoint restore, and necessarily
divergent between the sync and async runtimes.  ``GeometryController`` is a
registered pytree whose array leaves (beta, drift EMA) flow through jitted
round functions and checkpoints, while its rule configuration (beta_max,
adaptive, ema) is static metadata — changing it retraces, as it should.

The drift-adaptive rule (beyond-paper; see EXPERIMENTS §Paper-claims):

  d_r    = (1 - c) d_{r-1} + c * norm_drift_r      (EMA, c=1 => raw drift)
  beta_r = beta_max * d_r / (1 + d_r) * freshness

Thm 5.6's penalty is proportional to the drift Delta_D — when client
geometries barely move apart, a fixed beta only injects staleness from
g_G^{r-1}; the rule backs the correction off exactly then.  ``freshness``
(the async buffer's rho) additionally scales beta down when the g_G estimate
the next cohort corrects toward is itself stale; the sync runtime passes 1.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

# cap for the drift-adaptive beta="auto" rule (both runtimes)
BETA_MAX_AUTO = 0.7


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("beta", "drift_ema"),
                   meta_fields=("beta_max", "adaptive", "ema"))
@dataclasses.dataclass(frozen=True)
class GeometryController:
    beta: jax.Array                 # correction strength used next round
    drift_ema: jax.Array            # smoothed normalized drift
    beta_max: float = BETA_MAX_AUTO
    adaptive: bool = False
    ema: float = 1.0                # EMA coefficient; 1.0 = no smoothing


def fixed_controller(beta: float) -> GeometryController:
    """Constant-beta controller (fixed beta, FedCM, or no correction)."""
    return GeometryController(jnp.float32(beta), jnp.float32(0.0))


def auto_controller(beta_max: float = BETA_MAX_AUTO,
                    ema: float = 1.0) -> GeometryController:
    """Drift-adaptive controller; beta starts at 0 (no drift signal yet)."""
    return GeometryController(jnp.float32(0.0), jnp.float32(0.0),
                              beta_max=float(beta_max), adaptive=True,
                              ema=float(ema))


def update_controller(ctrl: GeometryController, norm_drift,
                      freshness=1.0) -> GeometryController:
    """One controller step (jit-pure). Fixed controllers pass through."""
    if not ctrl.adaptive:
        return ctrl
    d = ((1.0 - ctrl.ema) * ctrl.drift_ema
         + ctrl.ema * norm_drift).astype(jnp.float32)
    beta = (ctrl.beta_max * d / (1.0 + d) * freshness).astype(jnp.float32)
    return dataclasses.replace(ctrl, beta=beta, drift_ema=d)


def make_controller(beta, *, correct: bool = True,
                    beta_max: float = BETA_MAX_AUTO,
                    ema: float = 1.0) -> GeometryController:
    """The one beta rule for both runtimes: beta="auto" => adaptive;
    correct=False => beta pinned to 0."""
    if not correct:
        return fixed_controller(0.0)
    if beta == "auto":
        return auto_controller(beta_max=beta_max, ema=ema)
    return fixed_controller(float(beta))
