"""The single server-side aggregation core (Alg. 2 lines 14-17).

Every communication-round implementation in the repo — the synchronous
round fn (``core.fedpac``), SCAFFOLD (``fed.scaffold``), the
buffered-asynchronous flush (``fed.async_runtime.buffer``), and the
launch-layer lowering step (``launch.steps``) — funnels through
``aggregate``.  One code path means one set of semantics:

  params  x' = x + server_lr * (1/B) sum_i w_i Delta_i
          (unnormalized FedBuff step: a stale buffer moves the model less;
          w_i = 1 recovers the paper's synchronous uniform mean bitwise)
  g_G     g_B = -(sum_i w_i Delta_i / sum_i w_i) / (K eta),
          g' = (1 - rho) g + rho g_B,            rho = mean_i w_i
  Theta   Theta_B = sum_i w_i Theta_i / sum_i w_i,
          Theta' = (1 - rho) Theta + rho Theta_B   (only when cfg.align)

rho (the cohort "freshness") is 1 for a synchronous round, so the
freshness mixing degenerates to full replacement and a zero-staleness
buffer flush is *bitwise* identical to a synchronous round — the
equivalence the async runtime's correctness rests on (tested in
``tests/test_engine.py``).

Cohort results arrive stacked on a leading client axis; on the production
mesh that axis is sharded over ("pod","data") (see ``engine.executors``),
so every mean here lowers to an all-reduce.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.drift import drift_metric
from repro.core.server import ServerState
from repro.utils.tree import tree_norm_sq


@dataclasses.dataclass(frozen=True)
class AggregationConfig:
    """Static knobs of one server update (hashable: safe to close over)."""
    lr: float                  # client learning rate eta
    local_steps: int           # K
    server_lr: float = 1.0
    align: bool = True         # update the global Theta reference?


def weighted_client_mean(tree, weights=None):
    """Mean over the leading client axis; optionally w_i-scaled (FedBuff).

    With weights, returns (1/S) sum_i w_i x_i — unnormalized on purpose:
    w_i in (0,1] shrink the contribution of stale clients rather than
    re-normalizing it away, so a fully-stale buffer takes a smaller server
    step.  weights=None is the uniform mean (w_i = 1).
    """
    if weights is None:
        return jax.tree.map(lambda x: jnp.mean(x, axis=0), tree)
    w = weights.astype(jnp.float32)
    return jax.tree.map(
        lambda x: jnp.mean(
            w.reshape((-1,) + (1,) * (x.ndim - 1)) * x.astype(jnp.float32),
            axis=0),
        tree)


def normalized_client_mean(tree, weights):
    """sum_i w_i x_i / sum_i w_i over the leading client axis."""
    w = weights.astype(jnp.float32)
    denom = jnp.sum(w) + 1e-12
    return jax.tree.map(
        lambda x: jnp.sum(
            w.reshape((-1,) + (1,) * (x.ndim - 1)) * x.astype(jnp.float32),
            axis=0) / denom,
        tree)


def precond_mixing_weights(deltas, thetas, eps: float = 1e-8):
    """FedPM-style curvature-weighted mixing weights for the delta mean.

    Preconditioned mixing of local parameters (Ishii et al., 2025): each
    client's update is trusted inversely to the mass of its local curvature
    estimate — clients sitting in sharp regions (large mean |Theta_i|) move
    the server less, flat-region clients more.  Returns (S,) weights
    normalized to mean 1, so the cohort freshness rho stays 1 and the
    uniform mean is recovered when all clients see identical curvature.
    """
    del deltas
    leaves = jax.tree.leaves(thetas)
    if not leaves:
        raise ValueError(
            "preconditioned mixing needs per-client Theta uploads — use a "
            "second-order local optimizer (sophia/muon/soap/adamw)")
    total, count = 0.0, 0
    for t in leaves:
        tf = jnp.abs(t.astype(jnp.float32)).reshape(t.shape[0], -1)
        total = total + jnp.sum(tf, axis=1)
        count += tf.shape[1]
    curv = total / count                    # (S,) mean |Theta_i|
    w = 1.0 / (eps + curv)
    return w / (jnp.mean(w) + eps)


def aggregate(params, theta, g_global, deltas, thetas, weights,
              cfg: AggregationConfig):
    """One server update from a stacked cohort.

    deltas: pytree with leading (B,) client axis; thetas: same, or None for
    first-order algorithms (no geometry to aggregate — drift reports 0).
    weights: (B,) per-client weights; jnp.ones for a synchronous round.
    Returns (new_params, new_theta, new_g, metrics).
    """
    w = weights.astype(jnp.float32)
    rho = jnp.mean(w)                       # cohort freshness in (0, 1]
    step = weighted_client_mean(deltas, w)  # (1/B) sum_i w_i Delta_i
    new_params = jax.tree.map(
        lambda p, d: (p.astype(jnp.float32)
                      + cfg.server_lr * d).astype(p.dtype), params, step)
    # g_G estimate is w-normalized — only the parameter *step* shrinks with
    # staleness, not the magnitude of the direction (Alg. 2 line 14).
    g_batch = jax.tree.map(
        lambda d: -d / (cfg.local_steps * cfg.lr),
        normalized_client_mean(deltas, w))
    new_g = jax.tree.map(lambda old, gb: (1.0 - rho) * old + rho * gb,
                         g_global, g_batch)

    if thetas is None:
        new_theta = theta
        drift = jnp.zeros((), jnp.float32)
        norm_drift = jnp.zeros((), jnp.float32)
    else:
        drift = drift_metric(thetas)
        theta_batch = normalized_client_mean(thetas, w)
        norm_drift = drift / (tree_norm_sq(theta_batch) + 1e-12)
        if cfg.align:
            # Theta is a reference geometry, not a step: freshness-mixed so
            # a stale buffer drags the global geometry only part-way.
            old = theta if theta is not None else jax.tree.map(
                jnp.zeros_like, theta_batch)
            new_theta = jax.tree.map(
                lambda o, tb: ((1.0 - rho) * o.astype(jnp.float32)
                               + rho * tb).astype(o.dtype),
                old, theta_batch)
        else:
            new_theta = theta
    metrics = {"drift": drift, "norm_drift": norm_drift, "freshness": rho}
    return new_params, new_theta, new_g, metrics


def advance_server(server: ServerState, params, theta, g_global, *,
                   geom=None, aligned: bool) -> ServerState:
    """Next ServerState: round += 1; theta_version stamped only when the
    geometry reference actually refreshed (align=True rounds)."""
    r = server.round + 1
    return ServerState(params, theta, g_global, r,
                       r if aligned else server.theta_version,
                       geom if geom is not None else server.geom)


def aggregate_round(server: ServerState, deltas, thetas, *, lr: float,
                    local_steps: int, server_lr: float = 1.0,
                    weights=None) -> ServerState:
    """Core-level weighted entry point: one engine aggregate -> ServerState.

    weights: optional (B,) per-client weights (e.g. staleness decay); None
    is the synchronous uniform mean.  Passing thetas=None leaves the
    geometry reference and its version untouched.
    """
    cfg = AggregationConfig(lr=lr, local_steps=local_steps,
                            server_lr=server_lr, align=thetas is not None)
    if weights is None:
        weights = jnp.ones(
            (jax.tree.leaves(deltas)[0].shape[0],), jnp.float32)
    new_params, new_theta, new_g, _ = aggregate(
        server.params, server.theta, server.g_global, deltas, thetas,
        weights, cfg)
    return advance_server(server, new_params, new_theta, new_g,
                          aligned=thetas is not None)
