"""The single server-side aggregation core (Alg. 2 lines 14-17).

Every communication-round implementation in the repo — the synchronous
round fn (``core.fedpac``), SCAFFOLD (``fed.scaffold``), the
buffered-asynchronous flush (``fed.async_runtime.buffer``), and the
launch-layer lowering step (``launch.steps``) — funnels through
``aggregate``.  One code path means one set of semantics:

  params  x' = x + server_lr * (1/B) sum_i w_i Delta_i
          (unnormalized FedBuff step: a stale buffer moves the model less;
          w_i = 1 recovers the paper's synchronous uniform mean bitwise)
  g_G     g_B = -(sum_i w_i Delta_i / sum_i w_i) / (K eta),
          g' = (1 - rho) g + rho g_B,            rho = mean_i w_i
  Theta   Theta_B = sum_i w_i Theta_i / sum_i w_i,
          Theta' = (1 - rho) Theta + rho Theta_B   (only when cfg.align)

rho (the cohort "freshness") is 1 for a synchronous round, so the
freshness mixing degenerates to full replacement and a zero-staleness
buffer flush is *bitwise* identical to a synchronous round — the
equivalence the async runtime's correctness rests on (tested in
``tests/test_engine.py``).

Cohort results arrive stacked on a leading client axis; on the production
mesh that axis is sharded over ("pod","data") (see ``engine.executors``),
so every mean here lowers to an all-reduce.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.drift import drift_metric
from repro.core.server import ServerState
from repro.utils.tree import client_weighted_sum, tree_norm_sq


@dataclasses.dataclass(frozen=True)
class AggregationConfig:
    """Static knobs of one server update (hashable: safe to close over)."""
    lr: float                  # client learning rate eta
    local_steps: int           # K
    server_lr: float = 1.0
    align: bool = True         # update the global Theta reference?


def weighted_client_mean(tree, weights=None):
    """Mean over the leading client axis; optionally w_i-scaled (FedBuff).

    With weights, returns (1/S) sum_i w_i x_i — unnormalized on purpose:
    w_i in (0,1] shrink the contribution of stale clients rather than
    re-normalizing it away, so a fully-stale buffer takes a smaller server
    step.  weights=None is the uniform mean (w_i = 1).

    The weighted form lowers to one ``dot_general`` contraction of the
    weight vector against the client axis (``utils.tree
    .client_weighted_sum``) — the legacy w-scaled f32 copy of every
    stacked leaf is never materialized.
    """
    if weights is None:
        return jax.tree.map(lambda x: jnp.mean(x, axis=0), tree)
    b = weights.shape[0]
    return jax.tree.map(lambda x: x / b, client_weighted_sum(tree, weights))


def normalized_client_mean(tree, weights):
    """sum_i w_i x_i / sum_i w_i over the leading client axis (one
    ``dot_general`` contraction, no w-scaled stacked copy)."""
    w = weights.astype(jnp.float32)
    denom = jnp.sum(w) + 1e-12
    return jax.tree.map(lambda x: x / denom, client_weighted_sum(tree, w))


def precond_mixing_weights(deltas, thetas, eps: float = 1e-8):
    """FedPM-style curvature-weighted mixing weights for the delta mean.

    Preconditioned mixing of local parameters (Ishii et al., 2025): each
    client's update is trusted inversely to the mass of its local curvature
    estimate — clients sitting in sharp regions (large mean |Theta_i|) move
    the server less, flat-region clients more.  Returns (S,) weights
    normalized to mean 1, so the cohort freshness rho stays 1 and the
    uniform mean is recovered when all clients see identical curvature.
    """
    del deltas
    leaves = jax.tree.leaves(thetas)
    if not leaves:
        raise ValueError(
            "preconditioned mixing needs per-client Theta uploads — use a "
            "second-order local optimizer (sophia/muon/soap/adamw)")
    total, count = 0.0, 0
    for t in leaves:
        tf = jnp.abs(t.astype(jnp.float32)).reshape(t.shape[0], -1)
        total = total + jnp.sum(tf, axis=1)
        count += tf.shape[1]
    curv = total / count                    # (S,) mean |Theta_i|
    w = 1.0 / (eps + curv)
    return w / (jnp.mean(w) + eps)


def _finish_update(params, theta, g_global, delta_wsum, w,
                   cfg: AggregationConfig, theta_stats):
    """Shared tail of ``aggregate``/``aggregate_wire``: apply Alg. 2 lines
    14-17 given sum_i w_i Delta_i and the Theta statistics
    ``(drift, sum_i w_i Theta_i)`` (None for first-order cohorts)."""
    b = w.shape[0]
    rho = jnp.mean(w)                       # cohort freshness in (0, 1]
    denom = jnp.sum(w) + 1e-12
    return _finish_update_stats(params, theta, g_global, delta_wsum, b, rho,
                                denom, cfg, theta_stats)


def _finish_update_stats(params, theta, g_global, delta_wsum, b, rho, denom,
                         cfg: AggregationConfig, theta_stats):
    """The Alg. 2 tail from *reduced* cohort statistics: ``b`` is the
    (static) cohort size, ``rho``/``denom`` the freshness mean and weight
    sum.  ``_finish_update`` derives them from the stacked weight vector;
    the streamed pipeline derives them from its running ``w_sum`` — both
    lower to the same sum/size expressions, so the split introduces no
    numeric fork."""
    step = jax.tree.map(lambda x: x / b, delta_wsum)
    new_params = jax.tree.map(
        lambda p, d: (p.astype(jnp.float32)
                      + cfg.server_lr * d).astype(p.dtype), params, step)
    # g_G estimate is w-normalized — only the parameter *step* shrinks with
    # staleness, not the magnitude of the direction (Alg. 2 line 14).
    g_batch = jax.tree.map(
        lambda x: -(x / denom) / (cfg.local_steps * cfg.lr), delta_wsum)
    new_g = jax.tree.map(lambda old, gb: (1.0 - rho) * old + rho * gb,
                         g_global, g_batch)

    if theta_stats is None:
        new_theta = theta
        drift = jnp.zeros((), jnp.float32)
        norm_drift = jnp.zeros((), jnp.float32)
    else:
        drift, theta_wsum = theta_stats
        theta_batch = jax.tree.map(lambda x: x / denom, theta_wsum)
        norm_drift = drift / (tree_norm_sq(theta_batch) + 1e-12)
        if cfg.align:
            # Theta is a reference geometry, not a step: freshness-mixed so
            # a stale buffer drags the global geometry only part-way.
            old = theta if theta is not None else jax.tree.map(
                jnp.zeros_like, theta_batch)
            new_theta = jax.tree.map(
                lambda o, tb: ((1.0 - rho) * o.astype(jnp.float32)
                               + rho * tb).astype(o.dtype),
                old, theta_batch)
        else:
            new_theta = theta
    metrics = {"drift": drift, "norm_drift": norm_drift, "freshness": rho}
    return new_params, new_theta, new_g, metrics


def aggregate(params, theta, g_global, deltas, thetas, weights,
              cfg: AggregationConfig):
    """One server update from a stacked cohort.

    deltas: pytree with leading (B,) client axis; thetas: same, or None for
    first-order algorithms (no geometry to aggregate — drift reports 0).
    weights: (B,) per-client weights; jnp.ones for a synchronous round.
    Returns (new_params, new_theta, new_g, metrics).
    """
    w = weights.astype(jnp.float32)
    delta_wsum = client_weighted_sum(deltas, w)
    theta_stats = (None if thetas is None else
                   (drift_metric(thetas), client_weighted_sum(thetas, w)))
    return _finish_update(params, theta, g_global, delta_wsum, w, cfg,
                          theta_stats)


def aggregate_wire(params, theta, g_global, dmsgs, weights,
                   cfg: AggregationConfig, transport, *, tmsgs=None,
                   thetas=None, need_thetas: bool = False):
    """The fused wire-native server update: accumulate encoded uploads
    straight into the running weighted sums (``Codec.accumulate``) instead
    of decoding the cohort to a dense stack first.

    dmsgs: cohort-stacked delta ``WireMsg``.  Theta uploads arrive either
    as stacked wire messages (``tmsgs``, aligned algorithms) or as an
    already-dense stacked tree (``thetas``, align=False uploads are not
    encoded); pass neither for first-order cohorts.  Lossless theta codecs
    decode (free for dense — the payload IS the leaf) and take the exact
    classic drift path, so the result is bitwise-identical to
    decode-then-``aggregate``; lossy codecs compute drift wire-natively
    from per-client squared norms (Def. 1 decomposed as
    mean_i ||Theta_i||^2 - ||mean_i Theta_i||^2, clamped at 0).

    ``need_thetas=True`` additionally decodes the stacked thetas (the
    telemetry geometry sketch needs per-client values) — training numerics
    do NOT change with this flag; the lossy drift stays wire-native.

    Returns (new_params, new_theta, new_g, metrics, aux); ``aux["step"]``
    is the reusable weighted delta mean and ``aux["thetas"]`` the decoded
    stack (or None) for telemetry.
    """
    if tmsgs is not None and thetas is not None:
        raise ValueError("pass theta uploads as tmsgs (wire) or thetas "
                         "(dense), not both")
    w = weights.astype(jnp.float32)
    b = w.shape[0]
    delta_wsum = transport.delta.accumulate(dmsgs, w)

    thetas_dec = thetas
    if tmsgs is not None:
        if transport.theta.lossless:
            # exact path: decode (free for dense) and reuse the classic
            # drift — bitwise parity with decode-then-aggregate
            thetas_dec = jax.vmap(transport.theta.decode)(tmsgs)
            theta_stats = (drift_metric(thetas_dec),
                           client_weighted_sum(thetas_dec, w))
        else:
            if need_thetas:
                thetas_dec = jax.vmap(transport.theta.decode)(tmsgs)
            sq = transport.theta.sq_norms(tmsgs)
            usum = transport.theta.accumulate(
                tmsgs, jnp.ones((b,), jnp.float32))
            ubar_sq = tree_norm_sq(jax.tree.map(lambda x: x / b, usum))
            drift = jnp.maximum(jnp.mean(sq) - ubar_sq, 0.0)
            theta_stats = (drift, transport.theta.accumulate(tmsgs, w))
    elif thetas is not None:
        theta_stats = (drift_metric(thetas), client_weighted_sum(thetas, w))
    else:
        theta_stats = None

    out = _finish_update(params, theta, g_global, delta_wsum, w, cfg,
                         theta_stats)
    step = jax.tree.map(lambda x: x / b, delta_wsum)
    return (*out, {"step": step, "thetas": thetas_dec})


# ------------------------------------------------- streamed aggregation
#
# The chunk-streaming pipeline (fed.pipeline) never stacks the whole
# cohort: each chunk's wire uploads fold into running f32 weighted sums
# (``stream_chunk``, backed by the carry-accepting ``Codec.accumulate``)
# and one ``finish_stream`` applies the Alg. 2 tail from the reduced
# statistics.  A single-chunk stream with ``exact=True`` routes through
# the very same expressions as ``aggregate_wire`` (carry=None accumulate,
# classic drift), so it is bitwise-identical to the monolithic flush;
# multi-chunk streams compute drift by the decomposition
# mean_i ||Theta_i||^2 - ||mean_i Theta_i||^2 (clamped at 0) — the same
# formula ``aggregate_wire`` already uses for lossy theta codecs.

def stream_chunk(carry, dmsgs, weights, transport, *, tmsgs=None,
                 thetas=None, exact: bool = False):
    """Fold one chunk's uploads into the running aggregation carry.

    carry: None for the first chunk (the accumulates then ARE the legacy
    one-shot expressions), else the dict this function returned for the
    previous chunk.  ``tmsgs``/``thetas`` mirror ``aggregate_wire``: theta
    uploads as stacked wire messages or as an already-dense stacked tree.
    ``exact=True`` is the single-chunk mode: drift comes out the classic
    centered ``drift_metric`` for lossless/dense thetas (bitwise parity
    with ``aggregate_wire``); it is invalid with a carry.
    """
    if tmsgs is not None and thetas is not None:
        raise ValueError("pass theta uploads as tmsgs (wire) or thetas "
                         "(dense), not both")
    if exact and carry is not None:
        raise ValueError("exact streaming is single-chunk only "
                         "(carry must be None)")
    w = weights.astype(jnp.float32)
    b = w.shape[0]
    prev = carry if carry is not None else {
        "delta_wsum": None, "w_sum": None, "theta_wsum": None,
        "theta_usum": None, "theta_sq_sum": None, "theta_drift": None}
    out = dict(prev)
    out["delta_wsum"] = transport.delta.accumulate(
        dmsgs, w, carry=prev["delta_wsum"])
    w_sum = jnp.sum(w)
    out["w_sum"] = w_sum if prev["w_sum"] is None else prev["w_sum"] + w_sum
    out["theta_drift"] = None

    if tmsgs is not None:
        if exact and transport.theta.lossless:
            thetas_dec = jax.vmap(transport.theta.decode)(tmsgs)
            out["theta_drift"] = drift_metric(thetas_dec)
            out["theta_wsum"] = client_weighted_sum(thetas_dec, w)
        else:
            sq = transport.theta.sq_norms(tmsgs)
            out["theta_sq_sum"] = _acc(prev["theta_sq_sum"], jnp.sum(sq))
            out["theta_usum"] = transport.theta.accumulate(
                tmsgs, jnp.ones((b,), jnp.float32),
                carry=prev["theta_usum"])
            out["theta_wsum"] = transport.theta.accumulate(
                tmsgs, w, carry=prev["theta_wsum"])
    elif thetas is not None:
        if exact:
            out["theta_drift"] = drift_metric(thetas)
            out["theta_wsum"] = client_weighted_sum(thetas, w)
        else:
            flat = jax.tree.map(
                lambda x: x.astype(jnp.float32).reshape(x.shape[0], -1),
                thetas)
            sq = sum(jnp.sum(x * x, axis=-1) for x in jax.tree.leaves(flat))
            out["theta_sq_sum"] = _acc(prev["theta_sq_sum"], jnp.sum(sq))
            out["theta_usum"] = _acc_tree(
                prev["theta_usum"],
                client_weighted_sum(thetas, jnp.ones((b,), jnp.float32)))
            out["theta_wsum"] = _acc_tree(
                prev["theta_wsum"], client_weighted_sum(thetas, w))
    return out


def _acc(prev, x):
    return x if prev is None else prev + x


def _acc_tree(prev, tree):
    if prev is None:
        return tree
    return jax.tree.map(lambda a, c: a + c, prev, tree)


def finish_stream(params, theta, g_global, carry, cohort_size: int,
                  cfg: AggregationConfig):
    """Apply the Alg. 2 tail to a fully-folded stream carry.

    ``cohort_size`` is the static total cohort size b (the chunks'
    leading dims sum to it).  Returns the same 4-tuple as ``aggregate``
    plus an aux dict carrying the reusable weighted step.
    """
    b = int(cohort_size)
    rho = carry["w_sum"] / b
    denom = carry["w_sum"] + 1e-12
    if carry["theta_wsum"] is None:
        theta_stats = None
    elif carry["theta_drift"] is not None:       # exact single-chunk path
        theta_stats = (carry["theta_drift"], carry["theta_wsum"])
    else:
        usum = carry["theta_usum"]
        ubar_sq = tree_norm_sq(jax.tree.map(lambda x: x / b, usum))
        drift = jnp.maximum(carry["theta_sq_sum"] / b - ubar_sq, 0.0)
        theta_stats = (drift, carry["theta_wsum"])
    out = _finish_update_stats(params, theta, g_global, carry["delta_wsum"],
                               b, rho, denom, cfg, theta_stats)
    step = jax.tree.map(lambda x: x / b, carry["delta_wsum"])
    return (*out, {"step": step})


def advance_server(server: ServerState, params, theta, g_global, *,
                   geom=None, aligned: bool) -> ServerState:
    """Next ServerState: round += 1; theta_version stamped only when the
    geometry reference actually refreshed (align=True rounds)."""
    r = server.round + 1
    return ServerState(params, theta, g_global, r,
                       r if aligned else server.theta_version,
                       geom if geom is not None else server.geom)


def aggregate_round(server: ServerState, deltas, thetas, *, lr: float,
                    local_steps: int, server_lr: float = 1.0,
                    weights=None) -> ServerState:
    """Core-level weighted entry point: one engine aggregate -> ServerState.

    weights: optional (B,) per-client weights (e.g. staleness decay); None
    is the synchronous uniform mean.  Passing thetas=None leaves the
    geometry reference and its version untouched.
    """
    cfg = AggregationConfig(lr=lr, local_steps=local_steps,
                            server_lr=server_lr, align=thetas is not None)
    if weights is None:
        weights = jnp.ones(
            (jax.tree.leaves(deltas)[0].shape[0],), jnp.float32)
    new_params, new_theta, new_g, _ = aggregate(
        server.params, server.theta, server.g_global, deltas, thetas,
        weights, cfg)
    return advance_server(server, new_params, new_theta, new_g,
                          aligned=thetas is not None)
