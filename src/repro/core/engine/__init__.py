"""Unified round engine: the one implementation of a communication round.

Layering (top to bottom):

  runtimes     fed.rounds (sync) / fed.async_runtime (buffered async) —
               thin drivers: sample cohorts, stage batches, manage state
  engine       aggregation.py  one ``aggregate`` for every server update
               geometry.py     functional GeometryController (adaptive beta)
               executors.py    vmap | shard_map | chunked cohort execution
  optimizers   optim.* behind the (Theta, P_Theta) LocalOptimizer API
  kernels      Pallas TPU kernels for the second-order hot paths
"""
from repro.core.engine.aggregation import (
    AggregationConfig, aggregate, aggregate_round, aggregate_wire,
    advance_server, finish_stream, precond_mixing_weights, stream_chunk,
    weighted_client_mean, normalized_client_mean,
)
from repro.core.engine.geometry import (
    BETA_MAX_AUTO, GeometryController, auto_controller, fixed_controller,
    make_controller, update_controller,
)
from repro.core.engine.executors import (
    BACKENDS, ExecutorConfig, make_cohort_executor,
)
