"""Dense codec: the identity wire format (legacy upload path).

Every leaf ships as-is; ``decode(encode(x))`` is bitwise ``x``, and
``wire_bytes`` equals ``tree_bytes`` — the pre-transport per-round byte
totals, reproduced exactly (tested in tests/test_transport.py).
"""
from __future__ import annotations

import dataclasses

from repro.core.transport.base import (
    Codec, LeafMsg, TransportConfig, dense_leaf, register_codec,
)


@dataclasses.dataclass(frozen=True)
class Dense(Codec):
    name = "dense"
    lossless = True

    def encode_leaf(self, leaf) -> LeafMsg:
        return dense_leaf(leaf)

    def decode_leaf(self, msg: LeafMsg):
        return msg.parts["x"]


@register_codec("dense")
def _make_dense(cfg: TransportConfig) -> Dense:
    del cfg
    return Dense()
