"""Dense codec: the identity wire format (legacy upload path).

With the default ``wire_dtype="f32"`` every leaf ships as-is;
``decode(encode(x))`` is bitwise ``x``, and ``wire_bytes`` equals
``tree_bytes`` — the pre-transport per-round byte totals, reproduced
exactly (tested in tests/test_transport.py).  ``wire_dtype="bf16"``
halves every floating payload on the wire (decode casts back to the
original dtype); the codec is then lossy, so error feedback activates
for delta uploads like any other lossy codec.
"""
from __future__ import annotations

import dataclasses

from repro.core.transport.base import (
    Codec, LeafMsg, TransportConfig, dense_leaf, register_codec,
    validate_wire_dtype,
)


@dataclasses.dataclass(frozen=True)
class Dense(Codec):
    wire_dtype: str = "f32"
    name = "dense"

    def __post_init__(self):
        validate_wire_dtype(self.wire_dtype)

    @property
    def lossless(self) -> bool:  # type: ignore[override]
        return self.wire_dtype == "f32"

    def encode_leaf(self, leaf) -> LeafMsg:
        return dense_leaf(leaf, self.wire_dtype)

    def decode_leaf(self, msg: LeafMsg):
        return msg.parts["x"].astype(msg.dtype)


@register_codec("dense")
def _make_dense(cfg: TransportConfig) -> Dense:
    return Dense(wire_dtype=cfg.wire_dtype)
