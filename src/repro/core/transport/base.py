"""Wire-true geometry transport: the ``Codec`` protocol and wire messages.

A codec turns a pytree (a client's delta or Theta upload) into a
``WireMsg`` — the *actual* structures that would cross the network — and
back.  ``wire_bytes`` derives communication accounting purely from those
structures (shape x itemsize of every payload array, host-side
``math.prod``), never from analytic side-formulas, so the byte counts in
benchmarks/table6_comm.py and ``comm_bytes_per_round`` are measurements of
what the codec ships, not estimates of what it ought to ship.

Messages are jit-transparent pytrees: payload arrays are data leaves,
everything else (codec name, source treedef, per-leaf shape/dtype/kind) is
static metadata.  That means a ``WireMsg`` can be produced inside a jitted
round, vmapped over a stacked client axis, stacked into an async buffer,
or abstractly evaluated with ``jax.eval_shape`` for accounting without
touching a device.

Codecs operate on *per-client* trees; stacked cohort trees go through
``jax.vmap(codec.encode)`` so a codec never mixes data across clients.
Leaves with more than two dims treat the leading dims as a batch of
trailing (m, n) matrices — the same convention the optimizers use.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.utils import hw
from repro.utils.tree import client_weighted_sum


class UnknownCodecError(ValueError):
    """Codec spec names no registered codec."""


# wire dtypes: "f32" ships floating payloads in their native dtype (the
# legacy, lossless wire format); "bf16" halves every floating payload on
# the wire (dense leaves, low-rank/sketch factors — qblock is already
# int8 + f32 scales and is unaffected).  Decode always casts back to the
# envelope's original dtype.
WIRE_DTYPES = {"f32": None, "bf16": jnp.bfloat16}


def validate_wire_dtype(name: str) -> str:
    if name not in WIRE_DTYPES:
        raise ValueError(
            f"unknown wire_dtype {name!r} (want one of "
            f"{tuple(sorted(WIRE_DTYPES))})")
    return name


def wire_cast(leaf, wire_dtype: str):
    """Cast a floating payload to the wire dtype ("f32" ships native)."""
    dt = WIRE_DTYPES[wire_dtype]
    if dt is None or not jnp.issubdtype(jnp.dtype(leaf.dtype), jnp.floating):
        return leaf
    return leaf.astype(dt)


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("parts",),
                   meta_fields=("kind", "shape", "dtype", "extra"))
@dataclasses.dataclass(frozen=True)
class LeafMsg:
    """One leaf's wire representation: payload arrays + static envelope.

    ``extra`` carries codec-specific static framing (e.g. qblock's block
    size) so a message is self-describing — decode never depends on
    out-of-band agreement with the encoder's configuration."""
    kind: str          # "dense" | "lowrank" | "sketch" | "qblock"
    shape: tuple       # original leaf shape (decode target)
    dtype: Any         # original leaf dtype (decode target)
    parts: dict        # name -> payload array (what actually ships)
    extra: Any = None  # static codec framing (hashable)


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("leaves",),
                   meta_fields=("codec", "treedef"))
@dataclasses.dataclass(frozen=True)
class WireMsg:
    """One upload: the encoded leaves of a pytree + its static treedef."""
    codec: str
    treedef: Any       # jax treedef of the source tree
    leaves: tuple      # tuple[LeafMsg, ...], one per source leaf


def wire_bytes(msg) -> int:
    """Bytes on the wire for ``msg`` — summed from the payload arrays
    themselves.  Works on concrete arrays, tracers, and the
    ``jax.eval_shape`` output (accounting without device compute)."""
    total = 0
    for arr in jax.tree.leaves(msg):
        total += math.prod(arr.shape) * jnp.dtype(arr.dtype).itemsize
    return int(total)


def dense_leaf(leaf, wire_dtype: str = "f32") -> LeafMsg:
    """Passthrough envelope: the leaf itself is the payload (cast to the
    wire dtype on the way out; the envelope keeps the decode target)."""
    return LeafMsg("dense", tuple(leaf.shape), jnp.dtype(leaf.dtype),
                   {"x": wire_cast(leaf, wire_dtype)})


class Codec:
    """encode(tree) -> WireMsg; decode(WireMsg) -> tree.

    Subclasses implement the per-leaf pair; ``encode``/``decode`` handle
    tree plumbing.  ``lossless`` declares bitwise round-trips (error
    feedback is skipped for lossless codecs).

    ``accumulate``/``sq_norms`` are the *fused* server-side entry points:
    they consume a cohort-stacked message (leading (B,) client axis on
    every payload) and reduce it without materializing the decoded dense
    stack.  The base fallbacks decode leaf-wise and contract — correct
    for any codec, including chains — and wire-native subclasses override
    them (qblock dequantize-accumulates through the ``kernels/fused_agg``
    Pallas kernel, low-rank contracts the factors directly).
    """
    name: str = "codec"
    lossless: bool = False

    def encode_leaf(self, leaf) -> LeafMsg:
        raise NotImplementedError

    def decode_leaf(self, msg: LeafMsg):
        if msg.kind == "dense":
            return msg.parts["x"].astype(msg.dtype)
        raise NotImplementedError(
            f"{type(self).__name__} cannot decode kind {msg.kind!r}")

    def encode(self, tree) -> WireMsg:
        leaves, treedef = jax.tree.flatten(tree)
        return WireMsg(self.name, treedef,
                       tuple(self.encode_leaf(leaf) for leaf in leaves))

    def decode(self, msg: WireMsg):
        return jax.tree.unflatten(
            msg.treedef, [self.decode_leaf(m) for m in msg.leaves])

    def roundtrip(self, tree):
        """What the server reconstructs from this client's upload."""
        return self.decode(self.encode(tree))

    # ------------------------------------------------- fused aggregation

    def accumulate_leaf(self, msgs: LeafMsg, weights, carry=None):
        """sum_i w_i * decode(msg_i) for one stacked leaf, in f32.

        Fallback: vmapped decode + the same ``dot_general`` contraction
        the dense engine path uses (``utils.tree.client_weighted_sum``),
        so a lossless codec's fused flush is bitwise-identical to
        decode-then-aggregate.

        ``carry`` is a running partial sum from previous chunks of the
        same cohort (the streaming pipeline's fold); ``carry=None`` keeps
        the exact legacy single-shot expression — no zeros added — so a
        one-chunk streamed round is bitwise-identical to the monolithic
        flush."""
        out = client_weighted_sum(jax.vmap(self.decode_leaf)(msgs), weights)
        return out if carry is None else carry + out

    def accumulate(self, msgs: WireMsg, weights, carry=None):
        """Fused decode-aggregate of a cohort-stacked message: the tree of
        sum_i w_i * decode(msg_i).  weights: (B,).  ``carry`` (a tree like
        the decode target, from a previous chunk's accumulate) folds this
        chunk into running partial sums; None is the one-shot flush."""
        cleaves = (jax.tree.flatten(carry)[0] if carry is not None
                   else [None] * len(msgs.leaves))
        return jax.tree.unflatten(
            msgs.treedef,
            [self.accumulate_leaf(m, weights, carry=c)
             for m, c in zip(msgs.leaves, cleaves)])

    def sq_norms_leaf(self, msgs: LeafMsg):
        """(B,) squared Frobenius norm of each client's decoded leaf."""
        dec = jax.vmap(self.decode_leaf)(msgs)
        x = dec.astype(jnp.float32).reshape(dec.shape[0], -1)
        return jnp.sum(x * x, axis=-1)

    def sq_norms(self, msgs: WireMsg):
        """(B,) per-client squared norm over all leaves — the wire-native
        half of the drift decomposition
        drift = mean_i ||Theta_i||^2 - ||mean_i Theta_i||^2."""
        total = jnp.zeros((), jnp.float32)
        for m in msgs.leaves:
            total = total + self.sq_norms_leaf(m)
        return total


# --------------------------------------------------------------- registry

_FACTORIES: dict[str, Callable[["TransportConfig"], Codec]] = {}


def register_codec(name: str):
    """Class/factory decorator: ``factory(cfg: TransportConfig) -> Codec``."""
    def deco(factory):
        _FACTORIES[name] = factory
        return factory
    return deco


def registered_codecs() -> tuple:
    return tuple(sorted(_FACTORIES))


@dataclasses.dataclass(frozen=True)
class TransportConfig:
    """Knobs shared by codec factories (one config, every codec).

    ``use_pallas``/``interpret`` default through the shared backend auto
    rule (``repro.utils.hw``): real Pallas kernels on TPU, the jnp
    reference/interpreter elsewhere — an accelerator host no longer
    silently runs the reference path.  ``wire_dtype`` caps the dtype of
    floating payloads ("f32" ships native; "bf16" halves dense payloads
    and low-rank factors on the wire).
    """
    rank: int = 8            # low-rank codecs (legacy FedConfig.svd_rank)
    block: int = 128         # qblock elements per scale
    sketch_iters: int = 2    # power_sketch subspace iterations
    use_pallas: bool = dataclasses.field(
        default_factory=hw.default_use_pallas)   # Pallas kernel vs jnp ref
    interpret: bool = dataclasses.field(
        default_factory=hw.default_interpret)    # interpret-mode fallback
    wire_dtype: str = "f32"  # floating payload dtype on the wire

    def __post_init__(self):
        validate_wire_dtype(self.wire_dtype)


def _parse_spec(spec) -> list:
    """'a+b' -> validated registry names; raises UnknownCodecError."""
    names = [p.strip() for p in str(spec).split("+")]
    for name in names:
        if name not in _FACTORIES:
            raise UnknownCodecError(
                f"unknown upload codec {name!r} (want one of "
                f"{registered_codecs()}, or a '+'-chain of them)")
    return names


def resolve_codec(spec, cfg: Optional[TransportConfig] = None) -> Codec:
    """Codec instances pass through; strings resolve against the registry.

    ``"a+b"`` composes a chain (a's wire structures re-encoded by b, e.g.
    ``"lowrank_svd+qblock"`` quantizes the SVD factors).  Legacy
    ``AlgorithmSpec.upload`` strings (``"dense"``/``"svd"``) are registered
    names, so every pre-codec spec keeps resolving.
    """
    if isinstance(spec, Codec):
        return spec
    cfg = cfg or TransportConfig()
    stages = [_FACTORIES[name](cfg) for name in _parse_spec(spec)]
    if len(stages) == 1:
        return stages[0]
    from repro.core.transport.chain import Chain
    return Chain(tuple(stages))


def validate_codec_spec(spec) -> None:
    """Raises UnknownCodecError for unresolvable specs (cheap, no build)."""
    if not isinstance(spec, Codec):
        _parse_spec(spec)


# --------------------------------------------------------------- transport

@dataclasses.dataclass(frozen=True)
class Transport:
    """The resolved wire policy of one experiment: one codec per channel.

    delta  — every client's parameter update (always uploaded);
    theta  — the preconditioner upload of aligned algorithms;
    error_feedback — carry the residual of the lossy *delta* codec as
      per-client state and add it back before the next encode (EF-SGD);
      a no-op for lossless codecs.
    """
    delta: Codec
    theta: Codec
    error_feedback: bool = True

    @property
    def feedback_active(self) -> bool:
        return self.error_feedback and not self.delta.lossless

    def round_bytes(self, params, theta=None) -> int:
        """Per-client upload bytes for one round, measured from the wire
        messages the codecs actually build (``jax.eval_shape`` — static
        shape math only, no device compute)."""
        delta_like = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params)
        total = wire_bytes(jax.eval_shape(self.delta.encode, delta_like))
        if theta is not None:
            total += wire_bytes(jax.eval_shape(self.theta.encode, theta))
        return total
