"""Geometry transport subsystem: wire-true codecs for federated uploads.

Layering:

  base.py            WireMsg / LeafMsg envelopes, the Codec protocol,
                     wire_bytes accounting, the codec registry, Transport
  dense.py           identity wire format (legacy upload path, bitwise)
  lowrank.py         lowrank_svd (factored U·s·Vᵀ) and power_sketch
  qblock.py          blockwise int8 quantization (kernels/qblock Pallas)
  chain.py           codec composition ("lowrank_svd+qblock")
  error_feedback.py  residual state for lossy delta codecs

Every upload in both runtimes is an encoded ``WireMsg``; every byte of
communication accounting comes from ``wire_bytes`` of those messages.
"""
from repro.core.transport.base import (
    Codec, LeafMsg, Transport, TransportConfig, UnknownCodecError,
    WIRE_DTYPES, WireMsg, dense_leaf, register_codec, registered_codecs,
    resolve_codec, validate_codec_spec, validate_wire_dtype, wire_bytes,
    wire_cast,
)
from repro.core.transport.dense import Dense
from repro.core.transport.lowrank import LowRankSVD, PowerSketch
from repro.core.transport.qblock import QBlock
from repro.core.transport.chain import Chain
from repro.core.transport.error_feedback import (
    ef_init, ef_scatter, ef_view, encode_with_feedback,
)

__all__ = [
    "Chain", "Codec", "Dense", "LeafMsg", "LowRankSVD", "PowerSketch",
    "QBlock", "Transport", "TransportConfig", "UnknownCodecError",
    "WIRE_DTYPES", "WireMsg", "dense_leaf", "ef_init", "ef_scatter",
    "ef_view", "encode_with_feedback", "register_codec",
    "registered_codecs", "resolve_codec", "validate_codec_spec",
    "validate_wire_dtype", "wire_bytes", "wire_cast",
]
