"""Error feedback for lossy upload codecs (EF-SGD, Karimireddy et al. 2019).

A lossy delta codec introduces a bias: what the server decodes is not
what the client computed.  Error feedback carries the residual

    e_i' = (delta_i + e_i) - decode(encode(delta_i + e_i))

as per-client persistent state, adding it back before the next round's
encode — the compression error is delayed, not lost, and convergence is
restored for biased compressors (e.g. aggressive low-rank truncation).

The residual is *declared* state: the sync runtime threads it through the
unified ``ClientStateSpec`` protocol (composed with any algorithm state,
see ``core.algorithms``), and the async runtime drives the same protocol
functions per dispatch, so residuals persist across rounds in both
runtimes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.transport.base import Codec


def ef_init(params, n_clients: int):
    """Stacked (N, ...) f32 residuals, zero at round 0."""
    return jax.tree.map(
        lambda p: jnp.zeros((n_clients, *p.shape), jnp.float32), params)


def ef_view(state, cid):
    """One client's residual."""
    return jax.tree.map(lambda r: r[cid], state)


def ef_scatter(state, cohort, new_residuals):
    """Write the cohort's refreshed residuals back (leading cohort axis)."""
    return jax.tree.map(lambda a, u: a.at[cohort].set(u), state,
                        new_residuals)


def encode_with_feedback(codec: Codec, tree, residual=None):
    """Encode ``tree`` (error-compensated when ``residual`` is given).

    Returns (msg, decoded, new_residual): ``decoded`` is the server-side
    reconstruction of ``msg`` (computed here anyway to form the residual —
    callers in the same program reuse it instead of decoding twice);
    decoded and new_residual are None when no residual was passed.  The
    residual accumulates in f32, but what goes to the codec keeps
    ``tree``'s dtypes — the wire format (and its byte count) must not
    change just because error feedback is on; any loss from casting the
    compensated value back down is captured by the residual like any
    other compression error.
    """
    if residual is None:
        return codec.encode(tree), None, None
    src32 = jax.tree.map(
        lambda t, r: t.astype(jnp.float32) + r, tree, residual)
    src = jax.tree.map(lambda s, t: s.astype(t.dtype), src32, tree)
    msg = codec.encode(src)
    decoded = codec.decode(msg)
    new_residual = jax.tree.map(
        lambda s, d: s - d.astype(jnp.float32), src32, decoded)
    return msg, decoded, new_residual
