"""Low-rank codecs: factored U·s·Vᵀ on the wire.

``lowrank_svd`` ships the truncated SVD factors themselves — r(m+n+1)
numbers per (m, n) matrix leaf — replacing the old reconstruct-then-ship
simulation (``core.compression.make_svd_codec``), whose byte count was an
analytic side-formula rather than a measurement.  ``power_sketch`` is the
randomized-range-finder variant (Halko et al., 2011): a few power
iterations + one thin QR instead of a full SVD, r(m+n) numbers on the
wire — cheaper to encode on large leaves at slightly worse error.

Both compress leaves with ``ndim >= 2`` whose trailing dims exceed the
rank (leading dims are a batch of matrices); everything else passes
through dense.  This is the per-client analogue of the legacy stacked
``ndim >= 3`` rule, and — unlike the legacy pair — the exact set of
compressed leaves is shared with accounting by construction, because
accounting reads the encoded message.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.transport.base import (
    Codec, LeafMsg, TransportConfig, dense_leaf, register_codec,
)


def _compressible(leaf, rank: int) -> bool:
    return (leaf.ndim >= 2 and leaf.shape[-1] > rank
            and leaf.shape[-2] > rank)


@dataclasses.dataclass(frozen=True)
class LowRankSVD(Codec):
    rank: int = 8
    name = "lowrank_svd"
    lossless = False

    def encode_leaf(self, leaf) -> LeafMsg:
        if not _compressible(leaf, self.rank):
            return dense_leaf(leaf)
        u, s, vt = jnp.linalg.svd(leaf.astype(jnp.float32),
                                  full_matrices=False)
        r = self.rank
        parts = {"u": u[..., :, :r].astype(leaf.dtype),
                 "s": s[..., :r].astype(leaf.dtype),
                 "vt": vt[..., :r, :].astype(leaf.dtype)}
        return LeafMsg("lowrank", tuple(leaf.shape), jnp.dtype(leaf.dtype),
                       parts)

    def decode_leaf(self, msg: LeafMsg):
        if msg.kind == "dense":
            return msg.parts["x"]
        u = msg.parts["u"].astype(jnp.float32)
        s = msg.parts["s"].astype(jnp.float32)
        vt = msg.parts["vt"].astype(jnp.float32)
        return ((u * s[..., None, :]) @ vt).astype(msg.dtype)


@dataclasses.dataclass(frozen=True)
class PowerSketch(Codec):
    rank: int = 8
    iters: int = 2
    name = "power_sketch"
    lossless = False

    def encode_leaf(self, leaf) -> LeafMsg:
        if not _compressible(leaf, self.rank):
            return dense_leaf(leaf)
        a = leaf.astype(jnp.float32)
        at = jnp.swapaxes(a, -1, -2)
        # fixed sketch: every client projects through the same Omega, so
        # the server could even aggregate sketches directly
        omega = jax.random.normal(jax.random.key(0xC0DEC),
                                  (a.shape[-1], self.rank), jnp.float32)
        q, _ = jnp.linalg.qr(a @ omega)             # (..., m, r)
        # subspace iteration with re-orthonormalization each half-step
        # (Halko et al. Alg. 4.4): without it the column energies spread
        # like the squared spectrum per iteration and trailing directions
        # drown in f32 noise on ill-conditioned curvature leaves
        for _ in range(self.iters):
            z, _ = jnp.linalg.qr(at @ q)
            q, _ = jnp.linalg.qr(a @ z)
        b = jnp.swapaxes(q, -1, -2) @ a             # (..., r, n)
        parts = {"q": q.astype(leaf.dtype), "b": b.astype(leaf.dtype)}
        return LeafMsg("sketch", tuple(leaf.shape), jnp.dtype(leaf.dtype),
                       parts)

    def decode_leaf(self, msg: LeafMsg):
        if msg.kind == "dense":
            return msg.parts["x"]
        q = msg.parts["q"].astype(jnp.float32)
        b = msg.parts["b"].astype(jnp.float32)
        return (q @ b).astype(msg.dtype)


@register_codec("lowrank_svd")
def _make_lowrank(cfg: TransportConfig) -> LowRankSVD:
    return LowRankSVD(rank=cfg.rank)


# legacy AlgorithmSpec.upload token for the *_light variants
register_codec("svd")(_make_lowrank)


@register_codec("power_sketch")
def _make_sketch(cfg: TransportConfig) -> PowerSketch:
    return PowerSketch(rank=cfg.rank, iters=cfg.sketch_iters)
