"""Low-rank codecs: factored U·s·Vᵀ on the wire.

``lowrank_svd`` ships the truncated SVD factors themselves — r(m+n+1)
numbers per (m, n) matrix leaf — replacing the old reconstruct-then-ship
simulation (``core.compression.make_svd_codec``), whose byte count was an
analytic side-formula rather than a measurement.  ``power_sketch`` is the
randomized-range-finder variant (Halko et al., 2011): a few power
iterations + one thin QR instead of a full SVD, r(m+n) numbers on the
wire — cheaper to encode on large leaves at slightly worse error.

Both compress leaves with ``ndim >= 2`` whose trailing dims exceed the
rank (leading dims are a batch of matrices); everything else passes
through dense.  This is the per-client analogue of the legacy stacked
``ndim >= 3`` rule, and — unlike the legacy pair — the exact set of
compressed leaves is shared with accounting by construction, because
accounting reads the encoded message.

Server-side, ``accumulate_leaf`` contracts the w-scaled factors through
one merged (m, B·r) x (B·r, n) GEMM (``kernels/fused_agg``) — the dense
per-client reconstructions never exist — and ``sq_norms_leaf`` uses the
r x r gram trick.  ``wire_dtype="bf16"`` ships the factors (and dense
passthrough leaves) in bf16.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.transport.base import (
    Codec, LeafMsg, TransportConfig, WIRE_DTYPES, dense_leaf, register_codec,
    validate_wire_dtype,
)
from repro.kernels.fused_agg import ops as fused_ops


def _compressible(leaf, rank: int) -> bool:
    return (leaf.ndim >= 2 and leaf.shape[-1] > rank
            and leaf.shape[-2] > rank)


def _factor_dtype(leaf_dtype, wire_dtype: str):
    """Factors ship in the leaf dtype, capped by the wire dtype."""
    dt = WIRE_DTYPES[wire_dtype]
    return leaf_dtype if dt is None else dt


def _gram_sq_norms(lhs, rhs):
    """(B,) squared Frobenius norms of sum-factored lhs @ rhs per client
    via the r x r gram trick: ||L R||^2 = <LᵀL, R Rᵀ>."""
    gl = jnp.einsum("...mr,...ms->...rs", lhs, lhs)
    gr = jnp.einsum("...rn,...sn->...rs", rhs, rhs)
    per = jnp.sum(gl * gr, axis=(-2, -1))
    return jnp.sum(per.reshape(per.shape[0], -1), axis=-1)


@dataclasses.dataclass(frozen=True)
class LowRankSVD(Codec):
    rank: int = 8
    wire_dtype: str = "f32"
    name = "lowrank_svd"
    lossless = False

    def __post_init__(self):
        validate_wire_dtype(self.wire_dtype)

    def encode_leaf(self, leaf) -> LeafMsg:
        if not _compressible(leaf, self.rank):
            return dense_leaf(leaf, self.wire_dtype)
        u, s, vt = jnp.linalg.svd(leaf.astype(jnp.float32),
                                  full_matrices=False)
        r = self.rank
        wd = _factor_dtype(leaf.dtype, self.wire_dtype)
        parts = {"u": u[..., :, :r].astype(wd),
                 "s": s[..., :r].astype(wd),
                 "vt": vt[..., :r, :].astype(wd)}
        return LeafMsg("lowrank", tuple(leaf.shape), jnp.dtype(leaf.dtype),
                       parts)

    def decode_leaf(self, msg: LeafMsg):
        if msg.kind == "dense":
            return msg.parts["x"].astype(msg.dtype)
        u = msg.parts["u"].astype(jnp.float32)
        s = msg.parts["s"].astype(jnp.float32)
        vt = msg.parts["vt"].astype(jnp.float32)
        return ((u * s[..., None, :]) @ vt).astype(msg.dtype)

    def accumulate_leaf(self, msgs: LeafMsg, weights, carry=None):
        if msgs.kind == "dense":
            return super().accumulate_leaf(msgs, weights, carry=carry)
        out = fused_ops.lowrank_accumulate(
            msgs.parts["u"], msgs.parts["s"], msgs.parts["vt"], weights)
        return out if carry is None else carry + out

    def sq_norms_leaf(self, msgs: LeafMsg):
        if msgs.kind == "dense":
            return super().sq_norms_leaf(msgs)
        u = msgs.parts["u"].astype(jnp.float32)
        s = msgs.parts["s"].astype(jnp.float32)
        vt = msgs.parts["vt"].astype(jnp.float32)
        return _gram_sq_norms(u * s[..., None, :], vt)


@dataclasses.dataclass(frozen=True)
class PowerSketch(Codec):
    rank: int = 8
    iters: int = 2
    wire_dtype: str = "f32"
    name = "power_sketch"
    lossless = False

    def __post_init__(self):
        validate_wire_dtype(self.wire_dtype)

    def encode_leaf(self, leaf) -> LeafMsg:
        if not _compressible(leaf, self.rank):
            return dense_leaf(leaf, self.wire_dtype)
        a = leaf.astype(jnp.float32)
        at = jnp.swapaxes(a, -1, -2)
        # fixed sketch: every client projects through the same Omega, so
        # the server could even aggregate sketches directly
        omega = jax.random.normal(jax.random.key(0xC0DEC),
                                  (a.shape[-1], self.rank), jnp.float32)
        q, _ = jnp.linalg.qr(a @ omega)             # (..., m, r)
        # subspace iteration with re-orthonormalization each half-step
        # (Halko et al. Alg. 4.4): without it the column energies spread
        # like the squared spectrum per iteration and trailing directions
        # drown in f32 noise on ill-conditioned curvature leaves
        for _ in range(self.iters):
            z, _ = jnp.linalg.qr(at @ q)
            q, _ = jnp.linalg.qr(a @ z)
        b = jnp.swapaxes(q, -1, -2) @ a             # (..., r, n)
        wd = _factor_dtype(leaf.dtype, self.wire_dtype)
        parts = {"q": q.astype(wd), "b": b.astype(wd)}
        return LeafMsg("sketch", tuple(leaf.shape), jnp.dtype(leaf.dtype),
                       parts)

    def decode_leaf(self, msg: LeafMsg):
        if msg.kind == "dense":
            return msg.parts["x"].astype(msg.dtype)
        q = msg.parts["q"].astype(jnp.float32)
        b = msg.parts["b"].astype(jnp.float32)
        return (q @ b).astype(msg.dtype)

    def accumulate_leaf(self, msgs: LeafMsg, weights, carry=None):
        if msgs.kind == "dense":
            return super().accumulate_leaf(msgs, weights, carry=carry)
        out = fused_ops.sketch_accumulate(
            msgs.parts["q"], msgs.parts["b"], weights)
        return out if carry is None else carry + out

    def sq_norms_leaf(self, msgs: LeafMsg):
        if msgs.kind == "dense":
            return super().sq_norms_leaf(msgs)
        return _gram_sq_norms(msgs.parts["q"].astype(jnp.float32),
                              msgs.parts["b"].astype(jnp.float32))


@register_codec("lowrank_svd")
def _make_lowrank(cfg: TransportConfig) -> LowRankSVD:
    return LowRankSVD(rank=cfg.rank, wire_dtype=cfg.wire_dtype)


# legacy AlgorithmSpec.upload token for the *_light variants
register_codec("svd")(_make_lowrank)


@register_codec("power_sketch")
def _make_sketch(cfg: TransportConfig) -> PowerSketch:
    return PowerSketch(rank=cfg.rank, iters=cfg.sketch_iters,
                       wire_dtype=cfg.wire_dtype)
