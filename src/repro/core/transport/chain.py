"""Chain codec: compose codecs by re-encoding wire structures.

``Chain((a, b))`` feeds the *payload arrays* of ``a``'s message through
``b`` — e.g. ``lowrank_svd+qblock`` ships int8-quantized SVD factors.
This works because a ``WireMsg`` is itself a pytree whose leaves are the
payload arrays, so the next stage needs no special cases; ``wire_bytes``
of the outermost message is what actually ships.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core.transport.base import Codec, WireMsg


@dataclasses.dataclass(frozen=True)
class Chain(Codec):
    stages: tuple   # of Codec, applied left to right on encode

    def __post_init__(self):
        if len(self.stages) < 2:
            raise ValueError("Chain wants at least two codecs")

    @property
    def name(self) -> str:  # type: ignore[override]
        return "+".join(c.name for c in self.stages)

    @property
    def lossless(self) -> bool:  # type: ignore[override]
        return all(c.lossless for c in self.stages)

    def encode(self, tree) -> WireMsg:
        msg = tree
        for codec in self.stages:
            msg = codec.encode(msg)
        return msg

    def decode(self, msg: WireMsg):
        for codec in reversed(self.stages):
            msg = codec.decode(msg)
        return msg

    def _peel(self, msgs: WireMsg) -> WireMsg:
        """Decode the outer stages of a cohort-stacked message, leaving the
        innermost stage's (still stacked) message — its fused reduction
        does the heavy lifting.  The outer payloads (e.g. quantized SVD
        factors) are small relative to the dense tree, so decoding them
        per client is cheap."""
        for codec in reversed(self.stages[1:]):
            msgs = jax.vmap(codec.decode)(msgs)
        return msgs

    def accumulate(self, msgs: WireMsg, weights, carry=None):
        return self.stages[0].accumulate(self._peel(msgs), weights,
                                         carry=carry)

    def sq_norms(self, msgs: WireMsg):
        return self.stages[0].sq_norms(self._peel(msgs))
