"""qblock codec: blockwise int8 quantization with per-block f32 scales.

Every leaf is flattened and quantized in blocks of ``block`` elements —
n int8 values + ceil(n/block) f32 scales on the wire, a ~4x shrink for
f32 trees with per-element error bounded by scale/2 per block.  The
quantization pass is backed by the ``kernels/qblock`` Pallas kernel
(ref/ops/kernel triad, interpret-mode fallback on CPU); the jnp reference
is the default off-TPU.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.core.transport.base import (
    Codec, LeafMsg, TransportConfig, register_codec,
)
from repro.kernels.qblock import ops


@dataclasses.dataclass(frozen=True)
class QBlock(Codec):
    block: int = 128
    use_pallas: bool = False
    interpret: bool = True
    name = "qblock"
    lossless = False

    def encode_leaf(self, leaf) -> LeafMsg:
        q, scale = ops.quantize(leaf, block=self.block,
                                use_pallas=self.use_pallas,
                                interpret=self.interpret)
        n = math.prod(leaf.shape)
        # ship exactly n int8 values; the block padding is reconstructed
        # from the scale count at decode.  The block size rides in the
        # static envelope so the message is self-describing: a decoder
        # configured differently still frames the blocks correctly.
        parts = {"q": q.reshape(-1)[:n], "scale": scale}
        return LeafMsg("qblock", tuple(leaf.shape), jnp.dtype(leaf.dtype),
                       parts, extra=self.block)

    def decode_leaf(self, msg: LeafMsg):
        if msg.kind == "dense":
            return msg.parts["x"]
        block = msg.extra
        q, scale = msg.parts["q"], msg.parts["scale"]
        pad = scale.shape[0] * block - q.shape[0]
        if pad:
            q = jnp.pad(q, (0, pad))
        return ops.dequantize(q.reshape(scale.shape[0], block), scale,
                              msg.shape, msg.dtype)


@register_codec("qblock")
def _make_qblock(cfg: TransportConfig) -> QBlock:
    return QBlock(block=cfg.block, use_pallas=cfg.use_pallas,
                  interpret=cfg.interpret)
