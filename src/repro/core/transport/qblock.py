"""qblock codec: blockwise int8 quantization with per-block f32 scales.

Every leaf is flattened and quantized in blocks of ``block`` elements —
n int8 values + ceil(n/block) f32 scales on the wire, a ~4x shrink for
f32 trees with per-element error bounded by scale/2 per block.  The
quantization pass is backed by the ``kernels/qblock`` Pallas kernel
(ref/ops/kernel triad, interpret-mode fallback on CPU); server-side the
codec never decodes a stacked cohort — ``accumulate_leaf`` folds the
per-block scales into the client weights and runs the fused
dequantize-accumulate pass (``kernels/fused_agg``) straight into the
weighted sum.  The wire format is already int8 + f32 scales, so
``wire_dtype`` does not apply.  ``use_pallas``/``interpret`` default
through the shared backend auto rule (``repro.utils.hw``).
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.core.transport.base import (
    Codec, LeafMsg, TransportConfig, register_codec,
)
from repro.kernels.fused_agg import ops as fused_ops
from repro.kernels.qblock import ops
from repro.utils import hw


@dataclasses.dataclass(frozen=True)
class QBlock(Codec):
    block: int = 128
    use_pallas: bool = dataclasses.field(
        default_factory=hw.default_use_pallas)
    interpret: bool = dataclasses.field(
        default_factory=hw.default_interpret)
    name = "qblock"
    lossless = False

    def encode_leaf(self, leaf) -> LeafMsg:
        q, scale = ops.quantize(leaf, block=self.block,
                                use_pallas=self.use_pallas,
                                interpret=self.interpret)
        n = math.prod(leaf.shape)
        # ship exactly n int8 values; the block padding is reconstructed
        # from the scale count at decode.  The block size rides in the
        # static envelope so the message is self-describing: a decoder
        # configured differently still frames the blocks correctly.
        parts = {"q": q.reshape(-1)[:n], "scale": scale}
        return LeafMsg("qblock", tuple(leaf.shape), jnp.dtype(leaf.dtype),
                       parts, extra=self.block)

    def decode_leaf(self, msg: LeafMsg):
        if msg.kind == "dense":
            return msg.parts["x"].astype(msg.dtype)
        block = msg.extra
        q, scale = msg.parts["q"], msg.parts["scale"]
        pad = scale.shape[0] * block - q.shape[0]
        if pad:
            q = jnp.pad(q, (0, pad))
        return ops.dequantize(q.reshape(scale.shape[0], block), scale,
                              msg.shape, msg.dtype)

    def _stacked_blocks(self, msgs: LeafMsg):
        """(B, nb, block) int8 + (B, nb) f32 from a cohort-stacked leaf."""
        block = msgs.extra
        q, scale = msgs.parts["q"], msgs.parts["scale"]
        b, n = q.shape
        nb = scale.shape[1]
        pad = nb * block - n
        if pad:
            q = jnp.pad(q, ((0, 0), (0, pad)))
        return q.reshape(b, nb, block), scale

    def accumulate_leaf(self, msgs: LeafMsg, weights, carry=None):
        if msgs.kind == "dense":
            return super().accumulate_leaf(msgs, weights, carry=carry)
        q3, scale = self._stacked_blocks(msgs)
        out = fused_ops.dequant_accumulate(
            q3, scale, weights, use_pallas=self.use_pallas,
            interpret=self.interpret)
        n = math.prod(msgs.shape)
        out = out.reshape(-1)[:n].reshape(msgs.shape)
        return out if carry is None else carry + out

    def sq_norms_leaf(self, msgs: LeafMsg):
        if msgs.kind == "dense":
            return super().sq_norms_leaf(msgs)
        # ||q * s||^2 per block = s^2 * sum(q^2): the scales come out of
        # the inner sum, so the pass stays on the int8 buffer
        q3, scale = self._stacked_blocks(msgs)
        qf = q3.astype(jnp.float32)
        per_block = jnp.einsum("bnk,bnk->bn", qf, qf)
        return jnp.einsum("bn,bn->b", per_block,
                          scale.astype(jnp.float32) ** 2)


@register_codec("qblock")
def _make_qblock(cfg: TransportConfig) -> QBlock:
    return QBlock(block=cfg.block, use_pallas=cfg.use_pallas,
                  interpret=cfg.interpret)
