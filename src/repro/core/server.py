"""Server state for the federated runtimes.

The aggregation math itself lives in ``core.engine.aggregation`` — the one
implementation shared by the sync round fn, SCAFFOLD, and the async buffer
flush.  ``ServerState.theta_version`` records the server round at which
Theta was last refreshed so stale geometries can be dated against the
version a client trained from; ``geom`` carries the functional
``GeometryController`` (adaptive correction strength) so beta evolves
inside jit and survives checkpoints.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class ServerState:
    params: Any
    theta: Any          # global preconditioner reference Theta^r (or None)
    g_global: Any       # estimated global direction g_G^r
    round: int = 0
    theta_version: int = 0   # round at which theta was last aggregated
    geom: Any = None         # GeometryController (or None: fixed-beta legacy)


def init_server(params, opt, g_dtype=jnp.float32, geom=None) -> ServerState:
    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, g_dtype), params)
    return ServerState(params=params, theta=None, g_global=g0, round=0,
                       geom=geom)
