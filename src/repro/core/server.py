"""Server-side aggregation (Alg. 2 lines 14-17).

Cohort results arrive stacked on a leading client axis (from vmap); on the
production mesh that axis is sharded over ("pod","data"), so every mean here
lowers to an all-reduce — the paper's server round-trip becomes a collective.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class ServerState:
    params: Any
    theta: Any          # global preconditioner reference Theta^r (or None)
    g_global: Any       # estimated global direction g_G^r
    round: int = 0


def aggregate_round(server: ServerState, deltas, thetas, *, lr: float,
                    local_steps: int, server_lr: float = 1.0) -> ServerState:
    """deltas/thetas: pytrees with leading client axis (stacked)."""
    mean_delta = jax.tree.map(lambda d: jnp.mean(d, axis=0), deltas)
    new_params = jax.tree.map(
        lambda p, d: (p.astype(jnp.float32) + server_lr * d).astype(p.dtype),
        server.params, mean_delta)
    # g_G^{r+1} = -(1/(S K eta)) sum_i Delta x_i  (Alg. 2 line 14)
    g_global = jax.tree.map(lambda d: -d / (local_steps * lr), mean_delta)
    theta = jax.tree.map(lambda t: jnp.mean(t, axis=0), thetas) \
        if thetas is not None else None
    return ServerState(new_params, theta, g_global, server.round + 1)


def init_server(params, opt, g_dtype=jnp.float32) -> ServerState:
    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, g_dtype), params)
    return ServerState(params=params, theta=None, g_global=g0, round=0)
