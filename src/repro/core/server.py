"""Server-side aggregation (Alg. 2 lines 14-17).

Cohort results arrive stacked on a leading client axis (from vmap); on the
production mesh that axis is sharded over ("pod","data"), so every mean here
lowers to an all-reduce — the paper's server round-trip becomes a collective.

Aggregation optionally takes per-client ``weights`` (leading-axis vector):
None is the uniform mean (the paper's synchronous setting); staleness
weights w_i in (0, 1] shrink stale clients' contributions.  The helpers
``weighted_client_mean``/``normalized_client_mean`` are the shared building
blocks — the buffered-asynchronous flush in ``fed.async_runtime.buffer``
composes them with freshness mixing, while ``aggregate_round`` is the
core-level weighted entry point.  ``ServerState.theta_version`` records the
server round at which Theta was last refreshed so stale geometries can be
dated against the version a client trained from.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class ServerState:
    params: Any
    theta: Any          # global preconditioner reference Theta^r (or None)
    g_global: Any       # estimated global direction g_G^r
    round: int = 0
    theta_version: int = 0   # round at which theta was last aggregated


def weighted_client_mean(tree, weights=None):
    """Mean over the leading client axis; optionally w_i-scaled (FedBuff).

    With weights, returns (1/S) sum_i w_i x_i — unnormalized on purpose:
    w_i in (0,1] shrink the contribution of stale clients rather than
    re-normalizing it away, so a fully-stale buffer takes a smaller server
    step.  weights=None is the uniform mean (w_i = 1).
    """
    if weights is None:
        return jax.tree.map(lambda x: jnp.mean(x, axis=0), tree)
    w = weights.astype(jnp.float32)
    return jax.tree.map(
        lambda x: jnp.mean(
            w.reshape((-1,) + (1,) * (x.ndim - 1)) * x.astype(jnp.float32),
            axis=0),
        tree)


def normalized_client_mean(tree, weights):
    """sum_i w_i x_i / sum_i w_i over the leading client axis."""
    w = weights.astype(jnp.float32)
    denom = jnp.sum(w) + 1e-12
    return jax.tree.map(
        lambda x: jnp.sum(
            w.reshape((-1,) + (1,) * (x.ndim - 1)) * x.astype(jnp.float32),
            axis=0) / denom,
        tree)


def aggregate_round(server: ServerState, deltas, thetas, *, lr: float,
                    local_steps: int, server_lr: float = 1.0,
                    weights=None) -> ServerState:
    """deltas/thetas: pytrees with leading client axis (stacked).

    weights: optional (S,) per-client weights (e.g. staleness decay); None
    is the synchronous uniform mean.
    """
    mean_delta = weighted_client_mean(deltas, weights)
    new_params = jax.tree.map(
        lambda p, d: (p.astype(jnp.float32) + server_lr * d).astype(p.dtype),
        server.params, mean_delta)
    # g_G^{r+1} = -(1/(S K eta)) sum_i Delta x_i  (Alg. 2 line 14).  Under
    # weights the direction estimate is w-normalized — only the parameter
    # *step* shrinks with staleness, not the magnitude of g_G (buffer.py
    # makes the same distinction).
    g_src = mean_delta if weights is None \
        else normalized_client_mean(deltas, weights)
    g_global = jax.tree.map(lambda d: -d / (local_steps * lr), g_src)
    if thetas is not None:
        # Theta is a reference geometry, not a step: always w-normalized
        theta = weighted_client_mean(thetas, None) if weights is None \
            else normalized_client_mean(thetas, weights)
        theta_version = server.round + 1
    else:
        theta, theta_version = None, server.theta_version
    return ServerState(new_params, theta, g_global, server.round + 1,
                       theta_version)


def init_server(params, opt, g_dtype=jnp.float32) -> ServerState:
    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, g_dtype), params)
    return ServerState(params=params, theta=None, g_global=g0, round=0)
