"""Preconditioner drift metric (Definition 1).

Delta_D = (1/S) sum_i E || Theta_i^{r,K} - mean_j Theta_j^{r,K} ||^2

``drift_metric`` consumes client-stacked Theta pytrees (leading axis S) and
returns the scalar; ``drift_per_layer`` keeps the per-leaf breakdown the
paper plots in Fig. 3; ``spectral_drift`` measures the layer-wise spectral
norm of (Theta_i - mean) for matrix-valued states (the Fig. 3 SOAP variant).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.api import path_str


def _centered(stacked):
    mean = jnp.mean(stacked, axis=0, keepdims=True)
    return stacked - mean


def drift_metric(thetas) -> jnp.ndarray:
    """Scalar Frobenius drift over all Theta leaves. thetas: stacked (S,...)."""
    leaves = jax.tree.leaves(thetas)
    total = jnp.zeros((), jnp.float32)
    for leaf in leaves:
        c = _centered(leaf.astype(jnp.float32))
        total += jnp.mean(jnp.sum(
            c.reshape(c.shape[0], -1) ** 2, axis=-1))
    return total


def drift_per_layer(thetas):
    """Dict path -> per-leaf drift (Fig. 3 layer-wise view)."""
    flat = jax.tree_util.tree_flatten_with_path(thetas)[0]
    out = {}
    for path, leaf in flat:
        c = _centered(leaf.astype(jnp.float32))
        out[path_str(path)] = jnp.mean(
            jnp.sum(c.reshape(c.shape[0], -1) ** 2, axis=-1))
    return out


def spectral_drift(thetas):
    """Mean spectral norm ||Theta_i - mean||_2 over clients, per matrix leaf.

    Used for SOAP's L/R factors (the paper's Fig. 3 measurement). Leaves with
    fewer than 2 dims are skipped.
    """
    flat = jax.tree_util.tree_flatten_with_path(thetas)[0]
    out = {}
    for path, leaf in flat:
        if leaf.ndim < 3:  # (S, m, n) at minimum
            continue
        c = _centered(leaf.astype(jnp.float32))
        mats = c.reshape(-1, c.shape[-2], c.shape[-1])
        sn = jnp.linalg.norm(mats, ord=2, axis=(-2, -1))
        out[path_str(path)] = jnp.mean(sn)
    return out
