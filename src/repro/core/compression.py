"""FedPAC_light: SVD-compressed preconditioner upload (Table 6 / 11).

Matrix-valued Theta leaves are truncated to rank r before "upload"; the
server aggregates the reconstructions.  ``comm_bytes`` provides the
per-round communication accounting used by benchmarks/table6_comm.py:
  Local X      : |x|
  FedPAC_X     : |x| + c|Theta|           (c = optimizer's multiplier)
  FedPAC_light : |x| + compressed |Theta|
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_bytes


def svd_truncate(mat, rank: int):
    """Rank-r truncation of the trailing two dims."""
    u, s, vt = jnp.linalg.svd(mat.astype(jnp.float32), full_matrices=False)
    r = min(rank, s.shape[-1])
    return (u[..., :, :r] * s[..., None, :r]) @ vt[..., :r, :]


def make_svd_codec(rank: int) -> Callable:
    """Returns compress(thetas) applying rank-r SVD to matrix leaves.

    Simulates the upload->decode round-trip: output has the original shapes
    but carries only rank-r information (what the server would reconstruct).
    """

    def compress(thetas):
        def leaf(x):
            # stacked client axis in front: treat trailing 2 dims as matrix
            if x.ndim >= 3 and x.shape[-1] > rank and x.shape[-2] > rank:
                return svd_truncate(x, rank).astype(x.dtype)
            return x
        return jax.tree.map(leaf, thetas)

    return compress


def compressed_bytes(theta, rank: int) -> int:
    """Bytes uploaded per client for a rank-r factored Theta."""
    total = 0
    for leaf in jax.tree.leaves(theta):
        if leaf.ndim >= 2 and leaf.shape[-1] > rank and leaf.shape[-2] > rank:
            m, n = leaf.shape[-2], leaf.shape[-1]
            batch = int(jnp.prod(jnp.array(leaf.shape[:-2]))) if leaf.ndim > 2 else 1
            total += batch * rank * (m + n + 1) * leaf.dtype.itemsize
        else:
            total += leaf.size * leaf.dtype.itemsize
    return int(total)


def round_comm_bytes(params, theta=None, *, compressed_rank=None) -> int:
    """Per-round upload bytes for one client (Table 6 accounting)."""
    total = tree_bytes(params)
    if theta is not None:
        if compressed_rank:
            total += compressed_bytes(theta, compressed_rank)
        else:
            total += tree_bytes(theta)
    return int(total)
