"""Legacy FedPAC_light compression shims, now backed by ``core.transport``.

The wire-true codec subsystem (``repro.core.transport``) superseded this
module: uploads are encoded ``WireMsg`` structures and all byte accounting
derives from ``transport.wire_bytes`` of those messages.  These shims keep
the historical entry points alive by delegating to the ``lowrank_svd``
codec, which also fixes the old mismatch where ``make_svd_codec``
compressed only ``ndim >= 3`` (stacked) leaves while ``compressed_bytes``
counted ``ndim >= 2`` leaves as compressed: both directions now share one
codec, so the set of compressed leaves is identical by construction.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.transport import Dense, LowRankSVD, wire_bytes


def svd_truncate(mat, rank: int):
    """Rank-r truncation of the trailing two dims."""
    u, s, vt = jnp.linalg.svd(mat.astype(jnp.float32), full_matrices=False)
    r = min(rank, s.shape[-1])
    return (u[..., :, :r] * s[..., None, :r]) @ vt[..., :r, :]


def make_svd_codec(rank: int) -> Callable:
    """Legacy stacked round-trip: rank-r SVD per client, dense result.

    Expects a *stacked* pytree with a leading (S,) client axis; each
    client's tree goes through the ``lowrank_svd`` codec's
    encode -> decode, so a stacked ``ndim >= 3`` leaf is compressed iff
    the per-client ``ndim >= 2`` leaf is — the same rule accounting uses.
    """
    codec = LowRankSVD(rank=rank)
    return jax.vmap(codec.roundtrip)


def compressed_bytes(theta, rank: int) -> int:
    """Bytes uploaded per client for a rank-r factored Theta.

    Measured from the wire message the ``lowrank_svd`` codec actually
    builds for this (per-client) tree — static shape math only.
    """
    codec = LowRankSVD(rank=rank)
    return wire_bytes(jax.eval_shape(codec.encode, theta))


def round_comm_bytes(params, theta=None, *, compressed_rank=None) -> int:
    """Per-round upload bytes for one client (Table 6 accounting)."""
    total = wire_bytes(jax.eval_shape(Dense().encode, params))
    if theta is not None:
        if compressed_rank:
            total += compressed_bytes(theta, compressed_rank)
        else:
            total += wire_bytes(jax.eval_shape(Dense().encode, theta))
    return int(total)
