"""FedPAC (Alg. 2): Federated Preconditioner Alignment and Correction.

Decouples parameter aggregation from geometry synchronization:
  Alignment  — server aggregates Theta^{r+1} = mean_i Theta_i^{r,K} and
               clients warm-start Theta_i^{r,0} <- Theta^r  (lines 3 & 16);
  Correction — local steps mix the locally preconditioned direction with the
               estimated global direction g_G^r (line 9, Eq. 9).

``make_round_fn`` builds a single jitted function computing one communication
round for a cohort of S clients (vmapped; shard the client axis over the mesh
to realize the paper's linear speedup in S).

Beyond-paper: ``beta="auto"`` scales the correction strength with the
*measured normalized drift* of the previous round,
  beta_r = beta_max * d / (1 + d),   d = Delta_D / (||Theta_mean||^2 + eps).
Rationale: Thm 5.6's penalty is proportional to Delta_D — when clients'
geometries barely drift (near-IID or curvature-homogeneous data), a fixed
beta only injects staleness from g_G^{r-1}; adaptive beta backs the
correction off exactly then (see EXPERIMENTS §Paper-claims analysis).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.client import LocalRunConfig, client_round
from repro.core.server import ServerState
from repro.core.drift import drift_metric
from repro.utils.tree import tree_norm_sq
from repro.optim.api import LocalOptimizer

# cap for the drift-adaptive beta="auto" rule (both runtimes)
BETA_MAX_AUTO = 0.7


def make_round_fn(
    loss_fn: Callable,
    opt: LocalOptimizer,
    *,
    lr: float,
    local_steps: int,
    beta: Union[float, str] = 0.5,
    align: bool = True,
    correct: bool = True,
    hessian_freq: int = 10,
    server_lr: float = 1.0,
    compress_fn=None,       # FedPAC_light: Theta codec (see core.compression)
    beta_max: float = BETA_MAX_AUTO,  # cap for beta="auto"
    jit: bool = True,
):
    """Returns round_fn(server_state, batches, rng) -> (server_state, metrics).

    batches: pytree with leading (S, K, ...) axes (client, local step).
    ``align=False, correct=False`` (or ``variant="fedsoa"`` upstream) is the
    naive FedSOA baseline of Alg. 1.  ``beta="auto"`` enables drift-adaptive
    correction (beyond-paper; see module docstring).
    """
    adaptive = beta == "auto"
    static_beta = 0.0 if (adaptive or not correct) else float(beta)
    run = LocalRunConfig(lr=lr, local_steps=local_steps, beta=static_beta,
                         hessian_freq=hessian_freq, align=align)

    def round_fn(params, theta, g_global, batches, rng, beta_in):
        n_clients = jax.tree.leaves(batches)[0].shape[0]
        keys = jax.random.split(rng, n_clients)

        def one_client(batch_i, key_i):
            return client_round(loss_fn, opt, run, params, theta,
                                g_global, batch_i, key_i, beta=beta_in)

        deltas, thetas, losses = jax.vmap(one_client)(batches, keys)
        if compress_fn is not None:
            # Clients upload compressed Theta; server aggregates the decoded
            # reconstruction (accuracy/bandwidth trade-off of Table 6).
            thetas = compress_fn(thetas)
        drift = drift_metric(thetas)
        mean_delta = jax.tree.map(lambda d: jnp.mean(d, axis=0), deltas)
        new_params = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32)
                          + server_lr * d).astype(p.dtype), params, mean_delta)
        new_g = jax.tree.map(lambda d: -d / (local_steps * lr), mean_delta)
        new_theta = jax.tree.map(lambda t: jnp.mean(t, axis=0), thetas)
        theta_norm = tree_norm_sq(new_theta)
        norm_drift = drift / (theta_norm + 1e-12)
        metrics = {"loss": jnp.mean(losses), "drift": drift,
                   "norm_drift": norm_drift, "beta": beta_in}
        return new_params, new_theta, new_g, metrics

    if jit:
        round_fn = jax.jit(round_fn)

    beta_cell = {"value": jnp.float32(static_beta)}

    def driver(server: ServerState, batches, rng):
        theta = server.theta
        if theta is None:
            # round 0: no reference yet -> align to the fresh (zero) state.
            theta = zero_theta(opt, server.params)
        p, th, g, metrics = round_fn(server.params, theta, server.g_global,
                                     batches, rng, beta_cell["value"])
        if adaptive and correct:
            d = metrics["norm_drift"]
            beta_cell["value"] = (beta_max * d / (1.0 + d)).astype(jnp.float32)
        return ServerState(p, th, g, server.round + 1, server.round + 1), \
            metrics

    return driver


def zero_theta(opt: LocalOptimizer, params):
    """Fresh (zero) preconditioner pytree for ``opt`` on ``params``.

    Round 0 has no global reference yet; both runtimes align to this."""
    state = jax.eval_shape(opt.init, params)
    theta_shape = jax.eval_shape(lambda s: opt.get_precond(s), state)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), theta_shape)
