"""FedPAC (Alg. 2): Federated Preconditioner Alignment and Correction.

Decouples parameter aggregation from geometry synchronization:
  Alignment  — server aggregates Theta^{r+1} = mean_i Theta_i^{r,K} and
               clients warm-start Theta_i^{r,0} <- Theta^r  (lines 3 & 16);
  Correction — local steps mix the locally preconditioned direction with the
               estimated global direction g_G^r (line 9, Eq. 9).

``make_round_fn`` is the core-level *stateless* entry point with the
historical ``round_fn(server, batches, rng)`` signature: it builds an
anonymous ``AlgorithmSpec`` for the requested (align, correct) combination
and adapts ``core.algorithms.build_round_fn`` — the one uniform round
implementation shared with SCAFFOLD, FedPM and both runtimes — by fixing
``client_state=None`` and ``cohort=arange(S)``.  Registered algorithms and
per-client persistent state go through ``build_round_fn`` directly.
"""
from __future__ import annotations

from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.algorithms import AlgorithmSpec, build_round_fn, zero_theta
from repro.core.engine import BETA_MAX_AUTO, ExecutorConfig
from repro.core.server import ServerState
from repro.optim.api import LocalOptimizer

__all__ = ["make_round_fn", "zero_theta"]


def make_round_fn(
    loss_fn: Callable,
    opt: LocalOptimizer,
    *,
    lr: float,
    local_steps: int,
    beta: Union[float, str] = 0.5,
    align: bool = True,
    correct: bool = True,
    hessian_freq: int = 10,
    server_lr: float = 1.0,
    compress_fn=None,       # legacy stacked Theta round-trip (pre-transport)
    transport=None,         # core.transport.Transport: wire-true codecs
    beta_max: float = BETA_MAX_AUTO,  # cap for beta="auto"
    drift_ema: float = 1.0,           # EMA coeff for beta="auto" (1 = raw)
    executor: Optional[ExecutorConfig] = None,
    jit: bool = True,
    telemetry: bool = False,   # metrics["telemetry"] (repro.obs) when True
):
    """Returns round_fn(server_state, batches, rng) -> (server_state, metrics).

    batches: pytree with leading (S, K, ...) axes (client, local step).
    ``align=False, correct=False`` (or ``variant="fedsoa"`` upstream) is the
    naive FedSOA baseline of Alg. 1.  ``beta="auto"`` enables drift-adaptive
    correction (see ``core.engine.geometry``).  ``transport`` with an
    error-feedback-active delta codec needs per-client state — use
    ``build_round_fn`` with ``n_clients`` for that.
    """
    spec = AlgorithmSpec(name=f"<inline:{opt.name}>", optimizer=opt.name,
                         align=align, correct=correct)
    driver = build_round_fn(
        spec, loss_fn, opt, lr=lr, local_steps=local_steps, beta=beta,
        hessian_freq=hessian_freq, server_lr=server_lr,
        compress_fn=compress_fn, transport=transport, beta_max=beta_max,
        drift_ema=drift_ema, executor=executor, jit=jit,
        telemetry=telemetry)

    def round_fn(server: ServerState, batches, rng):
        s = jax.tree.leaves(batches)[0].shape[0]
        new_server, _, metrics = driver(server, None, jnp.arange(s), batches,
                                        rng)
        return new_server, metrics

    return round_fn
