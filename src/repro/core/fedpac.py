"""FedPAC (Alg. 2): Federated Preconditioner Alignment and Correction.

Decouples parameter aggregation from geometry synchronization:
  Alignment  — server aggregates Theta^{r+1} = mean_i Theta_i^{r,K} and
               clients warm-start Theta_i^{r,0} <- Theta^r  (lines 3 & 16);
  Correction — local steps mix the locally preconditioned direction with the
               estimated global direction g_G^r (line 9, Eq. 9).

``make_round_fn`` is a thin driver over the unified round engine
(``core.engine``): the cohort runs under a pluggable executor (vmap |
shard_map | chunked), the server update is the engine's single
``aggregate``, and the drift-adaptive ``beta="auto"`` rule is the
functional ``GeometryController`` carried in ``ServerState.geom`` — jit-
pure, checkpointable, and identical across the sync and async runtimes.
"""
from __future__ import annotations

from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.client import LocalRunConfig, client_round
from repro.core.server import ServerState
from repro.core.engine import (
    AggregationConfig, BETA_MAX_AUTO, ExecutorConfig, advance_server,
    aggregate, make_cohort_executor, make_controller, update_controller,
)
from repro.optim.api import LocalOptimizer


def make_round_fn(
    loss_fn: Callable,
    opt: LocalOptimizer,
    *,
    lr: float,
    local_steps: int,
    beta: Union[float, str] = 0.5,
    align: bool = True,
    correct: bool = True,
    hessian_freq: int = 10,
    server_lr: float = 1.0,
    compress_fn=None,       # FedPAC_light: Theta codec (see core.compression)
    beta_max: float = BETA_MAX_AUTO,  # cap for beta="auto"
    drift_ema: float = 1.0,           # EMA coeff for beta="auto" (1 = raw)
    executor: Optional[ExecutorConfig] = None,
    jit: bool = True,
):
    """Returns round_fn(server_state, batches, rng) -> (server_state, metrics).

    batches: pytree with leading (S, K, ...) axes (client, local step).
    ``align=False, correct=False`` (or ``variant="fedsoa"`` upstream) is the
    naive FedSOA baseline of Alg. 1.  ``beta="auto"`` enables drift-adaptive
    correction (see ``core.engine.geometry``).
    """
    default_ctrl = make_controller(beta, correct=correct, beta_max=beta_max,
                                   ema=drift_ema)
    run = LocalRunConfig(lr=lr, local_steps=local_steps, beta=0.0,
                         hessian_freq=hessian_freq, align=align)
    agg_cfg = AggregationConfig(lr=lr, local_steps=local_steps,
                                server_lr=server_lr, align=align)
    cohort = make_cohort_executor(executor)

    def round_fn(params, theta, g_global, ctrl, batches, rng):
        n_clients = jax.tree.leaves(batches)[0].shape[0]
        keys = jax.random.split(rng, n_clients)

        def one_client(batch_i, key_i):
            return client_round(loss_fn, opt, run, params, theta,
                                g_global, batch_i, key_i, beta=ctrl.beta)

        deltas, thetas, losses = cohort(one_client, batches, keys)
        if compress_fn is not None:
            # Clients upload compressed Theta; server aggregates the decoded
            # reconstruction (accuracy/bandwidth trade-off of Table 6).
            thetas = compress_fn(thetas)
        weights = jnp.ones((n_clients,), jnp.float32)
        new_params, new_theta, new_g, agg = aggregate(
            params, theta, g_global, deltas, thetas, weights, agg_cfg)
        new_ctrl = update_controller(ctrl, agg["norm_drift"],
                                     agg["freshness"])
        metrics = dict(agg, loss=jnp.mean(losses), beta=ctrl.beta)
        return new_params, new_theta, new_g, new_ctrl, metrics

    if jit:
        round_fn = jax.jit(round_fn)

    def driver(server: ServerState, batches, rng):
        ctrl = server.geom if server.geom is not None else default_ctrl
        theta = server.theta
        if align and theta is None:
            # round 0: no reference yet -> align to the fresh (zero) state.
            theta = zero_theta(opt, server.params)
        p, th, g, ctrl, metrics = round_fn(server.params, theta,
                                           server.g_global, ctrl, batches,
                                           rng)
        return advance_server(server, p, th, g, geom=ctrl,
                              aligned=align), metrics

    return driver


def zero_theta(opt: LocalOptimizer, params):
    """Fresh (zero) preconditioner pytree for ``opt`` on ``params``.

    Round 0 has no global reference yet; both runtimes align to this."""
    state = jax.eval_shape(opt.init, params)
    theta_shape = jax.eval_shape(lambda s: opt.get_precond(s), state)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), theta_shape)
