"""FedPM-style preconditioned-mixing aggregation — the registry's
extensibility proof.

Curvature-weighted mixing of local updates (after Ishii et al., 2025):
clients train with a second-order optimizer under FedPAC Alignment, and the
server replaces the uniform delta mean with weights inversely proportional
to each client's local curvature mass (``engine.aggregation.
precond_mixing_weights``) — sharp-region clients move the model less.

Note what this module does NOT touch: ``fed/rounds.py``, the runtimes, the
engine.  A genuinely new algorithm is ~10 lines of ``AlgorithmSpec`` —
declare the optimizer, the alignment policy, and a mixing hook, and both
runtimes (sync and buffered-async) run it through the one engine path.
"""
from __future__ import annotations

from repro.core.algorithms import AlgorithmSpec, register
from repro.core.engine.aggregation import precond_mixing_weights

# second-order local optimizers only: mixing needs a non-empty Theta upload
_FEDPM_OPTS = ("adamw", "sophia", "muon", "soap")

FEDPM_SPECS = {
    opt_name: register(AlgorithmSpec(
        name=f"fedpm_{opt_name}", optimizer=opt_name, align=True,
        mixing=precond_mixing_weights,
        description=f"preconditioned mixing with {opt_name}: curvature-"
                    "weighted delta mean under aligned geometry"))
    for opt_name in _FEDPM_OPTS
}
