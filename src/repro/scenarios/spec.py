"""Declarative scenario data model: what a federated task *is*.

A scenario is the second axis of the experiment API (the first is the
algorithm, ``core.algorithms.AlgorithmSpec``): a frozen ``ScenarioSpec``
declares data source x partition x model x batching declaratively, and
``materialize`` (``scenarios.registry``) turns it into the concrete
``Scenario`` bundle — ``(params, loss_fn, client_batch_fn, eval_fn,
partition_stats)`` — that both runtimes consume through
``repro.api.build_experiment(algorithm, scenario=...)``.

``PartitionSpec`` is the heterogeneity control: it names one of the
standard partitioners (``repro.data.partition``) plus its severity knob,
so "the same task under Dir-0.1 / Dir-0.05 / shard / IID" is a one-field
variation instead of re-plumbed wiring.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional, Union

import numpy as np

from repro.data.partition import (
    ClientIndexMap, dirichlet_partition, iid_partition, quantity_partition,
    shard_partition, stream_dirichlet_map,
)


class UnknownScenarioError(ValueError):
    """Name resolves to no registered ``ScenarioSpec``."""


class DuplicateScenarioError(ValueError):
    """``register`` called twice for the same scenario name."""


PARTITION_KINDS = ("dirichlet", "shard", "quantity", "iid",
                   "stream_dirichlet")

#: kinds whose split is derived per client on demand (``build`` returns a
#: ``ClientIndexMap`` instead of an eager list) — the only kinds usable at
#: population scale (10^5+ client ids)
LAZY_PARTITION_KINDS = ("stream_dirichlet",)


@dataclasses.dataclass(frozen=True)
class PartitionSpec:
    """How samples (or documents) are split across clients.

    kind: one of ``PARTITION_KINDS``; ``alpha`` is the Dirichlet
    concentration for ``dirichlet`` (label skew), ``quantity`` (size
    skew), and ``stream_dirichlet`` (per-client label mixture);
    ``shards_per_client`` drives the pathological ``shard`` split.

    ``stream_dirichlet`` is the lazy, population-scale analog of
    ``dirichlet``: nothing is enumerated up front — each client's
    ``samples_per_client`` indices derive from ``(seed, client_id)`` alone
    (``repro.data.partition.stream_dirichlet_map``), so the same spec
    materializes over 10 clients or 10^6 ids at the same cost.  Streamed
    clients view the sample pool with replacement rather than owning
    disjoint slices.
    """
    kind: str = "dirichlet"
    alpha: float = 0.1
    shards_per_client: int = 2
    min_size: int = 2
    samples_per_client: int = 64

    def __post_init__(self):
        if self.kind not in PARTITION_KINDS:
            raise ValueError(
                f"unknown partition kind {self.kind!r} "
                f"(want one of {PARTITION_KINDS})")
        if self.kind in ("dirichlet", "quantity", "stream_dirichlet") and \
                self.alpha <= 0:
            raise ValueError(f"alpha must be > 0, got {self.alpha}")
        if self.shards_per_client < 1:
            raise ValueError(
                f"shards_per_client must be >= 1, got "
                f"{self.shards_per_client}")
        if self.samples_per_client < 1:
            raise ValueError(
                f"samples_per_client must be >= 1, got "
                f"{self.samples_per_client}")

    @property
    def lazy(self) -> bool:
        """Whether ``build`` yields a lazy map rather than an eager list."""
        return self.kind in LAZY_PARTITION_KINDS

    def build(self, labels: Optional[np.ndarray], n_samples: int,
              n_clients: int, seed: int):
        """Materialize the split.

        Eager kinds return a list of ``n_clients`` index arrays (exactly as
        before); lazy kinds return a ``ClientIndexMap`` whose ``[cid]``
        lookup derives that client's indices on demand.  Both support
        ``parts[cid]`` indexing, which is all the batch functions use.
        """
        if self.kind == "iid":
            return iid_partition(n_samples, n_clients, seed=seed)
        if self.kind == "quantity":
            return quantity_partition(n_samples, n_clients, self.alpha,
                                      seed=seed, min_size=self.min_size)
        if labels is None:
            raise ValueError(
                f"partition kind {self.kind!r} needs labels, but this "
                "scenario's data source provides none")
        if self.kind == "dirichlet":
            return dirichlet_partition(labels, n_clients, self.alpha,
                                       seed=seed, min_size=self.min_size)
        if self.kind == "stream_dirichlet":
            return stream_dirichlet_map(
                labels, n_clients, self.alpha,
                samples_per_client=self.samples_per_client, seed=seed)
        return shard_partition(labels, n_clients,
                               shards_per_client=self.shards_per_client,
                               seed=seed)

    def tag(self) -> str:
        """Short name for sweep rows / derived-variant names."""
        if self.kind == "dirichlet":
            return f"dir{self.alpha:g}"
        if self.kind == "quantity":
            return f"qty{self.alpha:g}"
        if self.kind == "shard":
            return f"shard{self.shards_per_client}"
        if self.kind == "stream_dirichlet":
            return f"sdir{self.alpha:g}"
        return "iid"


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One federated task, declaratively.

    source: data-source family — a key in the source registry
      (``"synth_image"``, ``"lm_zipf"``; extend via
      ``scenarios.register_source``) or a callable materializer
      ``(spec, seed, n_clients) -> Scenario`` for fully custom tasks.
    partition: the heterogeneity axis (``PartitionSpec``).
    model: model-factory key understood by the source family
      (vision: ``"cnn"`` | ``"vit"``; LM: ``"transformer_lm"``).
    n_clients / batch_size: task-level defaults; ``build_experiment``
      overrides ``n_clients`` from the fed config when the caller sets it.
    source_kwargs / model_kwargs: family-specific knobs (sample counts,
      image size, vocab, model width, ...), applied over the family's
      defaults.
    """
    name: str
    source: Union[str, Callable] = "synth_image"
    partition: PartitionSpec = PartitionSpec()
    model: str = "cnn"
    n_clients: int = 10
    batch_size: int = 16
    source_kwargs: Mapping = dataclasses.field(default_factory=dict)
    model_kwargs: Mapping = dataclasses.field(default_factory=dict)
    description: str = ""

    def __post_init__(self):
        if not self.name:
            raise ValueError("a ScenarioSpec needs a non-empty name")
        if self.n_clients < 1:
            raise ValueError(f"n_clients must be >= 1, got {self.n_clients}")
        if self.batch_size < 1:
            raise ValueError(
                f"batch_size must be >= 1, got {self.batch_size}")

    # ------------------------------------------------------------ variants

    def with_partition(self, partition: PartitionSpec,
                       suffix: Optional[str] = None) -> "ScenarioSpec":
        """Derived variant of the same task under another partition.

        The derived spec is unregistered (usable directly, like unregistered
        ``AlgorithmSpec`` values); its name gains the partition tag.
        """
        return dataclasses.replace(
            self, partition=partition,
            name=f"{self.name}@{suffix or partition.tag()}")

    def variant(self, suffix: str, **changes) -> "ScenarioSpec":
        """Renamed derived spec with field overrides (registry helpers)."""
        return dataclasses.replace(self, name=f"{self.name}_{suffix}",
                                   **changes)


def check_source_kwargs(spec: "ScenarioSpec", defaults: Mapping) -> dict:
    """Defaults overlaid with the spec's knobs; unknown keys are an error
    (a typo'd knob must not silently run the wrong experiment)."""
    unknown = set(spec.source_kwargs) - set(defaults)
    if unknown:
        raise ValueError(
            f"scenario {spec.name!r}: unknown source_kwargs "
            f"{sorted(unknown)} (this source understands "
            f"{sorted(defaults)})")
    kw = dict(defaults)
    kw.update(spec.source_kwargs)
    return kw


@dataclasses.dataclass
class Scenario:
    """A materialized scenario: the concrete problem both runtimes consume.

    ``problem()`` returns the legacy 4-tuple
    ``(params, loss_fn, client_batch_fn, eval_fn)`` —
    ``benchmarks.common.make_fed_vision_problem`` is a thin adapter over it.

    partitions: per-client index arrays into the source's training set —
      a list for eager partition kinds, a lazy ``ClientIndexMap`` for
      streamed kinds (both index as ``partitions[cid]``), or None for
      sources that synthesize per-client data directly.
    partition_stats: sizes + label-skew summary
      (``repro.data.partition.partition_stats``).
    meta: family-specific extras (model config, eval-set sizes, ...).
    """
    spec: ScenarioSpec
    seed: int
    n_clients: int
    params: Any
    loss_fn: Callable
    client_batch_fn: Callable
    eval_fn: Optional[Callable]
    partitions: Optional[Union[list, ClientIndexMap]] = None
    partition_stats: dict = dataclasses.field(default_factory=dict)
    meta: dict = dataclasses.field(default_factory=dict)

    def problem(self):
        """The legacy positional bundle (params, loss, batch, eval)."""
        return (self.params, self.loss_fn, self.client_batch_fn,
                self.eval_fn)
