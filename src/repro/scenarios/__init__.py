"""First-class scenario API: declarative federated tasks.

A scenario — data source x partition x model x eval — is registered data,
exactly like an algorithm (``core.algorithms``):

    from repro.scenarios import ScenarioSpec, PartitionSpec, register

    register(ScenarioSpec(name="my_task", source="synth_image",
                          partition=PartitionSpec("dirichlet", alpha=0.05)))

and consumed by name (or as an unregistered spec) through the one builder:

    from repro.api import build_experiment
    exp = build_experiment("fedpac_soap", scenario="cifar_like_cnn",
                           rounds=30)

``materialize(spec, seed)`` produces the concrete ``Scenario`` bundle —
``(params, loss_fn, client_batch_fn, eval_fn, partition_stats)`` — both
runtimes consume; the registered catalog lives in ``scenarios.catalog``.
"""
from repro.scenarios.spec import (  # noqa: F401
    DuplicateScenarioError, PARTITION_KINDS, PartitionSpec, Scenario,
    ScenarioSpec, UnknownScenarioError,
)
from repro.scenarios.registry import (  # noqa: F401
    get, materialize, register, register_source, registered, resolve,
    resolve_source,
)
from repro.scenarios.catalog import cifar_like, lm_zipf  # noqa: F401
