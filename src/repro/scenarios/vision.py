"""``synth_image`` source family: Dirichlet/shard/quantity/IID-partitioned
synthetic image classification (CIFAR-like gaussian mixtures) with CNN or
ViT backbones.

Materialization is bitwise-faithful to the legacy
``benchmarks.common.make_fed_vision_problem`` wiring (same data, partition,
init and batch RNG consumption), which is what the golden equivalence test
pins: declaring the task did not change the task.

The ``stream_dirichlet`` partition kind makes this source population-scale:
``spec.partition.build`` then returns a lazy ``ClientIndexMap`` instead of
an eager list, and since ``batch_fn`` only ever does ``parts[cid]``, a
10^6-id scenario materializes in O(dataset) — client slices are derived
the first time a cohort actually samples them.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.data import make_image_classification, partition_stats
from repro.models.vision import (
    accuracy, classification_loss, cnn_apply, init_cnn, init_vit, vit_apply,
)
from repro.fed.staging import mark_thread_safe
from repro.scenarios.registry import register_source
from repro.scenarios.spec import Scenario, ScenarioSpec, check_source_kwargs

SOURCE_DEFAULTS = dict(n=3000, image_size=12, n_classes=8, noise=2.5,
                       n_eval=768)


def _make_cnn(seed: int, *, image_size: int, n_classes: int, width: int = 8,
              blocks: int = 2):
    del image_size  # fully convolutional
    params = init_cnn(jax.random.key(seed), n_classes=n_classes, width=width,
                      blocks=blocks)
    return params, cnn_apply


def _make_vit(seed: int, *, image_size: int, n_classes: int, patch: int = 4,
              d_model: int = 48, layers: int = 2, heads: int = 2):
    params, meta = init_vit(jax.random.key(seed), image_size=image_size,
                            patch=patch, d_model=d_model, layers=layers,
                            heads=heads, n_classes=n_classes)
    return params, lambda p, x: vit_apply(p, meta, x)


VISION_MODELS = {"cnn": _make_cnn, "vit": _make_vit}


def register_vision_model(name: str, factory: Callable) -> Callable:
    """Add a vision backbone: ``factory(seed, image_size=, n_classes=,
    **model_kwargs) -> (params, apply_fn)``."""
    VISION_MODELS[name] = factory
    return factory


def materialize_vision(spec: ScenarioSpec, seed: int,
                       n_clients: int) -> Scenario:
    kw = check_source_kwargs(spec, SOURCE_DEFAULTS)
    n, n_eval = kw["n"], kw["n_eval"]
    image_size, n_classes = kw["image_size"], kw["n_classes"]
    if spec.model not in VISION_MODELS:
        raise ValueError(
            f"scenario {spec.name!r}: unknown vision model {spec.model!r} "
            f"(want one of {sorted(VISION_MODELS)}); add backbones via "
            "scenarios.vision.register_vision_model")

    X_all, y_all = make_image_classification(
        n + n_eval, image_size=image_size, n_classes=n_classes, seed=seed,
        noise=kw["noise"])
    X, y = X_all[:n], y_all[:n]
    Xe, ye = jnp.asarray(X_all[n:]), jnp.asarray(y_all[n:])
    parts = spec.partition.build(y, n, n_clients, seed)
    params, apply = VISION_MODELS[spec.model](
        seed, image_size=image_size, n_classes=n_classes,
        **dict(spec.model_kwargs))

    def loss_fn(p, b):
        return classification_loss(apply(p, b["x"]), b["y"])

    @jax.jit
    def eval_logits(p):
        return apply(p, Xe)

    def eval_fn(p):
        logits = eval_logits(p)
        return {"test_acc": accuracy(logits, ye),
                "test_loss": classification_loss(logits, ye)}

    batch = spec.batch_size

    # pure in (cid, rng): reads immutable arrays + the lock-guarded lazy
    # partition map, so concurrent stager workers may call it directly
    @mark_thread_safe
    def batch_fn(cid, rng):
        # fixed size (with replacement) so cohort batches stack
        idx = rng.choice(parts[cid], size=batch, replace=True)
        return {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}

    return Scenario(
        spec=spec, seed=seed, n_clients=n_clients, params=params,
        loss_fn=loss_fn, client_batch_fn=batch_fn, eval_fn=eval_fn,
        partitions=parts, partition_stats=partition_stats(parts, y),
        meta={"n_train": n, "n_eval": n_eval, "n_classes": n_classes,
              "image_size": image_size})


register_source("synth_image", materialize_vision)
