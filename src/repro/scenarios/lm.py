"""``lm_zipf`` source family: federated LM pre-training on topic-skewed
token streams (the paper's Dirichlet-partitioned-C4 stand-in, Table 3).

The corpus is topic-labelled documents (``data.synth.make_lm_topic_corpus``)
so the *same* partitioners as the vision tasks drive heterogeneity: a
Dirichlet/shard/quantity/IID split over topic labels assigns documents to
clients, whose training streams are the concatenated assigned documents.
The model is the in-tree transformer LM (``repro.models.model``) at a
reduced architecture declared in ``model_kwargs``.
"""
from __future__ import annotations

from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.data import lm_batches, make_lm_topic_corpus, partition_stats
from repro.models import model as M
from repro.fed.staging import mark_thread_safe
from repro.scenarios.registry import register_source
from repro.scenarios.spec import Scenario, ScenarioSpec, check_source_kwargs

# doc/topic counts sized so Dirichlet(0.05..0.1) over topic labels with
# min_size=1 partitions cleanly (no alpha softening): severity names like
# "dir0.05" must mean what they say.  Each topic is an independent
# Dirichlet draw, so n_topics is the lever that keeps every client >= 1
# doc at small alpha (empirically clean for seeds 0-9 at 256 docs x 32
# topics for 8 clients; a degenerate seed still only warns).
SOURCE_DEFAULTS = dict(vocab=256, n_docs=256, tokens_per_doc=500,
                       n_topics=32, seq_len=32, n_eval_docs=16,
                       eval_batch=16)


def _make_transformer_lm(seed: int, *, vocab: int, arch: str = "llama-60m",
                         layers: int = 2, d_model: int = 64):
    cfg = configs.get_reduced(arch, layers=layers, d_model=d_model,
                              vocab=vocab).replace(dtype="float32")
    return M.init_params(cfg, jax.random.key(seed)), cfg


LM_MODELS = {"transformer_lm": _make_transformer_lm}


def register_lm_model(name: str, factory: Callable) -> Callable:
    """Add an LM backbone: ``factory(seed, vocab=, **model_kwargs) ->
    (params, model_cfg)`` where ``model_cfg`` feeds ``models.model.loss_fn``."""
    LM_MODELS[name] = factory
    return factory


def materialize_lm(spec: ScenarioSpec, seed: int, n_clients: int) -> Scenario:
    kw = check_source_kwargs(spec, SOURCE_DEFAULTS)
    n_docs, n_eval_docs = kw["n_docs"], kw["n_eval_docs"]
    seq_len, vocab = kw["seq_len"], kw["vocab"]
    if spec.model not in LM_MODELS:
        raise ValueError(
            f"scenario {spec.name!r}: unknown LM model {spec.model!r} "
            f"(want one of {sorted(LM_MODELS)}); add backbones via "
            "scenarios.lm.register_lm_model")

    docs, topics = make_lm_topic_corpus(
        n_docs + n_eval_docs, kw["tokens_per_doc"], vocab=vocab,
        n_topics=kw["n_topics"], seed=seed)
    train_docs, train_topics = docs[:n_docs], topics[:n_docs]
    eval_stream = docs[n_docs:].reshape(-1)
    if spec.partition.lazy:
        raise ValueError(
            f"scenario {spec.name!r}: the lm_zipf source builds eager "
            f"per-client token streams and does not support lazy partition "
            f"kinds ({spec.partition.kind!r}) — use an eager kind, or the "
            "synth_image source for population-scale runs")
    parts = spec.partition.build(train_topics, n_docs, n_clients, seed)
    streams = [train_docs[p].reshape(-1) for p in parts]
    for cid, stream in enumerate(streams):
        if len(stream) <= seq_len + 1:
            raise ValueError(
                f"scenario {spec.name!r}: client {cid} received "
                f"{len(parts[cid])} documents ({len(stream)} tokens), too "
                f"few to sample a seq_len={seq_len} window — raise "
                "tokens_per_doc/n_docs or lower n_clients")

    params, cfg = LM_MODELS[spec.model](seed, vocab=vocab,
                                        **dict(spec.model_kwargs))

    def loss_fn(p, batch):
        return M.loss_fn(p, batch, cfg)

    et, el = lm_batches(eval_stream, seq_len=seq_len, batch=kw["eval_batch"],
                        steps=1, seed=seed)
    eval_batch = {"tokens": jnp.asarray(et[0]), "labels": jnp.asarray(el[0])}

    @jax.jit
    def eval_stats(p):
        logits, _, _ = M.forward(p, eval_batch, cfg)
        acc = jnp.mean((jnp.argmax(logits, -1)
                        == eval_batch["labels"]).astype(jnp.float32))
        return M.loss_fn(p, eval_batch, cfg), acc

    def eval_fn(p):
        loss, acc = eval_stats(p)
        return {"eval_loss": loss, "token_acc": acc}

    batch = spec.batch_size

    # pure in (cid, rng) over immutable token streams: safe for
    # concurrent stager workers
    @mark_thread_safe
    def batch_fn(cid, rng):
        s = streams[cid]
        starts = rng.integers(0, len(s) - seq_len - 1, batch)
        idx = starts[:, None] + np.arange(seq_len + 1)
        w = s[idx]
        return {"tokens": jnp.asarray(w[:, :-1]),
                "labels": jnp.asarray(w[:, 1:])}

    stats = partition_stats(parts, train_topics)
    stats["tokens_per_client"] = [int(len(s)) for s in streams]
    return Scenario(
        spec=spec, seed=seed, n_clients=n_clients, params=params,
        loss_fn=loss_fn, client_batch_fn=batch_fn, eval_fn=eval_fn,
        partitions=parts, partition_stats=stats,
        meta={"model_cfg": cfg, "seq_len": seq_len, "vocab": vocab,
              "n_docs": n_docs, "n_eval_docs": n_eval_docs})


register_source("lm_zipf", materialize_lm)
