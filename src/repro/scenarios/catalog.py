"""The registered scenario catalog.

Three task families, each under four heterogeneity variants:

  cifar_like_cnn[_dir0.05|_shard|_iid]   CNN on CIFAR-like images
  cifar_like_vit[_dir0.05|_shard|_iid]   ViT-Tiny on the same images
  lm_zipf[_dir0.05|_shard|_iid]          transformer LM on topic-skewed text

The base names carry the paper's default severity, Dirichlet(0.1).  The
``cifar_like`` helper is also the construction path of the legacy
``benchmarks.common.make_fed_vision_problem`` adapter, so the registered
``cifar_like_cnn`` entry is bitwise-identical to the hand-rolled problem
(golden-tested in ``tests/test_scenarios.py``).
"""
from __future__ import annotations

from typing import Optional

# the source families this catalog builds on self-register on import,
# populating the source table register() validates against
import repro.scenarios.vision  # noqa: F401
import repro.scenarios.lm  # noqa: F401
from repro.scenarios.registry import register
from repro.scenarios.spec import PartitionSpec, ScenarioSpec

DIR01 = PartitionSpec("dirichlet", alpha=0.1)


def cifar_like(*, model: str = "cnn", n: int = 3000, image_size: int = 12,
               n_classes: int = 8, alpha: Optional[float] = 0.1,
               batch: int = 16, noise: float = 2.5, n_eval: int = 768,
               n_clients: int = 10, partition: Optional[PartitionSpec] = None,
               name: Optional[str] = None) -> ScenarioSpec:
    """Synthetic-image ScenarioSpec with the legacy problem's defaults.

    ``alpha=None`` selects the IID split (the historical convention of
    ``make_fed_vision_problem``); an explicit ``partition`` wins over
    ``alpha``.
    """
    if partition is None:
        partition = (PartitionSpec("iid") if alpha is None
                     else PartitionSpec("dirichlet", alpha=alpha))
    model_kwargs = ({"width": 8, "blocks": 2} if model == "cnn"
                    else {"patch": 4, "d_model": 48, "layers": 2, "heads": 2}
                    if model == "vit" else {})
    return ScenarioSpec(
        name=name or f"cifar_like_{model}@{partition.tag()}",
        source="synth_image", partition=partition, model=model,
        n_clients=n_clients, batch_size=batch,
        source_kwargs=dict(n=n, image_size=image_size, n_classes=n_classes,
                           noise=noise, n_eval=n_eval),
        model_kwargs=model_kwargs,
        description=f"synthetic CIFAR-like images, {model} backbone, "
                    f"{partition.tag()} split")


def lm_zipf(*, vocab: int = 256, n_docs: int = 256, tokens_per_doc: int = 500,
            n_topics: int = 32, seq_len: int = 32, batch: int = 8,
            n_eval_docs: int = 16, n_clients: int = 8, layers: int = 2,
            d_model: int = 64, arch: str = "llama-60m",
            partition: Optional[PartitionSpec] = None,
            name: Optional[str] = None) -> ScenarioSpec:
    """Topic-skewed LM pre-training ScenarioSpec (Table 3 stand-in).

    Partitioning is over *documents* (each thousands of tokens), so the
    default split allows single-document clients (``min_size=1``) instead
    of softening small alphas.
    """
    partition = partition or PartitionSpec("dirichlet", alpha=0.1,
                                           min_size=1)
    return ScenarioSpec(
        name=name or f"lm_zipf@{partition.tag()}",
        source="lm_zipf", partition=partition, model="transformer_lm",
        n_clients=n_clients, batch_size=batch,
        source_kwargs=dict(vocab=vocab, n_docs=n_docs,
                           tokens_per_doc=tokens_per_doc, n_topics=n_topics,
                           seq_len=seq_len, n_eval_docs=n_eval_docs),
        model_kwargs=dict(arch=arch, layers=layers, d_model=d_model),
        description=f"topic-Zipf LM corpus, reduced {arch}, "
                    f"{partition.tag()} split")


# partition variants every base task is registered under; the base name
# itself is the paper's default severity, Dirichlet(0.1)
VARIANTS = (
    ("dir0.05", PartitionSpec("dirichlet", alpha=0.05)),
    ("shard", PartitionSpec("shard", shards_per_client=2)),
    ("iid", PartitionSpec("iid")),
)
# document-level variants (LM): a single-document client is a valid client
LM_VARIANTS = (
    ("dir0.05", PartitionSpec("dirichlet", alpha=0.05, min_size=1)),
    ("shard", PartitionSpec("shard", shards_per_client=2)),
    ("iid", PartitionSpec("iid")),
)


def _register_family(base: ScenarioSpec, variants=VARIANTS):
    register(base)
    for suffix, part in variants:
        register(base.variant(suffix, partition=part))


_register_family(cifar_like(model="cnn", name="cifar_like_cnn"))
_register_family(cifar_like(model="vit", name="cifar_like_vit"))
_register_family(lm_zipf(name="lm_zipf"), variants=LM_VARIANTS)
