"""The scenario registry: ``register`` / ``resolve`` / ``materialize``.

Mirrors the algorithm registry (``core.algorithms``): registered names
resolve to frozen ``ScenarioSpec`` values, duplicates and unknowns raise
typed errors, and unregistered specs pass straight through ``resolve`` so a
custom scenario is usable the moment it is constructed.

Source families (the pluggable data factories) register here too:
``register_source(name, fn)`` with ``fn(spec, seed, n_clients) ->
Scenario``.  The built-in families (``synth_image`` in ``scenarios.vision``,
``lm_zipf`` in ``scenarios.lm``) self-register when the package imports the
catalog, so a spec's source key is always validated against a fully
populated source table.
"""
from __future__ import annotations

from typing import Callable, Optional, Union

from repro.scenarios.spec import (
    DuplicateScenarioError, Scenario, ScenarioSpec, UnknownScenarioError,
)

_REGISTRY: dict = {}
_SOURCES: dict = {}


def register_source(name: str, fn: Callable, *,
                    overwrite: bool = False) -> Callable:
    """Add a data-source family: ``fn(spec, seed, n_clients) -> Scenario``."""
    if name in _SOURCES and not overwrite:
        raise DuplicateScenarioError(
            f"scenario source {name!r} is already registered "
            "(pass overwrite=True to replace it)")
    _SOURCES[name] = fn
    return fn


def resolve_source(spec: ScenarioSpec) -> Callable:
    """The materializer for ``spec`` — its callable source, or the
    registered family named by its source key."""
    if callable(spec.source):
        return spec.source
    if spec.source not in _SOURCES:
        raise UnknownScenarioError(
            f"scenario {spec.name!r} names unknown source {spec.source!r} "
            f"(registered sources: {', '.join(sorted(_SOURCES))}); add new "
            "families via repro.scenarios.register_source")
    return _SOURCES[spec.source]


def register(spec: ScenarioSpec, *, overwrite: bool = False) -> ScenarioSpec:
    """Add ``spec`` to the registry; returns it for chaining."""
    if not isinstance(spec, ScenarioSpec):
        raise TypeError(f"register wants a ScenarioSpec, got {type(spec)}")
    if isinstance(spec.source, str) and spec.source not in _SOURCES:
        raise ValueError(
            f"spec {spec.name!r} names unknown source {spec.source!r} "
            f"(registered sources: {', '.join(sorted(_SOURCES))}); add new "
            "families via repro.scenarios.register_source")
    if spec.name in _REGISTRY and not overwrite:
        raise DuplicateScenarioError(
            f"scenario {spec.name!r} is already registered "
            "(pass overwrite=True to replace it)")
    _REGISTRY[spec.name] = spec
    return spec


def registered() -> tuple:
    """Sorted names of all registered scenarios."""
    return tuple(sorted(_REGISTRY))


def get(name: str) -> ScenarioSpec:
    if name in _REGISTRY:
        return _REGISTRY[name]
    raise UnknownScenarioError(
        f"unknown scenario {name!r}: registered scenarios are "
        f"{', '.join(registered())}; add new ones via "
        "repro.scenarios.register")


def resolve(spec_or_name: Union[str, ScenarioSpec]) -> ScenarioSpec:
    """Spec passes through; strings resolve against the registry."""
    if isinstance(spec_or_name, ScenarioSpec):
        return spec_or_name
    return get(str(spec_or_name))


def materialize(scenario: Union[str, ScenarioSpec], seed: int = 0,
                n_clients: Optional[int] = None) -> Scenario:
    """Turn a declarative spec into the concrete problem bundle.

    ``n_clients`` defaults to the spec's own; the override is what
    ``build_experiment`` passes when the fed config names a cohort size.
    The result's ``problem()`` is the legacy 4-tuple
    ``(params, loss_fn, client_batch_fn, eval_fn)``.
    """
    spec = resolve(scenario)
    n = spec.n_clients if n_clients is None else int(n_clients)
    if n < 1:
        raise ValueError(f"n_clients must be >= 1, got {n}")
    scn = resolve_source(spec)(spec, int(seed), n)
    if not isinstance(scn, Scenario):
        raise TypeError(
            f"source for scenario {spec.name!r} returned {type(scn)}; "
            "materializers must return a scenarios.Scenario")
    return scn
