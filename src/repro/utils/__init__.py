from repro.utils import hw
from repro.utils.tree import (
    tree_add,
    tree_sub,
    tree_scale,
    tree_mean,
    tree_zeros_like,
    tree_dot,
    tree_norm_sq,
    tree_size,
    tree_bytes,
    tree_cast,
    client_weighted_sum,
)
