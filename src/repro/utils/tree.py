"""Pytree arithmetic helpers used across the federated runtime.

All helpers are jit-friendly (pure jnp) and operate leaf-wise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(s, a, b):
    """s*a + b, leaf-wise."""
    return jax.tree.map(lambda x, y: s * x + y, a, b)


def tree_lerp(a, b, t):
    """(1-t)*a + t*b, leaf-wise."""
    return jax.tree.map(lambda x, y: (1.0 - t) * x + t * y, a, b)


def tree_mean(trees):
    """Mean of a list of pytrees (same treedef)."""
    n = len(trees)
    acc = trees[0]
    for t in trees[1:]:
        acc = tree_add(acc, t)
    return tree_scale(acc, 1.0 / n)


def tree_stack_mean(tree):
    """Mean over leading (client) axis of every leaf."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), tree)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def client_weighted_sum(tree, weights):
    """sum_i w_i x_i over the leading (client) axis of every leaf, in f32.

    Lowered as a ``dot_general`` contraction of the weight vector against
    the client axis: the w-scaled copy of the stacked leaf is never
    materialized (the legacy formulation built a full (B, ...) f32
    intermediate before reducing).
    """
    w = weights.astype(jnp.float32)
    return jax.tree.map(
        lambda x: jax.lax.dot_general(
            w, x.astype(jnp.float32),
            dimension_numbers=(((0,), (0,)), ((), ()))),
        tree)


def tree_dot(a, b):
    leaves = jax.tree.leaves(jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b))
    return sum(leaves)


def tree_norm_sq(a):
    leaves = jax.tree.leaves(jax.tree.map(lambda x: jnp.vdot(x, x), a))
    return sum(leaves)


def tree_size(a) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(a))


def tree_bytes(a) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(a))


def tree_cast(a, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, a
    )


def global_norm(a):
    return jnp.sqrt(tree_norm_sq(a))
