"""Backend detection shared by every Pallas dispatch site.

One auto rule, defined once: real Pallas kernels on TPU, the interpreter
(or the jnp reference path) everywhere else.  Transport codecs
(``TransportConfig``/``QBlock``), the algorithm-level transport factory,
and the kernel profiling harness all resolve their ``use_pallas`` /
``interpret`` defaults here, so an accelerator host never silently runs
the reference path just because a caller left the knobs at their CPU
defaults.
"""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    """True when the default JAX backend is a TPU."""
    return jax.default_backend() == "tpu"


def default_use_pallas() -> bool:
    """Pallas kernels by default on TPU; jnp reference elsewhere."""
    return on_tpu()


def default_interpret() -> bool:
    """Interpret-mode Pallas off-TPU (CPU validation), compiled on TPU."""
    return not on_tpu()


def resolve_interpret(interpret=None) -> bool:
    """``None`` means auto; explicit booleans pass through."""
    return default_interpret() if interpret is None else bool(interpret)


def resolve_use_pallas(use_pallas=None) -> bool:
    """``None`` means auto; explicit booleans pass through."""
    return default_use_pallas() if use_pallas is None else bool(use_pallas)
