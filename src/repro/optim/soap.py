"""SOAP (Alg. 4/5): Shampoo-style Kronecker factors L = EMA[G G^T],
R = EMA[G^T G]; eigenbasis (Q_L, Q_R) refreshed by one QR power-iteration every
``precond_freq`` steps; AdamW run in the rotated basis.

Theta = {L, R} (the curvature statistics the paper aligns; Q is re-derived
from the aggregated factors after alignment — averaging orthogonal bases
directly would leave the Stiefel manifold).

Matrices with a dimension above ``max_precond_dim`` go one-sided (identity on
that side), matching the official SOAP treatment of huge layers.  3-D expert
tensors are batched matrices (vmap over the expert dim).  Non-matrix leaves
fall back to AdamW.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.optim.api import LocalOptimizer, matrix_mask, as_matrix


def _tree_unzip(tree, n):
    is_leaf = lambda x: isinstance(x, tuple) and len(x) == n
    return tuple(jax.tree.map(lambda t: t[i], tree, is_leaf=is_leaf)
                 for i in range(n))


def _eig_refresh(p_mat, q, method: str = "qr"):
    """Eigenvectors(P, Q): one power iteration + orthogonalization.

    method="qr"  — the paper's Alg. 4 (QR decomposition);
    method="ns"  — Newton–Schulz orthogonalization of P@Q: pure matmuls,
                   MXU-aligned (beyond-paper TPU adaptation; QR lowers poorly
                   on the systolic array at large m).
    """
    s = p_mat @ q
    if method == "ns":
        from repro.kernels.ns_ortho import ref as ns_ref
        flat = s.reshape(-1, s.shape[-2], s.shape[-1]) if s.ndim > 2 else s
        out = (jax.vmap(ns_ref.newton_schulz)(flat)
               if flat.ndim == 3 else ns_ref.newton_schulz(flat))
        return out.reshape(s.shape)
    q_new, _ = jnp.linalg.qr(s)
    return q_new


def _rot(g, ql, qr, inverse=False):
    """Rotate into (or out of) the eigenbasis; None side = identity."""
    if ql is not None:
        g = jnp.einsum("...ij,...ik->...jk", ql, g) if not inverse else \
            jnp.einsum("...ij,...jk->...ik", ql, g)
    if qr is not None:
        g = jnp.einsum("...ij,...jk->...ik", g, qr) if not inverse else \
            jnp.einsum("...ik,...jk->...ij", g, qr)
    return g


def make(b1: float = 0.95, b2: float = 0.95, eps: float = 1e-8,
         precond_freq: int = 10, max_precond_dim: int = 8192,
         weight_decay: float = 0.0, state_dtype=jnp.float32,
         adam_b1: float = 0.9, adam_b2: float = 0.999,
         eig_method: str = "qr") -> LocalOptimizer:
    sd = state_dtype

    def _leaf_state(p, is_mat):
        if not is_mat:
            return None
        pm, _ = as_matrix(p)
        m, n = pm.shape[-2], pm.shape[-1]
        batch = pm.shape[:-2]
        st = {}
        if m <= max_precond_dim:
            st["L"] = jnp.zeros((*batch, m, m), sd)
            st["QL"] = jnp.broadcast_to(jnp.eye(m, dtype=sd), (*batch, m, m))
        if n <= max_precond_dim:
            st["R"] = jnp.zeros((*batch, n, n), sd)
            st["QR"] = jnp.broadcast_to(jnp.eye(n, dtype=sd), (*batch, n, n))
        st["M"] = jnp.zeros(pm.shape, jnp.float32)
        st["V"] = jnp.zeros(pm.shape, jnp.float32)
        return st

    def init(params):
        mask = matrix_mask(params)
        mat = jax.tree.map(_leaf_state, params, mask)
        # Masked AdamW fallback: moments only for non-matrix leaves (a dense
        # fallback costs ~2x params of f32 on MoE-scale models).
        adam = jax.tree.map(
            lambda im, p: None if im else jnp.zeros(p.shape, jnp.float32),
            mask, params)
        return {"mat": mat, "am": adam, "av": adam}

    def _leaf_update(g, st, p, step, is_mat, am, av):
        if not is_mat:
            g = g.astype(jnp.float32)
            t = jnp.asarray(step, jnp.float32) + 1.0
            am_new = adam_b1 * am + (1 - adam_b1) * g
            av_new = adam_b2 * av + (1 - adam_b2) * g * g
            d = (am_new / (1 - adam_b1 ** t)) / (
                jnp.sqrt(av_new / (1 - adam_b2 ** t)) + 1e-8)
            if weight_decay:
                d = d + weight_decay * p.astype(jnp.float32)
            return d, st, am_new, av_new
        g, orig_shape = as_matrix(g.astype(jnp.float32))
        ql = st.get("QL")
        qr = st.get("QR")
        new = dict(st)
        if "L" in st:
            gl = jnp.einsum("...ik,...jk->...ij", g, g)  # G G^T
            new["L"] = (b2 * st["L"].astype(jnp.float32)
                        + (1 - b2) * gl).astype(sd)
        if "R" in st:
            gr = jnp.einsum("...ki,...kj->...ij", g, g)  # G^T G
            new["R"] = (b2 * st["R"].astype(jnp.float32)
                        + (1 - b2) * gr).astype(sd)

        refresh = (step % precond_freq) == 0

        def do_refresh(args):
            ln, rn, qlo, qro = args
            qln = _eig_refresh(ln.astype(jnp.float32),
                               qlo.astype(jnp.float32),
                               eig_method).astype(sd) \
                if qlo is not None else None
            qrn = _eig_refresh(rn.astype(jnp.float32),
                               qro.astype(jnp.float32),
                               eig_method).astype(sd) \
                if qro is not None else None
            return qln, qrn

        def no_refresh(args):
            _, _, qlo, qro = args
            return qlo, qro

        ql_new, qr_new = jax.lax.cond(
            refresh, do_refresh, no_refresh,
            (new.get("L"), new.get("R"), ql, qr))
        if ql is not None:
            new["QL"] = ql_new
        if qr is not None:
            new["QR"] = qr_new

        qlf = ql_new.astype(jnp.float32) if ql_new is not None else None
        qrf = qr_new.astype(jnp.float32) if qr_new is not None else None
        g_rot = _rot(g, qlf, qrf)  # Q_L^T G Q_R
        m_new = b1 * st["M"] + (1 - b1) * g_rot
        v_new = b2 * st["V"] + (1 - b2) * g_rot * g_rot
        # Bias-corrected Adam in the rotated basis (matches the non-matrix
        # fallback).  With warm restarts from zeroed moments every federated
        # round, the uncorrected step is ~sqrt(1-b2^t)/(1-b1^t) of nominal
        # for all K local steps — slow enough to sink Alg. 2's convergence.
        t = jnp.asarray(step, jnp.float32) + 1.0
        n_rot = (m_new / (1 - b1 ** t)) / (
            jnp.sqrt(v_new / (1 - b2 ** t)) + eps)
        d = _rot(n_rot, qlf, qrf, inverse=True)  # Q_L N Q_R^T
        if orig_shape is not None:
            d = d.reshape(orig_shape)
        if weight_decay:
            d = d + weight_decay * p.astype(jnp.float32)
        new["M"], new["V"] = m_new, v_new
        return d, new, None, None

    def update(grads, state, params, step, extras=None):
        mask = matrix_mask(params)
        out = jax.tree.map(
            lambda g, st, p, im, am, av: _leaf_update(g, st, p, step, im,
                                                      am, av),
            grads, state["mat"], params, mask, state["am"], state["av"],
            is_leaf=lambda x: x is None,
        )
        # out has 4-tuples at param-leaf positions of the grads tree
        direction, mat_state, am, av = _tree_unzip(out, 4)
        return direction, {"mat": mat_state, "am": am, "av": av}

    def get_precond(state):
        def leaf(st):
            if st is None:
                return None
            return {k: st[k] for k in ("L", "R") if k in st}
        return {"LR": jax.tree.map(leaf, state["mat"],
                                   is_leaf=lambda x: x is None or (
                                       isinstance(x, dict) and "M" in x))}

    def set_precond(state, theta):
        # Alignment replaces the curvature statistics (paper Alg. 5 line 3);
        # the eigenbasis Q re-derives from the aggregated L/R at the next
        # scheduled refresh (k % precond_freq == 0, i.e. the first local
        # step of the round), not eagerly here.
        def leaf(st, th):
            if st is None:
                return None
            new = dict(st)
            for k in ("L", "R"):
                if k in st and th is not None and k in th:
                    new[k] = th[k]
            return new

        mat = jax.tree.map(
            leaf, state["mat"], theta["LR"],
            is_leaf=lambda x: x is None or (isinstance(x, dict) and "M" in x))
        return dict(state, mat=mat)

    return LocalOptimizer("soap", init, update, get_precond, set_precond,
                          precond_multiplier=2.0)
