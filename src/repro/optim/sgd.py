"""SGD (+momentum) — FedAvg / Local SGD baseline. Identity preconditioner."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.api import LocalOptimizer


def make(momentum: float = 0.0, weight_decay: float = 0.0) -> LocalOptimizer:
    def init(params):
        if momentum:
            return {"m": jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)}
        return {"m": None}

    def update(grads, state, params, step, extras=None):
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if weight_decay:
            gf = jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(jnp.float32), gf, params)
        if momentum:
            m = jax.tree.map(lambda mm, g: momentum * mm + g, state["m"], gf)
            return m, {"m": m}
        return gf, state

    def get_precond(state):
        return state

    def set_precond(state, theta):
        return theta

    return LocalOptimizer("sgd", init, update, get_precond, set_precond,
                          precond_multiplier=1.0 if momentum else 0.0)
