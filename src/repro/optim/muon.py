"""Muon (Alg. 6/7): momentum orthogonalized by Newton–Schulz iterations.

Theta = {m} (the momentum IS the alignable preconditioner state, as in the
paper's (Theta, P) instantiation).  Applies to hidden 2-D matrices (3-D/4-D
stacked tensors are batched matrices); other leaves use an AdamW fallback.

State is *masked*: momentum exists only for matrix leaves, Adam moments only
for the rest (None elsewhere) — on a 236B-parameter model the dense variant
wastes ~2x params of f32 per device (found via dry-run memory_analysis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ns_ortho import ops as ns_ops
from repro.optim.api import LocalOptimizer, matrix_mask, as_matrix


def _ortho(m_leaf, steps, use_pallas):
    mat, orig = as_matrix(m_leaf)
    u = ns_ops.newton_schulz(mat, steps=steps, use_pallas=use_pallas)
    rows, cols = mat.shape[-2], mat.shape[-1]
    u = u * jnp.sqrt(jnp.maximum(1.0, rows / cols))
    return u.reshape(orig) if orig is not None else u


def _is_none(x):
    return x is None


def make(b1: float = 0.9, ns_steps: int = 5, weight_decay: float = 0.0,
         use_pallas: bool = False,
         adam_b1: float = 0.9, adam_b2: float = 0.95,
         adam_eps: float = 1e-8, state_dtype=jnp.float32) -> LocalOptimizer:
    def init(params):
        mask = matrix_mask(params)
        mom = jax.tree.map(
            lambda im, p: jnp.zeros(p.shape, state_dtype) if im else None,
            mask, params)
        adam = jax.tree.map(
            lambda im, p: None if im else jnp.zeros(p.shape, jnp.float32),
            mask, params)
        return {"m": mom, "am": adam, "av": adam}

    def update(grads, state, params, step, extras=None):
        mask = matrix_mask(params)
        t = jnp.asarray(step, jnp.float32) + 1.0
        bc1 = 1.0 - adam_b1 ** t
        bc2 = 1.0 - adam_b2 ** t

        def leaf(is_mat, g, mm, am, av, p):
            g = g.astype(jnp.float32)
            if is_mat:
                m_new = (b1 * mm.astype(jnp.float32)
                         + (1 - b1) * g).astype(state_dtype)
                d = _ortho(m_new.astype(jnp.float32), ns_steps, use_pallas)
                if weight_decay:
                    d = d + weight_decay * p.astype(jnp.float32)
                return d, m_new, None, None
            am_new = adam_b1 * am + (1 - adam_b1) * g
            av_new = adam_b2 * av + (1 - adam_b2) * g * g
            d = (am_new / bc1) / (jnp.sqrt(av_new / bc2) + adam_eps)
            if weight_decay:
                d = d + weight_decay * p.astype(jnp.float32)
            return d, None, am_new, av_new

        out = jax.tree.map(leaf, mask, grads, state["m"], state["am"],
                           state["av"], params)
        is4 = lambda x: isinstance(x, tuple) and len(x) == 4
        pick = lambda i: jax.tree.map(lambda o: o[i], out, is_leaf=is4)
        return pick(0), {"m": pick(1), "am": pick(2), "av": pick(3)}

    def get_precond(state):
        return {"m": state["m"]}

    def set_precond(state, theta):
        return dict(state, m=theta["m"])

    return LocalOptimizer("muon", init, update, get_precond, set_precond,
                          precond_multiplier=1.0)
