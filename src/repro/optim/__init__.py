"""Local optimizers implementing the paper's (Theta, P_Theta) abstraction."""
from repro.optim.api import LocalOptimizer, matrix_mask, is_hidden_matrix
from repro.optim import adamw, muon, soap, sophia, sgd

_FACTORIES = {
    "sgd": sgd.make,
    "adamw": adamw.make,
    "muon": muon.make,
    "soap": soap.make,
    "sophia": sophia.make,
}


def make(name: str, **kw) -> LocalOptimizer:
    return _FACTORIES[name](**kw)


def available() -> tuple:
    """Sorted optimizer names ``make`` accepts (AlgorithmSpec validation)."""
    return tuple(sorted(_FACTORIES))


DEFAULT_LR = {  # paper's Appendix Table 8 defaults
    "sgd": 0.1,
    "adamw": 3e-4,
    "sophia": 3e-4,
    "muon": 3e-2,
    "soap": 3e-3,
}
