"""Sophia (Alg. 8/9): diagonal-Hessian (Hutchinson) preconditioning with
element-wise clipping.  Theta = {h}.

The client loop supplies ``extras = {"h_est": pytree, "h_gate": bool}`` where
``h_est = u * (H u)`` is the Hutchinson estimate (Pearlmutter HVP) and
``h_gate`` enables the EMA refresh (every f_h steps in the paper).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.api import LocalOptimizer


def make(b1: float = 0.9, b2: float = 0.99, eps: float = 1e-12,
         rho: float = 0.05, weight_decay: float = 0.0) -> LocalOptimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "h": jax.tree.map(z, params)}

    def update(grads, state, params, step, extras=None):
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state["m"], gf)
        h = state["h"]
        if extras is not None and extras.get("h_est") is not None:
            gate = extras.get("h_gate", True)
            gate = jnp.asarray(gate)

            def h_leaf(hh, est):
                new = b2 * hh + (1 - b2) * jnp.maximum(est.astype(jnp.float32), 0.0)
                return jnp.where(gate, new, hh)

            h = jax.tree.map(h_leaf, h, extras["h_est"])

        def leaf(mm, hh, p):
            d = jnp.clip(mm / jnp.maximum(hh, eps), -rho, rho)
            if weight_decay:
                d = d + weight_decay * p.astype(jnp.float32)
            return d

        direction = jax.tree.map(leaf, m, h, params)
        return direction, {"m": m, "h": h}

    def get_precond(state):
        return {"h": state["h"]}

    def set_precond(state, theta):
        return dict(state, h=theta["h"])

    return LocalOptimizer("sophia", init, update, get_precond, set_precond,
                          needs_hessian=True, precond_multiplier=1.0)
