"""Unified local-optimizer API: the paper's (Theta, P_Theta) abstraction.

Every optimizer is a ``LocalOptimizer`` of pure functions:

  init(params)                      -> state
  update(grads, state, params, step, extras) -> (direction, new_state)
      ``direction`` is the *preconditioned* update P_Theta(g) (descent
      direction; caller applies x <- x - lr * mix(direction, g_G)).
  get_precond(state)                -> Theta   (the alignable geometry)
  set_precond(state, theta)         -> state   (FedPAC alignment warm-start)

``extras`` carries optional per-step inputs (e.g. Sophia's Hutchinson
diagonal-Hessian estimate).  All states are float32 pytrees mirroring params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax


@dataclasses.dataclass(frozen=True)
class LocalOptimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params, step, extras) -> (dir, state)
    get_precond: Callable[[Any], Any]
    set_precond: Callable[[Any, Any], Any]
    # True if the client loop must supply a Hutchinson diag-Hessian estimate.
    needs_hessian: bool = False
    # Fraction/structure of Theta uploaded per round, for comm accounting.
    precond_multiplier: float = 1.0


def path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


_NON_MATRIX_TOKENS = ("embed", "tok", "head", "norm", "bias", "scale",
                      "conv", "a_log", "lam", "cls", "pos", "dt_bias")


def is_hidden_matrix(path, leaf) -> bool:
    """Hidden-layer weight (Muon/SOAP domain): excludes embeddings, lm heads,
    norms/biases/convs/recurrence constants."""
    if leaf.ndim < 2:
        return False
    if leaf.shape[-1] < 8 or leaf.shape[-2] < 8:
        # degenerate matrices (cls tokens, tiny gates) -> Adam fallback
        if not (leaf.ndim == 4 and leaf.shape[0] <= 7):
            return False
    s = path_str(path).lower()
    return not any(tok in s for tok in _NON_MATRIX_TOKENS)


def as_matrix(x):
    """Canonical matrix view for structured preconditioners.

    2-D: as-is; 3-D (layers-or-experts, m, n): batched matrices;
    4-D conv HWIO (small spatial dims): flattened to (k*k*c_in, c_out), the
    Muon/Shampoo convention; other 4-D+ (stacked expert tensors (L,E,m,n)):
    batch dims collapsed.  Returns (mat, orig_shape_or_None).
    """
    if x.ndim <= 3:
        return x, None
    if x.ndim == 4 and x.shape[0] <= 7 and x.shape[1] <= 7:
        return x.reshape(-1, x.shape[-1]), x.shape
    return x.reshape(-1, x.shape[-2], x.shape[-1]), x.shape


def matrix_mask(params):
    """Pytree of bools: which leaves get the matrix preconditioner."""
    flat = jax.tree_util.tree_flatten_with_path(params)
    paths, treedef = flat[0], flat[1]
    leaves = [is_hidden_matrix(p, l) for p, l in paths]
    return jax.tree_util.tree_unflatten(treedef, leaves)
