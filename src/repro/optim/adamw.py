"""AdamW — the paper's `Local AdamW` baseline, and the fallback rule that
Muon/SOAP/Sophia variants apply to non-matrix parameters."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.api import LocalOptimizer


def make(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> LocalOptimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step, extras=None):
        t = jnp.asarray(step, jnp.float32) + 1.0
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state["m"], gf)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, state["v"], gf)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def leaf(mm, vv, p):
            d = (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
            if weight_decay:
                d = d + weight_decay * p.astype(jnp.float32)
            return d

        direction = jax.tree.map(leaf, m, v, params)
        return direction, {"m": m, "v": v}

    def get_precond(state):
        return {"m": state["m"], "v": state["v"]}

    def set_precond(state, theta):
        return {"m": theta["m"], "v": theta["v"]}

    return LocalOptimizer("adamw", init, update, get_precond, set_precond,
                          precond_multiplier=2.0)
