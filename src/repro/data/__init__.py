from repro.data.partition import dirichlet_partition, heterogeneity_stat
from repro.data.synth import make_image_classification, make_lm_corpus, lm_batches
