from repro.data.partition import (
    dirichlet_partition, heterogeneity_stat, iid_partition, partition_stats,
    quantity_partition, shard_partition,
)
from repro.data.synth import (
    lm_batches, make_image_classification, make_lm_corpus,
    make_lm_topic_corpus,
)
