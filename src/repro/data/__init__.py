from repro.data.partition import (
    ClientIndexMap, dirichlet_partition, heterogeneity_stat, iid_partition,
    partition_stats, quantity_partition, shard_partition,
    stream_dirichlet_indices, stream_dirichlet_map,
)
from repro.data.synth import (
    lm_batches, make_image_classification, make_lm_corpus,
    make_lm_topic_corpus,
)
