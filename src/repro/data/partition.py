"""Client partitioners — the heterogeneity axis of a federated scenario.

``dirichlet_partition`` (Hsu et al. 2019) is the paper's severity control:
smaller alpha => more severe label skew (Dir-0.1, Dir-0.05 in the tables).
The scenario API (``repro.scenarios.PartitionSpec``) additionally exposes

* ``shard_partition``    — pathological label split (McMahan et al. 2017):
                           sort by label, deal a fixed number of shards to
                           each client, so each sees few classes;
* ``quantity_partition`` — label-IID but Dirichlet-skewed client sizes;
* ``iid_partition``      — uniform random split (the control condition).

All classic partitioners return a list of ``n_clients`` index arrays
covering every sample exactly once, and are deterministic in ``seed``.

Population scale adds a *lazy* form: ``ClientIndexMap`` maps a client id to
its sample indices on demand (nothing is enumerated up front), and
``stream_dirichlet_map`` derives each client's Dirichlet label mixture from
``SeedSequence((seed, client_id))`` alone — a 10^6-client partition costs
O(1) until a client is actually sampled, and a client's data is invariant
to the population size around it.  Streamed clients draw *views* of the
sample pool (with replacement), so the exactly-once covering property is
deliberately relaxed: it cannot hold with more clients than samples.
"""
from __future__ import annotations

import warnings
import threading
from collections import OrderedDict
from typing import Callable

import numpy as np

# domain-separation tag for streamed per-client partition draws
_STREAM_TAG = 0x5D1B


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0, min_size: int = 2,
                        max_retries: int = 20):
    """Returns list of index arrays, one per client.

    Every sample is assigned to exactly one client; per-class proportions are
    drawn from Dirichlet(alpha).  Degenerate draws that leave some client
    below ``min_size`` are retried with a softened alpha (x1.5 each time) at
    most ``max_retries`` times; softening is reported with a
    ``RuntimeWarning`` naming the effective alpha actually used, and an
    infeasible request (or retry exhaustion) raises ``ValueError`` instead
    of spinning forever.
    """
    if alpha <= 0:
        raise ValueError(f"alpha must be > 0, got {alpha}")
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    if n_clients * min_size > len(labels):
        raise ValueError(
            f"dirichlet_partition is infeasible: n_clients={n_clients} x "
            f"min_size={min_size} needs {n_clients * min_size} samples but "
            f"only {len(labels)} are available")
    n_classes = int(labels.max()) + 1
    requested = alpha
    for attempt in range(max_retries + 1):
        idx_per_client = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            idx_c = np.where(labels == c)[0]
            rng.shuffle(idx_c)
            props = rng.dirichlet(np.full(n_clients, alpha))
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for i, part in enumerate(np.split(idx_c, cuts)):
                idx_per_client[i].append(part)
        parts = [np.concatenate(p) if p else np.empty(0, np.int64)
                 for p in idx_per_client]
        if min(len(p) for p in parts) >= min_size:
            break
        if attempt < max_retries:  # degenerate draw; soften and retry
            alpha = alpha * 1.5    # (guarded so the error below reports
            #                         the largest alpha actually tried)
    else:
        raise ValueError(
            f"dirichlet_partition gave up after {max_retries} retries: "
            f"alpha softened {requested:g} -> {alpha:g} without every "
            f"client reaching min_size={min_size} ({len(labels)} samples, "
            f"{n_clients} clients) — lower min_size/n_clients or raise "
            "alpha")
    if alpha != requested:
        warnings.warn(
            f"dirichlet_partition: degenerate draws at alpha={requested:g}; "
            f"effective alpha={alpha:g} after softening retries",
            RuntimeWarning, stacklevel=2)
    for p in parts:
        rng.shuffle(p)
    return parts


def iid_partition(n_samples: int, n_clients: int, seed: int = 0):
    """Uniform random split of ``n_samples`` indices into ``n_clients``."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n_samples)
    return np.array_split(idx, n_clients)


def shard_partition(labels: np.ndarray, n_clients: int,
                    shards_per_client: int = 2, seed: int = 0):
    """Pathological label split: sort by label, deal shards to clients.

    With ``shards_per_client`` small each client sees only a handful of
    classes — the classic extreme non-IID setting of McMahan et al. 2017.
    """
    if shards_per_client < 1:
        raise ValueError(
            f"shards_per_client must be >= 1, got {shards_per_client}")
    labels = np.asarray(labels)
    n_shards = n_clients * shards_per_client
    if n_shards > len(labels):
        raise ValueError(
            f"shard_partition is infeasible: {n_shards} shards for "
            f"{len(labels)} samples")
    rng = np.random.default_rng(seed)
    order = np.argsort(labels, kind="stable")
    shards = np.array_split(order, n_shards)
    deal = rng.permutation(n_shards)
    parts = []
    for i in range(n_clients):
        own = deal[i * shards_per_client:(i + 1) * shards_per_client]
        p = np.concatenate([shards[s] for s in own])
        rng.shuffle(p)
        parts.append(p)
    return parts


def quantity_partition(n_samples: int, n_clients: int, alpha: float = 0.5,
                       seed: int = 0, min_size: int = 1):
    """Quantity skew: label-IID clients with Dirichlet(alpha)-skewed sizes."""
    if alpha <= 0:
        raise ValueError(f"alpha must be > 0, got {alpha}")
    if n_clients * min_size > n_samples:
        raise ValueError(
            f"quantity_partition is infeasible: n_clients={n_clients} x "
            f"min_size={min_size} needs {n_clients * min_size} samples but "
            f"only {n_samples} are available")
    rng = np.random.default_rng(seed)
    props = rng.dirichlet(np.full(n_clients, alpha))
    spare = n_samples - n_clients * min_size
    cuts = (np.cumsum(props) * spare).astype(int)[:-1]
    sizes = np.diff(np.concatenate([[0], cuts, [spare]])) + min_size
    idx = rng.permutation(n_samples)
    return np.split(idx, np.cumsum(sizes)[:-1])


def heterogeneity_stat(parts, labels, n_classes=None) -> float:
    """Mean total-variation distance between client label dists and global."""
    labels = np.asarray(labels)
    n_classes = n_classes or int(labels.max()) + 1
    global_p = np.bincount(labels, minlength=n_classes) / len(labels)
    tvs = []
    for p in parts:
        if len(p) == 0:
            continue
        cp = np.bincount(labels[p], minlength=n_classes) / len(p)
        tvs.append(0.5 * np.abs(cp - global_p).sum())
    return float(np.mean(tvs))


def partition_stats(parts, labels=None) -> dict:
    """Summary of one partition: sizes and (with labels) label-skew TV.

    Accepts either an eager list of index arrays or a ``ClientIndexMap``
    (which is probed, not enumerated — see ``ClientIndexMap.sample_stats``).
    """
    if isinstance(parts, ClientIndexMap):
        return parts.sample_stats(labels)
    sizes = [int(len(p)) for p in parts]
    stats = {"n_clients": len(parts), "n_samples": int(sum(sizes)),
             "min_size": min(sizes), "max_size": max(sizes),
             "mean_size": float(np.mean(sizes))}
    if labels is not None:
        stats["label_tv"] = heterogeneity_stat(parts, labels)
    return stats


class ClientIndexMap:
    """Lazy client-id -> sample-index mapping.

    The population path replaces eager per-client index lists with this map:
    ``map[client_id]`` derives that client's indices on demand from a pure
    function of the id, so a million-client partition allocates nothing
    until a client is actually staged.  A small LRU cache keeps hot clients
    (the current cohort) free to re-query.

    The derivation function must be deterministic in ``client_id`` — the
    same id always yields the same indices, independent of query order.
    """

    def __init__(self, n_clients: int, fn: Callable[[int], np.ndarray],
                 cache_size: int = 4096):
        if n_clients < 1:
            raise ValueError(f"n_clients must be >= 1, got {n_clients}")
        self.n_clients = int(n_clients)
        self._fn = fn
        self._cache: OrderedDict[int, np.ndarray] = OrderedDict()
        self._cache_size = int(cache_size)
        # concurrent stager workers (fed.pipeline) query the map from
        # multiple threads; the LRU bookkeeping must not race
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return self.n_clients

    def __getitem__(self, client_id) -> np.ndarray:
        cid = int(client_id)
        if not 0 <= cid < self.n_clients:
            raise IndexError(
                f"client id {cid} outside id space [0, {self.n_clients})")
        with self._lock:
            hit = self._cache.get(cid)
            if hit is not None:
                self._cache.move_to_end(cid)
                return hit
        idx = np.asarray(self._fn(cid), dtype=np.int64)
        with self._lock:
            self._cache[cid] = idx
            if len(self._cache) > self._cache_size:
                self._cache.popitem(last=False)
        return idx

    client_indices = __getitem__

    def sample_stats(self, labels=None, probe: int = 64) -> dict:
        """Partition stats from a deterministic probe of ``probe`` clients.

        Enumerating a streamed population is the anti-pattern this class
        exists to avoid, so stats are estimated from evenly spaced ids and
        flagged ``lazy: True`` with the probe count alongside.
        """
        ids = np.unique(np.linspace(
            0, self.n_clients - 1, min(probe, self.n_clients)).astype(int))
        parts = [self[c] for c in ids]
        stats = partition_stats(parts, labels)
        stats.update(n_clients=self.n_clients, lazy=True,
                     probed_clients=int(len(ids)))
        return stats


def stream_dirichlet_indices(class_indices, client_id: int, alpha: float,
                             samples_per_client: int, seed: int = 0):
    """One streamed client's sample indices, derived from the id alone.

    ``SeedSequence((seed, _STREAM_TAG, client_id))`` seeds the draw, so the
    result is invariant to population size and query order: the client draws
    a Dirichlet(alpha) label mixture, splits ``samples_per_client`` across
    classes multinomially, and picks that many indices per class with
    replacement (clients view the pool; they do not own disjoint slices).
    """
    rng = np.random.default_rng(
        np.random.SeedSequence((seed, _STREAM_TAG, int(client_id))))
    n_classes = len(class_indices)
    props = rng.dirichlet(np.full(n_classes, alpha))
    counts = rng.multinomial(samples_per_client, props)
    picks = [rng.choice(class_indices[c], size=int(k), replace=True)
             for c, k in enumerate(counts) if k > 0]
    idx = np.concatenate(picks) if picks else np.empty(0, np.int64)
    rng.shuffle(idx)
    return idx


def stream_dirichlet_map(labels: np.ndarray, n_clients: int, alpha: float,
                         samples_per_client: int = 64,
                         seed: int = 0) -> ClientIndexMap:
    """Lazy Dirichlet label-skew partition over an arbitrary id space.

    The classic ``dirichlet_partition`` enumerates every client up front;
    this map is its population-scale analog — per-class index pools are
    built once (O(n_samples)), and each client's slice is derived on demand
    by ``stream_dirichlet_indices``.
    """
    if alpha <= 0:
        raise ValueError(f"alpha must be > 0, got {alpha}")
    if samples_per_client < 1:
        raise ValueError(
            f"samples_per_client must be >= 1, got {samples_per_client}")
    labels = np.asarray(labels)
    n_classes = int(labels.max()) + 1
    class_indices = [np.where(labels == c)[0] for c in range(n_classes)]
    empty = [c for c, ix in enumerate(class_indices) if len(ix) == 0]
    if empty:
        raise ValueError(
            f"stream_dirichlet_map needs every class populated; classes "
            f"{empty} have no samples")
    return ClientIndexMap(
        n_clients,
        lambda cid: stream_dirichlet_indices(
            class_indices, cid, alpha, samples_per_client, seed))
