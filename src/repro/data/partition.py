"""Dirichlet(alpha) non-IID partitioner (Hsu et al. 2019) — the paper's
heterogeneity control.  Smaller alpha => more severe label skew (Dir-0.1,
Dir-0.05 in the paper's tables).
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0, min_size: int = 2):
    """Returns list of index arrays, one per client.

    Every sample is assigned to exactly one client; per-class proportions are
    drawn from Dirichlet(alpha).
    """
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    n_classes = int(labels.max()) + 1
    while True:
        idx_per_client = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            idx_c = np.where(labels == c)[0]
            rng.shuffle(idx_c)
            props = rng.dirichlet(np.full(n_clients, alpha))
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for i, part in enumerate(np.split(idx_c, cuts)):
                idx_per_client[i].append(part)
        parts = [np.concatenate(p) if p else np.empty(0, np.int64)
                 for p in idx_per_client]
        if min(len(p) for p in parts) >= min_size:
            break
        alpha = alpha * 1.5  # degenerate draw; soften slightly and retry
    for p in parts:
        rng.shuffle(p)
    return parts


def heterogeneity_stat(parts, labels, n_classes=None) -> float:
    """Mean total-variation distance between client label dists and global."""
    labels = np.asarray(labels)
    n_classes = n_classes or int(labels.max()) + 1
    global_p = np.bincount(labels, minlength=n_classes) / len(labels)
    tvs = []
    for p in parts:
        if len(p) == 0:
            continue
        cp = np.bincount(labels[p], minlength=n_classes) / len(p)
        tvs.append(0.5 * np.abs(cp - global_p).sum())
    return float(np.mean(tvs))
