"""Synthetic datasets (no datasets ship offline; heterogeneity is the
controlled variable and transfers to the real benchmarks).

* ``make_image_classification`` — gaussian-mixture "CIFAR-like" images with
  class-dependent means: a stand-in for CIFAR-100/Tiny-ImageNet.
* ``make_lm_corpus`` — per-client token streams with client-specific Zipf
  parameters + topic offsets: a stand-in for Dirichlet-partitioned C4.
* ``make_lm_topic_corpus`` — topic-labelled documents with topic-specific
  Zipf unigram distributions: the label-bearing LM source that lets the
  scenario API drive heterogeneity through the same partitioners
  (Dirichlet/shard/quantity/IID over topic labels) as the vision tasks.
"""
from __future__ import annotations

import numpy as np


def make_image_classification(n: int, *, image_size: int = 16, channels: int = 3,
                              n_classes: int = 10, noise: float = 0.8,
                              seed: int = 0):
    """Returns (images (n, H, W, C) float32, labels (n,) int32)."""
    rng = np.random.default_rng(seed)
    d = image_size * image_size * channels
    protos = rng.normal(0, 1, (n_classes, d)).astype(np.float32)
    labels = rng.integers(0, n_classes, n).astype(np.int32)
    x = protos[labels] + noise * rng.normal(0, 1, (n, d)).astype(np.float32)
    return x.reshape(n, image_size, image_size, channels), labels


def make_lm_corpus(n_clients: int, tokens_per_client: int, *, vocab: int = 512,
                   hetero: float = 1.0, seed: int = 0):
    """Per-client token streams with client-specific unigram distributions.

    ``hetero`` in [0,1]: 0 => identical zipf for all clients (IID);
    1 => each client's zipf is shifted by a random permutation over a
    client-specific "topic" block (strongly non-IID).
    """
    if not 0.0 <= hetero <= 1.0:
        raise ValueError(f"hetero must be in [0, 1], got {hetero}")
    rng = np.random.default_rng(seed)
    base = 1.0 / (np.arange(1, vocab + 1) ** 1.1)
    streams = []
    for i in range(n_clients):
        perm = np.arange(vocab)
        if hetero > 0:
            shift = rng.permutation(vocab)
            keep = rng.random(vocab) > hetero
            perm = np.where(keep, perm, shift)
        p = base[perm]
        p = p / p.sum()
        streams.append(rng.choice(vocab, size=tokens_per_client, p=p)
                       .astype(np.int32))
    return streams


def make_lm_topic_corpus(n_docs: int, tokens_per_doc: int, *, vocab: int = 512,
                         n_topics: int = 8, seed: int = 0):
    """Topic-labelled documents: (docs (n_docs, tokens_per_doc) int32,
    topics (n_docs,) int32).

    Each topic owns a Zipf unigram distribution over a topic-specific vocab
    permutation; a document's tokens are drawn from its topic's
    distribution.  Partitioning documents by topic label with the standard
    partitioners reproduces Dirichlet-partitioned-corpus heterogeneity.
    """
    if n_docs < 1 or tokens_per_doc < 1:
        raise ValueError(
            f"need n_docs >= 1 and tokens_per_doc >= 1, got "
            f"n_docs={n_docs}, tokens_per_doc={tokens_per_doc}")
    if vocab < 2 or n_topics < 1:
        raise ValueError(
            f"need vocab >= 2 and n_topics >= 1, got vocab={vocab}, "
            f"n_topics={n_topics}")
    rng = np.random.default_rng(seed)
    base = 1.0 / (np.arange(1, vocab + 1) ** 1.1)
    topic_ps = []
    for _ in range(n_topics):
        p = base[rng.permutation(vocab)]
        topic_ps.append(p / p.sum())
    topics = rng.integers(0, n_topics, n_docs).astype(np.int32)
    docs = np.stack([rng.choice(vocab, size=tokens_per_doc, p=topic_ps[t])
                     for t in topics]).astype(np.int32)
    return docs, topics


def lm_batches(stream: np.ndarray, *, seq_len: int, batch: int, steps: int,
               seed: int = 0):
    """Sample (steps, batch, seq_len+1) windows -> tokens/labels pairs."""
    if seq_len < 1 or batch < 1 or steps < 1:
        raise ValueError(
            f"need seq_len/batch/steps >= 1, got seq_len={seq_len}, "
            f"batch={batch}, steps={steps}")
    if len(stream) <= seq_len + 1:
        raise ValueError(
            f"lm_batches needs a stream longer than seq_len + 1 = "
            f"{seq_len + 1} tokens to sample a window, got {len(stream)}")
    rng = np.random.default_rng(seed)
    starts = rng.integers(0, len(stream) - seq_len - 1, (steps, batch))
    idx = starts[..., None] + np.arange(seq_len + 1)
    windows = stream[idx]  # (steps, batch, seq+1)
    return windows[..., :-1], windows[..., 1:]
