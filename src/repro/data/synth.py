"""Synthetic datasets (no datasets ship offline; heterogeneity is the
controlled variable and transfers to the real benchmarks).

* ``make_image_classification`` — gaussian-mixture "CIFAR-like" images with
  class-dependent means: a stand-in for CIFAR-100/Tiny-ImageNet.
* ``make_lm_corpus`` — per-client token streams with client-specific Zipf
  parameters + topic offsets: a stand-in for Dirichlet-partitioned C4.
"""
from __future__ import annotations

import numpy as np


def make_image_classification(n: int, *, image_size: int = 16, channels: int = 3,
                              n_classes: int = 10, noise: float = 0.8,
                              seed: int = 0):
    """Returns (images (n, H, W, C) float32, labels (n,) int32)."""
    rng = np.random.default_rng(seed)
    d = image_size * image_size * channels
    protos = rng.normal(0, 1, (n_classes, d)).astype(np.float32)
    labels = rng.integers(0, n_classes, n).astype(np.int32)
    x = protos[labels] + noise * rng.normal(0, 1, (n, d)).astype(np.float32)
    return x.reshape(n, image_size, image_size, channels), labels


def make_lm_corpus(n_clients: int, tokens_per_client: int, *, vocab: int = 512,
                   hetero: float = 1.0, seed: int = 0):
    """Per-client token streams with client-specific unigram distributions.

    ``hetero`` in [0,1]: 0 => identical zipf for all clients (IID);
    1 => each client's zipf is shifted by a random permutation over a
    client-specific "topic" block (strongly non-IID).
    """
    rng = np.random.default_rng(seed)
    base = 1.0 / (np.arange(1, vocab + 1) ** 1.1)
    streams = []
    for i in range(n_clients):
        perm = np.arange(vocab)
        if hetero > 0:
            shift = rng.permutation(vocab)
            keep = rng.random(vocab) > hetero
            perm = np.where(keep, perm, shift)
        p = base[perm]
        p = p / p.sum()
        streams.append(rng.choice(vocab, size=tokens_per_client, p=p)
                       .astype(np.int32))
    return streams


def lm_batches(stream: np.ndarray, *, seq_len: int, batch: int, steps: int,
               seed: int = 0):
    """Sample (steps, batch, seq_len+1) windows -> tokens/labels pairs."""
    rng = np.random.default_rng(seed)
    starts = rng.integers(0, len(stream) - seq_len - 1, (steps, batch))
    idx = starts[..., None] + np.arange(seq_len + 1)
    windows = stream[idx]  # (steps, batch, seq+1)
    return windows[..., :-1], windows[..., 1:]
