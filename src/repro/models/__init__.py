from repro.models.config import (
    ModelConfig, MoEConfig, MLAConfig, SSMConfig, RGLRUConfig, reduced,
)
from repro.models.model import (
    init_params, init_boxed, param_axes, param_shapes, num_params,
    forward, loss_fn, prefill, decode_step, init_caches,
)
from repro.models.vision import (
    accuracy, classification_loss, cnn_apply, init_cnn, init_vit, vit_apply,
)
