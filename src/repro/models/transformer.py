"""Unified decoder: per-layer blocks, stacked into lax.scan groups.

Consecutive layers with the same signature (block kind, MoE-ness) are stacked
on a leading "layers" axis and executed with ``lax.scan`` — HLO size (and
compile time) is depth-independent, which matters when the dry-run compiles
80-layer models against a 512-chip mesh on a single host.  Hybrid patterns
(RecurrentGemma's rec/rec/attn) fall out as short consecutive groups.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.param import Initializer, Box
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import rglru as rglru_lib
from repro.models.layers import (
    init_norm, apply_norm, init_mlp, apply_mlp,
)


def _layer_is_moe(cfg: ModelConfig, layer: int) -> bool:
    return cfg.moe is not None and layer >= cfg.moe.first_dense_layers


def layer_signature(cfg: ModelConfig, layer: int):
    return (cfg.block_kind(layer), _layer_is_moe(cfg, layer))


def layer_groups(cfg: ModelConfig):
    """Consecutive same-signature runs: [(start, length, signature)]."""
    groups = []
    start = 0
    sig = layer_signature(cfg, 0)
    for l in range(1, cfg.num_layers):
        s = layer_signature(cfg, l)
        if s != sig:
            groups.append((start, l - start, sig))
            start, sig = l, s
    groups.append((start, cfg.num_layers - start, sig))
    return groups


# ---------------------------------------------------------------- init

def init_layer(ini: Initializer, cfg: ModelConfig, kind: str, is_moe: bool):
    p = {"pre_norm": init_norm(ini, cfg.d_model, cfg.norm_type)}
    if kind in ("attn", "swa", "local_attn"):
        if cfg.mla is not None:
            p["mixer"] = attn.init_mla(ini, cfg)
        else:
            p["mixer"] = attn.init_attention(ini, cfg)
    elif kind == "mamba":
        p["mixer"] = ssm_lib.init_mamba(ini, cfg)
    elif kind == "rglru":
        p["mixer"] = rglru_lib.init_rglru(ini, cfg)
    else:
        raise ValueError(kind)

    if kind != "mamba" and (cfg.d_ff > 0 or is_moe):
        p["post_norm"] = init_norm(ini, cfg.d_model, cfg.norm_type)
        if is_moe:
            p["moe"] = moe_lib.init_moe(ini, cfg)
        else:
            p["mlp"] = init_mlp(ini, cfg.d_model, cfg.d_ff, cfg.mlp_type)
    return p


def _stack_boxed(trees):
    """Stack boxed param trees on a new leading 'layers' axis."""
    def stack(*boxes):
        vals = jnp.stack([b.value for b in boxes])
        return Box(vals, ("layers",) + boxes[0].axes)

    return jax.tree.map(stack, *trees, is_leaf=lambda x: isinstance(x, Box))


def init_blocks(ini: Initializer, cfg: ModelConfig):
    """Returns list of stacked per-group params (leading axis = group size)."""
    blocks = []
    for start, length, (kind, is_moe) in layer_groups(cfg):
        layers = [init_layer(ini, cfg, kind, is_moe) for _ in range(length)]
        blocks.append(_stack_boxed(layers))
    return blocks


# ---------------------------------------------------------------- forward

def layer_forward(p, x, positions, cfg: ModelConfig, kind: str, is_moe: bool,
                  *, cache=None, cache_index=None):
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["pre_norm"], x, cfg.norm_type)
    if kind in ("attn", "swa", "local_attn"):
        window = cfg.window if kind in ("swa", "local_attn") else 0
        if cfg.mla is not None:
            out, new_cache = attn.mla_forward(
                p["mixer"], h, positions, cfg, cache=cache,
                cache_index=cache_index)
        else:
            out, new_cache = attn.attention_forward(
                p["mixer"], h, positions, cfg, window=window, cache=cache,
                cache_index=cache_index)
    elif kind == "mamba":
        out, new_cache = ssm_lib.mamba_forward(p["mixer"], h, cfg, cache=cache)
    else:  # rglru
        out, new_cache = rglru_lib.rglru_forward(p["mixer"], h, cfg,
                                                 cache=cache)
    x = x + out

    if "moe" in p:
        h = apply_norm(p["post_norm"], x, cfg.norm_type)
        out, aux = moe_lib.moe_forward(p["moe"], h, cfg)
        x = x + out
    elif "mlp" in p:
        h = apply_norm(p["post_norm"], x, cfg.norm_type)
        x = x + apply_mlp(p["mlp"], h, cfg.mlp_type)
    return x, new_cache, aux


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype, ring: bool = False):
    if kind in ("attn", "swa", "local_attn"):
        if cfg.mla is not None:
            return attn.init_mla_cache(cfg, batch, max_len, dtype)
        window = cfg.window if kind in ("swa", "local_attn") else 0
        return attn.init_attn_cache(cfg, batch, max_len, window, dtype,
                                    ring=ring)
    if kind == "mamba":
        return ssm_lib.init_mamba_cache(cfg, batch, dtype)
    return rglru_lib.init_rglru_cache(cfg, batch, dtype)


def init_group_caches(cfg: ModelConfig, batch: int, max_len: int, dtype,
                      ring: bool = False):
    """One stacked cache pytree per scan group."""
    caches = []
    for start, length, (kind, is_moe) in layer_groups(cfg):
        one = init_layer_cache(cfg, kind, batch, max_len, dtype, ring=ring)
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (length, *a.shape)).copy()
            if length > 1 else a[None], one))
    return caches


def _kind_cache_axes(cfg: ModelConfig, kind: str):
    if kind in ("attn", "swa", "local_attn"):
        if cfg.mla is not None:
            return {"c_kv": ("batch", "seq", "kv_lora"),
                    "k_rope": ("batch", "seq", None),
                    "pos": ("batch", "seq")}
        return {"k": ("batch", "seq", "kv_heads", "head_dim"),
                "v": ("batch", "seq", "kv_heads", "head_dim"),
                "pos": ("batch", "seq")}
    if kind == "mamba":
        return {"conv": ("batch", None, "ffn"), "ssm": ("batch", "ffn", None)}
    return {"conv": ("batch", None, "ffn"), "h": ("batch", "ffn")}


def cache_axes(cfg: ModelConfig):
    """Logical axes per group-stacked cache (leading 'layers' axis)."""
    out = []
    for start, length, (kind, is_moe) in layer_groups(cfg):
        ax = _kind_cache_axes(cfg, kind)
        out.append(jax.tree.map(
            lambda t: ("layers",) + t, ax,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x)))
    return out


def backbone_forward(params, x, positions, cfg: ModelConfig, *, caches=None,
                     cache_index=None, remat: bool = False,
                     layer_constraint=None, unroll: bool = False):
    """x: (B,S,D) embeddings. Returns (hidden, new_caches, aux_sum).

    unroll=True replaces lax.scan with a python loop — used by the dry-run's
    cost-analysis pass (XLA counts while-loop bodies once; the unrolled HLO
    yields true whole-step FLOP/byte totals without being compiled).
    """
    new_caches = []
    aux_total = jnp.zeros((), jnp.float32)
    groups = layer_groups(cfg)
    for gi, (start, length, (kind, is_moe)) in enumerate(groups):
        p_stack = params["blocks"][gi]
        cache_stack = caches[gi] if caches is not None else None

        def inner(p, x, cache):
            return layer_forward(p, x, positions, cfg, kind, is_moe,
                                 cache=cache, cache_index=cache_index)

        if remat and cache_stack is None:
            inner = jax.checkpoint(inner)

        def one_layer(p, x, cache):
            # Constraint applied OUTSIDE the remat boundary: the tensor the
            # backward pass stores is the (e.g. sequence-sharded) layer input.
            if layer_constraint is not None:
                x = layer_constraint(x)
            return inner(p, x, cache)

        if length == 1 or unroll:
            outs = []
            for i in range(length):
                p0 = jax.tree.map(lambda a: a[i], p_stack)
                c0 = jax.tree.map(lambda a: a[i], cache_stack) \
                    if cache_stack is not None else None
                x, new_cache, aux = one_layer(p0, x, c0)
                aux_total = aux_total + aux
                outs.append(new_cache)
            new_caches.append(
                jax.tree.map(lambda *a: jnp.stack(a), *outs)
                if cache_stack is not None else None)
        else:
            def body(carry, xs):
                h, aux_acc = carry
                if cache_stack is not None:
                    p_l, c_l = xs
                else:
                    p_l, c_l = xs, None
                h, new_c, aux_l = one_layer(p_l, h, c_l)
                return (h, aux_acc + aux_l), new_c

            xs = (p_stack, cache_stack) if cache_stack is not None else p_stack
            (x, aux_total), stacked_new = jax.lax.scan(
                body, (x, aux_total), xs)
            new_caches.append(stacked_new if cache_stack is not None else None)
    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    return x, (new_caches if caches is not None else None), aux_total
