"""Mamba-1 selective SSM (falcon-mamba-7b family).

Training/prefill uses a chunked scan: outer ``lax.scan`` over time chunks with
an inner associative scan, bounding the (chunk, d_inner, d_state) transient to
VMEM-friendly sizes instead of materializing (S, d_inner, d_state).
Decode is an O(1) state update.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.param import Initializer


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or math.ceil(cfg.d_model / 16)
    return d_inner, dt_rank


def init_mamba(ini: Initializer, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, dt_rank = _dims(cfg)
    a_init = jnp.log(jnp.broadcast_to(
        jnp.arange(1, s.d_state + 1, dtype=jnp.float32), (d_inner, s.d_state)))
    return {
        "in_proj": ini.dense((d, 2 * d_inner), ("embed", "ffn")),
        "conv_w": ini.dense((s.d_conv, d_inner), ("conv", "ffn"), scale=0.5),
        "conv_b": ini.zeros((d_inner,), ("ffn",)),
        "x_proj": ini.dense((d_inner, dt_rank + 2 * s.d_state), ("ffn", "state")),
        "dt_proj": ini.dense((dt_rank, d_inner), ("state", "ffn")),
        "dt_bias": ini.constant(
            jnp.log(jnp.expm1(jnp.full((d_inner,), 0.01))), ("ffn",)),
        "A_log": ini.constant(a_init, ("ffn", "state")),
        "D": ini.ones((d_inner,), ("ffn",)),
        "out_proj": ini.dense((d_inner, d), ("ffn", "embed")),
    }


def _ssm_params(p, xz, cfg):
    """Common per-step projections. xz: (..., d_inner) post-conv branch."""
    s = cfg.ssm
    _, dt_rank = _dims(cfg)
    proj = xz @ p["x_proj"]  # (..., dt_rank + 2*state)
    dt, b_mat, c_mat = jnp.split(proj, [dt_rank, dt_rank + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])  # (..., d_inner)
    return dt, b_mat, c_mat


def _selective_scan_chunked(p, x, cfg: ModelConfig):
    """x: (B,S,d_inner) conv+silu branch. Returns y: (B,S,d_inner)."""
    s_cfg = cfg.ssm
    b, s, d_inner = x.shape
    # Pick the largest chunk <= scan_chunk that divides s exactly: padded
    # steps would advance the recurrence (dt(0) > 0) and corrupt the state.
    chunk = min(s_cfg.scan_chunk, s)
    while s % chunk:
        chunk -= 1
    n_chunks = x.shape[1] // chunk
    xc = x.reshape(b, n_chunks, chunk, d_inner).swapaxes(0, 1)

    a = -jnp.exp(p["A_log"].astype(jnp.float32))  # (d_inner, state)

    def chunk_step(h0, x_blk):
        # x_blk: (B,C,d_inner); h0: (B,d_inner,state)
        dt, b_mat, c_mat = _ssm_params(p, x_blk, cfg)
        dt = dt.astype(jnp.float32)
        da = jnp.exp(dt[..., None] * a)                       # (B,C,d,n)
        dbx = (dt * x_blk.astype(jnp.float32))[..., None] * \
            b_mat.astype(jnp.float32)[..., None, :]           # (B,C,d,n)

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        da_s, dbx_s = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        h = da_s * h0[:, None] + dbx_s                        # (B,C,d,n)
        y = jnp.einsum("bcdn,bcn->bcd", h, c_mat.astype(jnp.float32))
        h_last = h[:, -1]
        return h_last, y.astype(x_blk.dtype)

    h0 = jnp.zeros((b, d_inner, s_cfg.d_state), jnp.float32)
    h_final, yc = jax.lax.scan(chunk_step, h0, xc)
    y = yc.swapaxes(0, 1).reshape(b, -1, d_inner)[:, :s]
    return y, h_final


def mamba_forward(p, x, cfg: ModelConfig, *, cache=None):
    """Full-sequence (train/prefill) or single-step (decode) Mamba block.

    cache: {"conv": (B, d_conv-1, d_inner), "ssm": (B, d_inner, state)}.
    """
    s_cfg = cfg.ssm
    b, s, _ = x.shape
    d_inner, _ = _dims(cfg)
    xz = x @ p["in_proj"]
    xb, z = jnp.split(xz, 2, axis=-1)  # (B,S,d_inner) each

    if cache is None or s > 1:
        # Full-sequence path (training, or prefill when cache is supplied).
        # Causal depthwise conv via shifted adds (d_conv is tiny).
        conv = jnp.zeros_like(xb)
        for i in range(s_cfg.d_conv):
            shift = s_cfg.d_conv - 1 - i
            shifted = jnp.pad(xb, ((0, 0), (shift, 0), (0, 0)))[:, :s]
            conv = conv + shifted * p["conv_w"][i]
        conv = jax.nn.silu(conv + p["conv_b"])
        y, h_final = _selective_scan_chunked(p, conv, cfg)
        if cache is not None:
            tail = jnp.concatenate([cache["conv"], xb], axis=1)
            new_cache = {"conv": tail[:, -(s_cfg.d_conv - 1):], "ssm": h_final}
        else:
            new_cache = None
    else:
        conv_state, h = cache["conv"], cache["ssm"]
        window = jnp.concatenate([conv_state, xb], axis=1)  # (B,d_conv,d)
        conv = jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"]
        conv = jax.nn.silu(conv)[:, None]  # (B,1,d_inner)
        dt, b_mat, c_mat = _ssm_params(p, conv, cfg)
        a = -jnp.exp(p["A_log"].astype(jnp.float32))
        dt = dt[:, 0].astype(jnp.float32)
        da = jnp.exp(dt[..., None] * a)  # (B,d,n)
        dbx = (dt * conv[:, 0].astype(jnp.float32))[..., None] * \
            b_mat[:, 0].astype(jnp.float32)[:, None, :]
        h = da * h + dbx
        y = jnp.einsum("bdn,bn->bd", h, c_mat[:, 0].astype(jnp.float32))
        y = y.astype(x.dtype)[:, None]
        new_cache = {"conv": window[:, 1:], "ssm": h}

    y = y + _d_skip(p, conv)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], new_cache


def _d_skip(p, conv):
    return conv * p["D"]


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    s = cfg.ssm
    d_inner, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, d_inner), dtype),
        "ssm": jnp.zeros((batch, d_inner, s.d_state), jnp.float32),
    }
