"""Parameter boxing: arrays tagged with logical sharding axes.

Model ``init`` functions build pytrees whose leaves are ``Box(value, axes)``.
``unbox``/``axes_of`` split that into a plain params pytree and a matching
pytree of logical-axis tuples consumed by ``repro.sharding``.

Box is registered as a pytree node so ``jax.eval_shape`` over an init function
yields boxed ShapeDtypeStructs — the dry-run path never materializes weights.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import jax.tree_util as jtu


@jtu.register_pytree_node_class
class Box:
    """An array leaf annotated with per-dimension logical axis names."""

    def __init__(self, value, axes):
        self.value = value
        self.axes = tuple(axes)

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)

    def __repr__(self):
        return f"Box({getattr(self.value, 'shape', self.value)}, axes={self.axes})"


def _is_box(x):
    return isinstance(x, Box)


def unbox(tree):
    return jax.tree.map(lambda b: b.value, tree, is_leaf=_is_box)


def axes_of(tree):
    return jax.tree.map(lambda b: b.axes, tree, is_leaf=_is_box)


def boxed_zeros(shape, axes, dtype=jnp.float32):
    return Box(jnp.zeros(shape, dtype), axes)


class Initializer:
    """Splits a PRNG key on demand; produces boxed parameters."""

    def __init__(self, key, dtype=jnp.float32):
        self._key = key
        self.dtype = dtype

    def _next(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def dense(self, shape, axes, scale=None):
        fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
        if len(shape) == 3:  # (expert, d_in, d_out)
            fan_in = shape[1]
        std = scale if scale is not None else fan_in ** -0.5
        v = (jax.random.normal(self._next(), shape, jnp.float32) * std).astype(self.dtype)
        return Box(v, axes)

    def embedding(self, shape, axes, scale=1.0):
        v = (jax.random.normal(self._next(), shape, jnp.float32) * scale).astype(self.dtype)
        return Box(v, axes)

    def zeros(self, shape, axes):
        return Box(jnp.zeros(shape, self.dtype), axes)

    def ones(self, shape, axes):
        return Box(jnp.ones(shape, self.dtype), axes)

    def constant(self, value, axes):
        return Box(jnp.asarray(value, self.dtype), axes)
