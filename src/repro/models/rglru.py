"""RG-LRU recurrent block (RecurrentGemma / Griffin family).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
with a_t = exp(-c * softplus(Lambda) * sigmoid(r_t)); gates r, i are linear in x.
Elementwise over the lru width -> a single associative scan suffices (no state
dimension), so no chunking is needed at 4k sequence length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.param import Initializer


def _width(cfg: ModelConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def init_rglru(ini: Initializer, cfg: ModelConfig):
    r = cfg.rglru
    d = cfg.d_model
    w = _width(cfg)
    return {
        "in_proj": ini.dense((d, 2 * w), ("embed", "ffn")),   # x branch + gate branch
        "conv_w": ini.dense((r.d_conv, w), ("conv", "ffn"), scale=0.5),
        "conv_b": ini.zeros((w,), ("ffn",)),
        "w_r": ini.dense((w, w), ("ffn", "ffn")),
        "b_r": ini.zeros((w,), ("ffn",)),
        "w_i": ini.dense((w, w), ("ffn", "ffn")),
        "b_i": ini.zeros((w,), ("ffn",)),
        # Lambda parameterized so a ~ U(0.9, 0.999) at init.
        "lam": ini.constant(jnp.linspace(-4.0, -9.0, w), ("ffn",)),
        "out_proj": ini.dense((w, d), ("ffn", "embed")),
    }


def _gates(p, xb, cfg: ModelConfig):
    r = jax.nn.sigmoid(xb @ p["w_r"] + p["b_r"])
    i = jax.nn.sigmoid(xb @ p["w_i"] + p["b_i"])
    log_a = -cfg.rglru.c * jax.nn.softplus(p["lam"]) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i.astype(jnp.float32) * xb.astype(jnp.float32))
    return a, gated


def rglru_forward(p, x, cfg: ModelConfig, *, cache=None):
    """x: (B,S,D). cache: {"conv": (B,d_conv-1,w), "h": (B,w)}."""
    rcfg = cfg.rglru
    b, s, _ = x.shape
    w = _width(cfg)
    xz = x @ p["in_proj"]
    xb, z = jnp.split(xz, 2, axis=-1)

    if cache is None or s > 1:
        # Full-sequence path (training, or prefill when cache is supplied).
        conv = jnp.zeros_like(xb)
        for i in range(rcfg.d_conv):
            shift = rcfg.d_conv - 1 - i
            shifted = jnp.pad(xb, ((0, 0), (shift, 0), (0, 0)))[:, :s]
            conv = conv + shifted * p["conv_w"][i]
        conv = conv + p["conv_b"]
        a, gated = _gates(p, conv, cfg)

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        a_s, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
        if cache is not None:
            tail = jnp.concatenate([cache["conv"], xb], axis=1)
            new_cache = {"conv": tail[:, -(rcfg.d_conv - 1):],
                         "h": h[:, -1]}
        else:
            new_cache = None
        y = h.astype(x.dtype)
    else:
        conv_state, h_prev = cache["conv"], cache["h"]
        window = jnp.concatenate([conv_state, xb], axis=1)
        conv = (jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"])[:, None]
        a, gated = _gates(p, conv, cfg)
        h = a[:, 0] * h_prev + gated[:, 0]
        y = h.astype(x.dtype)[:, None]
        new_cache = {"conv": window[:, 1:], "h": h}

    y = y * jax.nn.gelu(z)
    return y @ p["out_proj"], new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype):
    w = _width(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.rglru.d_conv - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }
