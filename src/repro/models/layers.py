"""Core layers: norms, MLPs, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.param import Initializer


# ---------------------------------------------------------------- norms

def init_norm(ini: Initializer, d: int, kind: str):
    if kind == "rms":
        return {"scale": ini.ones((d,), ("embed",))}
    return {"scale": ini.ones((d,), ("embed",)), "bias": ini.zeros((d,), ("embed",))}


def apply_norm(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rms":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------- mlp

def init_mlp(ini: Initializer, d: int, d_ff: int, mlp_type: str):
    if mlp_type in ("swiglu", "geglu"):
        return {
            "w_gate": ini.dense((d, d_ff), ("embed", "ffn")),
            "w_up": ini.dense((d, d_ff), ("embed", "ffn")),
            "w_down": ini.dense((d_ff, d), ("ffn", "embed")),
        }
    if mlp_type == "gelu":
        return {
            "w_up": ini.dense((d, d_ff), ("embed", "ffn")),
            "b_up": ini.zeros((d_ff,), ("ffn",)),
            "w_down": ini.dense((d_ff, d), ("ffn", "embed")),
            "b_down": ini.zeros((d,), ("embed",)),
        }
    raise ValueError(mlp_type)


def apply_mlp(p, x, mlp_type: str):
    if mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if mlp_type == "swiglu" else jax.nn.gelu
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
        return h @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"] + p["b_up"])
    return h @ p["w_down"] + p["b_down"]


# ---------------------------------------------------------------- embeddings / head

def init_embedding(ini: Initializer, cfg: ModelConfig):
    p = {}
    if cfg.num_codebooks > 1:
        p["tok"] = ini.embedding(
            (cfg.num_codebooks, cfg.vocab_size, cfg.d_model),
            ("codebook", "vocab", "embed"), scale=0.02)
    else:
        p["tok"] = ini.embedding((cfg.vocab_size, cfg.d_model),
                                 ("vocab", "embed"), scale=0.02)
    if not cfg.tie_embeddings:
        if cfg.num_codebooks > 1:
            p["head"] = ini.dense(
                (cfg.num_codebooks, cfg.d_model, cfg.vocab_size),
                ("codebook", "embed", "vocab"))
        else:
            p["head"] = ini.dense((cfg.d_model, cfg.vocab_size),
                                  ("embed", "vocab"))
    return p


def embed_tokens(p, tokens, cfg: ModelConfig):
    """tokens: (B, S) int or (B, S, C) for multi-codebook models."""
    if cfg.num_codebooks > 1:
        # Sum codebook embeddings (MusicGen-style; the delay pattern is a data
        # pipeline concern, the backbone consumes summed embeddings).
        # tokens (B,S,C): gather per codebook.
        parts = [jnp.take(p["tok"][c], tokens[..., c], axis=0)
                 for c in range(cfg.num_codebooks)]
        return sum(parts)
    return jnp.take(p["tok"], tokens, axis=0)


def lm_logits(p, x, cfg: ModelConfig):
    if cfg.num_codebooks > 1:
        if cfg.tie_embeddings:
            # (B,S,D) x (C,V,D) -> (B,S,C,V)
            return jnp.einsum("bsd,cvd->bscv", x, p["tok"])
        return jnp.einsum("bsd,cdv->bscv", x, p["head"])
    if cfg.tie_embeddings:
        return x @ p["tok"].T
    return x @ p["head"]
