"""Public model API: init / loss / prefill / decode / input_specs.

Batch convention (all entries optional except labels for training):
  tokens : (B, S) int32, or (B, S, C) for multi-codebook (MusicGen)
  embeds : (B, S, D) precomputed frontend embeddings (VLM / audio stubs)
  labels : same shape as tokens
  positions : (B, S) or (B, S, 3) for M-RoPE
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.param import Initializer, unbox, axes_of
from repro.models.layers import init_embedding, embed_tokens, lm_logits, init_norm
from repro.models.transformer import (
    init_blocks, backbone_forward, init_group_caches,
)


# ---------------------------------------------------------------- init

def init_boxed(cfg: ModelConfig, key):
    ini = Initializer(key, dtype=cfg.jnp_dtype)
    params = {
        "embed": init_embedding(ini, cfg),
        "blocks": init_blocks(ini, cfg),
        "final_norm": init_norm(ini, cfg.d_model, cfg.norm_type),
    }
    return params


def init_params(cfg: ModelConfig, key):
    return unbox(init_boxed(cfg, key))


def param_axes(cfg: ModelConfig):
    boxed = jax.eval_shape(lambda k: init_boxed(cfg, k),
                           jax.ShapeDtypeStruct((2,), jnp.uint32))
    return axes_of(boxed)


def param_shapes(cfg: ModelConfig):
    boxed = jax.eval_shape(lambda k: init_boxed(cfg, k),
                           jax.ShapeDtypeStruct((2,), jnp.uint32))
    return unbox(boxed)


def num_params(cfg: ModelConfig) -> int:
    import math
    shapes = param_shapes(cfg)
    return sum(math.prod(s.shape) if s.shape else 1
               for s in jax.tree.leaves(shapes))


# ---------------------------------------------------------------- forward

def _positions_for(cfg: ModelConfig, batch: int, seq: int, offset=0):
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[..., None], (batch, seq, 3))
    return pos


def forward(params, batch, cfg: ModelConfig, *, caches=None, cache_index=None,
            remat: bool = False, layer_constraint=None, unroll: bool = False):
    """Returns (logits, new_caches, aux_loss)."""
    if batch.get("embeds") is not None:
        x = batch["embeds"].astype(cfg.jnp_dtype)
        b, s = x.shape[:2]
    else:
        tokens = batch["tokens"]
        b, s = tokens.shape[:2]
        x = embed_tokens(params["embed"], tokens, cfg)
    positions = batch.get("positions")
    if positions is None:
        offset = cache_index if cache_index is not None else 0
        positions = _positions_for(cfg, b, s, offset=offset)
    h, new_caches, aux = backbone_forward(
        params, x, positions, cfg, caches=caches, cache_index=cache_index,
        remat=remat, layer_constraint=layer_constraint, unroll=unroll)
    logits = lm_logits(params["embed"], h, cfg)
    return logits, new_caches, aux


def loss_fn(params, batch, cfg: ModelConfig, *, remat: bool = False,
            layer_constraint=None, unroll: bool = False):
    """Mean next-token cross-entropy (+ MoE aux). Labels are pre-shifted."""
    logits, _, aux = forward(params, batch, cfg, remat=remat,
                             layer_constraint=layer_constraint, unroll=unroll)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        ce = jnp.sum(nll) / denom
    else:
        ce = jnp.mean(nll)
    return ce + aux


# ---------------------------------------------------------------- serving

def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                ring: bool = False):
    """ring=True bounds windowed-attention caches to the window (long decode).

    Caches are stacked per scan group (leading 'layers' axis)."""
    dtype = cfg.jnp_dtype
    return init_group_caches(cfg, batch, max_len, dtype, ring=ring)


def prefill(params, batch, cfg: ModelConfig, max_len: int,
            unroll: bool = False):
    """Run the prompt through the model, filling caches.

    Returns (last_token_logits, caches).  For attention layers the caches are
    filled by inserting at index 0 with the full prompt.
    """
    tokens = batch.get("tokens")
    if batch.get("embeds") is not None:
        b, s = batch["embeds"].shape[:2]
    else:
        b, s = tokens.shape[:2]
    caches = init_caches(cfg, b, max_len)
    logits, caches, _ = forward(params, batch, cfg, caches=caches,
                                cache_index=0, unroll=unroll)
    return logits[:, -1], caches


def decode_step(params, tokens, caches, index, cfg: ModelConfig,
                unroll: bool = False):
    """One decode step. tokens: (B, 1[, C]); index: int32 scalar position."""
    batch = {"tokens": tokens}
    logits, caches, _ = forward(params, batch, cfg, caches=caches,
                                cache_index=index, unroll=unroll)
    return logits[:, -1], caches
