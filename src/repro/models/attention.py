"""Attention: GQA (+bias), sliding-window / local attention, MLA, KV caches.

Long sequences use a pure-JAX flash-style chunked attention (online softmax
over KV chunks) so 32k-token prefill lowers without materializing S x S
score matrices.  On TPU this is the natural blocking for a Pallas port; here
it is the memory-correct reference the dry-run compiles.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.param import Initializer
from repro.models.layers import init_norm, apply_norm
from repro.models.rope import apply_rope

NEG_INF = -1e30


# ================================================================ init

def init_attention(ini: Initializer, cfg: ModelConfig):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    p = {
        "wq": ini.dense((d, nq * hd), ("embed", "qkv")),
        "wk": ini.dense((d, nkv * hd), ("embed", "qkv")),
        "wv": ini.dense((d, nkv * hd), ("embed", "qkv")),
        "wo": ini.dense((nq * hd, d), ("qkv", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = ini.zeros((nq * hd,), ("qkv",))
        p["bk"] = ini.zeros((nkv * hd,), ("qkv",))
        p["bv"] = ini.zeros((nkv * hd,), ("qkv",))
    return p


def init_mla(ini: Initializer, cfg: ModelConfig):
    m = cfg.mla
    d = cfg.d_model
    h = cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": ini.dense((d, m.q_lora_rank), ("embed", "kv_lora")),
        "q_norm": init_norm(ini, m.q_lora_rank, cfg.norm_type),
        "wq_b": ini.dense((m.q_lora_rank, h * qk), ("kv_lora", "qkv")),
        "wkv_a": ini.dense((d, m.kv_lora_rank + m.qk_rope_head_dim),
                           ("embed", "kv_lora")),
        "kv_norm": init_norm(ini, m.kv_lora_rank, cfg.norm_type),
        "wkv_b": ini.dense((m.kv_lora_rank,
                            h * (m.qk_nope_head_dim + m.v_head_dim)),
                           ("kv_lora", "qkv")),
        "wo": ini.dense((h * m.v_head_dim, d), ("qkv", "embed")),
    }


# ================================================================ masks

def _causal_window_mask(q_pos, k_pos, window: int):
    """q_pos: (..., Sq), k_pos: (..., Sk) -> bool mask (..., Sq, Sk)."""
    dq = q_pos[..., :, None]
    dk = k_pos[..., None, :]
    m = dk <= dq
    if window:
        m &= dk > dq - window
    return m


# ================================================================ core attention

def _sdpa(q, k, v, q_pos, k_pos, window: int, k_valid=None):
    """Plain attention. q: (B,Sq,H,D) k/v: (B,Sk,Hkv,D).

    GQA contracts grouped query heads against the raw KV heads (no
    ``jnp.repeat``): the KV cache is read once instead of rep x — the §Perf
    decode-memory lever.
    """
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    qg = q.reshape(b, sq, hkv, rep, dh)
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k).astype(jnp.float32)
    scores *= dh ** -0.5
    mask = _causal_window_mask(q_pos, k_pos, window)[:, None, None]
    if k_valid is not None:
        mask = mask & k_valid[:, None, None, None, :]
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", w, v)
    return out.reshape(b, sq, h, v.shape[-1])  # v head dim may differ (MLA)


def _chunked_sdpa(q, k, v, q_pos, k_pos, window: int, chunk: int):
    """Flash-style online-softmax over KV chunks; O(Sq * chunk) transients."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    rep = h // hkv
    pad = (-sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=2**30)
    nchunks = k.shape[1] // chunk
    kc = k.reshape(b, nchunks, chunk, hkv, k.shape[-1])
    vc = v.reshape(b, nchunks, chunk, hkv, v.shape[-1])
    pc = k_pos.reshape(b, nchunks, chunk)
    scale = dh ** -0.5

    qg = q.reshape(b, sq, hkv, rep, dh)

    def step(carry, xs):
        m_prev, l_prev, acc = carry  # (B,Hkv,R,Sq[,D])
        kb, vb, pb = xs  # (B,C,Hkv,D), (B,C)
        s = jnp.einsum("bqhrd,bkhd->bhrqk", qg, kb).astype(jnp.float32) * scale
        mask = _causal_window_mask(q_pos, pb, window)[:, None, None]
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[..., None])
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhrqk,bkhd->bhrqd", p.astype(q.dtype), vb)
        acc = acc * alpha[..., None].astype(q.dtype) + pv
        return (m_cur, l_cur, acc), None

    m0 = jnp.full((b, hkv, rep, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, rep, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, rep, sq, v.shape[-1]), q.dtype)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), pc.swapaxes(0, 1)))
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(q.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, v.shape[-1])


def sdpa(q, k, v, q_pos, k_pos, cfg: ModelConfig, window: int, k_valid=None):
    sq, sk = q.shape[1], k.shape[1]
    if sq >= cfg.attn_chunk_threshold and k_valid is None:
        return _chunked_sdpa(q, k, v, q_pos, k_pos, window, cfg.attn_chunk)
    return _sdpa(q, k, v, q_pos, k_pos, window, k_valid)


# ================================================================ GQA layer

def attention_forward(p, x, positions, cfg: ModelConfig, *, window: int,
                      cache=None, cache_index=None):
    """x: (B,S,D). cache: dict with k/v ring or linear buffers (decode).

    Returns (out, new_cache).
    """
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, nq, hd)
    k = k.reshape(b, s, nkv, hd)
    v = v.reshape(b, s, nkv, hd)

    rope_pos = positions
    q = apply_rope(q, rope_pos, theta=cfg.rope_theta, fraction=cfg.rope_fraction,
                   mrope_sections=cfg.mrope_sections)
    k = apply_rope(k, rope_pos, theta=cfg.rope_theta, fraction=cfg.rope_fraction,
                   mrope_sections=cfg.mrope_sections)

    q_pos1d = positions[..., 0] if positions.ndim == 3 else positions

    if cache is None:
        out = sdpa(q, k, v, q_pos1d, q_pos1d, cfg, window)
        new_cache = None
    else:
        # decode / cached prefill: insert the new k/v then attend to buffer.
        ck, cv, cpos = cache["k"], cache["v"], cache["pos"]
        buf_len = ck.shape[1]
        # Identity when the buffer covers the full context; ring-wrap when the
        # buffer is window-bounded (long-context decode).
        slot = cache_index % buf_len
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
        cpos = jax.lax.dynamic_update_slice(
            cpos, jnp.broadcast_to(q_pos1d.astype(cpos.dtype), (b, s)), (0, slot))
        k_valid = (cpos <= q_pos1d[:, -1:]) & (cpos >= 0)  # filled entries
        out = sdpa(q, ck, cv, q_pos1d, cpos, cfg, window, k_valid=k_valid)
        new_cache = {"k": ck, "v": cv, "pos": cpos}

    out = out.reshape(b, s, nq * hd)
    return out @ p["wo"], new_cache


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, window: int,
                    dtype, ring: bool = False):
    hd = cfg.resolved_head_dim
    buf = min(max_len, window) if (window and ring) else max_len
    return {
        "k": jnp.zeros((batch, buf, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, buf, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.full((batch, buf), -1, jnp.int32),
    }


# ================================================================ MLA layer

def mla_forward(p, x, positions, cfg: ModelConfig, *, cache=None,
                cache_index=None):
    """DeepSeek-V2 Multi-head Latent Attention.

    Decode cache stores only (c_kv, k_rope): (B, S, kv_lora + rope_dim).
    """
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    q = apply_norm(p["q_norm"], x @ p["wq_a"], cfg.norm_type) @ p["wq_b"]
    q = q.reshape(b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)

    kv_a = x @ p["wkv_a"]  # (B,S,kv_lora+rope)
    c_kv, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    c_kv = apply_norm(p["kv_norm"], c_kv, cfg.norm_type)

    pos1d = positions[..., 0] if positions.ndim == 3 else positions
    q_rope = apply_rope(q_rope, pos1d, theta=cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], pos1d, theta=cfg.rope_theta)

    if cache is not None:
        cc, cr, cpos = cache["c_kv"], cache["k_rope"], cache["pos"]
        cc = jax.lax.dynamic_update_slice(cc, c_kv.astype(cc.dtype), (0, cache_index, 0))
        cr = jax.lax.dynamic_update_slice(
            cr, k_rope[:, :, 0, :].astype(cr.dtype), (0, cache_index, 0))
        cpos = jax.lax.dynamic_update_slice(
            cpos, jnp.broadcast_to(pos1d.astype(cpos.dtype), (b, s)), (0, cache_index))
        k_valid = (cpos <= pos1d[:, -1:]) & (cpos >= 0)
        c_kv_all, k_rope_all, kpos = cc, cr, cpos
        new_cache = {"c_kv": cc, "k_rope": cr, "pos": cpos}
    else:
        c_kv_all, k_rope_all, kpos = c_kv, k_rope[:, :, 0, :], pos1d
        k_valid = None
        new_cache = None

    # Expand latent to per-head K (nope part) and V.
    kv = c_kv_all @ p["wkv_b"]  # (B,T,h*(nope+v))
    t = kv.shape[1]
    kv = kv.reshape(b, t, h, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_all[:, :, None, :],
                                  (b, t, h, m.qk_rope_head_dim))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = sdpa(q_full, k, v, pos1d, kpos, cfg, window=0, k_valid=k_valid)
    out = out.reshape(b, s, h * m.v_head_dim)
    return out @ p["wo"], new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
    }
