"""Model configuration dataclasses covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    first_dense_layers: int = 0  # DeepSeek-V2: layer 0 is a dense FFN
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25  # used by benchmarks; ragged path is dropless


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 (falcon-mamba)."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model/16)
    scan_chunk: int = 128


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU recurrent block."""

    lru_width: int = 0  # 0 -> d_model
    d_conv: int = 4
    c: float = 8.0  # power for the a parameterization


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # Block layout: pattern cycled across layers.
    # entries: "attn" | "swa" | "local_attn" | "mamba" | "rglru"
    block_pattern: Sequence[str] = ("attn",)
    mlp_type: str = "swiglu"  # "swiglu" | "gelu" | "none"
    norm_type: str = "rms"  # "rms" | "layer"
    # RoPE
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0  # ChatGLM3 "2d" rope: 0.5
    mrope_sections: Optional[Sequence[int]] = None  # Qwen2-VL: (16, 24, 24)
    qkv_bias: bool = False
    window: int = 0  # sliding-window size for swa/local_attn blocks
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    num_codebooks: int = 1  # musicgen: 4 parallel EnCodec codebooks
    accepts_embeds: bool = False  # VLM/audio: frontend supplies embeddings
    tie_embeddings: bool = True
    dtype: str = "float32"
    # attention chunking for long sequences (pure-JAX flash-style)
    attn_chunk: int = 1024
    attn_chunk_threshold: int = 8192
    source: str = ""  # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def jnp_dtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
                "float16": jnp.float16}[self.dtype]

    def block_kind(self, layer: int) -> str:
        return self.block_pattern[layer % len(self.block_pattern)]

    @property
    def is_attention_free(self) -> bool:
        return all(b == "mamba" for b in self.block_pattern)

    @property
    def supports_long_decode(self) -> bool:
        """Sub-quadratic decode: every block is SSM/recurrent/windowed."""
        return all(b in ("mamba", "rglru", "swa", "local_attn")
                   for b in self.block_pattern)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 256,
            vocab: int = 512, max_experts: int = 4) -> ModelConfig:
    """CPU-scale variant of the same family for smoke tests."""
    n_heads = max(2, min(cfg.n_heads, 4))
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    kw = dict(
        num_layers=layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=d_model // n_heads,
        d_ff=0 if cfg.d_ff == 0 else d_model * 2,
        vocab_size=vocab,
        window=min(cfg.window, 64) if cfg.window else 0,
        attn_chunk_threshold=1 << 30,
    )
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, max_experts),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=d_model,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
        )
    if cfg.mla:
        kw["mla"] = MLAConfig(kv_lora_rank=64, q_lora_rank=96,
                              qk_nope_head_dim=32, qk_rope_head_dim=16,
                              v_head_dim=32)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, scan_chunk=16)
    if cfg.rglru:
        kw["rglru"] = dataclasses.replace(cfg.rglru, lru_width=d_model)
    if cfg.mrope_sections:
        hd = d_model // n_heads
        third = hd // 2 // 4
        kw["mrope_sections"] = (hd // 2 - 2 * third, third, third)
    return dataclasses.replace(cfg, **kw)
