"""Rotary position embeddings: full, partial (ChatGLM3 "2d"), and M-RoPE (Qwen2-VL)."""
from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp


def _rotate(x, cos, sin):
    """x: (..., D) with D even; cos/sin: (..., D//2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _angles(positions, rot_dim, theta):
    """positions: (...,) -> (..., rot_dim//2) angles."""
    inv_freq = theta ** (-jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim)
    return positions[..., None].astype(jnp.float32) * inv_freq


def apply_rope(
    x,
    positions,
    *,
    theta: float = 10000.0,
    fraction: float = 1.0,
    mrope_sections: Optional[Sequence[int]] = None,
):
    """Apply rotary embedding.

    x: (B, S, H, D).
    positions: (B, S) int32, or (B, S, 3) for M-RoPE (temporal, height, width).
    fraction: apply rope to the first ``fraction*D`` dims (ChatGLM3 uses 0.5).
    mrope_sections: per-axis frequency-block sizes summing to rot_dim//2.
    """
    d = x.shape[-1]
    rot_dim = int(d * fraction)
    rot_dim -= rot_dim % 2
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]

    if mrope_sections is not None:
        assert positions.ndim == 3 and positions.shape[-1] == len(mrope_sections)
        ang_parts = []
        half = rot_dim // 2
        assert sum(mrope_sections) == half, (mrope_sections, half)
        full = _angles(positions[..., 0], rot_dim, theta)  # (B,S,half) template
        offset = 0
        for i, sec in enumerate(mrope_sections):
            ang_i = _angles(positions[..., i], rot_dim, theta)[..., offset:offset + sec]
            ang_parts.append(ang_i)
            offset += sec
        ang = jnp.concatenate(ang_parts, axis=-1)
        del full
    else:
        if positions.ndim == 3:  # text-only path of an M-RoPE model
            positions = positions[..., 0]
        ang = _angles(positions, rot_dim, theta)  # (B, S, half)

    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)  # (B,S,1,half)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([_rotate(x_rot, cos, sin), x_pass], axis=-1)


def default_positions(batch: int, seq: int, *, mrope: bool = False, offset=0):
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if mrope:
        pos = jnp.broadcast_to(pos[..., None], (batch, seq, 3))
    return pos
