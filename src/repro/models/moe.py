"""Mixture-of-Experts: top-k router + dropless ragged-dot expert compute.

Dispatch is sort-based (tokens grouped by expert, ``jax.lax.ragged_dot``)
rather than capacity-einsum: compiled FLOPs stay proportional to *active*
parameters (6 * N_active * D for the roofline's MODEL_FLOPS check) and no
(T, E, C) dispatch tensors are materialized.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.param import Initializer
from repro.models.layers import init_mlp, apply_mlp


def init_moe(ini: Initializer, cfg: ModelConfig):
    m = cfg.moe
    d = cfg.d_model
    f = m.d_ff_expert
    p = {
        "router": ini.dense((d, m.num_experts), ("embed", "expert"), scale=0.02),
        "w_gate": ini.dense((m.num_experts, d, f), ("expert", "embed", "ffn")),
        "w_up": ini.dense((m.num_experts, d, f), ("expert", "embed", "ffn")),
        "w_down": ini.dense((m.num_experts, f, d), ("expert", "ffn", "embed")),
    }
    if m.num_shared_experts:
        p["shared"] = init_mlp(ini, d, f * m.num_shared_experts, "swiglu")
    return p


def _ragged_expert_mlp(x_sorted, p, group_sizes):
    """x_sorted: (T*k, d) grouped by expert; SwiGLU expert MLP."""
    g = jax.lax.ragged_dot(x_sorted, p["w_gate"], group_sizes)
    u = jax.lax.ragged_dot(x_sorted, p["w_up"], group_sizes)
    h = jax.nn.silu(g) * u
    return jax.lax.ragged_dot(h, p["w_down"], group_sizes)


def moe_forward(p, x, cfg: ModelConfig):
    """x: (B,S,D) -> (B,S,D), aux_loss (router load-balance)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf @ p["router"]).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, m.top_k)  # (T,k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    # Load-balance aux loss (Switch-style).
    density = jnp.mean(
        jax.nn.one_hot(topi[:, 0], m.num_experts, dtype=jnp.float32), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * m.num_experts * m.router_aux_coef

    # Sort token-expert assignments by expert id.
    flat_expert = topi.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_expert)
    token_of = order // m.top_k
    x_sorted = jnp.take(xf, token_of, axis=0)  # (T*k, d)
    group_sizes = jnp.bincount(flat_expert, length=m.num_experts)

    y_sorted = _ragged_expert_mlp(x_sorted, p, group_sizes)  # (T*k, d)

    w_sorted = jnp.take(topw.reshape(-1), order)
    y_sorted = y_sorted * w_sorted[:, None].astype(y_sorted.dtype)
    y = jnp.zeros((t, d), y_sorted.dtype).at[token_of].add(y_sorted)

    if m.num_shared_experts:
        y = y + apply_mlp(p["shared"], xf, "swiglu")
    return y.reshape(b, s, d), aux
