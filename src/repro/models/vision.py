"""CPU-scale vision models for the paper's own experiments (Tables 1/2/5):
a ResNet-style CNN (GroupNorm variant of the paper's ResNet-18-BN) and a
ViT-Tiny classifier (Appendix D.4 spec, scaled down by default).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.param import Initializer, unbox


# ---------------------------------------------------------------- CNN

def init_cnn(key, *, channels: int = 3, n_classes: int = 10, width: int = 32,
             blocks: int = 2, dtype=jnp.float32):
    ini = Initializer(key, dtype)
    p = {"stem": ini.dense((3, 3, channels, width), (None, None, None, None),
                           scale=0.3)}
    for b in range(blocks):
        w_in = width * (2 ** b)
        w_out = width * (2 ** (b + 1))
        p[f"block{b}"] = {
            "conv1": ini.dense((3, 3, w_in, w_out), (None,) * 4, scale=0.1),
            "conv2": ini.dense((3, 3, w_out, w_out), (None,) * 4, scale=0.1),
            "skip": ini.dense((1, 1, w_in, w_out), (None,) * 4, scale=0.3),
            "gn1_scale": ini.ones((w_out,), (None,)),
            "gn1_bias": ini.zeros((w_out,), (None,)),
            "gn2_scale": ini.ones((w_out,), (None,)),
            "gn2_bias": ini.zeros((w_out,), (None,)),
        }
    w_final = width * (2 ** blocks)
    p["head"] = {"w": ini.dense((w_final, n_classes), (None, None)),
                 "b": ini.zeros((n_classes,), (None,))}
    return unbox(p)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _group_norm(x, scale, bias, groups=8, eps=1e-5):
    b, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    xg = x.reshape(b, h, w, g, c // g).astype(jnp.float32)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(b, h, w, c) * scale + bias).astype(x.dtype)


def cnn_apply(params, images):
    """images: (B,H,W,C) -> logits (B, n_classes)."""
    x = jax.nn.relu(_conv(images, params["stem"]))
    b = 0
    while f"block{b}" in params:
        pb = params[f"block{b}"]
        h = _conv(x, pb["conv1"], stride=2)
        h = jax.nn.relu(_group_norm(h, pb["gn1_scale"], pb["gn1_bias"]))
        h = _conv(h, pb["conv2"])
        h = _group_norm(h, pb["gn2_scale"], pb["gn2_bias"])
        x = jax.nn.relu(h + _conv(x, pb["skip"], stride=2))
        b += 1
    x = x.mean(axis=(1, 2))
    return x @ params["head"]["w"] + params["head"]["b"]


# ---------------------------------------------------------------- ViT

def init_vit(key, *, image_size: int = 16, patch: int = 4, channels: int = 3,
             d_model: int = 64, layers: int = 3, heads: int = 2,
             n_classes: int = 10, dtype=jnp.float32):
    ini = Initializer(key, dtype)
    n_patches = (image_size // patch) ** 2
    d_patch = patch * patch * channels
    p = {
        "patch_embed": ini.dense((d_patch, d_model), (None, None)),
        "pos_embed": ini.embedding((n_patches + 1, d_model), (None, None),
                                   scale=0.02),
        "cls": ini.zeros((1, 1, d_model), (None, None, None)),
        "blocks": [],
        "final_ln_scale": ini.ones((d_model,), (None,)),
        "final_ln_bias": ini.zeros((d_model,), (None,)),
        "head": {"w": ini.dense((d_model, n_classes), (None, None)),
                 "b": ini.zeros((n_classes,), (None,))},
    }
    for _ in range(layers):
        p["blocks"].append({
            "ln1_scale": ini.ones((d_model,), (None,)),
            "ln1_bias": ini.zeros((d_model,), (None,)),
            "wqkv": ini.dense((d_model, 3 * d_model), (None, None)),
            "wo": ini.dense((d_model, d_model), (None, None)),
            "ln2_scale": ini.ones((d_model,), (None,)),
            "ln2_bias": ini.zeros((d_model,), (None,)),
            "w1": ini.dense((d_model, 4 * d_model), (None, None)),
            "b1": ini.zeros((4 * d_model,), (None,)),
            "w2": ini.dense((4 * d_model, d_model), (None, None)),
            "b2": ini.zeros((d_model,), (None,)),
        })
    meta = {"patch": patch, "heads": heads}
    return unbox(p), meta


def _ln(x, scale, bias, eps=1e-6):
    xf = x.astype(jnp.float32)
    mean = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    return (((xf - mean) * jax.lax.rsqrt(var + eps)) * scale + bias).astype(x.dtype)


def vit_apply(params, meta, images):
    patch, heads = meta["patch"], meta["heads"]
    b, hh, ww, c = images.shape
    ph, pw = hh // patch, ww // patch
    x = images.reshape(b, ph, patch, pw, patch, c).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(b, ph * pw, patch * patch * c)
    x = x @ params["patch_embed"]
    cls = jnp.broadcast_to(params["cls"], (b, 1, x.shape[-1]))
    x = jnp.concatenate([cls, x], axis=1) + params["pos_embed"][None]
    d = x.shape[-1]
    hd = d // heads
    for blk in params["blocks"]:
        h = _ln(x, blk["ln1_scale"], blk["ln1_bias"])
        qkv = h @ blk["wqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        s = x.shape[1]
        q = q.reshape(b, s, heads, hd)
        k = k.reshape(b, s, heads, hd)
        v = v.reshape(b, s, heads, hd)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) * hd ** -0.5
        att = jax.nn.softmax(att.astype(jnp.float32), axis=-1).astype(x.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, d)
        x = x + o @ blk["wo"]
        h = _ln(x, blk["ln2_scale"], blk["ln2_bias"])
        h = jax.nn.gelu(h @ blk["w1"] + blk["b1"]) @ blk["w2"] + blk["b2"]
        x = x + h
    x = _ln(x, params["final_ln_scale"], params["final_ln_bias"])
    return x[:, 0] @ params["head"]["w"] + params["head"]["b"]


def classification_loss(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
