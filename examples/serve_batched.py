"""Batched serving example: prefill + KV-cache decode on three architecture
families (dense GQA, SSM, hybrid recurrent).

  PYTHONPATH=src python examples/serve_batched.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve

if __name__ == "__main__":
    for arch in ["smollm-360m", "falcon-mamba-7b", "recurrentgemma-2b"]:
        print(f"=== {arch} (reduced) ===")
        serve.main(["--arch", arch, "--reduced", "--batch", "2",
                    "--prompt-len", "16", "--gen", "8"])
