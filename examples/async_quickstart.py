"""Asynchronous quickstart: staleness-aware FedPAC vs naive async FedSOA.

Clients draw persistent lognormal speeds (stragglers stay slow); the server
flushes its buffer every `buffer_size` arrivals.  Naive async Local SOAP
averages whatever geometry arrives; staleness-aware FedPAC decays stale
deltas/Theta by 1/(1+s)^alpha before Alignment/Correction.

The task is the same registered ``cifar_like_cnn`` scenario the sync
quickstart runs; passing ``async_cfg`` to ``build_experiment`` selects the
buffered-asynchronous runtime for the *same algorithm and scenario specs*.

  PYTHONPATH=src python examples/async_quickstart.py

QUICKSTART_ROUNDS / QUICKSTART_SAMPLES shrink the run (CI smoke job).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

from repro.api import AsyncConfig, LatencyModel, build_experiment, \
    materialize, resolve_scenario

ROUNDS = int(os.environ.get("QUICKSTART_ROUNDS", "20"))
N = int(os.environ.get("QUICKSTART_SAMPLES", "3000"))

# --- the task: 10 clients, Dirichlet(0.1) label skew (strongly non-IID) ---
# materialized once: both runs share the data, partition, params and eval
spec = resolve_scenario("cifar_like_cnn")
scenario = materialize(
    dataclasses.replace(spec, source_kwargs=dict(spec.source_kwargs, n=N)))

# --- heavy latency heterogeneity + occasional dropout ----------------------
latency = LatencyModel(heterogeneity=1.5, jitter=0.5, dropout=0.05)

for algo, mode in [("local_soap", "none"), ("fedpac_soap", "poly")]:
    acfg = AsyncConfig(buffer_size=3, staleness_mode=mode,
                       staleness_alpha=0.5, latency=latency)
    exp = build_experiment(algo, scenario=scenario, async_cfg=acfg,
                           participation=0.5, rounds=ROUNDS, local_steps=5,
                           beta=0.5)
    hist = exp.run()
    h = hist[-1]
    print(f"{algo:12s} staleness={mode:4s} acc={h['test_acc']:.3f} "
          f"loss={h['loss']:.3f} mean_stale={h['staleness']:.2f} "
          f"sim_t={h['sim_time']:.1f}s dropped={exp.total_dropped}")
