"""Asynchronous quickstart: staleness-aware FedPAC vs naive async FedSOA.

Clients draw persistent lognormal speeds (stragglers stay slow); the server
flushes its buffer every `buffer_size` arrivals.  Naive async Local SOAP
averages whatever geometry arrives; staleness-aware FedPAC decays stale
deltas/Theta by 1/(1+s)^alpha before Alignment/Correction.

Passing ``async_cfg`` to ``build_experiment`` selects the buffered-
asynchronous runtime for the *same algorithm specs* the sync runtime runs.

  PYTHONPATH=src python examples/async_quickstart.py

QUICKSTART_ROUNDS / QUICKSTART_SAMPLES shrink the run (CI smoke job).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.api import AsyncConfig, LatencyModel, build_experiment
from repro.data import make_image_classification, dirichlet_partition
from repro.models.vision import init_cnn, cnn_apply, classification_loss, accuracy

ROUNDS = int(os.environ.get("QUICKSTART_ROUNDS", "20"))
N = int(os.environ.get("QUICKSTART_SAMPLES", "3000"))

# --- data: 10 clients, Dirichlet(0.1) label skew (strongly non-IID) -------
X, y = make_image_classification(N, image_size=12, n_classes=8, noise=2.0)
parts = dirichlet_partition(y, n_clients=10, alpha=0.1)
n_eval = max(N // 5, 100)
Xe, ye = jnp.asarray(X[-n_eval:]), jnp.asarray(y[-n_eval:])

params = init_cnn(jax.random.key(0), n_classes=8, width=8, blocks=2)

def loss_fn(p, batch):
    return classification_loss(cnn_apply(p, batch["x"]), batch["y"])

def eval_fn(p):
    return {"test_acc": accuracy(cnn_apply(p, Xe), ye)}

def batch_fn(cid, rng):
    idx = rng.choice(parts[cid], size=16)
    return {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}

# --- heavy latency heterogeneity + occasional dropout ----------------------
latency = LatencyModel(heterogeneity=1.5, jitter=0.5, dropout=0.05)

for algo, mode in [("local_soap", "none"), ("fedpac_soap", "poly")]:
    acfg = AsyncConfig(buffer_size=3, staleness_mode=mode,
                       staleness_alpha=0.5, latency=latency)
    exp = build_experiment(algo, params=params, loss_fn=loss_fn,
                           client_batch_fn=batch_fn, eval_fn=eval_fn,
                           async_cfg=acfg, n_clients=10, participation=0.5,
                           rounds=ROUNDS, local_steps=5, beta=0.5)
    hist = exp.run()
    h = hist[-1]
    print(f"{algo:12s} staleness={mode:4s} acc={h['test_acc']:.3f} "
          f"loss={h['loss']:.3f} mean_stale={h['staleness']:.2f} "
          f"sim_t={h['sim_time']:.1f}s dropped={exp.total_dropped}")
