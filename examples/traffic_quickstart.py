"""Continuous-traffic quickstart: a diurnal arrival trace with churn.

Instead of round-shaped execution ("collect buffer_size reports, flush,
repeat"), clients arrive on an open-ended **diurnal trace** — a day/night
sinusoid over simulated time — while ids join and leave the population
(churn).  The stream runs until a simulated-time budget trips, the server
model is evaluated on a fixed simulated-time grid (anytime eval), and the
headline number is **time-to-quality**: how many simulated seconds until
the anytime test loss crosses a bar.

Mid-stream the algorithm is hot-swapped from fedpac_soap to fedavg
(``swap_to``/``swap_at``): in-flight work trained under the old wire
format is discarded with a traced reason, the server keeps its parameters
and warm geometry, and the stream just keeps flowing.

  PYTHONPATH=src python examples/traffic_quickstart.py

QUICKSTART_SIM_BUDGET / QUICKSTART_SAMPLES shrink the run (CI smoke job).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

from repro.api import AsyncConfig, ChurnConfig, TrafficConfig, \
    build_experiment, materialize, resolve_scenario
from repro.fed.traffic import time_to_quality

SIM_BUDGET = float(os.environ.get("QUICKSTART_SIM_BUDGET", "12"))
N = int(os.environ.get("QUICKSTART_SAMPLES", "3000"))

spec = resolve_scenario("cifar_like_cnn")
scenario = materialize(
    dataclasses.replace(spec, source_kwargs=dict(spec.source_kwargs, n=N)))

traffic = TrafficConfig(
    # ~6 arrivals per simulated second, swinging +-80% over a 4s "day"
    trace="diurnal",
    trace_kwargs={"base": 6.0, "amplitude": 0.8, "period": 4.0},
    # ids join and leave the population; departures evict persistent
    # state and void in-flight work (traced as client_dropped events)
    churn=ChurnConfig(join_rate=0.5, leave_rate=0.5, initial_active=8),
    eval_every=1.0,                      # anytime eval each simulated second
    swap_to="fedavg", swap_at=SIM_BUDGET / 2,   # mid-stream hot-swap
)

exp = build_experiment(
    "fedpac_soap", scenario=scenario,
    async_cfg=AsyncConfig(buffer_size=3, concurrency=4),
    traffic=traffic, rounds=10, local_steps=5, beta=0.5)

summary = exp.run_stream(sim_budget=SIM_BUDGET)
ttq = time_to_quality(exp.eval_history, "test_loss",
                      exp.eval_history[0]["test_loss"] * 0.98,
                      higher_is_better=False)

last = exp.eval_history[-1]
print(f"flushes={summary['flushes']} sim_t={summary['sim_time']:.1f}s "
      f"evals={summary['evals']} joins={summary['joins']} "
      f"leaves={summary['leaves']} discarded={summary['discarded']}")
print(f"algorithm now: {exp.spec.name} (swapped at t={traffic.swap_at:.1f})")
print(f"final anytime eval: loss={last['test_loss']:.3f} "
      f"acc={last['test_acc']:.3f} at sim_t={last['sim_time']:.1f}s")
print(f"time-to-quality (2% below first eval): "
      f"{'never' if ttq is None else f'{ttq:.1f} sim s'}")
