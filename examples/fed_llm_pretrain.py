"""Federated LM pre-training (paper Table 3 setting), via the scenario API.

Trains a reduced LLaMA-60M over topic-skewed non-IID token streams with
FedAvg vs Local SOAP vs FedPAC_SOAP.  The whole task — corpus, Dirichlet
document partition, transformer config, loss/eval — is the registered
``lm_zipf`` scenario; only the run length and cohort come from flags.

  PYTHONPATH=src python examples/fed_llm_pretrain.py [--rounds 12]

(The host-scale flag-driven driver with checkpointing lives in
``repro.launch.train``.)
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.api import build_experiment

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--local-steps", type=int, default=5)
    args = ap.parse_args()

    for algo in ["fedavg", "local_soap", "fedpac_soap"]:
        print(f"=== {algo} ===")
        exp = build_experiment(algo, scenario="lm_zipf",
                               n_clients=args.clients, participation=0.5,
                               rounds=args.rounds,
                               local_steps=args.local_steps)
        hist = exp.run(log_every=max(1, args.rounds // 4))
        print(f"{algo}: train_loss={hist[-1]['loss']:.4f} "
              f"eval_loss={hist[-1]['eval_loss']:.4f} "
              f"token_acc={hist[-1]['token_acc']:.3f} "
              f"comm={exp.comm_bytes_per_round()/1e6:.1f}MB/round")
