"""End-to-end driver: federated LM pre-training (paper Table 3 setting).

Trains the paper's LLaMA-60M (reduced for CPU) for a few hundred local steps
total over non-IID token streams with FedPAC_SOAP vs FedAvg.

  PYTHONPATH=src python examples/fed_llm_pretrain.py [--rounds 20]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train

if __name__ == "__main__":
    args = sys.argv[1:]
    for algo in ["fedavg", "local_soap", "fedpac_soap"]:
        print(f"=== {algo} ===")
        train.main(["--arch", "llama-60m", "--reduced",
                    "--algorithm", algo, "--rounds", "12",
                    "--clients", "6", "--local-steps", "5",
                    "--batch", "4", "--seq", "48"] + args)
