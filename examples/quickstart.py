"""Quickstart: FedPAC in ~40 lines, via the public builder API.

Federated CIFAR-like classification on non-IID clients: compare Local SOAP
(Alg. 1, drifting preconditioners) against FedPAC_SOAP (Alg. 2) and its
bandwidth-light variant (rank-8 factored Theta on the wire — the reported
MB/round is measured from the encoded wire messages, see
``repro.core.transport``).

  PYTHONPATH=src python examples/quickstart.py

QUICKSTART_ROUNDS / QUICKSTART_SAMPLES shrink the run (CI smoke job).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.api import build_experiment
from repro.data import make_image_classification, dirichlet_partition
from repro.models.vision import init_cnn, cnn_apply, classification_loss, accuracy

ROUNDS = int(os.environ.get("QUICKSTART_ROUNDS", "15"))
N = int(os.environ.get("QUICKSTART_SAMPLES", "3000"))

# --- data: 10 clients, Dirichlet(0.1) label skew (strongly non-IID) -------
X, y = make_image_classification(N, image_size=12, n_classes=8, noise=2.0)
parts = dirichlet_partition(y, n_clients=10, alpha=0.1)
n_eval = max(N // 5, 100)
Xe, ye = jnp.asarray(X[-n_eval:]), jnp.asarray(y[-n_eval:])

params = init_cnn(jax.random.key(0), n_classes=8, width=8, blocks=2)

def loss_fn(p, batch):
    return classification_loss(cnn_apply(p, batch["x"]), batch["y"])

def eval_fn(p):
    return {"test_acc": accuracy(cnn_apply(p, Xe), ye)}

def batch_fn(cid, rng):
    idx = rng.choice(parts[cid], size=16)
    return {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}

# --- run the algorithms ----------------------------------------------------
for algo in ["local_soap", "fedpac_soap", "fedpac_soap_light"]:
    exp = build_experiment(algo, params=params, loss_fn=loss_fn,
                           client_batch_fn=batch_fn, eval_fn=eval_fn,
                           n_clients=10, participation=0.5, rounds=ROUNDS,
                           local_steps=5, beta=0.5)
    hist = exp.run()
    print(f"{algo:14s} acc={hist[-1]['test_acc']:.3f} "
          f"loss={hist[-1]['loss']:.3f} drift={hist[-1]['drift']:.2e} "
          f"comm={exp.comm_bytes_per_round()/1e6:.2f} MB/round")
