"""Quickstart: FedPAC in ~20 lines, via the two-registry builder API.

Federated CIFAR-like classification on non-IID clients: compare Local SOAP
(Alg. 1, drifting preconditioners) against FedPAC_SOAP (Alg. 2) and its
bandwidth-light variant (rank-8 factored Theta on the wire — the reported
MB/round is measured from the encoded wire messages, see
``repro.core.transport``).

The task is one registered scenario name — data, Dirichlet(0.1) partition,
CNN, loss/eval and batching all come from the ``cifar_like_cnn`` catalog
entry (``repro.scenarios``); no hand-rolled wiring.

  PYTHONPATH=src python examples/quickstart.py

QUICKSTART_ROUNDS / QUICKSTART_SAMPLES shrink the run (CI smoke job).
QUICKSTART_TRACE=path.jsonl writes the structured observability trace
(phase spans + per-round telemetry: drift, beta, staleness histogram,
wire bytes — see ``repro.obs``).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

from repro.api import build_experiment, materialize, resolve_scenario

ROUNDS = int(os.environ.get("QUICKSTART_ROUNDS", "15"))
N = int(os.environ.get("QUICKSTART_SAMPLES", "3000"))
TRACE = os.environ.get("QUICKSTART_TRACE")

# --- the task: 10 clients, Dirichlet(0.1) label skew (strongly non-IID) ---
# materialized once: all three algorithms share the data, partition, params
# and jitted eval
spec = resolve_scenario("cifar_like_cnn")
scenario = materialize(
    dataclasses.replace(spec, source_kwargs=dict(spec.source_kwargs, n=N)))

# --- run the algorithms ----------------------------------------------------
for algo in ["local_soap", "fedpac_soap", "fedpac_soap_light"]:
    exp = build_experiment(algo, scenario=scenario, participation=0.5,
                           rounds=ROUNDS, local_steps=5, beta=0.5)
    if TRACE:
        from repro.obs import JsonlSink, attach
        attach(exp, JsonlSink(TRACE, append=True))
    hist = exp.run()
    print(f"{algo:14s} acc={hist[-1]['test_acc']:.3f} "
          f"loss={hist[-1]['loss']:.3f} drift={hist[-1]['drift']:.2e} "
          f"comm={exp.comm_bytes_per_round()/1e6:.2f} MB/round "
          f"(label_tv={exp.scenario.partition_stats['label_tv']:.2f})")
