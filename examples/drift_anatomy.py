"""Drift anatomy: reproduce the paper's Fig. 3 mechanism on a quadratic.

Shows layer-wise preconditioner drift (Def. 1) growing with heterogeneity for
naive FedSOA and being suppressed by FedPAC alignment — with the drift term
printed alongside the convergence gap, making the Thm 5.6 coupling visible.

  PYTHONPATH=src python examples/drift_anatomy.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro import optim
from repro.core import make_variant_round_fn, init_server

D, OUT, C, K = 16, 8, 8, 6
key = jax.random.key(0)
W = jax.random.normal(key, (D, OUT))

def make_clients(hetero):
    mats = []
    for i in range(C):
        k1, k2 = jax.random.split(jax.random.key(i + 1))
        Q, _ = jnp.linalg.qr(jax.random.normal(k1, (D, D)))
        s = jnp.exp(jax.random.uniform(k2, (D,), minval=-hetero, maxval=hetero))
        mats.append(Q * s)
    return mats

def batches(mats, key):
    ks = jax.random.split(key, C)
    Xs = jnp.stack([jax.random.normal(ks[i], (K, 16, D)) @ mats[i]
                    for i in range(C)])
    return Xs, jnp.einsum("ckbd,do->ckbo", Xs, W)

def loss_fn(p, batch):
    X, Y = batch
    return jnp.mean((X @ p["w"] - Y) ** 2)

print(f"{'hetero':>7} {'variant':>10} {'final_loss':>11} {'drift':>10}")
for hetero in [0.2, 1.0, 2.0]:
    mats = make_clients(hetero)
    for variant in ["fedsoa", "fedpac"]:
        opt = optim.make("soap")
        rf = make_variant_round_fn(variant, loss_fn, opt, lr=0.05,
                                   local_steps=K, beta=0.5)
        server = init_server({"w": jnp.zeros((D, OUT))}, opt)
        rng = jax.random.key(7)
        for _ in range(50):
            rng, k1, k2 = jax.random.split(rng, 3)
            server, m = rf(server, batches(mats, k1), k2)
        print(f"{hetero:7.1f} {variant:>10} {float(m['loss']):11.5f} "
              f"{float(m['drift']):10.3e}")
