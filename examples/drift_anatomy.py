"""Drift anatomy: reproduce the paper's Fig. 3 mechanism on a quadratic.

Shows preconditioner drift (Def. 1) growing with heterogeneity for naive
FedSOA (``local_soap``) and being suppressed by FedPAC alignment — with the
drift term printed alongside the final loss, making the Thm 5.6 coupling
visible.

The quadratic task is a *custom pluggable scenario*: ``ScenarioSpec.source``
accepts a callable materializer, so a hand-built problem family runs
through exactly the same ``build_experiment(algorithm, scenario=...)``
path as the registered catalog — nothing about the runtimes is vision- or
LM-specific.

  PYTHONPATH=src python examples/drift_anatomy.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.api import ScenarioSpec, Scenario, build_experiment

D, OUT, C, K = 16, 8, 8, 6
W_TRUE = np.asarray(jax.random.normal(jax.random.key(0), (D, OUT)))


def quadratic_source(spec: ScenarioSpec, seed: int, n_clients: int):
    """Materializer: linear-regression clients with rotated+scaled input
    covariances; ``hetero`` controls the spread of the per-client scales —
    the covariance heterogeneity that drives preconditioner drift."""
    hetero = spec.source_kwargs["hetero"]
    rng = np.random.default_rng(seed)
    mats = []
    for _ in range(n_clients):
        Q, _ = np.linalg.qr(rng.normal(size=(D, D)))
        s = np.exp(rng.uniform(-hetero, hetero, D))
        mats.append((Q * s).astype(np.float32))

    def loss_fn(p, batch):
        return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)

    def batch_fn(cid, rng_):
        X = rng_.normal(size=(spec.batch_size, D)).astype(np.float32)
        X = X @ mats[cid]
        return {"x": X, "y": X @ W_TRUE}

    return Scenario(
        spec=spec, seed=seed, n_clients=n_clients,
        params={"w": jnp.zeros((D, OUT))}, loss_fn=loss_fn,
        client_batch_fn=batch_fn, eval_fn=None,
        partition_stats={"hetero": hetero})


print(f"{'hetero':>7} {'algorithm':>10} {'final_loss':>11} {'drift':>10}")
for hetero in [0.2, 1.0, 2.0]:
    spec = ScenarioSpec(name=f"quadratic_h{hetero:g}",
                        source=quadratic_source, model="linear",
                        n_clients=C, batch_size=16,
                        source_kwargs={"hetero": hetero})
    for algo in ["local_soap", "fedpac_soap"]:
        exp = build_experiment(algo, scenario=spec, participation=1.0,
                               rounds=50, local_steps=K, lr=0.05, beta=0.5,
                               seed=7)
        hist = exp.run()
        print(f"{hetero:7.1f} {algo:>10} {hist[-1]['loss']:11.5f} "
              f"{hist[-1]['drift']:10.3e}")
