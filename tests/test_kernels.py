"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
executed in interpret mode on CPU (deliverable c)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.ns_ortho import ops as ns_ops, ref as ns_ref
from repro.kernels.ns_ortho.kernel import matmul_fused
from repro.kernels.sophia_update import ops as so_ops, ref as so_ref
from repro.kernels.soap_rotate import ops as sr_ops, ref as sr_ref
from repro.kernels.soap_rotate.kernel import adam_moments
from repro.kernels.qblock import ops as qb_ops, ref as qb_ref

KEY = jax.random.key(7)

MM_SHAPES = [(8, 8, 8), (128, 128, 128), (64, 200, 96), (130, 257, 50),
             (256, 64, 384)]


@pytest.mark.parametrize("m,k,n", MM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_fused(m, k, n, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    lhs = jax.random.normal(k1, (m, k), dtype)
    rhs = jax.random.normal(k2, (k, n), dtype)
    aux = jax.random.normal(k3, (m, n), dtype)
    got = matmul_fused(lhs, rhs, aux, alpha=0.5, beta=-2.0, interpret=True)
    want = (0.5 * (lhs.astype(jnp.float32) @ rhs.astype(jnp.float32))
            - 2.0 * aux.astype(jnp.float32)).astype(dtype)
    tol = 1e-5 if dtype == jnp.float32 else 6e-2
    assert jnp.max(jnp.abs(got.astype(jnp.float32)
                           - want.astype(jnp.float32))) < tol * max(1, k ** 0.5)


@pytest.mark.parametrize("shape", [(32, 48), (128, 128), (96, 250), (257, 64)])
def test_newton_schulz_pallas_matches_ref(shape):
    g = jax.random.normal(KEY, shape, jnp.float32)
    want = ns_ref.newton_schulz(g)
    got = ns_ops.newton_schulz_pallas(g, interpret=True)
    assert jnp.max(jnp.abs(want - got)) < 1e-4


def test_newton_schulz_singular_values_near_one():
    g = jax.random.normal(KEY, (64, 128), jnp.float32)
    y = ns_ref.newton_schulz(g)
    s = jnp.linalg.svd(y, compute_uv=False)
    assert float(s.max()) < 1.35 and float(s.min()) > 0.45


@pytest.mark.parametrize("shape", [(17,), (64, 64), (3, 40, 50), (2048,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sophia_update_kernel(shape, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    g = jax.random.normal(k1, shape, dtype)
    m = jax.random.normal(k2, shape, jnp.float32)
    h = jax.random.uniform(k3, shape, jnp.float32)
    d_ref, m_ref = so_ref.sophia_update(g, m, h)
    d_pal, m_pal = so_ops.sophia_update(g, m, h, use_pallas=True,
                                        interpret=True)
    assert jnp.max(jnp.abs(d_ref - d_pal)) < 1e-5
    assert jnp.max(jnp.abs(m_ref - m_pal)) < 1e-5
    assert float(jnp.max(jnp.abs(d_pal))) <= 0.05 + 1e-6  # clip bound


@pytest.mark.parametrize("m,n", [(16, 24), (128, 128), (100, 60)])
def test_soap_rotate_kernel(m, n):
    ks = jax.random.split(KEY, 5)
    g = jax.random.normal(ks[0], (m, n), jnp.float32)
    ql, _ = jnp.linalg.qr(jax.random.normal(ks[1], (m, m)))
    qr_, _ = jnp.linalg.qr(jax.random.normal(ks[2], (n, n)))
    mm = jax.random.normal(ks[3], (m, n))
    vv = jax.random.uniform(ks[4], (m, n))
    want = sr_ref.soap_rotated_update(g, ql, qr_, mm, vv)
    got = sr_ops.soap_rotated_update(g, ql, qr_, mm, vv, use_pallas=True,
                                     interpret=True)
    for w, o in zip(want, got):
        assert jnp.max(jnp.abs(w - o)) < 5e-5
    # bias-corrected variant (step may be a traced scalar — see optim.soap)
    want_bc = sr_ref.soap_rotated_update(g, ql, qr_, mm, vv,
                                         step=jnp.int32(2))
    got_bc = sr_ops.soap_rotated_update(g, ql, qr_, mm, vv,
                                        step=jnp.int32(2), use_pallas=True,
                                        interpret=True)
    for w, o in zip(want_bc, got_bc):
        assert jnp.max(jnp.abs(w - o)) < 5e-5
    assert jnp.max(jnp.abs(want_bc[0] - want[0])) > 1e-3  # correction bites


@pytest.mark.parametrize("shape", [(17,), (128,), (64, 64), (3, 40, 50),
                                   (4096,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_qblock_kernel_matches_ref(shape, dtype):
    x = 3.0 * jax.random.normal(KEY, shape, dtype)
    q_ref, s_ref = qb_ref.quantize(x, block=128)
    q_pal, s_pal = qb_ops.quantize(x, block=128, use_pallas=True,
                                   interpret=True)
    assert q_pal.dtype == jnp.int8 and q_ref.shape == q_pal.shape
    assert jnp.array_equal(q_ref, q_pal)
    assert jnp.max(jnp.abs(s_ref - s_pal)) < 1e-7
    # dequantized error bounded by half a step per block
    x_hat = qb_ref.dequantize(q_pal, s_pal, x.shape)
    err = jnp.abs(x_hat - x.astype(jnp.float32)).reshape(-1)
    bound = jnp.repeat(s_pal / 2, 128)[: err.size]
    assert bool(jnp.all(err <= bound + 1e-6))


def test_qblock_kernel_rejects_bad_block():
    with pytest.raises(ValueError, match="multiple of 128"):
        qb_ops.quantize(jnp.ones((8,)), block=100, use_pallas=True,
                        interpret=True)


@pytest.mark.parametrize("shape", [(40,), (128, 256)])
def test_adam_moments_kernel(shape):
    ks = jax.random.split(KEY, 3)
    g = jax.random.normal(ks[0], shape)
    m = jax.random.normal(ks[1], shape)
    v = jax.random.uniform(ks[2], shape)
    n, m2, v2 = adam_moments(g, m, v, b1=0.9, b2=0.99, interpret=True)
    m_want = 0.9 * m + 0.1 * g
    v_want = 0.99 * v + 0.01 * g * g
    assert jnp.allclose(m2, m_want, atol=1e-6)
    assert jnp.allclose(v2, v_want, atol=1e-6)
    assert jnp.allclose(n, m_want / (jnp.sqrt(v_want) + 1e-8), atol=1e-5)
