"""Observability subsystem: jit-pure telemetry (incl. the sync ==
zero-staleness-async bitwise parity), tracer schema + checkpoint
continuity, sinks, async drop events, kernel profiling hooks, and the
BENCH_*.json document format."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import init_server, make_round_fn, zero_theta
from repro.core.client import LocalRunConfig, client_round
from repro.core.engine import fixed_controller
from repro.checkpoint import CheckpointManager
from repro.fed import (
    AsyncConfig, AsyncFederatedExperiment, FedConfig, LatencyModel,
)
from repro.fed.async_runtime.buffer import make_async_aggregate_fn
from repro.obs import (
    JsonlSink, MemorySink, STALENESS_BINS, StdoutRoundSink, Telemetry,
    Tracer, attach, client_geom_dist, make_bench, staleness_histogram,
    telemetry_dict, validate_bench, validate_event, validate_jsonl,
    write_bench,
)

S, K, D, OUT = 4, 3, 16, 8
KEY = jax.random.key(0)


def _problem():
    W = jax.random.normal(KEY, (D, OUT))
    params = {"w": jnp.zeros((D, OUT))}

    def loss_fn(p, b):
        X, Y = b
        return jnp.mean((X @ p["w"] - Y) ** 2)

    def batches(key):
        X = jax.random.normal(key, (S, K, 8, D))
        return X, X @ W

    return params, loss_fn, batches


def _tele_leaves(t: Telemetry):
    return jax.tree.flatten(t)[0]


# ------------------------------------------------------------- telemetry

def test_telemetry_is_a_jit_pure_pytree():
    t = Telemetry(*(jnp.float32(i) for i in range(7)),
                  client_geom_dist=jnp.arange(S, dtype=jnp.float32),
                  staleness_hist=jnp.zeros(STALENESS_BINS, jnp.int32))
    out = jax.jit(lambda x: x)(t)
    assert isinstance(out, Telemetry)
    for a, b in zip(_tele_leaves(t), _tele_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_round_fn_telemetry_has_no_host_callbacks():
    """The instrumented round must stay a single pure XLA program."""
    params, loss_fn, batches = _problem()
    opt = optim.make("soap")
    rf = make_round_fn(loss_fn, opt, lr=0.05, local_steps=K, beta=0.5,
                       jit=False, telemetry=True)
    server = init_server(params, opt)
    jaxpr = jax.make_jaxpr(
        lambda b, r: rf(server, b, r)[1]["telemetry"])(
            batches(jax.random.key(1)), jax.random.key(2))
    assert "callback" not in str(jaxpr)


def test_sync_round_emits_telemetry():
    params, loss_fn, batches = _problem()
    opt = optim.make("soap")
    rf = make_round_fn(loss_fn, opt, lr=0.05, local_steps=K, beta=0.5,
                       telemetry=True)
    _, metrics = rf(init_server(params, opt), batches(jax.random.key(1)),
                    jax.random.key(2))
    t = metrics["telemetry"]
    assert isinstance(t, Telemetry)
    assert float(t.drift) > 0.0
    assert float(t.beta) == pytest.approx(0.5)
    assert t.client_geom_dist.shape == (S,)
    # synchronous cohort: every client has staleness 0
    np.testing.assert_array_equal(
        np.asarray(t.staleness_hist),
        np.asarray([S] + [0] * (STALENESS_BINS - 1)))
    # host view is JSON-clean
    d = telemetry_dict(t)
    json.dumps(d)
    assert set(d) == {"drift", "norm_drift", "freshness", "beta",
                      "beta_next", "drift_ema", "update_corr_cos",
                      "client_geom_dist", "staleness_hist"}


def test_zero_staleness_async_telemetry_bitwise_matches_sync():
    """The telemetry of a w_i = 1 flush must equal the sync round's
    bitwise — same collect, same arrays (the engine parity contract of
    tests/test_engine.py extended to the diagnostics)."""
    params, loss_fn, batches = _problem()
    opt = optim.make("soap")
    lr, beta = 0.05, 0.5
    b = batches(jax.random.key(1))
    rng = jax.random.key(2)

    rf = make_round_fn(loss_fn, opt, lr=lr, local_steps=K, beta=beta,
                       jit=False, telemetry=True)
    server = init_server(params, opt)
    _, sync_metrics = rf(server, b, rng)
    sync_t = sync_metrics["telemetry"]

    theta0 = zero_theta(opt, params)
    run = LocalRunConfig(lr=lr, local_steps=K, beta=0.0, align=True)
    keys = jax.random.split(rng, S)
    deltas, thetas, _ = jax.vmap(
        lambda bi, ki: client_round(loss_fn, opt, run, params, theta0,
                                    server.g_global, bi, ki,
                                    beta=jnp.float32(beta)))(b, keys)
    flush = make_async_aggregate_fn(lr=lr, local_steps=K, jit=False,
                                    telemetry=True)
    *_, metrics = flush(params, theta0, server.g_global,
                        fixed_controller(beta), deltas, thetas,
                        jnp.ones(S, jnp.float32))
    async_t = metrics["telemetry"]

    for a, c in zip(_tele_leaves(sync_t), _tele_leaves(async_t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_staleness_histogram():
    h = staleness_histogram(jnp.asarray([0, 0, 1, 3, 99]))
    assert h.dtype == jnp.int32
    np.testing.assert_array_equal(
        np.asarray(h), [2, 1, 0, 1, 0, 0, 0, 1])  # 99 clips into last bin
    assert int(h.sum()) == 5


def test_client_geom_dist():
    # no geometry (first-order algorithms): zeros, right shape
    np.testing.assert_array_equal(np.asarray(client_geom_dist(None, 3)),
                                  np.zeros(3))
    # narrow leaves are exact: squared distance to the cohort mean
    thetas = {"a": jnp.asarray([[1.0, 0.0], [0.0, 1.0], [2.0, 2.0]])}
    d = client_geom_dist(thetas, 3)
    mean = np.asarray([1.0, 1.0])
    expect = [np.sum((r - mean) ** 2)
              for r in np.asarray(thetas["a"])]
    np.testing.assert_allclose(np.asarray(d), expect, rtol=1e-6)
    # wide leaves go through the fixed JL sketch: deterministic
    wide = {"a": jax.random.normal(jax.random.key(3), (4, 64))}
    np.testing.assert_array_equal(np.asarray(client_geom_dist(wide, 4)),
                                  np.asarray(client_geom_dist(wide, 4)))


# ---------------------------------------------------------------- tracer

def test_tracer_jsonl_schema(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    t = Tracer(sinks=(JsonlSink(path),))
    t.emit("run_start", runtime="sync")
    with t.span("staging", round=1):
        pass
    t.client_dropped(3, reason="dropout", version=0, sim_time=1.5)
    t.round_event(1, {"loss": 0.5}, telemetry={"drift": 0.1})
    t.sinks[0].close()
    assert validate_jsonl(path) == 4
    lines = [json.loads(x) for x in open(path)]
    assert [e["event"] for e in lines] == ["run_start", "span",
                                          "client_dropped", "round"]
    assert [e["seq"] for e in lines] == [0, 1, 2, 3]
    assert len({e["run_id"] for e in lines}) == 1
    assert lines[1]["phase"] == "staging" and lines[1]["dur_s"] >= 0.0
    assert lines[3]["telemetry"] == {"drift": 0.1}


def test_validate_event_rejects_malformed():
    with pytest.raises(ValueError, match="missing"):
        validate_event({"event": "round", "run_id": "x", "seq": 0})
    with pytest.raises(ValueError, match="unknown trace event"):
        validate_event({"event": "bogus", "run_id": "x", "seq": 0})
    with pytest.raises(ValueError, match="drop reason"):
        validate_event({"event": "client_dropped", "run_id": "x", "seq": 0,
                        "client_id": 1, "reason": "rage_quit", "version": 0})
    with pytest.raises(ValueError, match="empty trace"):
        import tempfile
        with tempfile.NamedTemporaryFile(suffix=".jsonl") as f:
            validate_jsonl(f.name)


def test_tracer_counts_when_disabled_and_state_roundtrips():
    t = Tracer()   # no sinks: counters still advance for checkpoints
    assert not t.enabled
    with t.span("update"):
        pass
    t.round_event(1, {"loss": 1.0})
    t.client_dropped(0, reason="dropout", version=0)  # no-op, no raise
    assert t.spans == 1 and t.rounds == 1 and t.seq == 0
    sink = MemorySink()
    t2 = Tracer.from_state(t.state(), sinks=(sink,))
    assert t2.run_id == t.run_id
    assert (t2.rounds, t2.spans, t2.seq) == (1, 1, 0)
    t2.round_event(2, {"loss": 0.9})
    assert sink.rounds()[0]["round"] == 2
    # empty state -> fresh identity
    assert Tracer.from_state(None).run_id != t.run_id


def test_checkpoint_persists_trace_identity(tmp_path):
    params = {"w": jnp.zeros((4, 4))}
    server = init_server(params, optim.make("sgd"))
    t = Tracer(sinks=(MemorySink(),))
    with t.span("update", round=1):
        pass
    t.round_event(1, {"loss": 1.0})
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(server, telemetry=t.state())
    meta = mgr.restore_meta()
    restored = Tracer.from_state(meta["telemetry"], sinks=(MemorySink(),))
    assert restored.run_id == t.run_id
    assert restored.seq == t.seq and restored.rounds == 1
    # legacy checkpoints (no telemetry key) restore a fresh tracer
    assert Tracer.from_state(meta.get("missing")).seq == 0


# ----------------------------------------------------------------- sinks

def test_stdout_sink_is_bitwise_legacy_log_round(capsys):
    rec = {"loss": 0.123456789, "round": 3, "note": None,
           "vec": [1.0, 2.0]}
    StdoutRoundSink().emit({"event": "round", "run_id": "x", "round": 3,
                            "metrics": rec})
    got = capsys.readouterr().out
    legacy = {}
    for k, v in rec.items():   # the pre-sink formatting, verbatim
        try:
            legacy[k] = round(v, 4)
        except TypeError:
            legacy[k] = v
    assert got == f"{legacy}\n"
    StdoutRoundSink().emit({"event": "span", "phase": "eval"})
    assert capsys.readouterr().out == ""


def test_experiment_log_round_routes_through_sink(capsys):
    params, loss_fn, batches = _problem()

    def batch_fn(cid, rng):
        X = jax.random.normal(jax.random.key(cid), (8, D))
        return (X, X @ jax.random.normal(KEY, (D, OUT)))

    fed = FedConfig(algorithm="fedpac_soap", n_clients=4, participation=1.0,
                    rounds=1, local_steps=2)
    from repro.fed import FederatedExperiment
    exp = FederatedExperiment(fed, params, loss_fn, batch_fn)
    rec = exp.run_round()
    capsys.readouterr()
    exp.log_round(rec, 0)
    assert capsys.readouterr().out == \
        f"{ {k: exp.format_metric(v) for k, v in rec.items()} }\n"
    # swapping the sink redirects the same hook
    exp.sink = MemorySink()
    exp.log_round(rec, 0)
    assert exp.sink.rounds()[0]["metrics"] is not None
    assert capsys.readouterr().out == ""


def test_csv_sink_round_rows(tmp_path):
    from repro.obs import CsvSink
    path = str(tmp_path / "rounds.csv")
    with CsvSink(path) as sink:
        sink.emit({"event": "round", "round": 1,
                   "metrics": {"loss": 0.5},
                   "telemetry": {"drift": 0.1,
                                 "staleness_hist": [4, 0]}})
        sink.emit({"event": "span", "phase": "eval"})   # skipped
        sink.emit({"event": "round", "round": 2,
                   "metrics": {"loss": 0.4},
                   "telemetry": {"drift": 0.2,
                                 "staleness_hist": [4, 0]}})
    lines = open(path).read().strip().split("\n")
    assert lines[0] == "round,loss,drift"   # vectors are not columns
    assert lines[1].startswith("1,0.5") and lines[2].startswith("2,0.4")


# --------------------------------------------------- end-to-end (runtimes)

N_CLIENTS = 6


@pytest.fixture(scope="module")
def vision_problem():
    from repro.data import dirichlet_partition, make_image_classification
    from repro.models.vision import classification_loss, cnn_apply, init_cnn
    X, y = make_image_classification(600, image_size=8, n_classes=4, seed=0,
                                     noise=1.0)
    parts = dirichlet_partition(y, N_CLIENTS, 0.2, seed=0)
    params = init_cnn(jax.random.key(0), n_classes=4, width=4, blocks=1)

    def loss_fn(p, batch):
        return classification_loss(cnn_apply(p, batch["x"]), batch["y"])

    def batch_fn(cid, rng):
        idx = rng.choice(parts[cid], size=4)
        return {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}

    return params, loss_fn, batch_fn


def _run_traced(vision_problem, seed=0):
    from repro.fed import FederatedExperiment
    params, loss_fn, batch_fn = vision_problem
    fed = FedConfig(algorithm="fedpac_soap", n_clients=N_CLIENTS,
                    participation=0.5, rounds=2, local_steps=2, seed=seed)
    exp = FederatedExperiment(fed, params, loss_fn, batch_fn)
    sink = MemorySink()
    attach(exp, sink)
    exp.run()
    return exp, sink


def test_sync_trace_golden_round(vision_problem):
    """One seeded CNN round: the trace carries schema-valid spans + a
    round event with the full telemetry, deterministically."""
    exp, sink = _run_traced(vision_problem)
    for ev in sink.events:
        validate_event(ev)
    phases = [e["phase"] for e in sink.events if e["event"] == "span"]
    assert phases == ["staging", "update", "staging", "update"]
    rounds = sink.rounds()
    assert [e["round"] for e in rounds] == [1, 2]
    tele = rounds[0]["telemetry"]
    assert tele["drift"] > 0.0 and tele["beta"] == pytest.approx(0.5)
    assert len(tele["client_geom_dist"]) == 3      # S = 6 * 0.5
    assert sum(tele["staleness_hist"]) == 3
    assert exp.last_telemetry is not None
    assert rounds[0]["metrics"]["loss"] == exp.history[0]["loss"]
    # same seed -> identical telemetry stream (golden determinism)
    _, sink2 = _run_traced(vision_problem)
    assert [e["telemetry"] for e in sink2.rounds()] == \
        [e["telemetry"] for e in rounds]


def test_async_trace_spans_drops_and_staleness(vision_problem):
    params, loss_fn, batch_fn = vision_problem
    fed = FedConfig(algorithm="fedpac_soap", n_clients=N_CLIENTS,
                    participation=1.0, rounds=3, local_steps=2, seed=0,
                    runtime="async")
    acfg = AsyncConfig(buffer_size=2, concurrency=4,
                       latency=LatencyModel(heterogeneity=1.0, jitter=0.5,
                                            dropout=0.3))
    exp = AsyncFederatedExperiment(fed, params, loss_fn, batch_fn,
                                   async_cfg=acfg)
    sink = MemorySink()
    attach(exp, sink)
    exp.run()
    for ev in sink.events:
        validate_event(ev)
    drops = [e for e in sink.events if e["event"] == "client_dropped"]
    # every silent counter bump is now an explicit trace event
    assert len(drops) == exp.total_dropped + exp.total_discarded
    for e in drops:
        assert e["reason"] in ("dropout", "max_staleness")
        assert "sim_time" in e
    phases = {e["phase"] for e in sink.events if e["event"] == "span"}
    assert {"staging", "local_update", "flush"} <= phases
    rounds = sink.rounds()
    assert len(rounds) == 3 and all("sim_time" in e for e in rounds)
    hist = rounds[-1]["telemetry"]["staleness_hist"]
    assert sum(hist) == acfg.buffer_size   # buffer's staleness, binned


# ------------------------------------------------------ kernel profiling

def test_profile_kernels_smoke():
    from repro.obs.profiling import profile_kernels
    recs = profile_kernels(shapes=((128, 128),), iters=1,
                           kernels=("qblock", "sophia_update"))
    assert len(recs) == 4   # 2 kernels x {ref, pallas}
    for r in recs:
        assert r["kind"] == "kernel"
        assert r["kernel"] in ("qblock", "sophia_update")
        assert r["impl"] in ("ref", "pallas")
        assert r["us_per_call"] > 0.0
        assert r["gflops_s"] > 0.0 and r["gbps"] > 0.0
        assert r["shape"] == [128, 128]
    with pytest.raises(ValueError, match="unknown kernels"):
        profile_kernels(kernels=("bogus",))


# ------------------------------------------------------------ BENCH docs

def test_bench_write_read_roundtrip(tmp_path):
    rows = [{"name": "exec_vmap_S4", "us_per_call": 12.5,
             "derived": {"loss": 0.9, "backend": "vmap"}},
            {"name": "exec_agree_S4", "us_per_call": 0.0,
             "derived": {"max_dev": 0.0}}]
    path = str(tmp_path / "BENCH_executor.json")
    doc = write_bench(path, "executor", rows, config={"quick": True})
    validate_bench(doc)
    from repro.obs import read_bench
    got = read_bench(path)
    assert got["bench"] == "executor" and got["config"] == {"quick": True}
    assert got["rows"] == rows


@pytest.mark.parametrize("mutate, match", [
    (lambda d: d.pop("rows"), "missing"),
    (lambda d: d.update(schema_version=99), "schema_version"),
    (lambda d: d.update(rows=[]), "non-empty"),
    (lambda d: d["rows"].append(dict(d["rows"][0])), "duplicate"),
    (lambda d: d["rows"][0].update(us_per_call="fast"), "numeric"),
    (lambda d: d["rows"][0]["derived"].update(bad=[1, 2]), "scalar"),
])
def test_bench_validation_rejects(mutate, match):
    doc = make_bench("executor",
                     [{"name": "a", "us_per_call": 1.0,
                       "derived": {"x": 1}}])
    mutate(doc)
    with pytest.raises(ValueError, match=match):
        validate_bench(doc)
