"""SVD codec path: round-trip on stacked Theta pytrees and the Table-6
communication accounting for *_light algorithms."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_svd_codec, round_comm_bytes, svd_truncate

S, M, N, RANK = 3, 16, 12, 4


def _stacked_theta(seed=0):
    k1, k2 = jax.random.split(jax.random.key(seed))
    return {"L": jax.random.normal(k1, (S, M, M)),
            "R": jax.random.normal(k2, (S, N, N)),
            "diag": jnp.ones((S, M))}


def test_svd_codec_roundtrip_shapes_and_rank():
    theta = _stacked_theta()
    out = make_svd_codec(RANK)(theta)
    # decoded reconstruction keeps every original shape/dtype
    assert jax.tree.map(lambda x: (x.shape, x.dtype), out) == \
        jax.tree.map(lambda x: (x.shape, x.dtype), theta)
    for key in ("L", "R"):
        for i in range(S):
            assert np.linalg.matrix_rank(np.asarray(out[key][i]),
                                         tol=1e-4) <= RANK
    # sub-rank leaves pass through untouched
    np.testing.assert_array_equal(out["diag"], theta["diag"])


def test_svd_truncate_error_shrinks_with_rank():
    mat = jax.random.normal(jax.random.key(1), (M, M))
    err = [float(jnp.linalg.norm(mat - svd_truncate(mat, r)))
           for r in (2, 8, M)]
    assert err[0] > err[1] > err[2]
    assert err[2] < 1e-3  # full rank reconstructs


def test_round_comm_bytes_shrinks_for_light():
    params = {"w": jnp.zeros((32, 24))}
    theta = {"L": jnp.zeros((32, 32)), "R": jnp.zeros((24, 24))}
    plain = round_comm_bytes(params, None)                    # local_*
    light = round_comm_bytes(params, theta, compressed_rank=RANK)
    full = round_comm_bytes(params, theta)                    # fedpac_*
    assert plain < light < full
