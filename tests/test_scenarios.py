"""Scenario API: registry semantics, golden bitwise equivalence with the
legacy hand-rolled problem, catalog smoke through both runtimes, the new
partitioners, and the data-layer validation satellites."""
import dataclasses
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import (
    AsyncConfig, FedConfig, build_experiment, resolve_scenario,
)
from repro.data import (
    dirichlet_partition, iid_partition, lm_batches, make_image_classification,
    make_lm_corpus, make_lm_topic_corpus, quantity_partition, shard_partition,
)
from repro.fed import FedExperiment, FederatedExperiment
from repro.fed.async_runtime import AsyncFederatedExperiment
from repro.models.vision import (
    accuracy, classification_loss, cnn_apply, init_cnn,
)
from repro import scenarios
from repro.scenarios import (
    DuplicateScenarioError, PartitionSpec, Scenario, ScenarioSpec,
    UnknownScenarioError, cifar_like, materialize,
)

# ------------------------------------------------------------------ registry


def test_unknown_scenario_raises():
    with pytest.raises(UnknownScenarioError) as ei:
        scenarios.get("no_such_task")
    assert "cifar_like_cnn" in str(ei.value)  # names the registered ones
    with pytest.raises(UnknownScenarioError):
        build_experiment("fedavg", scenario="no_such_task")


def test_duplicate_scenario_rejected():
    spec = ScenarioSpec(name="dup_test_scenario", source="synth_image")
    scenarios.register(spec)
    try:
        with pytest.raises(DuplicateScenarioError):
            scenarios.register(spec)
        scenarios.register(dataclasses.replace(spec, batch_size=8),
                           overwrite=True)
        assert scenarios.get("dup_test_scenario").batch_size == 8
    finally:
        scenarios.registry._REGISTRY.pop("dup_test_scenario", None)


def test_register_rejects_unknown_source_and_type():
    with pytest.raises(ValueError, match="unknown source"):
        scenarios.register(ScenarioSpec(name="bad_src", source="nope"))
    with pytest.raises(TypeError):
        scenarios.register("cifar_like_cnn")


def test_duplicate_source_rejected():
    with pytest.raises(DuplicateScenarioError):
        scenarios.register_source("synth_image", lambda *a: None)


def test_catalog_families_registered():
    names = scenarios.registered()
    for base in ("cifar_like_cnn", "cifar_like_vit", "lm_zipf"):
        for v in ("", "_dir0.05", "_shard", "_iid"):
            assert base + v in names


def test_resolve_passes_specs_through():
    spec = ScenarioSpec(name="inline", source="synth_image")
    assert resolve_scenario(spec) is spec
    assert resolve_scenario("cifar_like_cnn").name == "cifar_like_cnn"


def test_specs_are_frozen():
    spec = resolve_scenario("cifar_like_cnn")
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.n_clients = 99
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.partition.alpha = 0.7


def test_partition_spec_validation():
    with pytest.raises(ValueError, match="unknown partition kind"):
        PartitionSpec("banana")
    with pytest.raises(ValueError, match="alpha"):
        PartitionSpec("dirichlet", alpha=0.0)
    with pytest.raises(ValueError, match="shards_per_client"):
        PartitionSpec("shard", shards_per_client=0)


def test_materialize_rejects_unknown_source_kwargs():
    spec = dataclasses.replace(
        resolve_scenario("cifar_like_cnn"),
        source_kwargs={"n_samples": 100})  # typo for "n"
    with pytest.raises(ValueError, match="unknown source_kwargs"):
        materialize(spec)
    spec = dataclasses.replace(resolve_scenario("lm_zipf"),
                               source_kwargs={"vocabulary": 64})
    with pytest.raises(ValueError, match="unknown source_kwargs"):
        materialize(spec)


def test_build_experiment_accepts_materialized_bundle():
    spec = _ci_sized(resolve_scenario("cifar_like_cnn"))
    bundle = materialize(spec, seed=5, n_clients=4)
    exp = build_experiment("fedavg", scenario=bundle, rounds=1,
                           scenario_seed=5)
    assert exp.scenario is bundle and exp.fed.n_clients == 4
    with pytest.raises(ValueError, match="n_clients"):
        build_experiment("fedavg", scenario=bundle, n_clients=7)
    with pytest.raises(ValueError, match="seed"):
        build_experiment("fedavg", scenario=bundle, scenario_seed=6)


def test_materialize_rejects_bad_source_results():
    bad = ScenarioSpec(name="bad", source=lambda spec, seed, n: "nope")
    with pytest.raises(TypeError, match="must return"):
        materialize(bad)


def test_materialize_rejects_nonpositive_n_clients():
    with pytest.raises(ValueError, match="n_clients"):
        materialize("cifar_like_cnn", n_clients=0)


# ---------------------------------------------------- golden legacy problem


def _legacy_fed_vision_problem(*, model="cnn", n=3000, image_size=12,
                               n_classes=8, n_clients=10, alpha=0.1, seed=0,
                               batch=16, noise=2.5):
    """Frozen copy of the pre-scenario ``make_fed_vision_problem`` wiring
    (benchmarks/common.py before the registry existed) — the golden
    reference the registered ``cifar_like_cnn`` entry must reproduce
    bitwise.  Returns the partition too for exact comparison."""
    n_test = 768
    X_all, y_all = make_image_classification(n + n_test,
                                             image_size=image_size,
                                             n_classes=n_classes, seed=seed,
                                             noise=noise)
    X, y = X_all[:n], y_all[:n]
    Xe, ye = jnp.asarray(X_all[n:]), jnp.asarray(y_all[n:])
    if alpha is None:  # IID
        rng = np.random.default_rng(seed)
        idx = rng.permutation(n)
        parts = np.array_split(idx, n_clients)
    else:
        parts = dirichlet_partition(y, n_clients, alpha, seed=seed)
    params = init_cnn(jax.random.key(seed), n_classes=n_classes, width=8,
                      blocks=2)

    def loss_fn(p, b):
        return classification_loss(cnn_apply(p, b["x"]), b["y"])

    @jax.jit
    def eval_logits(p):
        return cnn_apply(p, Xe)

    def eval_fn(p):
        logits = eval_logits(p)
        return {"test_acc": accuracy(logits, ye),
                "test_loss": classification_loss(logits, ye)}

    def batch_fn(cid, rng):
        idx = rng.choice(parts[cid], size=batch, replace=True)
        return {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}

    return params, loss_fn, batch_fn, eval_fn, parts


GOLDEN_KW = dict(n=900, image_size=8, n_classes=4, n_clients=6, seed=0)


def _golden_pair():
    legacy = _legacy_fed_vision_problem(**GOLDEN_KW)
    spec = cifar_like(model="cnn", n=GOLDEN_KW["n"],
                      image_size=GOLDEN_KW["image_size"],
                      n_classes=GOLDEN_KW["n_classes"],
                      n_eval=768, alpha=0.1)
    scn = materialize(spec, seed=GOLDEN_KW["seed"],
                      n_clients=GOLDEN_KW["n_clients"])
    return legacy, scn


def test_golden_params_and_partition_bitwise():
    (params_l, _, _, _, parts_l), scn = _golden_pair()
    for a, b in zip(jax.tree.leaves(params_l), jax.tree.leaves(scn.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert len(parts_l) == len(scn.partitions)
    for a, b in zip(parts_l, scn.partitions):
        assert np.array_equal(a, b)


def test_golden_iid_partition_matches_legacy_convention():
    legacy = _legacy_fed_vision_problem(alpha=None, **GOLDEN_KW)
    spec = cifar_like(model="cnn", n=GOLDEN_KW["n"],
                      image_size=GOLDEN_KW["image_size"],
                      n_classes=GOLDEN_KW["n_classes"], alpha=None)
    scn = materialize(spec, seed=0, n_clients=GOLDEN_KW["n_clients"])
    assert spec.partition.kind == "iid"
    for a, b in zip(legacy[4], scn.partitions):
        assert np.array_equal(a, b)


def test_golden_first_round_metrics_sync():
    (params, loss_fn, batch_fn, eval_fn, _), scn = _golden_pair()
    fed = FedConfig(algorithm="fedpac_soap", n_clients=6, participation=0.5,
                    rounds=1, local_steps=2, seed=0)
    exp_legacy = FederatedExperiment(fed, params, loss_fn, batch_fn, eval_fn)
    exp_scn = build_experiment("fedpac_soap", scenario=scn.spec,
                               scenario_seed=0, fed=fed)
    rec_l, rec_s = exp_legacy.run_round(), exp_scn.run_round()
    assert rec_l.keys() == rec_s.keys()
    for k in rec_l:
        assert rec_l[k] == rec_s[k], k


def test_golden_first_round_metrics_async():
    (params, loss_fn, batch_fn, eval_fn, _), scn = _golden_pair()
    fed = FedConfig(algorithm="fedpac_soap", n_clients=6, participation=0.5,
                    rounds=1, local_steps=2, seed=0, runtime="async")
    acfg = AsyncConfig(buffer_size=2)
    exp_legacy = AsyncFederatedExperiment(fed, params, loss_fn, batch_fn,
                                          eval_fn, async_cfg=acfg)
    exp_scn = build_experiment("fedpac_soap", scenario=scn.spec,
                               scenario_seed=0, fed=fed,
                               async_cfg=AsyncConfig(buffer_size=2))
    rec_l, rec_s = exp_legacy.run_round(), exp_scn.run_round()
    assert rec_l.keys() == rec_s.keys()
    for k in rec_l:
        assert rec_l[k] == rec_s[k], k


def test_legacy_adapter_is_the_scenario_path():
    """benchmarks.common.make_fed_vision_problem is a thin scenario adapter."""
    from benchmarks.common import make_fed_vision_problem
    params_a, _, batch_a, _ = make_fed_vision_problem(**GOLDEN_KW)
    (params_l, _, batch_l, _, _) = _legacy_fed_vision_problem(**GOLDEN_KW)
    for a, b in zip(jax.tree.leaves(params_a), jax.tree.leaves(params_l)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    ba = batch_a(0, np.random.default_rng(3))
    bl = batch_l(0, np.random.default_rng(3))
    assert np.array_equal(np.asarray(ba["x"]), np.asarray(bl["x"]))
    assert np.array_equal(np.asarray(ba["y"]), np.asarray(bl["y"]))


# ------------------------------------------------------------- catalog smoke


def _ci_sized(spec: ScenarioSpec) -> ScenarioSpec:
    """Same scenario, CI-sized data/model (matches scenario_matrix quick)."""
    if spec.source == "synth_image":
        return dataclasses.replace(
            spec, n_clients=6,
            source_kwargs=dict(spec.source_kwargs, n=420, n_eval=64))
    return dataclasses.replace(
        spec, n_clients=4,
        source_kwargs=dict(spec.source_kwargs, n_docs=48, tokens_per_doc=80,
                           n_topics=8, n_eval_docs=2, vocab=64, seq_len=16,
                           eval_batch=4),
        model_kwargs=dict(spec.model_kwargs, layers=1, d_model=32))


@pytest.mark.parametrize("name", scenarios.registered())
def test_catalog_entry_smoke_sync_and_async(name):
    spec = _ci_sized(resolve_scenario(name))
    exp = build_experiment("fedpac_soap", scenario=spec, rounds=1,
                           local_steps=1, participation=0.5)
    rec = exp.run()[-1]
    assert np.isfinite(rec["loss"])
    assert exp.scenario.partition_stats["n_clients"] == spec.n_clients
    exp = build_experiment("local_soap", scenario=spec,
                           async_cfg=AsyncConfig(buffer_size=2), rounds=1,
                           local_steps=1, participation=0.5)
    rec = exp.run()[-1]
    assert np.isfinite(rec["loss"])


# -------------------------------------------------------- builder semantics


def test_build_experiment_scenario_conflicts():
    with pytest.raises(ValueError, match="not both"):
        build_experiment("fedavg", scenario="cifar_like_cnn",
                         params={"w": jnp.zeros(2)})
    with pytest.raises(ValueError, match="scenario_seed"):
        build_experiment("fedavg", scenario_seed=3,
                         params={"w": jnp.zeros(2)},
                         loss_fn=lambda p, b: 0.0,
                         client_batch_fn=lambda c, r: {})
    with pytest.raises(TypeError, match="needs either"):
        build_experiment("fedavg")


def test_build_experiment_n_clients_resolution():
    spec = _ci_sized(resolve_scenario("cifar_like_cnn"))  # n_clients=6
    exp = build_experiment("fedavg", scenario=spec, rounds=1)
    assert exp.fed.n_clients == 6
    assert len(exp.scenario.partitions) == 6
    exp = build_experiment("fedavg", scenario=spec, rounds=1, n_clients=3)
    assert exp.fed.n_clients == 3
    assert len(exp.scenario.partitions) == 3


def test_unregistered_scenario_spec_usable_directly():
    def toy_source(spec, seed, n_clients):
        rng = np.random.default_rng(seed)
        X = rng.normal(size=(64, 4)).astype(np.float32)
        w = rng.normal(size=(4, 1)).astype(np.float32)

        def loss_fn(p, b):
            return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)

        def batch_fn(cid, rng_):
            idx = rng_.integers(0, 64, 8)
            return {"x": X[idx], "y": X[idx] @ w}

        return Scenario(spec=spec, seed=seed, n_clients=n_clients,
                        params={"w": jnp.zeros((4, 1))}, loss_fn=loss_fn,
                        client_batch_fn=batch_fn, eval_fn=None)

    spec = ScenarioSpec(name="toy_linear", source=toy_source, n_clients=4)
    assert "toy_linear" not in scenarios.registered()
    exp = build_experiment("fedavg", scenario=spec, rounds=2, local_steps=2,
                           participation=1.0)
    hist = exp.run()
    assert len(hist) == 2 and np.isfinite(hist[-1]["loss"])


# ------------------------------------------------------------- partitioners


def test_iid_partition_matches_legacy_formula():
    rng = np.random.default_rng(5)
    want = np.array_split(rng.permutation(103), 7)
    got = iid_partition(103, 7, seed=5)
    for a, b in zip(want, got):
        assert np.array_equal(a, b)


def _cover(parts, n):
    allidx = np.concatenate(parts)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n


def test_shard_partition_limits_labels_per_client():
    labels = np.repeat(np.arange(10), 30)
    parts = shard_partition(labels, n_clients=10, shards_per_client=2,
                            seed=0)
    _cover(parts, 300)
    for p in parts:
        # 2 shards -> at most 3 distinct labels (shard may straddle a class)
        assert len(np.unique(labels[p])) <= 3
    with pytest.raises(ValueError, match="infeasible"):
        shard_partition(np.zeros(5, int), n_clients=3, shards_per_client=2)


def test_quantity_partition_skews_sizes():
    parts = quantity_partition(400, 8, alpha=0.3, seed=1, min_size=5)
    _cover(parts, 400)
    sizes = [len(p) for p in parts]
    assert min(sizes) >= 5
    assert max(sizes) > 2 * min(sizes)  # visibly skewed at alpha=0.3
    with pytest.raises(ValueError, match="infeasible"):
        quantity_partition(10, 4, min_size=5)


def test_dirichlet_partition_infeasible_raises():
    with pytest.raises(ValueError, match="infeasible"):
        dirichlet_partition(np.zeros(10, int), n_clients=4, alpha=0.1,
                            min_size=5)


def test_dirichlet_partition_bounded_retries():
    # one class, 12 samples, 4 clients, min_size=3: proportional cuts at a
    # tiny alpha essentially never give every client 3 -> must raise (with
    # the resolved alpha in the message), not spin forever
    labels = np.zeros(12, int)
    with pytest.raises(ValueError, match="alpha softened"):
        dirichlet_partition(labels, n_clients=4, alpha=1e-6, min_size=3,
                            max_retries=5)


def test_dirichlet_partition_softening_warns_and_recovers():
    labels = np.arange(64) % 8  # the lm_zipf document/topic shape
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        parts = dirichlet_partition(labels, n_clients=8, alpha=0.1, seed=0,
                                    min_size=2)
    _cover(parts, 64)
    assert min(len(p) for p in parts) >= 2
    msgs = [str(x.message) for x in w
            if issubclass(x.category, RuntimeWarning)]
    assert any("effective alpha" in m for m in msgs)


# ------------------------------------------------------- synth validation


def test_lm_batches_rejects_short_stream():
    with pytest.raises(ValueError, match="longer than seq_len"):
        lm_batches(np.arange(10), seq_len=16, batch=2, steps=1)
    with pytest.raises(ValueError, match=">= 1"):
        lm_batches(np.arange(100), seq_len=0, batch=2, steps=1)


def test_make_lm_corpus_rejects_bad_hetero():
    for h in (-0.1, 1.5):
        with pytest.raises(ValueError, match="hetero"):
            make_lm_corpus(2, 100, hetero=h)


def test_make_lm_topic_corpus_shapes_and_validation():
    docs, topics = make_lm_topic_corpus(12, 50, vocab=32, n_topics=4, seed=0)
    assert docs.shape == (12, 50) and topics.shape == (12,)
    assert docs.min() >= 0 and docs.max() < 32
    assert topics.min() >= 0 and topics.max() < 4
    with pytest.raises(ValueError, match="vocab"):
        make_lm_topic_corpus(4, 10, vocab=1)
    with pytest.raises(ValueError, match="n_docs"):
        make_lm_topic_corpus(0, 10)


# --------------------------------------------------------------- log_round


class _Recorder(FedExperiment):
    def __init__(self):
        super().__init__(type("Cfg", (), {"rounds": 3})())
        self.logged = []

    def run_round(self):
        rec = {"loss": 0.123456, "round": 2, "eval": None, "note": "skip",
               "arr": np.zeros(2)}
        self.history.append(rec)
        return rec

    def comm_bytes_per_round(self):
        return 0

    def log_round(self, rec, r):
        self.logged.append({k: self.format_metric(v) for k, v in
                            rec.items()})


def test_log_round_handles_non_float_metrics(capsys):
    exp = _Recorder()
    exp.run(log_every=1)  # overridden hook: must not raise on None/str/array
    assert exp.logged[0]["loss"] == 0.1235
    assert exp.logged[0]["round"] == 2
    assert exp.logged[0]["eval"] is None
    assert exp.logged[0]["note"] == "skip"
    # the default hook prints the same defensive formatting
    FedExperiment.log_round(exp, exp.history[-1], 0)
    out = capsys.readouterr().out
    assert "0.1235" in out and "None" in out
