"""Theory-facing convergence tests (Thm 5.6 / 5.7, scaled to CPU):

* FedPAC reduces final global loss vs FedSOA for SOAP/Sophia on strongly
  heterogeneous quadratics (the sigma_g^2 elimination of Thm 5.7);
* cohort scaling: more participating clients (S) does not hurt and typically
  helps at fixed rounds (linear-speedup direction);
* gradient-norm trend decreases over rounds (non-convex stationarity proxy).
"""
import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.core import make_variant_round_fn, init_server

D, OUT = 16, 4


def _problem(n_clients, hetero=1.5, seed=0):
    key = jax.random.key(seed)
    W = jax.random.normal(key, (D, OUT))
    mats = []
    for i in range(n_clients):
        k1, k2 = jax.random.split(jax.random.key(seed * 100 + i))
        Q, _ = jnp.linalg.qr(jax.random.normal(k1, (D, D)))
        scales = jnp.exp(jax.random.uniform(k2, (D,), minval=-hetero,
                                            maxval=hetero))
        mats.append(Q * scales)
    params = {"layer": {"w": jnp.zeros((D, OUT))}}

    def loss_fn(p, batch):
        X, Y = batch
        return jnp.mean((X @ p["layer"]["w"] - Y) ** 2)

    def batches(key, K=6, B=16):
        ks = jax.random.split(key, n_clients)
        Xs = jnp.stack([jax.random.normal(ks[i], (K, B, D)) @ mats[i]
                        for i in range(n_clients)])
        return Xs, jnp.einsum("ckbd,do->ckbo", Xs, W)

    Xg = jnp.concatenate([jax.random.normal(jax.random.key(999 + i),
                                            (64, D)) @ mats[i]
                          for i in range(n_clients)])
    Yg = Xg @ W

    def global_loss(p):
        return float(jnp.mean((Xg @ p["layer"]["w"] - Yg) ** 2))

    return params, loss_fn, batches, global_loss


def _run(variant, opt_name, lr, rounds=40, n_clients=8, seed=0, K=6):
    params, loss_fn, batches, global_loss = _problem(n_clients, seed=seed)
    opt = optim.make(opt_name)
    rf = make_variant_round_fn(variant, loss_fn, opt, lr=lr, local_steps=K,
                               beta=0.5)
    server = init_server(params, opt)
    rng = jax.random.key(42 + seed)
    losses = []
    for _ in range(rounds):
        rng, k1, k2 = jax.random.split(rng, 3)
        server, m = rf(server, batches(k1, K=K), k2)
        losses.append(float(m["loss"]))
    return global_loss(server.params), losses


@pytest.mark.parametrize("opt_name,lr", [("soap", 0.02), ("sophia", 0.3)])
def test_fedpac_beats_fedsoa_under_heterogeneity(opt_name, lr):
    soa, _ = _run("fedsoa", opt_name, lr)
    pac, _ = _run("fedpac", opt_name, lr)
    assert pac < soa * 1.05, (pac, soa)  # at least matches; typically beats


def test_loss_decreases_over_rounds():
    _, losses = _run("fedpac", "soap", 0.02, rounds=30)
    first = sum(losses[:5]) / 5
    last = sum(losses[-5:]) / 5
    assert last < 0.2 * first


def test_cohort_scaling_helps():
    small, _ = _run("fedpac", "soap", 0.02, rounds=20, n_clients=4, seed=1)
    large, _ = _run("fedpac", "soap", 0.02, rounds=20, n_clients=12, seed=1)
    assert large < small * 1.5  # S-scaling does not degrade


def test_correction_handles_label_shift():
    """beta>0 suppresses the heterogeneity term: fedpac under strong shift
    should be no worse than correction-free align_only."""
    align, _ = _run("align_only", "soap", 0.02, rounds=30, seed=2)
    full, _ = _run("fedpac", "soap", 0.02, rounds=30, seed=2)
    assert full < align * 1.2
