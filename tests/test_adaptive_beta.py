"""Beyond-paper: drift-adaptive correction strength (beta="auto").

beta_r = beta_max * d/(1+d) with d the normalized drift of the previous
round: correction backs off when client geometries agree (where fixed beta
only injects staleness) and ramps up under real drift.
"""
import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.core import make_round_fn, init_server

D, OUT, C, K = 16, 8, 6, 4


def _problem(hetero):
    key = jax.random.key(0)
    W = jax.random.normal(key, (D, OUT))
    mats = []
    for i in range(C):
        k1, k2 = jax.random.split(jax.random.key(i + 1))
        Q, _ = jnp.linalg.qr(jax.random.normal(k1, (D, D)))
        s = jnp.exp(jax.random.uniform(k2, (D,), minval=-hetero,
                                       maxval=hetero))
        mats.append(Q * s)

    def loss_fn(p, b):
        X, Y = b
        return jnp.mean((X @ p["w"] - Y) ** 2)

    def batches(key):
        ks = jax.random.split(key, C)
        Xs = jnp.stack([jax.random.normal(ks[i], (K, 16, D)) @ mats[i]
                        for i in range(C)])
        return Xs, jnp.einsum("ckbd,do->ckbo", Xs, W)

    return {"w": jnp.zeros((D, OUT))}, loss_fn, batches


def _run(beta, hetero, rounds=15, beta_max=0.7):
    params, loss_fn, batches = _problem(hetero)
    opt = optim.make("soap")
    rf = make_round_fn(loss_fn, opt, lr=0.05, local_steps=K, beta=beta,
                       beta_max=beta_max)
    server = init_server(params, opt)
    rng = jax.random.key(3)
    betas, losses = [], []
    for _ in range(rounds):
        rng, k1, k2 = jax.random.split(rng, 3)
        server, m = rf(server, batches(k1), k2)
        betas.append(float(m["beta"]))
        losses.append(float(m["loss"]))
    return betas, losses


def test_auto_beta_bounded():
    betas, _ = _run("auto", hetero=1.5)
    assert all(0.0 <= b <= 0.7 + 1e-6 for b in betas)
    assert betas[0] == 0.0  # no drift signal before round 1


def test_auto_beta_responds_to_drift():
    lo, _ = _run("auto", hetero=0.1)
    hi, _ = _run("auto", hetero=2.0)
    # stronger curvature heterogeneity => larger measured drift => larger beta
    assert max(hi) > max(lo)


def test_auto_beta_converges():
    _, losses = _run("auto", hetero=1.5, rounds=25)
    assert losses[-1] < 0.2 * losses[0]


def test_fixed_beta_metric_reported():
    betas, _ = _run(0.5, hetero=1.0, rounds=3)
    assert all(b == 0.5 for b in betas)
