"""Checkpoint round-trips, MoE ragged-vs-dense oracle, RoPE properties."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import optim, configs
from repro.checkpoint import (
    save_pytree, load_pytree, CheckpointManager,
)
from repro.core.server import ServerState, init_server
from repro.models import model as M
from repro.models.config import ModelConfig, MoEConfig
from repro.models.moe import init_moe, moe_forward
from repro.models.param import Initializer, unbox
from repro.models.rope import apply_rope, default_positions

KEY = jax.random.key(0)


# ---------------------------------------------------------------- checkpoint

class TestCheckpoint:
    def test_pytree_roundtrip_with_none_leaves(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
                "b": {"c": None, "d": jnp.ones(4)},
                "e": [jnp.zeros((2,)), None]}
        p = str(tmp_path / "t.npz")
        save_pytree(tree, p)
        out = load_pytree(tree, p)
        assert out["b"]["c"] is None and out["e"][1] is None
        assert out["a"].dtype == jnp.bfloat16
        assert jnp.array_equal(out["a"], tree["a"])

    def test_optimizer_state_roundtrip(self, tmp_path):
        params = {"layer": {"w": jax.random.normal(KEY, (16, 8))},
                  "norm": {"scale": jnp.ones(8)}}
        opt = optim.make("muon")
        state = opt.init(params)
        g = jax.tree.map(jnp.ones_like, params)
        _, state = opt.update(g, state, params, jnp.int32(0))
        p = str(tmp_path / "opt.npz")
        save_pytree(state, p)
        out = load_pytree(state, p)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(state)):
            assert jnp.allclose(a, b)

    def test_manager_rotation_and_restore(self, tmp_path):
        params = {"w": jnp.zeros((4, 4))}
        opt = optim.make("sgd")
        server = init_server(params, opt)
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for r in range(1, 5):
            server = ServerState(
                jax.tree.map(lambda x: x + 1.0, server.params),
                None, server.g_global, r)
            mgr.save(server)
        steps = sorted(n for n in os.listdir(tmp_path) if n.startswith("step"))
        assert len(steps) == 2  # rotation kept last 2
        restored = mgr.restore(server)
        assert restored.round == 4
        assert float(restored.params["w"][0, 0]) == 4.0


# ---------------------------------------------------------------- MoE oracle

def _dense_moe_oracle(p, x, cfg):
    """Per-token dense mixture: softmax top-k over experts, computed naively."""
    m = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, m.top_k)
    topw = topw / topw.sum(-1, keepdims=True)
    outs = []
    for t in range(xf.shape[0]):
        acc = jnp.zeros(d, xf.dtype)
        for j in range(m.top_k):
            e = topi[t, j]
            h = jax.nn.silu(xf[t] @ p["w_gate"][e]) * (xf[t] @ p["w_up"][e])
            acc = acc + topw[t, j] * (h @ p["w_down"][e])
        outs.append(acc)
    y = jnp.stack(outs)
    if m.num_shared_experts:
        from repro.models.layers import apply_mlp
        y = y + apply_mlp(p["shared"], xf, "swiglu")
    return y.reshape(b, s, d)


def test_moe_ragged_matches_dense_oracle():
    cfg = ModelConfig(
        name="t", num_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
        d_ff=32, vocab_size=64,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=16,
                      num_shared_experts=1))
    ini = Initializer(KEY, jnp.float32)
    p = unbox(init_moe(ini, cfg))
    x = jax.random.normal(jax.random.key(5), (2, 6, 16))
    got, aux = moe_forward(p, x, cfg)
    want = _dense_moe_oracle(p, x, cfg)
    assert jnp.max(jnp.abs(got - want)) < 1e-4
    assert float(aux) >= 0.0


def test_moe_router_gradient_flows():
    cfg = ModelConfig(
        name="t", num_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
        d_ff=32, vocab_size=64,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=16))
    ini = Initializer(KEY, jnp.float32)
    p = unbox(init_moe(ini, cfg))
    x = jax.random.normal(jax.random.key(6), (2, 4, 16))

    def loss(p):
        y, aux = moe_forward(p, x, cfg)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0.0
    assert float(jnp.sum(jnp.abs(g["w_gate"]))) > 0.0


# ---------------------------------------------------------------- RoPE

class TestRope:
    def test_norm_preserved(self):
        x = jax.random.normal(KEY, (2, 8, 3, 16))
        pos = default_positions(2, 8)
        y = apply_rope(x, pos)
        assert jnp.allclose(jnp.linalg.norm(x, axis=-1),
                            jnp.linalg.norm(y, axis=-1), atol=1e-5)

    def test_relative_property(self):
        """<rope(q,i), rope(k,j)> depends only on i-j."""
        q = jax.random.normal(KEY, (1, 1, 1, 8))
        k = jax.random.normal(jax.random.key(1), (1, 1, 1, 8))

        def dot_at(i, j):
            qi = apply_rope(q, jnp.full((1, 1), i, jnp.int32))
            kj = apply_rope(k, jnp.full((1, 1), j, jnp.int32))
            return float(jnp.vdot(qi, kj))

        assert dot_at(5, 3) == pytest.approx(dot_at(10, 8), abs=1e-4)
        assert dot_at(7, 7) == pytest.approx(dot_at(0, 0), abs=1e-4)

    def test_partial_rope_passthrough(self):
        x = jax.random.normal(KEY, (1, 4, 1, 16))
        pos = default_positions(1, 4)
        y = apply_rope(x, pos, fraction=0.5)
        assert jnp.array_equal(x[..., 8:], y[..., 8:])  # untouched half
        assert not jnp.array_equal(x[..., :8], y[..., :8])

    def test_mrope_equals_rope_when_positions_identical(self):
        x = jax.random.normal(KEY, (2, 6, 2, 16))
        pos1 = default_positions(2, 6)
        pos3 = default_positions(2, 6, mrope=True)
        y1 = apply_rope(x, pos1)
        y3 = apply_rope(x, pos3, mrope_sections=(4, 2, 2))
        assert jnp.max(jnp.abs(y1 - y3)) < 1e-5

    def test_mrope_differs_when_axes_diverge(self):
        x = jax.random.normal(KEY, (1, 4, 1, 16))
        pos = default_positions(1, 4, mrope=True)
        pos2 = pos.at[..., 1].add(7)  # shift the "height" axis
        y1 = apply_rope(x, pos, mrope_sections=(4, 2, 2))
        y2 = apply_rope(x, pos2, mrope_sections=(4, 2, 2))
        assert float(jnp.max(jnp.abs(y1 - y2))) > 1e-3
