"""Per-architecture smoke tests (deliverable f): reduced variant of each
assigned family runs one forward + one train step on CPU; output shapes and
finiteness asserted.  Also checks decode/prefill consistency vs the full
forward (f32)."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs, optim
from repro.models import model as M
from repro.launch.steps import make_train_step


def _tokens(cfg, key, b=2, s=16):
    shape = (b, s, cfg.num_codebooks) if cfg.num_codebooks > 1 else (b, s)
    return jax.random.randint(key, shape, 0, cfg.vocab_size)


@pytest.fixture(scope="module")
def rng():
    return jax.random.key(0)


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_forward_shapes_and_finite(arch, rng):
    cfg = configs.get_reduced(arch)
    params = M.init_params(cfg, rng)
    toks = _tokens(cfg, rng)
    logits, _, aux = M.forward(params, {"tokens": toks}, cfg)
    expected = ((2, 16, cfg.num_codebooks, cfg.vocab_size)
                if cfg.num_codebooks > 1 else (2, 16, cfg.vocab_size))
    assert logits.shape == expected
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_train_step(arch, rng):
    """One FedPAC(Muon) train step: loss finite, params move, dtypes stable."""
    cfg = configs.get_reduced(arch)
    params = M.init_params(cfg, rng)
    opt = optim.make("muon")
    step = make_train_step(cfg, opt, lr=1e-2, beta=0.5, remat=False)
    opt_state = opt.init(params)
    gg = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    toks = _tokens(cfg, rng)
    batch = {"tokens": toks, "labels": toks}
    new_params, new_state, loss = jax.jit(step)(params, opt_state, gg, batch,
                                                jnp.int32(0))
    assert bool(jnp.isfinite(loss)) and float(loss) > 0
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: jnp.any(a != b), new_params, params))
    assert any(bool(m) for m in moved)
    for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(params)):
        assert a.dtype == b.dtype and a.shape == b.shape


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_decode_matches_forward(arch, rng):
    cfg = configs.get_reduced(arch).replace(dtype="float32")
    params = M.init_params(cfg, rng)
    b, s = 2, 12
    toks = _tokens(cfg, rng, b, s)
    full, _, _ = M.forward(params, {"tokens": toks}, cfg)
    last_pre, caches = M.prefill(params, {"tokens": toks[:, :s - 1]}, cfg,
                                 max_len=s + 4)
    dec, _ = M.decode_step(params, toks[:, s - 1:s], caches,
                           jnp.int32(s - 1), cfg)
    assert jnp.max(jnp.abs(full[:, -1] - dec)) < 2e-4
    assert jnp.max(jnp.abs(full[:, s - 2] - last_pre)) < 2e-4


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "recurrentgemma-2b",
                                  "mixtral-8x22b"])
def test_ring_cache_long_decode(arch, rng):
    """Sub-quadratic archs decode past the window with a ring KV buffer."""
    cfg = configs.get_reduced(arch).replace(dtype="float32")
    if cfg.window:
        cfg = cfg.replace(window=8)
    assert cfg.supports_long_decode
    params = M.init_params(cfg, rng)
    b = 2
    caches = M.init_caches(cfg, b, max_len=64, ring=True)
    tok = _tokens(cfg, rng, b, 1)
    for i in range(20):  # > window
        logits, caches = M.decode_step(params, tok, caches, jnp.int32(i), cfg)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_vlm_embeds_path(rng):
    cfg = configs.get_reduced("qwen2-vl-7b")
    params = M.init_params(cfg, rng)
    emb = jax.random.normal(rng, (2, 16, cfg.d_model), cfg.jnp_dtype)
    labels = jax.random.randint(rng, (2, 16), 0, cfg.vocab_size)
    loss = M.loss_fn(params, {"embeds": emb, "tokens": None,
                              "labels": labels}, cfg)
    assert bool(jnp.isfinite(loss))


def test_full_configs_match_assignment():
    spec = {
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65024),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
    }
    for arch, (l, d, h, kv, ff, v) in spec.items():
        cfg = configs.get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (l, d, h, kv, ff, v), arch
