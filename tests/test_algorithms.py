"""AlgorithmSpec registry + unified client-state protocol + builder API.

Covers: registry error cases (duplicate, unknown), the derived *_light
variants, the fedcm_light beta=0.9 regression, the golden legacy-string
equivalence suite (every paper-table algorithm string produces bitwise-
identical round outputs through the spec API, sync and async), the
SCAFFOLD client-state protocol through the uniform round path, the
FedPM-style preconditioned-mixing extension, and the FedExperiment ABC
contract (config/rounds + log_round hook).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.api import build_experiment
from repro.core import init_server
from repro.core.algorithms import (
    AlgorithmSpec, ClientStateSpec, DuplicateAlgorithmError,
    UnknownAlgorithmError, build_round_fn, register, registered, resolve,
)
from repro.core.engine.aggregation import precond_mixing_weights
from repro.fed import (
    AsyncConfig, AsyncFederatedExperiment, FedConfig, FedExperiment,
    FederatedExperiment, LatencyModel,
)

N_CLIENTS, D, OUT, K = 4, 12, 8, 2   # w (12, 8): inside SOAP's matrix domain
_KEY = jax.random.key(0)
_W = jax.random.normal(_KEY, (D, OUT))
_XS = np.asarray(jax.random.normal(jax.random.key(1),
                                   (N_CLIENTS, 64, D))) @ np.asarray(_W.T.T)


def _problem():
    """Tiny linear regression, one shard per client (fast on CPU)."""
    params = {"w": jnp.zeros((D, OUT))}
    X = np.asarray(jax.random.normal(jax.random.key(1),
                                     (N_CLIENTS, 64, D)), np.float32)
    Y = X @ np.asarray(_W, np.float32)

    def loss_fn(p, b):
        xb, yb = b
        return jnp.mean((xb @ p["w"] - yb) ** 2)

    def batch_fn(cid, rng):
        idx = rng.choice(64, size=8, replace=True)
        return jnp.asarray(X[cid, idx]), jnp.asarray(Y[cid, idx])

    return params, loss_fn, batch_fn


def _fed(algo, **kw):
    defaults = dict(algorithm=algo, n_clients=N_CLIENTS, participation=0.5,
                    rounds=2, local_steps=K, svd_rank=2, seed=0)
    defaults.update(kw)
    return FedConfig(**defaults)


# ------------------------------------------------------------------ registry

def test_registry_unknown_name():
    for name in ["bogus", "local_bogus", "fedpac_", "adamw", "bogus_light"]:
        with pytest.raises(UnknownAlgorithmError, match="unknown"):
            resolve(name)


def test_registry_duplicate_rejected():
    with pytest.raises(DuplicateAlgorithmError, match="already registered"):
        register(AlgorithmSpec(name="fedavg", optimizer="sgd"))
    # overwrite is explicit, and restores cleanly
    original = resolve("fedavg")
    register(original, overwrite=True)
    assert resolve("fedavg") is original


def test_registry_rejects_unknown_optimizer_and_upload():
    with pytest.raises(ValueError, match="unknown optimizer"):
        register(AlgorithmSpec(name="tmp_x", optimizer="bogus"))
    with pytest.raises(ValueError, match="upload"):
        AlgorithmSpec(name="tmp_y", upload="gzip")


def test_registry_contains_paper_table():
    names = registered()
    for name in ["fedavg", "scaffold", "fedcm", "local_soap", "fedpac_soap",
                 "align_only_soap", "correct_only_muon", "fedpm_soap"]:
        assert name in names


def test_light_variant_is_derived():
    base = resolve("fedpac_soap")
    light = resolve("fedpac_soap_light")
    assert light.upload == "svd" and base.upload == "dense"
    assert light.name == "fedpac_soap_light"
    # everything else (incl. the beta policy) is inherited
    assert (light.align, light.correct, light.optimizer) == \
        (base.align, base.correct, base.optimizer)


# ------------------------------------------------------- beta policy (bugfix)

def test_fedcm_light_keeps_pinned_beta():
    """Regression: the legacy resolve_beta tested algorithm == 'fedcm', so
    fedcm_light silently fell back to the default beta — the pin is now part
    of the spec and survives derived variants."""
    assert resolve("fedcm").resolve_beta(0.5) == 0.9
    assert resolve("fedcm_light").resolve_beta(0.5) == 0.9
    assert resolve("fedcm_light").resolve_beta("auto") == 0.9

    params, loss_fn, batch_fn = _problem()
    for runtime_kw in [dict(), dict(runtime="async")]:
        exp = build_experiment("fedcm_light", params=params, loss_fn=loss_fn,
                               client_batch_fn=batch_fn,
                               fed=_fed("fedcm_light", **runtime_kw))
        assert float(exp.server.geom.beta) == pytest.approx(0.9)


def test_beta_policy_matrix():
    assert resolve("fedavg").resolve_beta(0.5) == 0.0       # no correction
    assert resolve("fedpac_soap").resolve_beta(0.25) == 0.25
    assert resolve("fedpac_soap").resolve_beta("auto") == "auto"
    assert resolve("align_only_soap").resolve_beta("auto") == 0.0


# ------------------------------------------------- golden legacy equivalence

TABLE_ALGOS = ["fedavg", "scaffold", "fedcm", "fedcm_light", "local_adamw",
               "local_sophia", "local_muon", "local_soap", "fedpac_sophia",
               "fedpac_muon", "fedpac_soap", "fedpac_soap_light",
               "align_only_soap", "correct_only_muon"]


def _history(exp):
    return [[(k, v) for k, v in sorted(rec.items())] for rec in exp.run()]


@pytest.mark.parametrize("algo", TABLE_ALGOS)
def test_legacy_string_equivalence_sync(algo):
    """Legacy string -> spec resolution is golden: the string path and the
    explicit-spec path produce bitwise-identical round outputs."""
    params, loss_fn, batch_fn = _problem()
    via_string = FederatedExperiment(_fed(algo), params, loss_fn, batch_fn)
    via_spec = build_experiment(resolve(algo), params=params, loss_fn=loss_fn,
                                client_batch_fn=batch_fn, fed=_fed(algo))
    h_string, h_spec = _history(via_string), _history(via_spec)
    assert h_string == h_spec          # exact float equality, every metric
    assert via_string.comm_bytes_per_round() == \
        via_spec.comm_bytes_per_round()
    for a, b in zip(jax.tree.leaves(via_string.server.params),
                    jax.tree.leaves(via_spec.server.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("algo", [a for a in TABLE_ALGOS if a != "scaffold"])
def test_legacy_string_equivalence_async(algo):
    params, loss_fn, batch_fn = _problem()

    def acfg():
        return AsyncConfig(buffer_size=2, concurrency=3,
                           latency=LatencyModel(heterogeneity=1.0))

    fed = _fed(algo, runtime="async")
    via_string = AsyncFederatedExperiment(fed, params, loss_fn, batch_fn,
                                          async_cfg=acfg())
    via_spec = build_experiment(resolve(algo), params=params, loss_fn=loss_fn,
                                client_batch_fn=batch_fn, async_cfg=acfg(),
                                fed=fed)
    assert _history(via_string) == _history(via_spec)


# ------------------------------------------------------ client-state protocol

def test_async_rejects_client_state_algorithms_generically():
    params, loss_fn, batch_fn = _problem()
    with pytest.raises(ValueError, match="per-client persistent state"):
        AsyncFederatedExperiment(_fed("scaffold", runtime="async"), params,
                                 loss_fn, batch_fn)


def test_scaffold_uniform_round_signature():
    """SCAFFOLD runs through the same driver signature as every algorithm:
    (server, client_state, cohort, batches, rng) -> 3-tuple."""
    params, loss_fn, batch_fn = _problem()
    exp = FederatedExperiment(_fed("scaffold", participation=0.5), params,
                              loss_fn, batch_fn)
    assert exp.client_state is not None
    c_before = np.asarray(jax.tree.leaves(exp.client_state.c_clients)[0])
    exp.run_round()
    c_after = np.asarray(jax.tree.leaves(exp.client_state.c_clients)[0])
    moved = np.any(c_before != c_after, axis=tuple(range(1, c_after.ndim)))
    assert moved.sum() == 2            # exactly the sampled cohort updated
    # global control moved too (partial participation => scaled by S/N)
    assert np.any(np.asarray(
        jax.tree.leaves(exp.client_state.c_global)[0]) != 0.0)


def test_custom_client_state_through_registry():
    """A brand-new stateful algorithm needs only a spec — no runtime edits.

    Declares a per-client step counter as persistent state and checks the
    engine gathers/scatters it by cohort."""
    params, loss_fn, batch_fn = _problem()

    def local_update(spec, lf, opt, run):
        from repro.core.algorithms import make_local_update
        base = make_local_update(dataclasses.replace(
            spec, local_update=None, client_state=None), lf, opt, run)

        def fn(p, theta, g, *, beta, view, batch_i, key_i):
            delta, theta_out, _, loss = base(p, theta, g, beta=beta,
                                             view=None, batch_i=batch_i,
                                             key_i=key_i)
            return delta, theta_out, view + 1, loss

        return fn

    state = ClientStateSpec(
        init=lambda p, n: jnp.zeros((n,), jnp.int32),
        client_view=lambda s, cid: s[cid],
        server_update=lambda s, cohort, outs, n: s.at[cohort].set(outs))
    spec = AlgorithmSpec(name="counting_sgd", optimizer="sgd",
                         local_update=local_update, client_state=state)
    exp = build_experiment(spec, params=params, loss_fn=loss_fn,
                           client_batch_fn=batch_fn,
                           fed=_fed("fedavg", participation=1.0, rounds=3))
    exp.run()
    # full participation, 3 rounds: every client's counter gathered,
    # incremented, and scattered back exactly 3 times
    np.testing.assert_array_equal(np.asarray(exp.client_state),
                                  np.full((N_CLIENTS,), 3))


# --------------------------------------------------- preconditioned mixing

def test_precond_mixing_weights_normalized():
    thetas = {"q": jnp.stack([jnp.full((3, 3), 1.0), jnp.full((3, 3), 4.0)])}
    w = precond_mixing_weights(None, thetas)
    assert w.shape == (2,)
    assert float(jnp.mean(w)) == pytest.approx(1.0, rel=1e-5)
    assert float(w[0]) > float(w[1])   # sharper curvature => less trust
    uniform = precond_mixing_weights(
        None, {"q": jnp.ones((2, 3, 3))})
    np.testing.assert_allclose(np.asarray(uniform), 1.0, rtol=1e-5)
    with pytest.raises(ValueError, match="Theta"):
        precond_mixing_weights(None, {"m": None})


def test_fedpm_runs_without_runtime_changes():
    """The extension algorithm registered purely through the registry runs
    end-to-end in both runtimes and actually reweights the delta mean."""
    params, loss_fn, batch_fn = _problem()
    exp = build_experiment("fedpm_soap", params=params, loss_fn=loss_fn,
                           client_batch_fn=batch_fn, fed=_fed("fedpm_soap"))
    hist = exp.run()
    assert len(hist) == 2 and np.isfinite(hist[-1]["loss"])
    assert exp.spec.mixing is precond_mixing_weights

    acfg = AsyncConfig(buffer_size=2, concurrency=3)
    a = build_experiment("fedpm_soap", params=params, loss_fn=loss_fn,
                         client_batch_fn=batch_fn, async_cfg=acfg,
                         fed=_fed("fedpm_soap", runtime="async"))
    ahist = a.run()
    assert len(ahist) == 2 and np.isfinite(ahist[-1]["loss"])


def test_fedpm_differs_from_uniform_fedsoa_aligned():
    """Same optimizer/alignment, uniform vs curvature-weighted mixing must
    diverge once client curvatures differ."""
    params, loss_fn, batch_fn = _problem()
    mixed = build_experiment("fedpm_soap", params=params, loss_fn=loss_fn,
                             client_batch_fn=batch_fn,
                             fed=_fed("fedpm_soap"))
    uniform = build_experiment("align_only_soap", params=params,
                               loss_fn=loss_fn, client_batch_fn=batch_fn,
                               fed=_fed("align_only_soap"))
    hm, hu = mixed.run(), uniform.run()
    pm = np.asarray(mixed.server.params["w"])
    pu = np.asarray(uniform.server.params["w"])
    assert hm[-1]["loss"] != hu[-1]["loss"] or np.any(pm != pu)


# ------------------------------------------------------------- builder + ABC

def test_scaffold_keeps_historical_default_lr():
    """The legacy parser's 'scaffold' token bypassed SGD's table lr; the
    spec pins default_lr=1e-2 so default runs reproduce the old numerics."""
    params, loss_fn, batch_fn = _problem()
    exp = FederatedExperiment(_fed("scaffold"), params, loss_fn, batch_fn)
    assert exp.lr == pytest.approx(1e-2)
    # explicit lr still wins
    exp2 = FederatedExperiment(_fed("scaffold", lr=0.05), params, loss_fn,
                               batch_fn)
    assert exp2.lr == 0.05
    assert FederatedExperiment(_fed("fedavg"), params, loss_fn,
                               batch_fn).lr == optim.DEFAULT_LR["sgd"]


def test_fed_round_step_honors_spec_beta_policy():
    from repro.launch.steps import make_fed_round_step
    with pytest.raises(ValueError, match="auto"):
        make_fed_round_step(None, optim.make("soap"), lr=0.1,
                            algorithm="fedpac_soap", beta="auto")


def test_build_experiment_dispatch_and_conflicts():
    params, loss_fn, batch_fn = _problem()
    sync = build_experiment("fedavg", params=params, loss_fn=loss_fn,
                            client_batch_fn=batch_fn, rounds=1)
    assert isinstance(sync, FederatedExperiment)
    # async_cfg implies the async runtime without naming it
    auto = build_experiment("fedavg", params=params, loss_fn=loss_fn,
                            client_batch_fn=batch_fn, rounds=1,
                            async_cfg=AsyncConfig(buffer_size=2,
                                                  concurrency=3))
    assert isinstance(auto, AsyncFederatedExperiment)
    with pytest.raises(ValueError, match="async_cfg"):
        build_experiment("fedavg", params=params, loss_fn=loss_fn,
                         client_batch_fn=batch_fn, runtime="sync",
                         async_cfg=AsyncConfig())
    # an explicit fed config is authoritative: sync + async_cfg is an
    # error, never a silent flip to the async runtime
    with pytest.raises(ValueError, match="async_cfg"):
        build_experiment("fedavg", params=params, loss_fn=loss_fn,
                         client_batch_fn=batch_fn,
                         fed=FedConfig(runtime="sync"),
                         async_cfg=AsyncConfig())
    with pytest.raises(UnknownAlgorithmError):
        build_experiment("bogus", params=params, loss_fn=loss_fn,
                         client_batch_fn=batch_fn)


def test_unregistered_spec_usable_directly():
    params, loss_fn, batch_fn = _problem()
    spec = AlgorithmSpec(name="my_unregistered", optimizer="soap",
                         align=True, correct=True, pinned_beta=0.3)
    exp = build_experiment(spec, params=params, loss_fn=loss_fn,
                           client_batch_fn=batch_fn, rounds=1,
                           n_clients=N_CLIENTS, local_steps=K)
    assert float(exp.server.geom.beta) == pytest.approx(0.3)
    assert np.isfinite(exp.run()[-1]["loss"])


def test_fed_experiment_declares_rounds_contract():
    params, loss_fn, batch_fn = _problem()
    with pytest.raises(TypeError, match="rounds"):
        FederatedExperiment(object(), params, loss_fn, batch_fn)


def test_log_round_hook_routes_logging():
    params, loss_fn, batch_fn = _problem()
    seen = []

    class Hooked(FederatedExperiment):
        def log_round(self, rec, r):
            seen.append((r, rec["round"]))

    exp = Hooked(_fed("fedavg", rounds=3), params, loss_fn, batch_fn)
    exp.run(log_every=1)
    assert seen == [(0, 1.0), (1, 2.0), (2, 3.0)]
    assert isinstance(exp, FedExperiment)


def test_build_round_fn_requires_n_clients_for_stateful():
    params, loss_fn, _ = _problem()
    with pytest.raises(ValueError, match="n_clients"):
        build_round_fn(resolve("scaffold"), loss_fn, optim.make("sgd"),
                       lr=0.1, local_steps=K)


def test_inline_spec_round_fn_matches_registered():
    """core.fedpac.make_round_fn (inline spec) == registry spec driver."""
    from repro.core import make_round_fn
    params, loss_fn, _ = _problem()
    opt = optim.make("soap")
    X = jax.random.normal(jax.random.key(5), (N_CLIENTS, K, 8, D))
    batches = (X, X @ _W)
    rng = jax.random.key(6)
    rf = make_round_fn(loss_fn, opt, lr=0.05, local_steps=K, beta=0.5)
    s_inline, m_inline = rf(init_server(params, opt), batches, rng)
    driver = build_round_fn(resolve("fedpac_soap"), loss_fn, opt, lr=0.05,
                            local_steps=K, beta=0.5)
    s_spec, _, m_spec = driver(init_server(params, opt), None,
                               jnp.arange(N_CLIENTS), batches, rng)
    np.testing.assert_array_equal(np.asarray(s_inline.params["w"]),
                                  np.asarray(s_spec.params["w"]))
    assert float(m_inline["loss"]) == float(m_spec["loss"])
