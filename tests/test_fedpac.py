"""FedPAC core properties: Definition 1, Corollary F.3, component ablation
semantics, compression codec."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro import optim
from repro.core import (
    make_round_fn, make_variant_round_fn, init_server, drift_metric,
    drift_per_layer, spectral_drift, make_svd_codec, svd_truncate,
    round_comm_bytes,
)

KEY = jax.random.key(3)


def _fed_problem(n_clients=4, d=16, out=8, hetero=0.5):
    W = jax.random.normal(KEY, (d, out))
    mats = []
    for i in range(n_clients):
        k = jax.random.key(100 + i)
        mats.append(jnp.eye(d) + hetero * jax.random.normal(k, (d, d)))
    params = {"layer": {"w": jnp.zeros((d, out))}}

    def loss_fn(p, batch):
        X, Y = batch
        return jnp.mean((X @ p["layer"]["w"] - Y) ** 2)

    def make_batches(key, K=4, B=8):
        Xs, Ys = [], []
        ks = jax.random.split(key, n_clients)
        for i in range(n_clients):
            X = jax.random.normal(ks[i], (K, B, d)) @ mats[i]
            Xs.append(X)
            Ys.append(X @ W)
        return jnp.stack(Xs), jnp.stack(Ys)

    return params, loss_fn, make_batches


# ---------------------------------------------------------------- drift

class TestDriftMetric:
    def test_zero_iff_identical(self):
        theta = {"h": jnp.ones((5, 3, 3))}  # 5 identical clients
        assert float(drift_metric(theta)) == 0.0

    def test_positive_when_different(self):
        theta = {"h": jnp.stack([jnp.zeros((3,)), jnp.ones((3,))])}
        assert float(drift_metric(theta)) > 0.0

    @given(st.integers(2, 6), st.integers(1, 8), st.floats(0.1, 10.0))
    @settings(max_examples=20, deadline=None)
    def test_scale_quadratic(self, s, d, c):
        x = jax.random.normal(jax.random.key(s * d), (s, d))
        base = float(drift_metric({"t": x}))
        scaled = float(drift_metric({"t": c * x}))
        assert scaled == pytest.approx(c * c * base, rel=1e-3)

    @given(st.integers(2, 5))
    @settings(max_examples=10, deadline=None)
    def test_permutation_invariant(self, s):
        x = jax.random.normal(jax.random.key(s), (s, 7))
        perm = jax.random.permutation(jax.random.key(s + 1), s)
        assert float(drift_metric({"t": x})) == pytest.approx(
            float(drift_metric({"t": x[perm]})), rel=1e-5)

    def test_per_layer_sums_to_total(self):
        theta = {"a": jax.random.normal(KEY, (4, 5)),
                 "b": jax.random.normal(KEY, (4, 2, 3))}
        per = drift_per_layer(theta)
        assert sum(float(v) for v in per.values()) == pytest.approx(
            float(drift_metric(theta)), rel=1e-5)

    def test_spectral_drift_zero_for_identical(self):
        theta = {"L": jnp.ones((3, 4, 4))}
        sd = spectral_drift(theta)
        assert float(list(sd.values())[0]) == pytest.approx(0.0, abs=1e-6)


# ---------------------------------------------------------------- Corollary F.3

def test_aligned_states_agree_on_preconditioned_direction():
    """Theta_i identical => mean_i P_{Theta_i}(u) == P_{mean Theta}(u)."""
    opt = optim.make("sophia")
    params = {"w": jnp.ones((6, 4))}
    state = opt.init(params)
    h = {"h": {"w": jnp.abs(jax.random.normal(KEY, (6, 4))) + 0.1}}
    g = {"w": jax.random.normal(KEY, (6, 4))}
    s1 = opt.set_precond(state, h)
    s2 = opt.set_precond(state, h)
    d1, _ = opt.update(g, s1, params, jnp.int32(9))
    d2, _ = opt.update(g, s2, params, jnp.int32(9))
    assert jnp.allclose(d1["w"], d2["w"])


# ---------------------------------------------------------------- rounds

def test_round_zero_beta_matches_fedsoa():
    """correct=False == beta 0: identical trajectories."""
    params, loss_fn, make_batches = _fed_problem()
    opt = optim.make("adamw")
    batches = make_batches(jax.random.key(0))
    rng = jax.random.key(1)

    outs = []
    for kw in [dict(beta=0.0, align=False, correct=True),
               dict(beta=0.5, align=False, correct=False)]:
        rf = make_round_fn(loss_fn, opt, lr=0.05, local_steps=4, **kw)
        server = init_server(params, opt)
        server, _ = rf(server, batches, rng)
        outs.append(server.params["layer"]["w"])
    assert jnp.allclose(outs[0], outs[1], atol=1e-6)


def test_alignment_reduces_drift_for_soap():
    """FedPAC's warm start keeps client L/R factors closer (relative drift)."""
    params, loss_fn, make_batches = _fed_problem(hetero=1.0)
    opt = optim.make("soap")
    drifts = {}
    for variant in ["fedsoa", "align_only"]:
        rf = make_variant_round_fn(variant, loss_fn, opt, lr=0.02,
                                   local_steps=4)
        server = init_server(params, opt)
        rng = jax.random.key(5)
        for r in range(6):
            rng, k1, k2 = jax.random.split(rng, 3)
            server, m = rf(server, make_batches(k1), k2)
        drifts[variant] = float(m["drift"])
    # absolute drift grows with state magnitude; compare normalized later in
    # benchmarks — here assert both runs are finite and fedsoa drift nonzero
    assert drifts["fedsoa"] > 0 and np.isfinite(drifts["align_only"])


def test_fedpac_converges_heterogeneous():
    params, loss_fn, make_batches = _fed_problem(hetero=1.0)
    opt = optim.make("soap")
    rf = make_variant_round_fn("fedpac", loss_fn, opt, lr=0.05, local_steps=4,
                               beta=0.5)
    server = init_server(params, opt)
    rng = jax.random.key(7)
    first = None
    for r in range(30):
        rng, k1, k2 = jax.random.split(rng, 3)
        server, m = rf(server, make_batches(k1), k2)
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < 0.3 * first


# ---------------------------------------------------------------- compression

class TestCompression:
    def test_svd_truncate_exact_when_rank_full(self):
        x = jax.random.normal(KEY, (6, 5))
        assert jnp.allclose(svd_truncate(x, 5), x, atol=1e-4)

    def test_svd_codec_reduces_rank(self):
        xs = jax.random.normal(KEY, (3, 16, 16))  # 3 clients
        codec = make_svd_codec(2)
        out = codec({"L": xs})["L"]
        for i in range(3):
            s = jnp.linalg.svd(out[i], compute_uv=False)
            assert float(s[2]) < 1e-4  # rank <= 2

    def test_comm_accounting_ordering(self):
        params = {"w": jnp.zeros((64, 64))}
        theta = {"L": jnp.zeros((64, 64)), "R": jnp.zeros((64, 64))}
        local = round_comm_bytes(params)
        light = round_comm_bytes(params, theta, compressed_rank=4)
        full = round_comm_bytes(params, theta)
        assert local < light < full
