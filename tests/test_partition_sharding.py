"""Property tests: Dirichlet partitioner and divisibility-safe sharding."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax
from jax.sharding import PartitionSpec as P

from repro.data import dirichlet_partition, heterogeneity_stat
from repro.sharding.partitioning import (
    resolve_spec, greedy_spec, TRAIN_RULES, SERVE_RULES,
)


def _mesh(shape=(2, 4), axes=("data", "model")):
    return jax.sharding.AbstractMesh(shape, axes)


# ---------------------------------------------------------------- partition

@given(st.integers(2, 20), st.floats(0.05, 10.0), st.integers(0, 3))
@settings(max_examples=25, deadline=None)
def test_partition_is_exact_cover(n_clients, alpha, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, 500)
    parts = dirichlet_partition(labels, n_clients, alpha, seed=seed)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == 500
    assert len(np.unique(all_idx)) == 500  # every sample exactly once


def test_heterogeneity_monotone_in_alpha():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, 4000)
    stats = []
    for alpha in [100.0, 1.0, 0.1, 0.05]:
        parts = dirichlet_partition(labels, 20, alpha, seed=1)
        stats.append(heterogeneity_stat(parts, labels))
    assert stats[0] < stats[-1]  # smaller alpha => more skew
    assert stats[0] < 0.2 and stats[-1] > 0.5


# ---------------------------------------------------------------- sharding

@given(
    dims=st.lists(st.sampled_from([1, 2, 5, 15, 16, 24, 64, 128, 960, 2560]),
                  min_size=1, max_size=4),
    names=st.lists(st.sampled_from(["batch", "embed", "ffn", "heads",
                                    "kv_heads", "vocab", None]),
                   min_size=4, max_size=4),
)
@settings(max_examples=60, deadline=None)
def test_resolve_spec_always_valid(dims, names):
    mesh = _mesh((2, 4), ("data", "model"))
    spec = resolve_spec(dims, names[: len(dims)], mesh, TRAIN_RULES)
    used = []
    for dim, entry in zip(dims, tuple(spec) + (None,) * len(dims)):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        factor = 1
        for ax in axes:
            assert ax not in used, "mesh axis reused"
            used.append(ax)
            factor *= mesh.shape[ax]
        assert dim % factor == 0, "indivisible assignment"


def test_resolve_spec_replicates_indivisible_kv_heads():
    mesh = _mesh((2, 16), ("data", "model"))
    # 5 kv heads cannot shard over 16-way model axis
    spec = resolve_spec((8, 1024, 5, 64), ("batch", "seq", "kv_heads",
                                           "head_dim"), mesh, SERVE_RULES)
    assert len(spec) < 3 or spec[2] is None
    # but head_dim (64) picks the model axis instead
    assert "model" in str(spec)


def test_greedy_spec_trailing_dims():
    mesh = _mesh((2, 4), ("data", "model"))
    assert greedy_spec((32, 64), mesh) == P("data", "model")
    assert greedy_spec((7,), mesh) == P()
    assert greedy_spec((10, 32, 64), mesh) == P(None, "data", "model")
    # indivisible dims stay replicated
    assert greedy_spec((3, 5), mesh) == P()
