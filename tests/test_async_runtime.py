"""Asynchronous federated runtime: deterministic simulated-time scheduling,
staleness weights, buffered aggregation, and end-to-end algorithm runs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import aggregate_round, init_server
from repro.data import make_image_classification, dirichlet_partition
from repro.models.vision import init_cnn, cnn_apply, classification_loss
from repro.fed import (
    AsyncConfig, AsyncFederatedExperiment, FedConfig, FederatedExperiment,
    LatencyModel, make_experiment, stage_cohort_batches,
)
from repro.fed.rounds import resolve_lr
from repro.fed.async_runtime import SimScheduler, make_staleness_weight

N_CLIENTS = 6


@pytest.fixture(scope="module")
def problem():
    X, y = make_image_classification(600, image_size=8, n_classes=4, seed=0,
                                     noise=1.0)
    parts = dirichlet_partition(y, N_CLIENTS, 0.2, seed=0)
    params = init_cnn(jax.random.key(0), n_classes=4, width=4, blocks=1)

    def loss_fn(p, batch):
        return classification_loss(cnn_apply(p, batch["x"]), batch["y"])

    def batch_fn(cid, rng):
        idx = rng.choice(parts[cid], size=4)
        return {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}

    return params, loss_fn, batch_fn


def _async_cfg(**kw):
    defaults = dict(buffer_size=2, concurrency=4,
                    latency=LatencyModel(heterogeneity=1.0, jitter=0.5))
    defaults.update(kw)
    return AsyncConfig(**defaults)


# ---------------------------------------------------------------- scheduler

def _trace(seed, versions=20):
    lat = LatencyModel(heterogeneity=1.0, jitter=0.5, dropout=0.2)
    sched = SimScheduler(lat, n_clients=8, concurrency=4, seed=seed)
    sched.fill(0)
    out = []
    for v in range(1, versions):
        ev = sched.next_completion()
        out.append((float(ev.time), ev.seq, ev.client_id, ev.version,
                    ev.dropped))
        sched.fill(v)  # replacements dispatched at the new version
    return out


def test_scheduler_event_order_deterministic():
    a, b = _trace(seed=7), _trace(seed=7)
    assert a == b                      # bit-identical replay per seed
    assert a != _trace(seed=8)         # and seed actually matters
    times = [t for t, *_ in a]
    assert times == sorted(times)      # simulated clock is monotone
    assert all(s >= 0 for _, s, *_ in a)


def test_scheduler_bounded_concurrency():
    lat = LatencyModel()
    sched = SimScheduler(lat, n_clients=5, concurrency=3, seed=0)
    sched.fill(0)
    assert sched.in_flight() == 3
    sched.next_completion()
    assert sched.in_flight() == 2
    sched.fill(1)
    assert sched.in_flight() == 3
    with pytest.raises(ValueError):
        SimScheduler(lat, n_clients=2, concurrency=4, seed=0)


def test_scheduler_staleness_and_weights():
    """Versions lag behind for clients dispatched before a flush, and the
    polynomial decay weights match 1/(1+s)^alpha exactly."""
    trace = _trace(seed=3, versions=30)
    weight = make_staleness_weight("poly", alpha=0.5)
    staleness = []
    for i, (_, _, _, dispatched_at, _) in enumerate(trace):
        now = i + 1  # version at delivery (one flush per delivery in _trace)
        s = now - dispatched_at - 1
        assert s >= 0
        staleness.append(s)
        assert weight(s) == pytest.approx((1.0 + s) ** -0.5)
        assert 0.0 < weight(s) <= 1.0
    assert max(staleness) > 0  # concurrency > buffer => stale arrivals exist


def test_staleness_weight_modes():
    poly = make_staleness_weight("poly", alpha=0.5)
    assert poly(0) == 1.0
    assert poly(3) == pytest.approx(0.5)
    const = make_staleness_weight("none")
    assert const(9) == 1.0
    hinge = make_staleness_weight("hinge", hinge_threshold=2)
    assert hinge(2) == 1.0
    assert hinge(4) == pytest.approx(1.0 / 3.0)
    with pytest.raises(ValueError):
        make_staleness_weight("bogus")


# ---------------------------------------------------------------- aggregation

def test_weighted_aggregate_reduces_to_mean():
    params = {"w": jnp.ones((4, 4))}
    opt = optim.make("sgd")
    server = init_server(params, opt)
    deltas = {"w": jnp.stack([jnp.full((4, 4), 1.0), jnp.full((4, 4), 3.0)])}
    uniform = aggregate_round(server, deltas, None, lr=0.1, local_steps=2)
    ones = aggregate_round(server, deltas, None, lr=0.1, local_steps=2,
                           weights=jnp.ones(2))
    np.testing.assert_allclose(uniform.params["w"], ones.params["w"])
    # w=0.5 shrinks the step by half (unnormalized FedBuff semantics)
    half = aggregate_round(server, deltas, None, lr=0.1, local_steps=2,
                           weights=jnp.full(2, 0.5))
    np.testing.assert_allclose(half.params["w"] - server.params["w"],
                               (ones.params["w"] - server.params["w"]) / 2)
    assert ones.round == 1 and ones.theta_version == server.theta_version


# ---------------------------------------------------------------- end-to-end

@pytest.mark.parametrize("algo", ["fedavg", "local_sophia", "fedpac_soap"])
def test_async_runs_algorithms(problem, algo):
    params, loss_fn, batch_fn = problem
    fed = FedConfig(algorithm=algo, n_clients=N_CLIENTS, participation=0.5,
                    rounds=3, local_steps=3, runtime="async")
    exp = AsyncFederatedExperiment(fed, params, loss_fn, batch_fn,
                                   async_cfg=_async_cfg())
    hist = exp.run()
    assert len(hist) == 3
    assert np.isfinite(hist[-1]["loss"])
    assert hist[-1]["round"] == 3
    assert all(h["staleness"] >= 0.0 for h in hist)
    assert exp.comm_bytes_per_round() > 0


def test_async_staleness_surfaces_in_metrics(problem):
    params, loss_fn, batch_fn = problem
    fed = FedConfig(algorithm="fedavg", n_clients=N_CLIENTS,
                    participation=1.0, rounds=4, local_steps=2,
                    runtime="async")
    exp = AsyncFederatedExperiment(
        fed, params, loss_fn, batch_fn,
        async_cfg=_async_cfg(buffer_size=2, concurrency=6))
    hist = exp.run()
    # concurrency > buffer: later flushes must see stale arrivals, and the
    # poly decay must push freshness below 1
    assert max(h["staleness"] for h in hist) > 0.0
    assert min(h["freshness"] for h in hist) < 1.0


def test_async_run_reproducible(problem):
    params, loss_fn, batch_fn = problem
    def go():
        fed = FedConfig(algorithm="fedpac_soap", n_clients=N_CLIENTS,
                        participation=0.5, rounds=3, local_steps=2, seed=11,
                        runtime="async")
        exp = AsyncFederatedExperiment(fed, params, loss_fn, batch_fn,
                                       async_cfg=_async_cfg())
        return exp.run()
    a, b = go(), go()
    assert [h["loss"] for h in a] == [h["loss"] for h in b]
    assert [h["staleness"] for h in a] == [h["staleness"] for h in b]


def test_async_rejects_scaffold(problem):
    params, loss_fn, batch_fn = problem
    fed = FedConfig(algorithm="scaffold", n_clients=N_CLIENTS)
    with pytest.raises(ValueError):
        AsyncFederatedExperiment(fed, params, loss_fn, batch_fn)


def test_make_experiment_dispatch(problem):
    params, loss_fn, batch_fn = problem
    fed = FedConfig(algorithm="fedavg", n_clients=N_CLIENTS, rounds=1)
    assert isinstance(make_experiment(fed, params, loss_fn, batch_fn),
                      FederatedExperiment)
    fed_async = FedConfig(algorithm="fedavg", n_clients=N_CLIENTS, rounds=1,
                          runtime="async")
    assert isinstance(make_experiment(fed_async, params, loss_fn, batch_fn),
                      AsyncFederatedExperiment)
    with pytest.raises(ValueError):
        make_experiment(FedConfig(runtime="bogus"), params, loss_fn, batch_fn)


# ---------------------------------------------------------------- satellites

def test_explicit_lr_zero_not_discarded(problem):
    params, loss_fn, batch_fn = problem
    fed = FedConfig(algorithm="fedavg", n_clients=N_CLIENTS, lr=0.0)
    exp = FederatedExperiment(fed, params, loss_fn, batch_fn)
    assert exp.lr == 0.0
    assert resolve_lr(FedConfig(lr=None), "sgd") == optim.DEFAULT_LR["sgd"]
    assert resolve_lr(FedConfig(lr=0.0), "sgd") == 0.0


def test_stage_cohort_batches_single_stack():
    def batch_fn(cid, rng):
        return {"x": np.full((3, 2), float(cid)), "y": np.arange(3)}
    rng = np.random.default_rng(0)
    out = stage_cohort_batches(batch_fn, [1, 4], local_steps=5, rng=rng)
    assert out["x"].shape == (2, 5, 3, 2)
    assert out["y"].shape == (2, 5, 3)
    assert isinstance(out["x"], jax.Array)
    np.testing.assert_allclose(np.asarray(out["x"][1]), 4.0)
