"""Unified round engine: sync/async bitwise equivalence, executor backends,
functional geometry controller, checkpointed controller state, config
validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import init_server, make_round_fn, zero_theta
from repro.core.client import LocalRunConfig, client_round
from repro.core.engine import (
    ExecutorConfig, GeometryController, auto_controller, fixed_controller,
    make_cohort_executor, make_controller, update_controller,
)
from repro.checkpoint import CheckpointManager
from repro.fed import AsyncConfig, FedConfig
from repro.fed.rounds import parse_algorithm
from repro.fed.async_runtime.buffer import make_async_aggregate_fn

S, K, D, OUT = 4, 3, 16, 8   # w is (16, 8): inside SOAP's matrix domain
KEY = jax.random.key(0)


def _problem():
    W = jax.random.normal(KEY, (D, OUT))
    params = {"w": jnp.zeros((D, OUT))}

    def loss_fn(p, b):
        X, Y = b
        return jnp.mean((X @ p["w"] - Y) ** 2)

    def batches(key):
        X = jax.random.normal(key, (S, K, 8, D))
        return X, X @ W

    return params, loss_fn, batches


# ------------------------------------------------------- sync == async flush

def test_zero_staleness_flush_bitwise_matches_sync_round():
    """A buffer flush with w_i = 1 (rho = 1) must produce a bitwise-identical
    ServerState to one synchronous round on the same cohort."""
    params, loss_fn, batches = _problem()
    opt = optim.make("soap")
    lr, beta = 0.05, 0.5
    b = batches(jax.random.key(1))
    rng = jax.random.key(2)

    # sync path: the engine-backed round fn (eager so each op is its own
    # XLA program — fusion cannot perturb the comparison)
    rf = make_round_fn(loss_fn, opt, lr=lr, local_steps=K, beta=beta,
                       jit=False)
    server = init_server(params, opt)
    sync_out, _ = rf(server, b, rng)

    # async path: train the same cohort from the same snapshot, then one
    # zero-staleness flush
    theta0 = zero_theta(opt, params)
    run = LocalRunConfig(lr=lr, local_steps=K, beta=0.0, align=True)
    keys = jax.random.split(rng, S)
    deltas, thetas, _ = jax.vmap(
        lambda bi, ki: client_round(loss_fn, opt, run, params, theta0,
                                    server.g_global, bi, ki,
                                    beta=jnp.float32(beta)))(b, keys)
    flush = make_async_aggregate_fn(lr=lr, local_steps=K, jit=False)
    p, th, g, _, _ = flush(params, theta0, server.g_global,
                           fixed_controller(beta), deltas, thetas,
                           jnp.ones(S, jnp.float32))

    for a, c in zip(jax.tree.leaves(sync_out.params), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    for a, c in zip(jax.tree.leaves(sync_out.theta), jax.tree.leaves(th)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    for a, c in zip(jax.tree.leaves(sync_out.g_global), jax.tree.leaves(g)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_no_align_round_keeps_theta_version():
    params, loss_fn, batches = _problem()
    opt = optim.make("soap")
    rf = make_round_fn(loss_fn, opt, lr=0.05, local_steps=K, beta=0.0,
                       align=False, correct=False)
    server = init_server(params, opt)
    out, metrics = rf(server, batches(jax.random.key(1)), jax.random.key(2))
    assert out.round == 1 and out.theta_version == 0
    assert out.theta is None
    assert float(metrics["drift"]) > 0.0  # drift still measured

    aligned = make_round_fn(loss_fn, opt, lr=0.05, local_steps=K, beta=0.0)(
        init_server(params, opt), batches(jax.random.key(1)),
        jax.random.key(2))[0]
    assert aligned.theta_version == 1


# ------------------------------------------------------------- executors

@pytest.mark.parametrize("cfg", [
    ExecutorConfig(backend="shard_map"),
    ExecutorConfig(backend="chunked", chunk_size=2),
    ExecutorConfig(backend="chunked", chunk_size=3),   # S=4: remainder path
    ExecutorConfig(backend="chunked", chunk_size=16),  # chunk > cohort
])
def test_executor_backends_match_vmap(cfg):
    params, loss_fn, batches = _problem()
    opt = optim.make("soap")
    b = batches(jax.random.key(1))
    rng = jax.random.key(2)
    outs = {}
    for c in [ExecutorConfig(), cfg]:
        rf = make_round_fn(loss_fn, opt, lr=0.05, local_steps=K, beta=0.5,
                           executor=c)
        server, m = rf(init_server(params, opt), b, rng)
        outs[c.backend if c is cfg else "vmap"] = (server, m)
    ref_s, ref_m = outs["vmap"]
    got_s, got_m = outs[cfg.backend]
    np.testing.assert_allclose(np.asarray(got_s.params["w"]),
                               np.asarray(ref_s.params["w"]),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(got_m["loss"]), float(ref_m["loss"]),
                               rtol=1e-6)
    for a, c in zip(jax.tree.leaves(ref_s.theta),
                    jax.tree.leaves(got_s.theta)):
        np.testing.assert_allclose(np.asarray(c), np.asarray(a),
                                   rtol=1e-6, atol=1e-7)


def test_shard_map_rejects_indivisible_cohort():
    n_dev = len(jax.devices())
    runner = make_cohort_executor(ExecutorConfig(backend="shard_map"))
    if n_dev == 1:
        pytest.skip("indivisibility needs a >1-device client axis")
    bad = jnp.zeros((n_dev + 1, 3))
    with pytest.raises(ValueError, match="not divisible"):
        runner(lambda x: x * 2, bad)


def test_executor_config_validation():
    with pytest.raises(ValueError, match="backend"):
        ExecutorConfig(backend="bogus")
    with pytest.raises(ValueError, match="chunk_size"):
        ExecutorConfig(backend="chunked", chunk_size=0)


# ------------------------------------------------------- geometry controller

def test_controller_is_jit_pure_state():
    ctrl = auto_controller(beta_max=0.7)

    @jax.jit
    def step(c, d):
        return update_controller(c, d, 1.0)

    c1 = step(ctrl, jnp.float32(1.0))
    assert isinstance(c1, GeometryController)
    assert float(c1.beta) == pytest.approx(0.35)   # 0.7 * 1/(1+1)
    assert float(c1.drift_ema) == pytest.approx(1.0)
    # fixed controllers pass through untouched
    fc = fixed_controller(0.5)
    assert float(step(fc, jnp.float32(9.0)).beta) == 0.5


def test_controller_freshness_backoff():
    ctrl = auto_controller(beta_max=0.7)
    full = update_controller(ctrl, jnp.float32(1.0), 1.0)
    half = update_controller(ctrl, jnp.float32(1.0), 0.5)
    assert float(half.beta) == pytest.approx(0.5 * float(full.beta))


def test_controller_ema_smoothing():
    ctrl = auto_controller(beta_max=0.7, ema=0.5)
    c1 = update_controller(ctrl, jnp.float32(2.0))
    assert float(c1.drift_ema) == pytest.approx(1.0)  # 0.5*0 + 0.5*2
    c2 = update_controller(c1, jnp.float32(2.0))
    assert float(c2.drift_ema) == pytest.approx(1.5)


def test_adaptive_beta_evolves_inside_server_state():
    params, loss_fn, batches = _problem()
    opt = optim.make("soap")
    rf = make_round_fn(loss_fn, opt, lr=0.05, local_steps=K, beta="auto")
    server = init_server(params, opt)
    rng = jax.random.key(3)
    for r in range(3):
        rng, k1, k2 = jax.random.split(rng, 3)
        server, m = rf(server, batches(k1), k2)
    assert isinstance(server.geom, GeometryController)
    assert server.geom.adaptive
    assert float(server.geom.beta) > 0.0
    assert isinstance(server.geom.beta, jax.Array)  # not a Python-side cell


# ------------------------------------------------------------- checkpointing

def test_checkpoint_roundtrips_controller_and_theta_version(tmp_path):
    params, loss_fn, batches = _problem()
    opt = optim.make("soap")
    rf = make_round_fn(loss_fn, opt, lr=0.05, local_steps=K, beta="auto")
    server = init_server(params, opt,
                         geom=make_controller("auto", beta_max=0.7))
    rng = jax.random.key(3)
    for r in range(3):
        rng, k1, k2 = jax.random.split(rng, 3)
        server, _ = rf(server, batches(k1), k2)
    assert float(server.geom.beta) > 0.0

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(server)
    restored = mgr.restore(server)
    assert restored.round == server.round
    assert restored.theta_version == server.theta_version == 3
    assert restored.geom.adaptive and restored.geom.ema == server.geom.ema
    assert float(restored.geom.beta) == pytest.approx(
        float(server.geom.beta))
    assert float(restored.geom.drift_ema) == pytest.approx(
        float(server.geom.drift_ema))

    # a restored run continues from the saved beta, not from 0: the next
    # round *uses* (and reports) the checkpointed value
    rng, k1, k2 = jax.random.split(rng, 3)
    _, metrics = rf(restored, batches(k1), k2)
    assert float(metrics["beta"]) == pytest.approx(float(server.geom.beta))


def test_checkpoint_without_geom_restores_none(tmp_path):
    params = {"w": jnp.zeros((4, 4))}
    opt = optim.make("sgd")
    server = init_server(params, opt)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(server)
    assert mgr.restore(server).geom is None


def test_legacy_checkpoint_keeps_template_controller(tmp_path):
    """A pre-geom checkpoint (no 'geom' in meta.json) must not clobber the
    running experiment's controller with None."""
    import json, os
    params = {"w": jnp.zeros((4, 4))}
    opt = optim.make("sgd")
    server = init_server(params, opt)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(server)
    d = os.path.join(str(tmp_path), "step_00000000")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    meta.pop("geom")   # simulate a checkpoint written before controllers
    with open(os.path.join(d, "meta.json"), "w") as f:
        json.dump(meta, f)
    template = init_server(params, opt, geom=fixed_controller(0.3))
    restored = mgr.restore(template)
    assert float(restored.geom.beta) == pytest.approx(0.3)


# ------------------------------------------------------------- validation

@pytest.mark.parametrize("kw", [
    dict(participation=0.0), dict(participation=1.5),
    dict(participation=-0.2), dict(runtime="bogus"),
    dict(executor="bogus"), dict(chunk_size=0), dict(n_clients=0),
    dict(local_steps=0), dict(beta="bananas"),
])
def test_fed_config_rejects_bad_values(kw):
    with pytest.raises(ValueError):
        FedConfig(**kw)


def test_async_config_rejects_bad_values():
    with pytest.raises(ValueError, match="buffer_size"):
        AsyncConfig(buffer_size=0)
    with pytest.raises(ValueError, match="concurrency"):
        AsyncConfig(concurrency=0)
    # buffer larger than what the resolved concurrency can ever deliver
    with pytest.raises(ValueError, match="exceeds the resolved concurrency"):
        AsyncConfig(buffer_size=8, concurrency=2).resolve_concurrency(
            20, 0.5)
    # clamped-by-n_clients path
    with pytest.raises(ValueError, match="exceeds the resolved concurrency"):
        AsyncConfig(buffer_size=8).resolve_concurrency(4, 1.0)
    assert AsyncConfig(buffer_size=2).resolve_concurrency(20, 0.5) == 10


@pytest.mark.parametrize("name", ["bogus", "local_bogus", "fedpac_",
                                  "fedpac_bogus", "adamw"])
def test_parse_algorithm_rejects_unknown(name):
    with pytest.raises(ValueError, match="unknown"):
        parse_algorithm(name)


def test_parse_algorithm_known_matrix_unchanged():
    assert parse_algorithm("fedavg") == ("sgd", False, False, False)
    assert parse_algorithm("fedpac_soap_light") == ("soap", True, True, True)
    assert parse_algorithm("correct_only_muon") == ("muon", False, True,
                                                    False)
