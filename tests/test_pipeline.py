"""Chunk-streaming pipelined rounds (fed.pipeline): streamed-aggregation
parity against the monolithic wire flush, single-chunk bitwise equality
with the legacy sync round, worker-count determinism, spill/prefetch
round-trips, donation safety, config validation, and the per-chunk trace
spans."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import build_experiment
from repro.core.engine import (
    AggregationConfig, ExecutorConfig, aggregate_wire, finish_stream,
    make_cohort_executor, stream_chunk,
)
from repro.core.engine.executors import _default_mesh
from repro.core.transport import Dense, Transport, TransportConfig, \
    resolve_codec
from repro.data import make_image_classification, stream_dirichlet_map
from repro.fed import FedConfig
from repro.fed.staging import (
    StagingBuffers, is_thread_safe, mark_thread_safe,
    serialized_unless_thread_safe,
)
from repro.models.vision import classification_loss, cnn_apply, init_cnn
from repro.obs import MemorySink, attach
from repro.obs.trace import validate_event

POP = 64
B = 6


# ------------------------------------------------- streamed aggregation

def _stacked(seed=0, b=B):
    k1, k2 = jax.random.split(jax.random.key(seed))
    return {"M": jax.random.normal(k1, (b, 9, 7)),
            "v": jax.random.normal(k2, (b, 5))}


def _server(seed=11):
    k1, k2 = jax.random.split(jax.random.key(seed))
    params = {"M": jax.random.normal(k1, (9, 7)),
              "v": jax.random.normal(k2, (5,))}
    theta = jax.tree.map(lambda x: 0.1 * jnp.abs(x), params)
    g = jax.tree.map(jnp.zeros_like, params)
    return params, theta, g


CFG = AggregationConfig(lr=0.05, local_steps=4)


def _tp(name):
    if name == "dense":
        return Transport(Dense(), Dense())
    codec = resolve_codec(name, TransportConfig(rank=3, use_pallas=False))
    return Transport(codec, codec)


@pytest.mark.parametrize("name", ["dense", "qblock"])
def test_stream_single_chunk_bitwise_equals_aggregate_wire(name):
    # exact=True + carry=None routes through the very expressions the
    # monolithic aggregate_wire uses -> bitwise, jitted-vs-jitted
    params, theta, g = _server()
    tp = _tp(name)
    dmsgs = jax.vmap(tp.delta.encode)(_stacked(1))
    tmsgs = jax.vmap(tp.theta.encode)(_stacked(2))
    w = jnp.ones((B,), jnp.float32)

    ref_fn = jax.jit(lambda: aggregate_wire(params, theta, g, dmsgs, w,
                                            CFG, tp, tmsgs=tmsgs))

    def stream():
        carry = stream_chunk(None, dmsgs, w, tp, tmsgs=tmsgs,
                             exact=tp.theta.lossless)
        return finish_stream(params, theta, g, carry, B, CFG)

    ref = ref_fn()
    out = jax.jit(stream)()
    for a, b in zip(jax.tree.leaves(ref[:3]), jax.tree.leaves(out[:3])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in ("drift", "norm_drift", "freshness"):
        assert float(ref[3][k]) == float(out[3][k])
    for a, b in zip(jax.tree.leaves(ref[4]["step"]),
                    jax.tree.leaves(out[4]["step"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stream_multichunk_carry_crosses_jit_bitwise():
    # the pipeline's fold always crosses jit boundaries chunk by chunk;
    # re-folding through FRESH jit compilations must reproduce the same
    # bits (no compilation nondeterminism in the streamed reduction), and
    # the result stays close to the monolithic flush.  (A single fused
    # jit over both folds is NOT bitwise — XLA may reassociate across the
    # chunk expressions — which is exactly why the parity contract is
    # jitted-chunk-program vs jitted-chunk-program.)
    params, theta, g = _server()
    tp = _tp("dense")
    deltas, thetas = _stacked(3), _stacked(4)
    dmsgs = jax.vmap(tp.delta.encode)(deltas)
    tmsgs = jax.vmap(tp.theta.encode)(thetas)
    w = jnp.ones((B,), jnp.float32)
    cut = 4
    part = lambda t, a, b: jax.tree.map(lambda x: x[a:b], t)  # noqa: E731

    def fold():
        # distinct lambda objects -> distinct jit cache entries -> a
        # genuine recompilation on every call to fold()
        step1 = jax.jit(lambda: stream_chunk(
            None, part(dmsgs, 0, cut), w[:cut], tp,
            tmsgs=part(tmsgs, 0, cut)))
        step2 = jax.jit(lambda c: stream_chunk(
            c, part(dmsgs, cut, B), w[cut:], tp,
            tmsgs=part(tmsgs, cut, B)))
        fin = jax.jit(lambda c: finish_stream(params, theta, g, c, B, CFG))
        return fin(step2(step1()))

    ref, out = fold(), fold()
    for a, b in zip(jax.tree.leaves(ref[:4]), jax.tree.leaves(out[:4])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    mono = aggregate_wire(params, theta, g, dmsgs, w, CFG, tp, tmsgs=tmsgs)
    for a, b in zip(jax.tree.leaves(mono[:3]), jax.tree.leaves(out[:3])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    # multi-chunk drift uses the decomposed form; clamped non-negative
    assert float(out[3]["drift"]) >= 0.0
    np.testing.assert_allclose(float(out[3]["drift"]),
                               float(mono[3]["drift"]),
                               rtol=1e-4, atol=1e-6)


def test_stream_chunk_rejects_bad_calls():
    tp = _tp("dense")
    dmsgs = jax.vmap(tp.delta.encode)(_stacked(1))
    w = jnp.ones((B,), jnp.float32)
    carry = stream_chunk(None, dmsgs, w, tp)
    with pytest.raises(ValueError, match="single-chunk"):
        stream_chunk(carry, dmsgs, w, tp, exact=True)
    with pytest.raises(ValueError, match="not both"):
        stream_chunk(None, dmsgs, w, tp, tmsgs=dmsgs, thetas=_stacked(2))


# ---------------------------------------------------- experiment fixture

@pytest.fixture(scope="module")
def problem():
    X, y = make_image_classification(400, image_size=8, n_classes=4,
                                     seed=0, noise=1.0)
    parts = stream_dirichlet_map(y, POP, alpha=0.3, samples_per_client=32,
                                 seed=0)
    params = init_cnn(jax.random.key(0), n_classes=4, width=4, blocks=1)

    def loss_fn(p, batch):
        return classification_loss(cnn_apply(p, batch["x"]), batch["y"])

    @mark_thread_safe
    def batch_fn(cid, rng):
        idx = rng.choice(parts[cid], size=4)
        return {"x": np.asarray(X[idx]), "y": np.asarray(y[idx])}

    return params, loss_fn, batch_fn


def _run(problem, algo="scaffold", rounds=3, budget=None, tmp_path=None,
         **kw):
    params, loss_fn, batch_fn = problem
    exp = build_experiment(
        algo, params=params, loss_fn=loss_fn, client_batch_fn=batch_fn,
        rounds=rounds, local_steps=2, population_size=POP, cohort_size=8,
        state_budget=budget, seed=0,
        spill_dir=None if tmp_path is None else str(tmp_path), **kw)
    hist = exp.run()
    return exp, hist


def _assert_bitwise(exp_a, h_a, exp_b, h_b, keys=("loss", "drift",
                                                 "upload_bytes")):
    for ra, rb in zip(h_a, h_b):
        for k in keys:
            if k in ra or k in rb:
                assert ra[k] == rb[k], (k, ra[k], rb[k])
    for a, b in zip(jax.tree.leaves(exp_a.server.params),
                    jax.tree.leaves(exp_b.server.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- single-chunk parity

@pytest.mark.parametrize("algo", ["scaffold", "fedavg"])
def test_single_chunk_pipelined_bitwise_equals_serial(problem, algo):
    # pipeline_chunk >= cohort -> one chunk, exact fold: the pipelined
    # round must reproduce the legacy fused round bit for bit
    e0, h0 = _run(problem, algo=algo)
    e1, h1 = _run(problem, algo=algo, pipeline=True, pipeline_chunk=8)
    assert e1.pipeline is not None and e1.pipeline.exact
    assert h1[-1]["pipeline_chunks"] == 1
    _assert_bitwise(e0, h0, e1, h1)


def test_single_chunk_pipelined_bitwise_second_order(problem):
    # aligned second-order path: theta uploads + drift controller engaged
    e0, h0 = _run(problem, algo="fedpac_soap", rounds=2)
    e1, h1 = _run(problem, algo="fedpac_soap", rounds=2, pipeline=True,
                  pipeline_chunk=64)
    _assert_bitwise(e0, h0, e1, h1, keys=("loss", "drift", "beta",
                                          "upload_bytes"))
    for a, b in zip(jax.tree.leaves(e0.server.theta),
                    jax.tree.leaves(e1.server.theta)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------- multi-chunk determinism

def test_multichunk_worker_count_invariant_and_close_to_serial(problem):
    # chunk=3 on cohort 8 -> chunks (3, 3, 2) incl. the tail program;
    # staged rows are keyed by client id, so the stager worker count can
    # never change the numbers
    runs = {w: _run(problem, pipeline=True, pipeline_chunk=3,
                    pipeline_workers=w) for w in (1, 2, 8)}
    e1, h1 = runs[1]
    assert h1[-1]["pipeline_chunks"] == 3
    assert h1[-1]["pipeline_chunk_size"] == 3
    assert 0.0 <= h1[-1]["pipeline_bubble"] <= 1.0
    for w in (2, 8):
        _assert_bitwise(e1, h1, *runs[w])
    # multi-chunk folds change the reduction order -> allclose, not ==
    e0, h0 = _run(problem)
    for ra, rb in zip(h0, h1):
        np.testing.assert_allclose(ra["loss"], rb["loss"], rtol=1e-5)
    for a, b in zip(jax.tree.leaves(e0.server.params),
                    jax.tree.leaves(e1.server.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)


def test_pipelined_spill_restore_bitwise(problem, tmp_path):
    # budget == cohort forces evict/spill every round; the pipeline's
    # deferred acquire + prefetch + collect_pending must reproduce the
    # serial store path exactly, spills and all
    e0, h0 = _run(problem, rounds=4, budget=8, tmp_path=tmp_path / "s")
    e1, h1 = _run(problem, rounds=4, budget=8, tmp_path=tmp_path / "p",
                  pipeline=True, pipeline_chunk=8)
    assert h1[-1]["state_spills"] > 0
    assert h1[-1]["state_spills"] == h0[-1]["state_spills"]
    assert h1[-1]["state_restores"] == h0[-1]["state_restores"]
    _assert_bitwise(e0, h0, e1, h1)


def test_multichunk_spill_restore_worker_invariant(problem, tmp_path):
    a = _run(problem, rounds=4, budget=8, tmp_path=tmp_path / "a",
             pipeline=True, pipeline_chunk=3, pipeline_workers=1)
    b = _run(problem, rounds=4, budget=8, tmp_path=tmp_path / "b",
             pipeline=True, pipeline_chunk=3, pipeline_workers=8)
    assert a[1][-1]["state_restores"] > 0
    _assert_bitwise(a[0], a[1], b[0], b[1])


def test_pipeline_donation_does_not_alias_live_buffers(problem):
    # chunk>1 rounds donate write_state/carry back to _next; the buffers
    # the experiment still holds (store state, server params) must stay
    # readable and unchanged by the in-place reuse
    params, loss_fn, batch_fn = problem
    exp = build_experiment(
        "scaffold", params=params, loss_fn=loss_fn,
        client_batch_fn=batch_fn, rounds=2, local_steps=2,
        population_size=POP, cohort_size=8, seed=0, pipeline=True,
        pipeline_chunk=3)
    live_params = exp.server.params
    live_state = exp.state_store.state
    snap_p = jax.tree.map(lambda x: np.asarray(x).copy(), live_params)
    snap_s = jax.tree.map(lambda x: np.asarray(x).copy(), live_state)
    exp.run_round()
    exp.run_round()
    for ref, snap in ((live_params, snap_p), (live_state, snap_s)):
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(snap)):
            np.testing.assert_array_equal(np.asarray(a), b)


# ------------------------------------------------- validation, fallback

def test_pipeline_config_validation(problem):
    with pytest.raises(ValueError, match="population"):
        FedConfig(pipeline=True, n_clients=4, cohort_size=4)
    with pytest.raises(ValueError, match="sync"):
        FedConfig(pipeline=True, population_size=100, cohort_size=4,
                  runtime="async")
    with pytest.raises(ValueError, match="pipeline_chunk"):
        FedConfig(pipeline_chunk=0)
    with pytest.raises(ValueError, match="pipeline_workers"):
        FedConfig(pipeline_workers=0)


def test_mixing_algorithms_fall_back_to_serial_round(problem):
    params, loss_fn, batch_fn = problem
    with pytest.warns(RuntimeWarning, match="mixing"):
        exp = build_experiment(
            "fedpm_soap", params=params, loss_fn=loss_fn,
            client_batch_fn=batch_fn, rounds=1, local_steps=2,
            population_size=POP, cohort_size=4, seed=0, pipeline=True)
    assert exp.pipeline is None
    rec = exp.run_round()          # serial round still works end to end
    assert np.isfinite(rec["loss"])


# ------------------------------------------------- chunked executor

def test_chunked_run_pads_and_drops_remainder():
    # 8 clients, chunk 3 -> scan over 2 full chunks + padded tail whose
    # garbage rows are dropped; must equal plain vmap bitwise
    def one(cid, x, k):
        return jnp.sin(x) * (cid + 1), x.sum() + cid

    ids = jnp.arange(8)
    xs = jax.random.normal(jax.random.key(0), (8, 5))
    ks = jnp.arange(8)
    ref = jax.vmap(one)(ids, xs, ks)
    exe = make_cohort_executor(ExecutorConfig(backend="chunked",
                                              chunk_size=3))
    out = exe(one, ids, xs, ks)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_default_mesh_is_cached():
    assert _default_mesh() is _default_mesh()


# ------------------------------------------------------ observability

def test_pipeline_emits_chunk_spans(problem):
    params, loss_fn, batch_fn = problem
    exp = build_experiment(
        "scaffold", params=params, loss_fn=loss_fn,
        client_batch_fn=batch_fn, rounds=1, local_steps=2,
        population_size=POP, cohort_size=8, seed=0, pipeline=True,
        pipeline_chunk=4)
    sink = MemorySink()
    attach(exp, sink)
    exp.run()
    for ev in sink.events:
        validate_event(ev)
    spans = [e for e in sink.events if e["event"] == "span"]
    phases = {e["phase"] for e in spans}
    assert {"staging", "state_acquire", "chunk_stage", "chunk_restore",
            "chunk_compute", "flush"} <= phases
    chunked = [e for e in spans if e["phase"] == "chunk_compute"]
    assert sorted(e["chunk"] for e in chunked) == [0, 1]
    assert all(e["dur_s"] >= 0 for e in spans)
    rec = exp.history[-1]
    assert rec["pipeline_stage_wait_s"] >= 0
    assert rec["pipeline_restore_wait_s"] >= 0


def test_serial_population_round_emits_staging_subspans(problem):
    params, loss_fn, batch_fn = problem
    exp = build_experiment(
        "scaffold", params=params, loss_fn=loss_fn,
        client_batch_fn=batch_fn, rounds=1, local_steps=2,
        population_size=POP, cohort_size=8, seed=0)
    sink = MemorySink()
    attach(exp, sink)
    exp.run()
    phases = {e["phase"] for e in sink.events if e["event"] == "span"}
    assert {"staging", "stage_batches", "state_acquire",
            "update"} <= phases


# -------------------------------------------------------- host buffers

def test_staging_buffers_reuse_and_peek():
    bufs = StagingBuffers()
    row = {"x": np.ones((2, 3), np.float32)}
    a = bufs.get(("pipe", 0), 4, row)
    b = bufs.get(("pipe", 0), 4, row)
    assert a["x"] is b["x"]                       # same storage, reused
    assert bufs.get(("pipe", 1), 4, row)["x"] is not a["x"]
    StagingBuffers.fill_row(a, 2, row)
    peeked = bufs.peek(("pipe", 0), 4)
    assert peeked["x"] is a["x"]
    np.testing.assert_array_equal(peeked["x"][2], row["x"])
    with pytest.raises(KeyError):
        bufs.peek(("pipe", 9), 4)


def test_thread_safety_contract():
    def unsafe(cid, rng):
        return cid

    @mark_thread_safe
    def safe(cid, rng):
        return cid

    assert not is_thread_safe(unsafe) and is_thread_safe(safe)
    assert serialized_unless_thread_safe(safe) is safe
    wrapped = serialized_unless_thread_safe(unsafe)
    assert wrapped is not unsafe and wrapped(3, None) == 3
