"""Federated runtime integration: every paper algorithm runs end-to-end on a
tiny vision problem; scaffold state bookkeeping; comm accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_image_classification, dirichlet_partition
from repro.models.vision import (
    init_cnn, cnn_apply, init_vit, vit_apply, classification_loss, accuracy,
)
from repro.fed import FedConfig, FederatedExperiment, parse_algorithm

N_CLIENTS = 6


@pytest.fixture(scope="module")
def problem():
    X, y = make_image_classification(600, image_size=8, n_classes=4, seed=0,
                                     noise=1.0)
    parts = dirichlet_partition(y, N_CLIENTS, 0.2, seed=0)
    params = init_cnn(jax.random.key(0), n_classes=4, width=4, blocks=1)

    def loss_fn(p, batch):
        return classification_loss(cnn_apply(p, batch["x"]), batch["y"])

    def batch_fn(cid, rng):
        idx = rng.choice(parts[cid], size=4)
        return {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}

    return params, loss_fn, batch_fn


ALGOS = ["fedavg", "scaffold", "fedcm", "local_adamw", "local_sophia",
         "local_muon", "local_soap", "fedpac_sophia", "fedpac_muon",
         "fedpac_soap", "fedpac_soap_light", "align_only_soap",
         "correct_only_muon", "fedpm_soap"]


@pytest.mark.parametrize("algo", ALGOS)
def test_algorithm_runs(problem, algo):
    params, loss_fn, batch_fn = problem
    fed = FedConfig(algorithm=algo, n_clients=N_CLIENTS, participation=0.5,
                    rounds=2, local_steps=3, svd_rank=2)
    exp = FederatedExperiment(fed, params, loss_fn, batch_fn)
    hist = exp.run()
    assert len(hist) == 2
    assert np.isfinite(hist[-1]["loss"])
    assert exp.comm_bytes_per_round() > 0


def test_parse_algorithm():
    assert parse_algorithm("fedavg") == ("sgd", False, False, False)
    assert parse_algorithm("fedpac_soap") == ("soap", True, True, False)
    assert parse_algorithm("fedpac_soap_light") == ("soap", True, True, True)
    assert parse_algorithm("local_muon") == ("muon", False, False, False)
    assert parse_algorithm("fedcm") == ("sgd", False, True, False)
    assert parse_algorithm("align_only_soap") == ("soap", True, False, False)


def test_scaffold_state_updates(problem):
    """SCAFFOLD's control variates live in the unified client_state slot."""
    params, loss_fn, batch_fn = problem
    fed = FedConfig(algorithm="scaffold", n_clients=N_CLIENTS,
                    participation=1.0, rounds=1, local_steps=3)
    exp = FederatedExperiment(fed, params, loss_fn, batch_fn)
    c0 = jax.tree.leaves(exp.client_state.c_clients)[0].copy()
    exp.run()
    c1 = jax.tree.leaves(exp.client_state.c_clients)[0]
    assert bool(jnp.any(c0 != c1))  # control variates moved
    assert exp.spec.client_state is not None  # declared, not special-cased


def test_fedpac_comm_cost_exceeds_local(problem):
    params, loss_fn, batch_fn = problem
    costs = {}
    for algo in ["local_soap", "fedpac_soap", "fedpac_soap_light"]:
        fed = FedConfig(algorithm=algo, n_clients=N_CLIENTS,
                        participation=0.5, rounds=1, local_steps=2,
                        svd_rank=2)
        exp = FederatedExperiment(fed, params, loss_fn, batch_fn)
        exp.run()
        costs[algo] = exp.comm_bytes_per_round()
    assert costs["local_soap"] < costs["fedpac_soap_light"] \
        < costs["fedpac_soap"]


def test_vit_apply_shapes():
    params, meta = init_vit(jax.random.key(0), image_size=8, patch=4,
                            d_model=32, layers=1, heads=2, n_classes=5)
    x = jnp.zeros((3, 8, 8, 3))
    logits = vit_apply(params, meta, x)
    assert logits.shape == (3, 5)
    assert float(accuracy(logits, jnp.zeros(3, jnp.int32))) >= 0.0
