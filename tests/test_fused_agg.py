"""Fused decode-aggregate flush: the kernels/fused_agg triad, the
Codec.accumulate/sq_norms protocol, aggregate_wire parity against
decode-then-aggregate, wire_dtype round-trip properties, the shared
backend auto rule, and the exact byte-accounting regression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (
    AggregationConfig, aggregate, aggregate_wire, make_controller,
    normalized_client_mean, weighted_client_mean,
)
from repro.core.transport import (
    Dense, Transport, TransportConfig, encode_with_feedback,
    registered_codecs, resolve_codec, wire_bytes,
)
from repro.fed.async_runtime.buffer import make_async_aggregate_fn
from repro.kernels.fused_agg import kernel as fused_kernel
from repro.kernels.fused_agg import ops as fused_ops
from repro.kernels.fused_agg import ref as fused_ref
from repro.utils import hw
from repro.utils.tree import client_weighted_sum

KEY = jax.random.key(0)
B = 5


def _stacked(seed=0, b=B, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    return {"L": jax.random.normal(k1, (b, 16, 12)).astype(dtype),
            "stack": jax.random.normal(k2, (b, 3, 10, 9)).astype(dtype),
            "vec": jax.random.normal(k3, (b, 7)).astype(dtype)}


def _weights(b=B):
    return 0.25 + 0.75 * jax.random.uniform(jax.random.key(9), (b,))


ALL_CODECS = sorted(set(registered_codecs()) | {"lowrank_svd+qblock"})


# ----------------------------------------------------------- Pallas kernel

@pytest.mark.parametrize("shape", [(3, 5, 128), (8, 70, 128), (2, 1, 256),
                                   (4, 33, 128)])
def test_dequant_accumulate_kernel_matches_ref(shape):
    b, nb, block = shape
    q = jax.random.randint(jax.random.key(1), shape, -127, 128, jnp.int8)
    scale = jnp.abs(jax.random.normal(jax.random.key(2), (b, nb))) + 1e-3
    w = _weights(b)
    ref = fused_ref.dequant_accumulate(q, scale, w)
    out = fused_kernel.dequant_accumulate(q, scale, w, interpret=True)
    assert out.shape == (nb, block) and out.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_dequant_accumulate_kernel_rejects_bad_block():
    q = jnp.zeros((2, 3, 64), jnp.int8)
    with pytest.raises(ValueError, match="128"):
        fused_kernel.dequant_accumulate(q, jnp.ones((2, 3)), jnp.ones((2,)),
                                        interpret=True)


def test_fused_ops_dispatch_paths_agree():
    q = jax.random.randint(jax.random.key(3), (4, 6, 128), -127, 128,
                           jnp.int8)
    scale = jnp.abs(jax.random.normal(jax.random.key(4), (4, 6))) + 1e-3
    w = _weights(4)
    a = fused_ops.dequant_accumulate(q, scale, w, use_pallas=False)
    bb = fused_ops.dequant_accumulate(q, scale, w, use_pallas=True,
                                      interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))


def test_lowrank_accumulate_matches_per_client_loop():
    b, m, r, n = 4, 10, 3, 8
    u = jax.random.normal(jax.random.key(5), (b, m, r))
    s = jnp.abs(jax.random.normal(jax.random.key(6), (b, r)))
    vt = jax.random.normal(jax.random.key(7), (b, r, n))
    w = _weights(b)
    loop = sum(w[i] * (u[i] * s[i]) @ vt[i] for i in range(b))
    fused = fused_ref.lowrank_accumulate(u, s, vt, w)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(loop),
                               rtol=2e-6, atol=2e-6)
    # batched leading dims (stacked matrices) contract per matrix
    u4 = jax.random.normal(jax.random.key(8), (b, 2, m, r))
    s4 = jnp.abs(jax.random.normal(jax.random.key(9), (b, 2, r)))
    vt4 = jax.random.normal(jax.random.key(10), (b, 2, r, n))
    fused4 = fused_ref.lowrank_accumulate(u4, s4, vt4, w)
    loop4 = sum(w[i] * np.einsum("kmr,kr,krn->kmn", u4[i], s4[i], vt4[i])
                for i in range(b))
    np.testing.assert_allclose(np.asarray(fused4), np.asarray(loop4),
                               rtol=2e-6, atol=2e-6)


# ----------------------------------------- Codec.accumulate / sq_norms

@pytest.mark.parametrize("name", ALL_CODECS)
def test_accumulate_matches_decode_then_contract(name):
    cfg = TransportConfig(rank=4, use_pallas=False)
    codec = resolve_codec(name, cfg)
    msgs = jax.vmap(codec.encode)(_stacked())
    w = _weights()
    fused = codec.accumulate(msgs, w)
    oracle = client_weighted_sum(jax.vmap(codec.decode)(msgs), w)
    for a, bb in zip(jax.tree.leaves(fused), jax.tree.leaves(oracle)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   rtol=1e-5, atol=1e-5)


def test_dense_accumulate_is_bitwise():
    codec = Dense()
    msgs = jax.vmap(codec.encode)(_stacked())
    w = _weights()
    fused = codec.accumulate(msgs, w)
    oracle = client_weighted_sum(jax.vmap(codec.decode)(msgs), w)
    for a, bb in zip(jax.tree.leaves(fused), jax.tree.leaves(oracle)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))


@pytest.mark.parametrize("name", ALL_CODECS)
def test_sq_norms_matches_decoded_norms(name):
    cfg = TransportConfig(rank=4, use_pallas=False)
    codec = resolve_codec(name, cfg)
    msgs = jax.vmap(codec.encode)(_stacked())
    sq = codec.sq_norms(msgs)
    dec = jax.vmap(codec.decode)(msgs)
    want = sum(np.sum(np.asarray(x, np.float32).reshape(B, -1) ** 2, axis=1)
               for x in jax.tree.leaves(dec))
    assert sq.shape == (B,)
    np.testing.assert_allclose(np.asarray(sq), want, rtol=1e-4)


# -------------------------------------------------- aggregate_wire parity

def _server(seed=11):
    k1, k2 = jax.random.split(jax.random.key(seed))
    params = {"L": jax.random.normal(k1, (16, 12)),
              "stack": jax.random.normal(k2, (3, 10, 9)),
              "vec": jnp.zeros((7,))}
    theta = jax.tree.map(lambda x: 0.1 * jnp.abs(x), params)
    g = jax.tree.map(jnp.zeros_like, params)
    return params, theta, g


CFG = AggregationConfig(lr=0.05, local_steps=4)


def test_aggregate_wire_dense_bitwise_equals_aggregate():
    params, theta, g = _server()
    deltas, thetas = _stacked(1), _stacked(2)
    w = _weights()
    tp = Transport(Dense(), Dense())
    dmsgs = jax.vmap(tp.delta.encode)(deltas)
    tmsgs = jax.vmap(tp.theta.encode)(thetas)
    ref = aggregate(params, theta, g, deltas, thetas, w, CFG)
    out = aggregate_wire(params, theta, g, dmsgs, w, CFG, tp, tmsgs=tmsgs)
    for a, bb in zip(jax.tree.leaves(ref[:3]), jax.tree.leaves(out[:3])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))
    for k in ("drift", "norm_drift", "freshness"):
        assert float(ref[3][k]) == float(out[3][k])
    # aux carries the reusable weighted mean for telemetry
    step = jax.tree.map(lambda x: x / B, client_weighted_sum(deltas, w))
    for a, bb in zip(jax.tree.leaves(step), jax.tree.leaves(out[4]["step"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))


@pytest.mark.parametrize("name", ["qblock", "lowrank_svd",
                                  "lowrank_svd+qblock"])
def test_aggregate_wire_lossy_close_to_decode_then_aggregate(name):
    params, theta, g = _server()
    cfg = TransportConfig(rank=4, use_pallas=False)
    codec = resolve_codec(name, cfg)
    tp = Transport(codec, codec)
    deltas, thetas = _stacked(3), _stacked(4)
    w = _weights()
    dmsgs = jax.vmap(codec.encode)(deltas)
    tmsgs = jax.vmap(codec.encode)(thetas)
    dec_d = jax.vmap(codec.decode)(dmsgs)
    dec_t = jax.vmap(codec.decode)(tmsgs)
    ref = aggregate(params, theta, g, dec_d, dec_t, w, CFG)
    out = aggregate_wire(params, theta, g, dmsgs, w, CFG, tp, tmsgs=tmsgs)
    for a, bb in zip(jax.tree.leaves(ref[:3]), jax.tree.leaves(out[:3])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(bb, np.float32),
                                   rtol=1e-4, atol=1e-5)
    # the wire-native drift decomposition matches the classic centered
    # form up to float error, and is clamped non-negative
    assert float(out[3]["drift"]) >= 0.0
    np.testing.assert_allclose(float(out[3]["drift"]),
                               float(ref[3]["drift"]), rtol=1e-3, atol=1e-5)


def test_aggregate_wire_need_thetas_does_not_change_numerics():
    params, theta, g = _server()
    codec = resolve_codec("qblock", TransportConfig(use_pallas=False))
    tp = Transport(codec, codec)
    dmsgs = jax.vmap(codec.encode)(_stacked(3))
    tmsgs = jax.vmap(codec.encode)(_stacked(4))
    w = _weights()
    a = aggregate_wire(params, theta, g, dmsgs, w, CFG, tp, tmsgs=tmsgs,
                       need_thetas=False)
    bb = aggregate_wire(params, theta, g, dmsgs, w, CFG, tp, tmsgs=tmsgs,
                        need_thetas=True)
    assert a[4]["thetas"] is None and bb[4]["thetas"] is not None
    for x, y in zip(jax.tree.leaves(a[:4]), jax.tree.leaves(bb[:4])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_aggregate_wire_rejects_both_theta_channels():
    params, theta, g = _server()
    tp = Transport(Dense(), Dense())
    dmsgs = jax.vmap(tp.delta.encode)(_stacked(1))
    tmsgs = jax.vmap(tp.theta.encode)(_stacked(2))
    with pytest.raises(ValueError, match="not both"):
        aggregate_wire(params, theta, g, dmsgs, _weights(), CFG, tp,
                       tmsgs=tmsgs, thetas=_stacked(2))


def test_fused_async_flush_matches_aggregate_wire_bitwise():
    """The jitted fused flush (no mixing) routes through the exact same
    aggregate_wire the sync round uses — same inputs, same bits."""
    params, theta, g = _server()
    codec = resolve_codec("qblock", TransportConfig(use_pallas=False))
    tp = Transport(codec, codec)
    dmsgs = jax.vmap(codec.encode)(_stacked(3))
    tmsgs = jax.vmap(codec.encode)(_stacked(4))
    w = jnp.ones((B,), jnp.float32)          # zero-staleness buffer
    ctrl = make_controller(0.5, correct=True)
    cell = {}
    flush = make_async_aggregate_fn(lr=CFG.lr, local_steps=CFG.local_steps,
                                    transport=tp, wire_cell=cell)
    fp, ft, fg, _, fm = flush(params, theta, g, ctrl, dmsgs, tmsgs, w)
    wire_fn = jax.jit(lambda p, th, gg, dm, tm, ww: aggregate_wire(
        p, th, gg, dm, ww, CFG, tp, tmsgs=tm))
    wp, wt, wg, wm, _ = wire_fn(params, theta, g, dmsgs, tmsgs, w)
    for a, bb in zip(jax.tree.leaves((fp, ft, fg)),
                     jax.tree.leaves((wp, wt, wg))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))
    assert float(fm["drift"]) == float(wm["drift"])
    # S1 regression: the wire cell records the exact total, not a
    # truncating per-client division
    assert cell["total"] == wire_bytes(dmsgs) + wire_bytes(tmsgs)
    assert cell["cohort"] == B


# ------------------------------------------------- contraction rewrite (S2)

def test_weighted_client_mean_is_dot_general_bitwise():
    tree = _stacked(7)
    w = _weights()
    got = weighted_client_mean(tree, w)
    for leaf, out in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        oracle = jax.lax.dot_general(
            w.astype(jnp.float32), leaf.astype(jnp.float32),
            dimension_numbers=(((0,), (0,)), ((), ()))) / B
        np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))
        # and agrees with the legacy w-scaled-copy formulation numerically
        legacy = jnp.mean(w.reshape((B,) + (1,) * (leaf.ndim - 1)) * leaf,
                          axis=0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(legacy),
                                   rtol=1e-5, atol=1e-6)
    # weights=None stays the plain uniform mean, bitwise
    for leaf, out in zip(jax.tree.leaves(tree),
                         jax.tree.leaves(weighted_client_mean(tree))):
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(jnp.mean(leaf, axis=0)))


def test_normalized_client_mean_is_dot_general_bitwise():
    tree = _stacked(8)
    w = _weights()
    denom = jnp.sum(w.astype(jnp.float32)) + 1e-12
    got = normalized_client_mean(tree, w)
    for leaf, out in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        oracle = jax.lax.dot_general(
            w.astype(jnp.float32), leaf.astype(jnp.float32),
            dimension_numbers=(((0,), (0,)), ((), ()))) / denom
        np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


# ------------------------------------------------------- backend auto (S3)

def test_hw_auto_rule_consistent_with_backend():
    tpu = jax.default_backend() == "tpu"
    assert hw.on_tpu() == tpu
    assert hw.default_use_pallas() == tpu
    assert hw.default_interpret() == (not tpu)
    assert hw.resolve_use_pallas(None) == tpu
    assert hw.resolve_interpret(None) == (not tpu)
    # explicit booleans always pass through
    assert hw.resolve_use_pallas(True) is True
    assert hw.resolve_use_pallas(False) is False
    assert hw.resolve_interpret(True) is True
    assert hw.resolve_interpret(False) is False


def test_transport_config_defaults_follow_auto_rule():
    cfg = TransportConfig()
    assert cfg.use_pallas == hw.default_use_pallas()
    assert cfg.interpret == hw.default_interpret()
    qb = resolve_codec("qblock")
    assert qb.use_pallas == hw.default_use_pallas()
    assert qb.interpret == hw.default_interpret()


# ------------------------------------------------- wire_dtype properties

@pytest.mark.parametrize("name", ALL_CODECS)
@pytest.mark.parametrize("wire_dtype", ["f32", "bf16"])
@pytest.mark.parametrize("leaf_dtype", [jnp.float32, jnp.bfloat16])
def test_codec_roundtrip_preserves_shape_dtype_under_vmap(name, wire_dtype,
                                                          leaf_dtype):
    cfg = TransportConfig(rank=4, use_pallas=False, wire_dtype=wire_dtype)
    codec = resolve_codec(name, cfg)
    stacked = _stacked(5, dtype=leaf_dtype)
    out = jax.vmap(codec.decode)(jax.vmap(codec.encode)(stacked))
    for src, dec in zip(jax.tree.leaves(stacked), jax.tree.leaves(out)):
        assert dec.shape == src.shape
        assert dec.dtype == src.dtype


def test_bf16_wire_halves_floating_payload_bytes():
    tree = {"L": jnp.zeros((64, 48), jnp.float32)}
    f32 = wire_bytes(Dense().encode(tree))
    bf16 = wire_bytes(Dense(wire_dtype="bf16").encode(tree))
    assert bf16 * 2 == f32
    lr32 = resolve_codec("lowrank_svd", TransportConfig(rank=4))
    lr16 = resolve_codec("lowrank_svd",
                         TransportConfig(rank=4, wire_dtype="bf16"))
    assert wire_bytes(lr16.encode(tree)) * 2 == wire_bytes(lr32.encode(tree))
    # qblock is int8 + f32 scales either way
    qb32 = resolve_codec("qblock", TransportConfig(use_pallas=False))
    qb16 = resolve_codec("qblock", TransportConfig(use_pallas=False,
                                                   wire_dtype="bf16"))
    assert wire_bytes(qb32.encode(tree)) == wire_bytes(qb16.encode(tree))


def test_bf16_dense_is_lossy_and_activates_error_feedback():
    assert Dense().lossless
    lossy = Dense(wire_dtype="bf16")
    assert not lossy.lossless
    assert Transport(lossy, Dense()).feedback_active
    # EF composes with the bf16 wire: residual carries the rounding error
    delta = {"w": jax.random.normal(KEY, (10, 9))}
    res0 = jax.tree.map(jnp.zeros_like, delta)
    msg, dec, res1 = encode_with_feedback(lossy, delta, res0)
    assert msg.leaves[0].parts["x"].dtype == jnp.bfloat16
    assert res1["w"].dtype == jnp.float32
    assert float(jnp.max(jnp.abs(res1["w"]))) > 0.0
    np.testing.assert_allclose(np.asarray(res1["w"]),
                               np.asarray(delta["w"] - dec["w"]),
                               rtol=1e-5, atol=1e-6)


def test_wire_dtype_validated_eagerly():
    with pytest.raises(ValueError, match="wire_dtype"):
        TransportConfig(wire_dtype="f16")
    with pytest.raises(ValueError, match="wire_dtype"):
        Dense(wire_dtype="f64")
    from repro.fed import FedConfig
    with pytest.raises(ValueError, match="wire_dtype"):
        FedConfig(wire_dtype="int4")


def test_fedconfig_wire_dtype_reaches_transport():
    from repro.core.algorithms import resolve
    from repro.fed import FedConfig
    fed = FedConfig(algorithm="fedpac_soap", wire_dtype="bf16")
    tp = fed.make_transport(resolve("fedpac_soap"))
    assert not tp.theta.lossless
    msg = tp.theta.encode({"w": jnp.zeros((8, 8), jnp.float32)})
    assert msg.leaves[0].parts["x"].dtype == jnp.bfloat16
