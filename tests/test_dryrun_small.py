"""Dry-run machinery on a small forced-device mesh (subprocess: jax locks the
device count at first init, so the 8-device test must run isolated)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.launch import dryrun
    from repro.launch.specs import InputShape

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    out = {}
    for arch, shape_name, kind in [
        ("smollm-360m", "train_4k", "train"),
        ("mixtral-8x22b", "decode_32k", "decode"),
        ("falcon-mamba-7b", "long_500k", "decode"),
        ("qwen2-vl-7b", "prefill_32k", "prefill"),
    ]:
        cfg = configs.get_reduced(arch)
        shape = InputShape(shape_name, 64, 8, kind)
        _, _, lowered = dryrun.build_lowering(
            arch, shape_name, mesh, cfg=cfg, shape_override=shape)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        hlo = compiled.as_text()
        cb, per = dryrun.collective_bytes_from_hlo(hlo)
        out[f"{arch}:{shape_name}"] = {
            "flops": float(cost.get("flops", 0)),
            "collective_bytes": cb,
        }
    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def dryrun_result():
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_all_small_combos_compile(dryrun_result):
    assert len(dryrun_result) == 4


def test_train_step_has_collectives(dryrun_result):
    # FSDP/TP sharding must produce cross-device traffic
    assert dryrun_result["smollm-360m:train_4k"]["collective_bytes"] > 0


def test_flops_positive(dryrun_result):
    for k, v in dryrun_result.items():
        assert v["flops"] > 0, k


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes_from_hlo
    hlo = """
      %ag = bf16[2,64]{1,0} all-gather(%x), replica_groups={}
      %ar = f32[128]{0} all-reduce(%y), to_apply=%sum
      %noise = f32[4]{0} add(%a, %b)
    """
    total, per = collective_bytes_from_hlo(hlo)
    assert per["all-gather"] == 2 * 64 * 2
    assert per["all-reduce"] == 128 * 4
    assert total == per["all-gather"] + per["all-reduce"]
