"""Geometry transport subsystem: wire-true codecs, accounting, error
feedback, and the dense-codec bitwise equivalence with the pre-refactor
upload path in both runtimes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.api import build_experiment
from repro.core import init_server
from repro.core.algorithms import build_round_fn, resolve
from repro.core.compression import compressed_bytes, round_comm_bytes
from repro.core.transport import (
    Chain, Dense, LowRankSVD, PowerSketch, QBlock, Transport,
    TransportConfig, UnknownCodecError, encode_with_feedback,
    registered_codecs, resolve_codec, wire_bytes,
)
from repro.fed import (
    AsyncConfig, AsyncFederatedExperiment, FedConfig, FederatedExperiment,
    LatencyModel,
)
from repro.fed.async_runtime.buffer import make_async_aggregate_fn
from repro.utils.tree import tree_bytes

KEY = jax.random.key(3)
N_CLIENTS, D, OUT, K = 4, 12, 8, 2


def _tree(seed=0):
    k1, k2 = jax.random.split(jax.random.key(seed))
    return {"L": jax.random.normal(k1, (16, 12)),
            "stack": jax.random.normal(k2, (3, 10, 9)),
            "vec": jnp.arange(7, dtype=jnp.float32)}


def _problem():
    params = {"w": jnp.zeros((D, OUT))}
    W = jax.random.normal(KEY, (D, OUT))
    X = np.asarray(jax.random.normal(jax.random.key(1),
                                     (N_CLIENTS, 64, D)), np.float32)
    Y = X @ np.asarray(W, np.float32)

    def loss_fn(p, b):
        xb, yb = b
        return jnp.mean((xb @ p["w"] - yb) ** 2)

    def batch_fn(cid, rng):
        idx = rng.choice(64, size=8, replace=True)
        return jnp.asarray(X[cid, idx]), jnp.asarray(Y[cid, idx])

    return params, loss_fn, batch_fn


def _fed(algo, **kw):
    defaults = dict(algorithm=algo, n_clients=N_CLIENTS, participation=0.5,
                    rounds=2, local_steps=K, svd_rank=2, seed=0)
    defaults.update(kw)
    return FedConfig(**defaults)


# ----------------------------------------------------------------- registry

def test_registered_codecs_and_resolution():
    names = registered_codecs()
    for name in ["dense", "svd", "lowrank_svd", "power_sketch", "qblock"]:
        assert name in names
    assert isinstance(resolve_codec("dense"), Dense)
    assert isinstance(resolve_codec("svd"), LowRankSVD)  # legacy alias
    chain = resolve_codec("lowrank_svd+qblock", TransportConfig(rank=3))
    assert isinstance(chain, Chain)
    assert chain.name == "lowrank_svd+qblock" and not chain.lossless
    codec = LowRankSVD(rank=5)
    assert resolve_codec(codec) is codec


def test_unknown_codec_spec_rejected():
    with pytest.raises(UnknownCodecError, match="unknown upload codec"):
        resolve_codec("gzip")
    with pytest.raises(UnknownCodecError, match="unknown upload codec"):
        resolve_codec("lowrank_svd+bogus")
    with pytest.raises(UnknownCodecError, match="upload"):
        FedConfig(delta_codec="bogus")
    from repro.core.algorithms import AlgorithmSpec
    with pytest.raises(ValueError, match="upload"):
        AlgorithmSpec(name="tmp_t", delta_upload="bogus")


# -------------------------------------------------------- round-trip bounds

def test_dense_roundtrip_bitwise():
    tree = _tree()
    codec = Dense()
    out = codec.roundtrip(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("cls", [LowRankSVD, PowerSketch])
def test_lowrank_error_nonincreasing_in_rank(cls):
    mat = {"L": jax.random.normal(KEY, (16, 12))}
    errs = []
    for r in (1, 2, 4, 8, 12):
        out = cls(rank=r).roundtrip(mat)
        errs.append(float(jnp.linalg.norm(out["L"] - mat["L"])))
    assert all(a >= b - 1e-6 for a, b in zip(errs, errs[1:]))
    assert errs[-1] < 1e-3          # full rank reconstructs
    # sketch can't beat the optimal rank-r approximation (SVD)
    if cls is PowerSketch:
        svd_err = float(jnp.linalg.norm(
            LowRankSVD(rank=4).roundtrip(mat)["L"] - mat["L"]))
        sk_err = float(jnp.linalg.norm(
            PowerSketch(rank=4).roundtrip(mat)["L"] - mat["L"]))
        assert sk_err >= svd_err - 1e-5


def test_lowrank_small_leaves_pass_through():
    tree = _tree()
    out = LowRankSVD(rank=4).roundtrip(tree)
    np.testing.assert_array_equal(np.asarray(out["vec"]),
                                  np.asarray(tree["vec"]))
    # batched leaf: trailing dims compressed per matrix
    for i in range(3):
        assert np.linalg.matrix_rank(np.asarray(out["stack"][i]),
                                     tol=1e-4) <= 4


def test_qblock_error_bounded_by_half_scale():
    tree = {"x": 10.0 * jax.random.normal(KEY, (5, 90))}
    codec = QBlock(block=128)
    msg = codec.encode(tree)
    out = codec.decode(msg)
    scale = np.asarray(msg.leaves[0].parts["scale"])      # (nblocks,)
    err = np.abs(np.asarray(out["x"] - tree["x"])).reshape(-1)
    pad = scale.size * 128 - err.size
    err = np.pad(err, (0, pad))
    per_block = err.reshape(scale.size, 128).max(axis=1)
    assert np.all(per_block <= scale / 2 + 1e-6)


def test_qblock_message_is_self_describing():
    """The block size rides in the envelope: a decoder configured with a
    different qblock_size still frames the blocks correctly."""
    tree = {"x": 7.0 * jax.random.normal(KEY, (5, 90))}
    msg = QBlock(block=128).encode(tree)
    assert msg.leaves[0].extra == 128
    same = QBlock(block=128).decode(msg)
    other = QBlock(block=256).decode(msg)
    np.testing.assert_array_equal(np.asarray(same["x"]),
                                  np.asarray(other["x"]))


def test_chain_quantizes_factors():
    tree = {"L": jax.random.normal(KEY, (32, 24))}
    cfg = TransportConfig(rank=4)
    chain = resolve_codec("lowrank_svd+qblock", cfg)
    lowrank = resolve_codec("lowrank_svd", cfg)
    msg = chain.encode(tree)
    assert wire_bytes(msg) < wire_bytes(lowrank.encode(tree))
    out = chain.decode(msg)
    # decoding recovers approximately the pure low-rank reconstruction
    ref = lowrank.roundtrip(tree)
    assert float(jnp.max(jnp.abs(out["L"] - ref["L"]))) < 0.2


# ------------------------------------------------------- golden wire bytes

def test_wire_bytes_golden_formulas():
    m, n, r, b = 16, 12, 4, 4                       # f32 itemsize 4
    tree = {"L": jnp.zeros((m, n)), "vec": jnp.zeros((7,))}
    dense = wire_bytes(Dense().encode(tree))
    assert dense == tree_bytes(tree) == (m * n + 7) * b
    light = wire_bytes(LowRankSVD(rank=r).encode(tree))
    assert light == r * (m + n + 1) * b + 7 * b     # U, s, Vt + dense vec
    sketch = wire_bytes(PowerSketch(rank=r).encode(tree))
    assert sketch == r * (m + n) * b + 7 * b        # Q, B + dense vec
    qb = wire_bytes(QBlock(block=128).encode(tree))
    n_el, blocks = m * n + 7, -(-m * n // 128) + 1
    assert qb == n_el + 4 * blocks                  # int8 values + f32 scales
    # batched leaf: leading dims multiply the factored payload
    stacked = {"s": jnp.zeros((3, m, n))}
    assert wire_bytes(LowRankSVD(rank=r).encode(stacked)) == \
        3 * r * (m + n + 1) * b


def test_accounting_derives_from_wire_messages():
    """The legacy accounting shims measure the same messages the codec
    ships — incl. the once-mismatched unstacked 2-D Theta leaf."""
    theta = {"L": jnp.zeros((16, 12))}               # 2-D leaf, regression
    rank = 4
    codec = LowRankSVD(rank=rank)
    assert compressed_bytes(theta, rank) == wire_bytes(codec.encode(theta))
    # and the codec really does compress that leaf (old codec did not,
    # while the old accounting already counted it as compressed)
    assert np.linalg.matrix_rank(
        np.asarray(codec.roundtrip(theta)["L"]), tol=1e-4) <= rank
    params = {"w": jnp.zeros((8, 8))}
    assert round_comm_bytes(params, theta) == tree_bytes(params) + \
        tree_bytes(theta)
    assert round_comm_bytes(params, theta, compressed_rank=rank) == \
        tree_bytes(params) + rank * (16 + 12 + 1) * 4


def test_transport_round_bytes_matches_run_metric():
    """comm_bytes_per_round (eval_shape accounting) == the upload_bytes
    measured inside the jitted round, in both runtimes."""
    params, loss_fn, batch_fn = _problem()
    for runtime_kw in [dict(), dict(runtime="async")]:
        for algo in ["fedpac_soap", "fedpac_soap_light"]:
            fed = _fed(algo, **runtime_kw)
            kw = dict(async_cfg=AsyncConfig(buffer_size=2, concurrency=3)) \
                if runtime_kw else {}
            exp = build_experiment(algo, params=params, loss_fn=loss_fn,
                                   client_batch_fn=batch_fn, fed=fed, **kw)
            hist = exp.run()
            assert hist[-1]["upload_bytes"] == exp.comm_bytes_per_round()
            # exact-total accounting: the untruncated cohort sum rides
            # along (regression for the old up_bytes // b truncation)
            assert hist[-1]["upload_total_bytes"] == \
                hist[-1]["cohort_size"] * exp.comm_bytes_per_round()


# ------------------------------------------------ dense bitwise equivalence

def test_dense_codec_bitwise_equals_pre_refactor_sync():
    """The transport-routed round with the dense codec is bitwise identical
    to the pre-refactor (no-transport) upload path."""
    params, loss_fn, _ = _problem()
    opt = optim.make("soap")
    X = jax.random.normal(jax.random.key(5), (N_CLIENTS, K, 8, D))
    W = jax.random.normal(KEY, (D, OUT))
    batches = (X, X @ W)
    rng = jax.random.key(6)
    spec = resolve("fedpac_soap")
    legacy = build_round_fn(spec, loss_fn, opt, lr=0.05, local_steps=K,
                            beta=0.5)
    wired = build_round_fn(spec, loss_fn, opt, lr=0.05, local_steps=K,
                           beta=0.5,
                           transport=Transport(Dense(), Dense()))
    s0 = init_server(params, opt)
    sl, _, ml = legacy(s0, None, jnp.arange(N_CLIENTS), batches, rng)
    sw, _, mw = wired(s0, None, jnp.arange(N_CLIENTS), batches, rng)
    np.testing.assert_array_equal(np.asarray(sl.params["w"]),
                                  np.asarray(sw.params["w"]))
    for leaf_l, leaf_w in zip(jax.tree.leaves(sl.theta),
                              jax.tree.leaves(sw.theta)):
        np.testing.assert_array_equal(np.asarray(leaf_l),
                                      np.asarray(leaf_w))
    assert float(ml["loss"]) == float(mw["loss"])


def test_dense_codec_bitwise_equals_pre_refactor_async_flush():
    """Async side of the same claim: a flush over stacked dense wire
    messages equals the legacy flush over the raw dense trees, bitwise."""
    dense = Dense()
    deltas = {"w": jax.random.normal(KEY, (3, D, OUT))}
    thetas = {"GG": jax.random.normal(jax.random.key(9), (3, D, D))}
    params = {"w": jnp.zeros((D, OUT))}
    theta = {"GG": jnp.zeros((D, D))}
    g = {"w": jnp.zeros((D, OUT))}
    from repro.core.engine import make_controller
    ctrl = make_controller(0.5, correct=True)
    w = jnp.asarray([1.0, 0.5, 0.25])
    legacy = make_async_aggregate_fn(lr=0.05, local_steps=K)
    wired = make_async_aggregate_fn(
        lr=0.05, local_steps=K, transport=Transport(dense, dense))
    dmsg = jax.vmap(dense.encode)(deltas)
    tmsg = jax.vmap(dense.encode)(thetas)
    # the wire messages hold the same arrays bitwise (identity format)
    np.testing.assert_array_equal(np.asarray(dmsg.leaves[0].parts["x"]),
                                  np.asarray(deltas["w"]))
    out_l = legacy(params, theta, g, ctrl, deltas, thetas, w)
    out_w = wired(params, theta, g, ctrl, dmsg, tmsg, w)
    for a, b in zip(jax.tree.leaves(out_l[:4]), jax.tree.leaves(out_w[:4])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------- error feedback

def test_encode_with_feedback_residual_algebra():
    codec = LowRankSVD(rank=1)
    delta = {"w": jax.random.normal(KEY, (10, 9))}
    res0 = jax.tree.map(jnp.zeros_like, delta)
    msg, dec, res1 = encode_with_feedback(codec, delta, res0)
    # the returned reconstruction is exactly decode(msg) (reused by the
    # sync round instead of a second decode pass)
    np.testing.assert_array_equal(np.asarray(dec["w"]),
                                  np.asarray(codec.decode(msg)["w"]))
    np.testing.assert_allclose(np.asarray(res1["w"]),
                               np.asarray(delta["w"] - dec["w"]), rtol=1e-5)
    # second round: the residual is added back before encoding
    msg2, _, _ = encode_with_feedback(codec, delta, res1)
    np.testing.assert_allclose(
        np.asarray(codec.decode(codec.encode(
            jax.tree.map(jnp.add, delta, res1)))["w"]),
        np.asarray(codec.decode(msg2)["w"]), rtol=1e-5)
    # lossless codec: residual stays zero
    _, _, res_d = encode_with_feedback(Dense(), delta, res0)
    assert float(jnp.max(jnp.abs(res_d["w"]))) == 0.0
    # EF must not change the wire format: a bf16 tree still ships bf16
    # factors (same bytes as the plain encode), residual stays f32
    bf = {"w": jax.random.normal(KEY, (16, 12), jnp.bfloat16)}
    res_bf = jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32), bf)
    msg_bf, _, res_bf1 = encode_with_feedback(codec, bf, res_bf)
    assert wire_bytes(msg_bf) == wire_bytes(codec.encode(bf))
    assert res_bf1["w"].dtype == jnp.float32


def test_error_feedback_state_persists_sync():
    params, loss_fn, batch_fn = _problem()
    fed = _fed("fedpac_soap", delta_codec="lowrank_svd", participation=1.0)
    exp = FederatedExperiment(fed, params, loss_fn, batch_fn)
    assert exp.transport.feedback_active
    assert exp.client_state is not None         # EF residuals declared
    res0 = np.asarray(jax.tree.leaves(exp.client_state)[0])
    assert not res0.any()
    exp.run()
    res1 = np.asarray(jax.tree.leaves(exp.client_state)[0])
    assert res1.any()                           # residuals accumulated
    assert res1.shape[0] == N_CLIENTS
    # EF really changes the trajectory vs the same codec without it
    noef = FederatedExperiment(
        _fed("fedpac_soap", delta_codec="lowrank_svd", participation=1.0,
             error_feedback=False), params, loss_fn, batch_fn)
    noef.run()
    assert noef.client_state is None
    assert np.any(np.asarray(exp.server.params["w"])
                  != np.asarray(noef.server.params["w"]))


def test_error_feedback_composes_with_algorithm_state():
    """SCAFFOLD state + EF residuals thread through one composed
    ClientStateSpec; both slots update."""
    params, loss_fn, batch_fn = _problem()
    fed = _fed("scaffold", delta_codec="qblock", participation=1.0)
    exp = FederatedExperiment(fed, params, loss_fn, batch_fn)
    algo_state, ef_state = exp.client_state
    exp.run()
    algo_state2, ef_state2 = exp.client_state
    assert np.any(np.asarray(jax.tree.leaves(algo_state.c_clients)[0])
                  != np.asarray(jax.tree.leaves(algo_state2.c_clients)[0]))
    assert np.asarray(jax.tree.leaves(ef_state2)[0]).any()
    del algo_state2, ef_state, ef_state2


def test_error_feedback_state_persists_async():
    params, loss_fn, batch_fn = _problem()
    fed = _fed("fedpac_soap", delta_codec="lowrank_svd", runtime="async")
    exp = AsyncFederatedExperiment(
        fed, params, loss_fn, batch_fn,
        async_cfg=AsyncConfig(buffer_size=2, concurrency=3,
                              latency=LatencyModel(heterogeneity=1.0)))
    assert exp._ef_state is not None
    exp.run()
    assert np.asarray(jax.tree.leaves(exp._ef_state)[0]).any()


def test_error_feedback_discard_restores_residual():
    """An over-stale (discarded) upload never reaches the server; its
    decoded content must be folded back into the client's residual —
    delayed, not lost."""
    params, loss_fn, batch_fn = _problem()
    fed = _fed("fedpac_soap", delta_codec="lowrank_svd", runtime="async")
    exp = AsyncFederatedExperiment(
        fed, params, loss_fn, batch_fn,
        async_cfg=AsyncConfig(buffer_size=2, concurrency=3,
                              latency=LatencyModel(heterogeneity=1.0)))
    payload = exp._client_payload(0)
    r1 = jax.tree.map(lambda x: np.asarray(x[0]).copy(), exp._ef_state)
    dec = exp.transport.delta.decode(payload["delta"])
    exp._ef_state = exp._ef_restore(exp._ef_state, jnp.asarray(0),
                                    payload["delta"])
    np.testing.assert_allclose(
        np.asarray(exp._ef_state["w"][0]), r1["w"] + np.asarray(dec["w"]),
        rtol=1e-5)
    # end-to-end: a config that discards every stale arrival still runs
    harsh = AsyncFederatedExperiment(
        _fed("fedpac_soap", delta_codec="lowrank_svd", runtime="async"),
        params, loss_fn, batch_fn,
        async_cfg=AsyncConfig(buffer_size=2, concurrency=4, max_staleness=1,
                              latency=LatencyModel(heterogeneity=2.0)))
    hist = harsh.run()
    assert np.isfinite(hist[-1]["loss"])
    assert np.all(np.isfinite(np.asarray(jax.tree.leaves(
        harsh._ef_state)[0])))


# ------------------------------------------------------------- lossy e2e

def test_lossy_codecs_still_converge():
    """Aggressively-compressed uploads keep both runtimes training."""
    params, loss_fn, batch_fn = _problem()
    for kw in [dict(delta_codec="qblock"),
               dict(delta_codec="lowrank_svd+qblock"),
               dict(theta_codec="power_sketch")]:
        exp = FederatedExperiment(_fed("fedpac_soap", rounds=3, **kw),
                                  params, loss_fn, batch_fn)
        hist = exp.run()
        assert np.isfinite(hist[-1]["loss"])
        assert hist[-1]["upload_bytes"] == exp.comm_bytes_per_round()


def test_build_round_fn_rejects_transport_plus_compress_fn():
    params, loss_fn, _ = _problem()
    with pytest.raises(ValueError, match="not both"):
        build_round_fn(resolve("fedpac_soap"), loss_fn, optim.make("soap"),
                       lr=0.1, local_steps=K, compress_fn=lambda t: t,
                       transport=Transport(Dense(), Dense()))


def test_ef_requires_n_clients():
    params, loss_fn, _ = _problem()
    with pytest.raises(ValueError, match="n_clients"):
        build_round_fn(resolve("fedpac_soap"), loss_fn, optim.make("soap"),
                       lr=0.1, local_steps=K,
                       transport=Transport(LowRankSVD(rank=2), Dense()))


# --------------------------------------------------------------- validation

def test_local_run_config_validates_eagerly():
    from repro.core.client import LocalRunConfig
    with pytest.raises(ValueError, match="hessian_freq"):
        LocalRunConfig(lr=0.1, local_steps=2, hessian_freq=0)
    with pytest.raises(ValueError, match="local_steps"):
        LocalRunConfig(lr=0.1, local_steps=0)
    with pytest.raises(ValueError, match="hessian_freq"):
        FedConfig(hessian_freq=0)
    # Pallas lane constraint is checked eagerly, not deep inside jit
    with pytest.raises(ValueError, match="multiple of 128"):
        FedConfig(qblock_size=64, use_pallas=True)
    FedConfig(qblock_size=64)  # jnp reference path: any block size is fine
