"""Optimizer unit tests: descent, state round-trips, paper Assumption 5.4
(coercivity/boundedness) spot checks."""
import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.optim.api import matrix_mask, as_matrix
from repro.utils.tree import tree_dot, tree_norm_sq

KEY = jax.random.key(0)


def _quadratic_problem():
    k1, k2, k3 = jax.random.split(KEY, 3)
    W = jax.random.normal(k1, (12, 8))
    X = jax.random.normal(k2, (128, 12))
    Y = X @ W
    params = {"layer": {"w": jax.random.normal(k3, (12, 8)) * 0.1,
                        "b": jnp.zeros(8)},
              "embed": {"tok": jnp.zeros((4, 8))}}

    def loss(p):
        return jnp.mean((X @ p["layer"]["w"] + p["layer"]["b"] - Y) ** 2)

    return params, loss


@pytest.mark.parametrize("name,lr", [("sgd", 0.05), ("adamw", 0.05),
                                     ("muon", 0.05), ("soap", 0.05),
                                     ("sophia", 0.5)])
def test_descent(name, lr):
    params, loss = _quadratic_problem()
    opt = optim.make(name)
    state = opt.init(params)
    p = params

    @jax.jit
    def step(p, state, i):
        g = jax.grad(loss)(p)
        extras = None
        if opt.needs_hessian:
            u = jax.tree.map(
                lambda x: jnp.sign(jax.random.normal(
                    jax.random.fold_in(KEY, i), x.shape)), p)
            _, hvp = jax.jvp(jax.grad(loss), (p,), (u,))
            extras = {"h_est": jax.tree.map(lambda a, b: a * b, u, hvp),
                      "h_gate": True}
        d, state = opt.update(g, state, p, i, extras)
        return jax.tree.map(lambda x, dd: x - lr * dd, p, d), state

    l0 = float(loss(p))
    for i in range(50):
        p, state = step(p, state, jnp.int32(i))
    assert float(loss(p)) < 0.5 * l0


@pytest.mark.parametrize("name", ["muon", "soap", "sophia", "adamw", "sgd"])
def test_precond_roundtrip(name):
    params, loss = _quadratic_problem()
    opt = optim.make(name)
    state = opt.init(params)
    g = jax.grad(loss)(params)
    _, state = opt.update(g, state, params, jnp.int32(0),
                          {"h_est": jax.tree.map(jnp.abs, g), "h_gate": True}
                          if opt.needs_hessian else None)
    theta = opt.get_precond(state)
    state2 = opt.set_precond(state, theta)
    d1, _ = opt.update(g, state, params, jnp.int32(1))
    d2, _ = opt.update(g, state2, params, jnp.int32(1))
    for a, b in zip(jax.tree.leaves(d1), jax.tree.leaves(d2)):
        assert jnp.allclose(a, b, atol=1e-5)


@pytest.mark.parametrize("name", ["adamw", "sophia", "soap"])
def test_coercivity_assumption(name):
    """Assumption 5.4(i): <g, P(g)> > 0 after warmup (descent direction)."""
    params, loss = _quadratic_problem()
    opt = optim.make(name)
    state = opt.init(params)
    p = params
    for i in range(5):
        g = jax.grad(loss)(p)
        extras = ({"h_est": jax.tree.map(lambda x: jnp.abs(x) + 0.1, g),
                   "h_gate": True} if opt.needs_hessian else None)
        d, state = opt.update(g, state, p, jnp.int32(i), extras)
        p = jax.tree.map(lambda x, dd: x - 0.01 * dd, p, d)
    g = jax.grad(loss)(p)
    d, _ = opt.update(g, state, p, jnp.int32(5))
    assert float(tree_dot(g, d)) > 0.0


def test_muon_direction_orthogonalized():
    params, loss = _quadratic_problem()
    opt = optim.make("muon", b1=0.0)
    state = opt.init(params)
    g = jax.grad(loss)(params)
    d, _ = opt.update(g, state, params, jnp.int32(0))
    w_dir = d["layer"]["w"] / jnp.sqrt(jnp.maximum(1.0, 12 / 8))
    s = jnp.linalg.svd(w_dir, compute_uv=False)
    assert float(s.max()) < 1.4 and float(s.min()) > 0.3


def test_sophia_clip_bound():
    params, loss = _quadratic_problem()
    opt = optim.make("sophia", rho=0.03)
    state = opt.init(params)
    g = jax.grad(loss)(params)
    d, _ = opt.update(g, state, params, jnp.int32(0),
                      {"h_est": jax.tree.map(jnp.abs, g), "h_gate": True})
    for leaf in jax.tree.leaves(d):
        assert float(jnp.max(jnp.abs(leaf))) <= 0.03 + 1e-7


def test_matrix_mask_excludes_embeddings_and_vectors():
    params = {"embed": {"tok": jnp.zeros((100, 32))},
              "layers": [{"mixer": {"wq": jnp.zeros((32, 32))},
                          "pre_norm": {"scale": jnp.zeros(32)}}],
              "head": {"w": jnp.zeros((32, 100))}}
    mask = matrix_mask(params)
    assert mask["layers"][0]["mixer"]["wq"] is True
    assert mask["embed"]["tok"] is False
    assert mask["head"]["w"] is False
    assert mask["layers"][0]["pre_norm"]["scale"] is False


def test_as_matrix_conv_flattening():
    x = jnp.zeros((3, 3, 8, 16))
    mat, orig = as_matrix(x)
    assert mat.shape == (72, 16) and orig == (3, 3, 8, 16)


def test_soap_one_sided_for_huge_dims():
    opt = optim.make("soap", max_precond_dim=32)
    params = {"layer": {"w": jnp.zeros((64, 16))}}
    state = opt.init(params)
    st = state["mat"]["layer"]["w"]
    assert "L" not in st and "R" in st  # 64 > 32 -> left side skipped
