"""Million-client population subsystem: streaming cohort samplers, sparse
LRU client-state store with checkpoint-store spill, lazy partitions, and the
bitwise sparse-vs-dense equivalence contract on both runtimes."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import AsyncConfig, build_experiment
from repro.core.algorithms import (
    resolve, round_client_state_spec, state_export, state_import,
)
from repro.core.scaffold import SCAFFOLD_SPEC
from repro.data import (
    ClientIndexMap, make_image_classification, stream_dirichlet_map,
)
from repro.fed import (
    AvailabilitySampler, ClientPopulation, ClientStateStore, FedConfig,
    UniformSampler, WeightedSampler, make_client_store, make_population,
)
from repro.models.vision import classification_loss, cnn_apply, init_cnn
from repro.scenarios import PartitionSpec, cifar_like, materialize

POP = 1_000_000


@pytest.fixture(scope="module")
def problem():
    X, y = make_image_classification(600, image_size=8, n_classes=4, seed=0,
                                     noise=1.0)
    parts = stream_dirichlet_map(y, POP, alpha=0.3, samples_per_client=32,
                                 seed=0)
    params = init_cnn(jax.random.key(0), n_classes=4, width=4, blocks=1)

    def loss_fn(p, batch):
        return classification_loss(cnn_apply(p, batch["x"]), batch["y"])

    def batch_fn(cid, rng):
        idx = rng.choice(parts[cid], size=4)
        return {"x": jnp.asarray(X[idx]), "y": jnp.asarray(y[idx])}

    return params, loss_fn, batch_fn


# ----------------------------------------------------------------- samplers

def test_uniform_cohorts_distinct_in_range_and_deterministic():
    pop = ClientPopulation(POP, seed=7)
    c1 = pop.sample_cohort(3, 64)
    c2 = pop.sample_cohort(3, 64)
    assert np.array_equal(c1, c2)            # per-round reproducible
    assert len(np.unique(c1)) == 64          # distinct
    assert c1.min() >= 0 and c1.max() < POP
    assert not np.array_equal(c1, pop.sample_cohort(4, 64))


def test_uniform_small_space_is_permutation_slice():
    pop = ClientPopulation(8, seed=0, sampler=UniformSampler())
    c = pop.sample_cohort(0, 8)
    assert sorted(c.tolist()) == list(range(8))


def test_weighted_sampler_prefers_heavy_ids():
    w = np.ones(100)
    w[:5] = 1000.0
    pop = ClientPopulation(100, seed=0,
                           sampler=WeightedSampler(lambda ids: w[ids]))
    hits = sum(int(c) < 5 for r in range(40)
               for c in pop.sample_cohort(r, 5))
    assert hits > 150   # ~199/200 expected under the weights; >75% is safe


def test_availability_sampler_masks_ids():
    avail = AvailabilitySampler(lambda ids, t: ids % 2 == 0)
    pop = ClientPopulation(1000, seed=0, sampler=avail)
    c = pop.sample_cohort(0, 16)
    assert (c % 2 == 0).all()


def test_client_rng_invariant_to_population_size():
    small = ClientPopulation(50, seed=9)
    large = ClientPopulation(POP, seed=9)
    for cid in (0, 17, 49):
        a = small.client_rng(cid, salt=3).integers(0, 2**31, 4)
        b = large.client_rng(cid, salt=3).integers(0, 2**31, 4)
        assert np.array_equal(a, b)
        ka = jax.random.key_data(small.client_key(cid, salt=3))
        kb = jax.random.key_data(large.client_key(cid, salt=3))
        assert np.array_equal(np.asarray(ka), np.asarray(kb))


def test_cohort_keys_match_per_client_keys():
    pop = ClientPopulation(POP, seed=1)
    cohort = pop.sample_cohort(0, 6)
    stacked = pop.cohort_keys(cohort, salt=2)
    for i, cid in enumerate(cohort):
        assert np.array_equal(
            np.asarray(jax.random.key_data(stacked[i])),
            np.asarray(jax.random.key_data(pop.client_key(int(cid),
                                                          salt=2))))


def test_bad_ids_rejected():
    pop = ClientPopulation(10, seed=0)
    with pytest.raises(ValueError):
        pop.sample_cohort(0, 11)
    with pytest.raises(ValueError):
        pop.client_rng(10)


# -------------------------------------------------------------- state store

def _store(tmp_path, budget=4, pop=100):
    params = {"w": jnp.zeros((3, 2)), "b": jnp.zeros(2)}
    proto = round_client_state_spec(resolve("scaffold"))
    return ClientStateStore(proto, params, pop, budget,
                            spill_dir=str(tmp_path)), proto, params


def test_store_spill_restore_roundtrip_bitwise(tmp_path):
    store, proto, _ = _store(tmp_path, budget=2)
    (slot,) = store.acquire([11])
    row = state_export(proto, store.state, int(slot))
    marked = jax.tree.map(lambda x: x + 3.25, row)
    store.state = state_import(proto, store.state, int(slot), marked)
    store.acquire([5])     # fills the other slot
    store.acquire([7])     # evicts 11 -> spill to disk
    assert store.spills == 1
    assert os.path.exists(os.path.join(str(tmp_path), f"client_{11:012d}.npz"))
    (slot2,) = store.acquire([11])     # restore
    back = state_export(proto, store.state, int(slot2))
    for a, b in zip(jax.tree.leaves(marked), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert store.restores == 1


def test_store_budget_and_peak(tmp_path):
    store, _, _ = _store(tmp_path, budget=3)
    with pytest.raises(ValueError):
        store.acquire([1, 2, 3, 4])            # cohort > budget
    with pytest.raises(ValueError):
        store.acquire([1, 1])                  # duplicate ids
    store.acquire([1, 2])
    store.acquire([3])
    assert store.peak_resident == 3 <= 3
    store.acquire([4, 5, 6])
    assert store.peak_resident == 3            # never exceeds budget
    assert store.resident == 3


def test_make_client_store_dense_identity(tmp_path):
    from repro.fed import DenseClientStore
    params = {"w": jnp.zeros(3)}
    proto = round_client_state_spec(resolve("scaffold"))
    assert make_client_store(None, params, 6) is None      # stateless algo
    store = make_client_store(proto, params, 6, budget=6,
                              spill_dir=str(tmp_path))
    assert isinstance(store, DenseClientStore)             # budget covers pop
    slots = store.acquire([4, 0, 2])
    assert np.array_equal(slots, [4, 0, 2])                # identity slots
    assert store.spills == 0


def test_scaffold_export_import_only_touches_client_rows():
    params = {"w": jnp.zeros((2, 2))}
    state = SCAFFOLD_SPEC.client_state.init(params, 3)
    row = SCAFFOLD_SPEC.client_state.client_export(state, 1)
    # the exported row is the c_clients slice only — same structure as params
    assert (jax.tree_util.tree_structure(row)
            == jax.tree_util.tree_structure(params))
    bumped = jax.tree.map(lambda x: x + 1.0, row)
    out = SCAFFOLD_SPEC.client_state.client_import(state, 1, bumped)
    np.testing.assert_array_equal(np.asarray(out.c_global["w"]),
                                  np.asarray(state.c_global["w"]))
    np.testing.assert_array_equal(np.asarray(out.c_clients["w"][1]),
                                  np.asarray(state.c_clients["w"][1] + 1.0))


# ------------------------------------------------------------ config knobs

def test_fedconfig_population_validation():
    with pytest.raises(ValueError):             # pop knobs without pop size
        FedConfig(cohort_size=8)
    with pytest.raises(ValueError):             # pop size needs cohort size
        FedConfig(population_size=100)
    with pytest.raises(ValueError):             # cohort > population
        FedConfig(population_size=4, cohort_size=8)
    with pytest.raises(ValueError):             # budget < cohort
        FedConfig(population_size=100, cohort_size=8, state_budget=4)
    with pytest.raises(ValueError):             # unknown sampler
        FedConfig(population_size=100, cohort_size=8,
                  cohort_sampler="nope")
    cfg = FedConfig(population_size=100, cohort_size=8)
    assert cfg.population_active
    assert cfg.resolve_state_budget() == 32    # min(pop, 4 x cohort)
    assert not FedConfig().population_active


def test_make_population_from_config():
    cfg = FedConfig(population_size=1234, cohort_size=8, seed=5)
    pop = make_population(cfg)
    assert pop.size == 1234
    assert len(pop.sample_cohort(0, 8)) == 8


# ------------------------------------------------- sparse-vs-dense, golden

def _run_sync(problem, budget, rounds=3, tmp_path=None, **kw):
    params, loss_fn, batch_fn = problem
    exp = build_experiment(
        "scaffold", params=params, loss_fn=loss_fn,
        client_batch_fn=batch_fn, rounds=rounds, local_steps=2,
        population_size=40, cohort_size=4, state_budget=budget,
        spill_dir=None if tmp_path is None else str(tmp_path),
        seed=0, **kw)
    hist = exp.run()
    return exp, hist


def test_sync_sparse_bitwise_equals_dense_with_spill(problem, tmp_path):
    # budget 4 (= cohort) forces evict/spill every round; budget 40 never
    # spills — the training trajectory must be bitwise identical
    _, h_sparse = _run_sync(problem, budget=4, tmp_path=tmp_path / "a")
    _, h_dense = _run_sync(problem, budget=40, tmp_path=tmp_path / "b")
    assert h_sparse[-1]["state_spills"] > 0
    assert h_dense[-1]["state_spills"] == 0
    for rs, rd in zip(h_sparse, h_dense):
        assert rs["loss"] == rd["loss"]
    assert h_sparse[-1]["state_peak"] <= 4


def test_sync_population_invariant_to_population_size(problem):
    # same cohort ids => same round results regardless of the id space
    # around them; pin the cohort by sampling from the same seed/popsize
    params, loss_fn, batch_fn = problem

    def run(pop_size):
        exp = build_experiment(
            "fedavg", params=params, loss_fn=loss_fn,
            client_batch_fn=batch_fn, rounds=1, local_steps=2,
            population_size=pop_size, cohort_size=4, seed=0)
        # force an identical cohort across population sizes
        exp.population.sample_cohort = lambda r, k: np.array([3, 11, 25, 39])
        return exp.run()[-1]["loss"]

    assert run(40) == run(POP)


def test_sharded_executor_matches_vmap(problem):
    _, h_vmap = _run_sync(problem, budget=40, executor="vmap")
    _, h_shard = _run_sync(problem, budget=40, executor="sharded",
                           chunk_size=2)
    for rv, rs in zip(h_vmap, h_shard):
        assert np.isclose(rv["loss"], rs["loss"], rtol=1e-6)


def _run_async(problem, budget, tmp_path=None):
    params, loss_fn, batch_fn = problem
    exp = build_experiment(
        "fedavg", params=params, loss_fn=loss_fn, client_batch_fn=batch_fn,
        rounds=3, local_steps=2, runtime="async", delta_codec="svd",
        population_size=40, cohort_size=4, state_budget=budget,
        spill_dir=None if tmp_path is None else str(tmp_path), seed=0,
        async_cfg=AsyncConfig(buffer_size=2, concurrency=4))
    hist = exp.run()
    return exp, hist


def test_async_sparse_bitwise_equals_dense_with_spill(problem, tmp_path):
    # delta_codec="svd" activates error feedback -> the EF store is live
    _, h_sparse = _run_async(problem, budget=4, tmp_path=tmp_path / "a")
    _, h_dense = _run_async(problem, budget=40, tmp_path=tmp_path / "b")
    assert h_sparse[-1]["state_spills"] > 0
    for rs, rd in zip(h_sparse, h_dense):
        assert rs["loss"] == rd["loss"]
        assert rs["staleness"] == rd["staleness"]
    assert h_sparse[-1]["state_peak"] <= 4


def test_async_scheduler_uses_stable_global_ids(problem):
    params, loss_fn, batch_fn = problem
    exp = build_experiment(
        "fedavg", params=params, loss_fn=loss_fn, client_batch_fn=batch_fn,
        rounds=2, local_steps=1, runtime="async",
        population_size=POP, cohort_size=4, seed=0,
        async_cfg=AsyncConfig(buffer_size=2, concurrency=4))
    exp.run()
    seen = exp.scheduler._dispatch_counts.keys()
    assert seen and all(0 <= cid < POP for cid in seen)
    assert any(cid >= 40 for cid in seen)   # ids beyond any dense range


# ------------------------------------------------------------ lazy scenario

def test_stream_dirichlet_map_lazy_and_invariant():
    y = np.repeat(np.arange(4), 25)
    m_small = stream_dirichlet_map(y, 10, alpha=0.3, samples_per_client=16,
                                   seed=2)
    m_large = stream_dirichlet_map(y, POP, alpha=0.3, samples_per_client=16,
                                   seed=2)
    assert isinstance(m_large, ClientIndexMap) and len(m_large) == POP
    for cid in (0, 9):
        assert np.array_equal(m_small[cid], m_large[cid])
    assert np.array_equal(m_large[123456], m_large[123456])
    with pytest.raises(IndexError):
        m_small[10]
    stats = m_large.sample_stats(y)
    assert stats["lazy"] and stats["n_clients"] == POP


def test_stream_scenario_materializes_over_large_id_space():
    spec = cifar_like(
        model="cnn", n=600, image_size=8, n_classes=4, batch=8,
        n_clients=POP, name="pop_test",
        partition=PartitionSpec("stream_dirichlet", alpha=0.3,
                                samples_per_client=16))
    scn = materialize(spec, seed=0, n_clients=POP)
    assert isinstance(scn.partitions, ClientIndexMap)
    assert scn.partition_stats["lazy"]
    b = scn.client_batch_fn(999_999, np.random.default_rng(0))
    assert b["x"].shape[0] == 8


def test_eager_scenarios_keep_list_partitions():
    spec = cifar_like(model="cnn", n=600, image_size=8, n_classes=4,
                      alpha=0.3, batch=8, n_clients=6, name="eager_test")
    scn = materialize(spec, seed=0, n_clients=6)
    assert isinstance(scn.partitions, list) and len(scn.partitions) == 6


def test_legacy_dense_path_unchanged_by_population_code(problem):
    # population_size=None must take the exact legacy path: no population,
    # no store, no state_* telemetry keys
    params, loss_fn, batch_fn = problem
    exp = build_experiment("scaffold", params=params, loss_fn=loss_fn,
                           client_batch_fn=batch_fn, n_clients=6,
                           participation=0.5, rounds=2, local_steps=2,
                           seed=0)
    hist = exp.run()
    assert exp.population is None
    assert "state_peak" not in hist[-1]
