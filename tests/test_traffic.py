"""Continuous-traffic runtime: golden scheduler traces, saturating-trace
parity with the round-shaped async runtime, churn/eviction, hourly
availability traces, mid-stream checkpoint/rollback in a fresh process,
hot-swap, and the sharded executor on a forced multi-device mesh."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import (
    AsyncConfig, ChurnConfig, TrafficConfig, build_experiment,
)
from repro.fed.async_runtime.latency import LatencyModel
from repro.fed.async_runtime.scheduler import SimScheduler
from repro.fed.population import (
    AvailabilitySampler, ClientPopulation, hourly_availability,
    load_hourly_trace,
)
from repro.fed.population.state import ClientStateStore, DenseClientStore
from repro.fed.traffic import (
    BurstyRate, ConstantRate, DiurnalRate, Membership, PiecewiseRate,
    run_ab, time_to_quality,
)
from repro.obs import MemorySink, attach

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# ------------------------------------------------------------------ fixtures


def _mlp_problem(n_clients=8, seed=0):
    """Tiny 2-layer MLP bundle (NOT single-layer {'w','b'}: tiny params
    give all-None SOAP Theta, breaking fedpac_soap wire decode)."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(240, 8)).astype(np.float32)
    y = rng.integers(0, 3, size=240).astype(np.int32)
    parts = np.array_split(np.arange(240), n_clients)
    params = {
        "w1": jnp.asarray(rng.normal(size=(8, 16)) * 0.1, jnp.float32),
        "b1": jnp.zeros((16,), jnp.float32),
        "w2": jnp.asarray(rng.normal(size=(16, 3)) * 0.1, jnp.float32),
        "b2": jnp.zeros((3,), jnp.float32),
    }

    def loss_fn(p, batch):
        xb, yb = batch
        h = jnp.tanh(xb @ p["w1"] + p["b1"])
        logp = jax.nn.log_softmax(h @ p["w2"] + p["b2"])
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

    def client_batch_fn(cid, rng_):
        idx = parts[cid % n_clients]
        sel = rng_.choice(idx, size=32)
        return jnp.asarray(X[sel]), jnp.asarray(y[sel])

    def eval_fn(p):
        h = jnp.tanh(X @ p["w1"] + p["b1"])
        acc = jnp.mean(jnp.argmax(h @ p["w2"] + p["b2"], -1) == y)
        return {"acc": float(acc)}

    return dict(params=params, loss_fn=loss_fn,
                client_batch_fn=client_batch_fn, eval_fn=eval_fn)


@pytest.fixture(scope="module")
def problem():
    return _mlp_problem()


ACFG = dict(buffer_size=3, concurrency=4)


# ------------------------------------------ satellite: sparse golden traces

# Event streams captured from the dense-array scheduler implementation
# before the sparse-dict refactor: (time rounded to 1e-10, seq, client_id,
# version, dropped) under seed 7, LatencyModel(heterogeneity=1.0,
# jitter=0.5, dropout=0.2), concurrency 4, fill(0) then 11 x
# [next_completion; fill(v)].  The sparse bookkeeping must reproduce them
# bitwise.
_GOLDEN_DENSE = [
    (0.1849445715, 3, 3, 0, False), (0.4788669236, 4, 4, 1, False),
    (0.872413376, 0, 1, 0, False), (0.9768364724, 5, 4, 2, False),
    (1.1202894693, 1, 6, 0, False), (1.3148618456, 7, 1, 4, False),
    (1.7559337257, 9, 4, 6, True), (1.8280339791, 6, 0, 3, False),
    (2.1121211088, 8, 6, 5, False), (2.1843801283, 11, 3, 8, False),
    (2.3123729954, 10, 5, 7, False),
]
_GOLDEN_POP = [
    (0.2218409762, 3, 591, 0, False), (0.2560703027, 0, 816, 0, False),
    (0.5192363124, 2, 882, 0, False), (0.6904145788, 6, 195, 3, False),
    (0.9751509702, 4, 967, 1, True), (1.1028231546, 5, 251, 2, False),
    (1.4313807391, 9, 328, 6, False), (1.8097268758, 1, 893, 0, False),
    (2.3299991907, 8, 635, 5, False), (2.7773877936, 12, 67, 9, True),
    (2.8587555338, 11, 300, 8, False),
]


def _drain(sched, n=11):
    sched.fill(0)
    out = []
    for v in range(1, n + 1):
        ev = sched.next_completion()
        out.append((round(ev.time, 10), ev.seq, ev.client_id, ev.version,
                    ev.dropped))
        sched.fill(v)
    return out


def test_scheduler_golden_dense():
    lat = LatencyModel(heterogeneity=1.0, jitter=0.5, dropout=0.2)
    assert _drain(SimScheduler(lat, 8, 4, seed=7)) == _GOLDEN_DENSE


def test_scheduler_golden_population():
    lat = LatencyModel(heterogeneity=1.0, jitter=0.5, dropout=0.2)
    sched = SimScheduler(lat, 0, 4, seed=7,
                         population=ClientPopulation(1000, seed=7))
    assert _drain(sched) == _GOLDEN_POP


def test_scheduler_void_and_state_roundtrip():
    lat = LatencyModel(heterogeneity=1.0, jitter=0.5, dropout=0.2)
    sched = SimScheduler(lat, 8, 4, seed=3)
    sched.fill(0)
    assert sched.peek_time() is not None
    cid = next(iter(sched._live_seq))
    seq = sched.void(cid)
    assert seq == sched._live_seq[cid]
    assert sched.void(999) is None
    st = sched.state()
    # voided mark survives a state round-trip
    sched2 = SimScheduler(lat, 8, 4, seed=3)
    sched2.load_state(st)
    sched2.restore_events(list(sched._heap))
    while True:
        ev = sched2.next_completion()
        if ev.client_id == cid:
            assert sched2.consume_voided(ev)
            break
        assert not sched2.consume_voided(ev)


# --------------------------------------------- acceptance: saturating parity


def test_saturating_trace_reproduces_round_shaped_async(problem):
    """Zero churn + ConstantRate(inf) + count policy == the legacy
    round-shaped async runtime, metric for metric."""
    kw = dict(problem, n_clients=8, rounds=4, seed=11)
    legacy = build_experiment("fedpac_soap", async_cfg=AsyncConfig(**ACFG),
                              **kw)
    hist_legacy = legacy.run()
    traffic = build_experiment(
        "fedpac_soap", async_cfg=AsyncConfig(**ACFG),
        traffic=TrafficConfig(trace="constant",
                              trace_kwargs={"rate": float("inf")}), **kw)
    hist_traffic = traffic.run()
    assert len(hist_legacy) == len(hist_traffic) == 4
    for a, b in zip(hist_legacy, hist_traffic):
        assert set(a) == set(b)
        for k in a:
            assert a[k] == b[k], (k, a[k], b[k])


# ----------------------------------------------------------- arrival traces


def test_trace_processes_deterministic_and_checkpointable():
    for proc in (ConstantRate(3.0, seed=5),
                 DiurnalRate(4.0, amplitude=0.7, period=6.0, seed=5),
                 BurstyRate(2.0, jump=0.5, decay=1.0, seed=5),
                 PiecewiseRate([1.0, 5.0, 0.5], bin_width=2.0, seed=5)):
        st = proc.state()
        t, times = 0.0, []
        for _ in range(20):
            t = proc.next_arrival(t)
            proc.notify_arrival(t)
            times.append(t)
        assert times == sorted(times)
        proc.load_state(st)
        t2, times2 = 0.0, []
        for _ in range(20):
            t2 = proc.next_arrival(t2)
            proc.notify_arrival(t2)
            times2.append(t2)
        assert times == times2, type(proc).__name__


def test_trace_validation():
    with pytest.raises(ValueError, match="rate"):
        ConstantRate(0.0)
    with pytest.raises(ValueError, match="amplitude"):
        DiurnalRate(1.0, amplitude=1.5)
    with pytest.raises(ValueError, match="non-stationary"):
        BurstyRate(1.0, jump=2.0, decay=1.0)
    with pytest.raises(ValueError, match="zero"):
        PiecewiseRate([0.0, 0.0])
    with pytest.raises(ValueError, match="buffer_policy"):
        TrafficConfig(buffer_policy="nope")
    with pytest.raises(ValueError, match="flush_interval"):
        TrafficConfig(buffer_policy="interval")
    with pytest.raises(ValueError, match="swap"):
        TrafficConfig(swap_to="fedavg")
    with pytest.raises(ValueError, match="trace"):
        TrafficConfig(trace="nope")


def test_sync_runtime_rejects_traffic(problem):
    with pytest.raises(ValueError, match="sync"):
        build_experiment("fedavg", runtime="sync",
                         traffic=TrafficConfig(), n_clients=8, rounds=1,
                         **problem)


# ------------------------------------------------- satellite: hourly traces


def test_hourly_mask_table_matches_synthetic_mask():
    """A (H, B) bucket table reproduces the synthetic-callable path the
    existing AvailabilitySampler tests use (ids % 2 == 0 online)."""
    pop = 64
    synthetic = AvailabilitySampler(lambda ids, t: ids % 2 == 0)
    empirical = AvailabilitySampler.from_hourly(np.array([[True, False]]))
    ids = np.arange(pop)
    for t in (0.0, 1.0, 7.5):
        np.testing.assert_array_equal(
            synthetic.available_fn(ids, t),
            empirical.available_fn(ids, t))
    # and the sampler machinery agrees end to end
    rng1, rng2 = (np.random.default_rng(9) for _ in range(2))
    c1 = synthetic.sample(rng1, pop, 8, t=0)
    c2 = empirical.sample(rng2, pop, 8, t=0)
    np.testing.assert_array_equal(np.sort(c1), np.sort(c2))


def test_hourly_fraction_table_is_deterministic_and_calibrated():
    fn = hourly_availability(np.array([0.25, 0.9]), hour_unit=2.0)
    ids = np.arange(20000)
    m0, m0b = fn(ids, 0.3), fn(ids, 1.9)       # same hour bin
    np.testing.assert_array_equal(m0, m0b)     # stable within the hour
    m1 = fn(ids, 2.1)                          # next bin
    assert abs(m0.mean() - 0.25) < 0.02
    assert abs(m1.mean() - 0.9) < 0.02
    m2 = fn(ids, 4.5)                          # table wraps: hour 0 again
    np.testing.assert_array_equal(m0, m2)


def test_hourly_trace_file_loading(tmp_path):
    table = np.array([[1.0, 0.0], [1.0, 1.0]])
    npy = tmp_path / "avail.npy"
    np.save(npy, table)
    csv = tmp_path / "avail.csv"
    np.savetxt(csv, np.array([0.5, 0.75]), delimiter=",")
    np.testing.assert_array_equal(load_hourly_trace(str(npy)), table)
    np.testing.assert_array_equal(load_hourly_trace(str(csv)),
                                  [0.5, 0.75])
    s = AvailabilitySampler.from_hourly(str(npy))
    ids = np.arange(10)
    np.testing.assert_array_equal(s.available_fn(ids, 0.0), ids % 2 == 0)
    np.testing.assert_array_equal(s.available_fn(ids, 1.0),
                                  np.ones(10, bool))
    with pytest.raises(ValueError, match="hour"):
        hourly_availability(np.zeros((0,)))
    with pytest.raises(ValueError, match="0, 1"):
        hourly_availability(np.array([2.0]))


# ------------------------------------------------------- churn and eviction


def test_membership_churn_deterministic():
    m = Membership(100, ChurnConfig(join_rate=1.0, leave_rate=1.0,
                                    initial_active=10, seed=4))
    assert m.n_active == 10
    st = m.state()
    seq = [(m.next_event(0.0), m.sample_join(), m.sample_leave())
           for _ in range(5)]
    m2 = Membership(100, ChurnConfig(join_rate=1.0, leave_rate=1.0,
                                     initial_active=10, seed=99))
    m2.load_state(st)
    seq2 = [(m2.next_event(0.0), m2.sample_join(), m2.sample_leave())
            for _ in range(5)]
    assert seq == seq2
    active = m.active_ids()
    assert all(m.is_active(c) for c in active)


def test_store_evict_client(tmp_path):
    from repro.core.algorithms import EF_STATE
    params = {"w": jnp.ones((4, 3)), "b": jnp.zeros((3,))}
    dense = DenseClientStore(EF_STATE, params, 6)
    dense.acquire([2])
    dense.state = jax.tree.map(lambda a: a.at[2].add(1.0), dense.state)
    assert dense.evict_client(2)
    assert not dense.evict_client(2)
    # the departed row is back to zero-init: a rejoin starts fresh
    assert all(float(jnp.abs(leaf[2]).sum()) == 0.0
               for leaf in jax.tree.leaves(dense.state))

    sparse = ClientStateStore(EF_STATE, params, population_size=10, budget=2,
                              spill_dir=str(tmp_path))
    sparse.acquire([0, 1])
    sparse.state = jax.tree.map(lambda a: a + 1.0, sparse.state)
    sparse.acquire([2])                    # spills the LRU (client 0)
    assert sparse.spills == 1
    assert sparse.evict_client(0)          # spilled: file unlinked
    assert not os.path.exists(sparse._spill_path(0))
    assert sparse.evict_client(1)          # resident: slot freed
    assert len(sparse._free) == 1
    assert not sparse.evict_client(7)      # never seen
    # evicted client re-acquires as fresh zero-init
    slot = int(sparse.acquire([0])[0])
    assert all(float(jnp.abs(leaf[slot]).sum()) == 0.0
               for leaf in jax.tree.leaves(sparse.state))


def test_churn_stream_traces_and_evicts(problem):
    kw = dict(problem, n_clients=8, rounds=2, seed=11)
    exp = build_experiment(
        "fedavg", async_cfg=AsyncConfig(**ACFG),
        traffic=TrafficConfig(
            trace="constant", trace_kwargs={"rate": 10.0},
            churn=ChurnConfig(join_rate=1.5, leave_rate=1.5,
                              initial_active=6, seed=2),
            eval_every=1.0), **kw)
    sink = MemorySink()
    attach(exp, sink)
    s = exp.run_stream(sim_budget=10.0)
    kinds = {e["event"] for e in sink.events}
    assert s["joins"] > 0 and s["leaves"] > 0
    assert "client_join" in kinds and "client_leave" in kinds
    assert "anytime_eval" in kinds
    leaves_inflight = [e for e in sink.events
                       if e["event"] == "client_leave" and e["in_flight"]]
    voided = [e for e in sink.events if e["event"] == "client_dropped"
              and e["reason"] == "client_left"]
    # every voided in-flight departure that completed inside the budget is
    # traced; some voided completions may still be pending past it
    assert len(voided) <= len(leaves_inflight)
    # anytime eval lands exactly on the simulated-time grid
    evals = [e for e in sink.events if e["event"] == "anytime_eval"]
    assert [e["sim_time"] for e in evals] == \
        [1.0 * (i + 1) for i in range(len(evals))]


# ---------------------------------------------------------------- hot-swap


def test_hotswap_mid_stream(problem):
    kw = dict(problem, n_clients=8, rounds=2, seed=11)
    tc = TrafficConfig(trace="constant", trace_kwargs={"rate": 8.0},
                       eval_every=1.0, swap_to="fedavg", swap_at=3.0)
    exp = build_experiment("fedpac_soap", async_cfg=AsyncConfig(**ACFG),
                           traffic=tc, **kw)
    sink = MemorySink()
    attach(exp, sink)
    exp.run_stream(sim_budget=7.0)
    assert exp.spec.name == "fedavg"
    swap_drops = [e for e in sink.events if e["event"] == "client_dropped"
                  and e["reason"] == "algo_swap"]
    assert swap_drops, "swap must discard in-flight/buffered work, traced"
    # the stream keeps flushing under the new algorithm
    assert any(r["round"] > 0 for r in exp.history)


def test_run_ab_shares_arrival_stream(problem):
    kw = dict(problem, n_clients=8, rounds=2, seed=11)
    tc = TrafficConfig(trace="diurnal",
                       trace_kwargs={"base": 6.0, "period": 4.0},
                       eval_every=1.0)
    a = build_experiment("fedavg", async_cfg=AsyncConfig(**ACFG),
                         traffic=tc, **kw)
    b = build_experiment("fedpac_soap", async_cfg=AsyncConfig(**ACFG),
                         traffic=tc, **kw)
    out = run_ab(a, b, sim_budget=5.0)
    # same seeds + same trace config -> identical arrival realizations:
    # the flush sim-times coincide even though the algorithms differ
    assert [r["sim_time"] for r in a.history] == \
        [r["sim_time"] for r in b.history]
    assert out["a"]["flushes"] == out["b"]["flushes"] > 0
    ttq = time_to_quality(out["eval_a"], "acc", 0.0)
    assert ttq == out["eval_a"][0]["sim_time"]
    assert time_to_quality(out["eval_a"], "acc", 2.0) is None


# ---------------------- satellite: mid-stream checkpoint, fresh process

_CKPT_SCRIPT = textwrap.dedent("""
    import json, sys
    import numpy as np
    import jax, jax.numpy as jnp
    sys.path.insert(0, {src!r})
    sys.path.insert(0, {testdir!r})
    from test_traffic import _mlp_problem, ACFG
    from repro.api import AsyncConfig, TrafficConfig, build_experiment
    from repro.obs import JsonlSink, attach

    mode, ckdir, tracefile = sys.argv[1], sys.argv[2], sys.argv[3]
    kw = dict(_mlp_problem(), n_clients=8, rounds=2, seed=11)
    tc = TrafficConfig(trace="constant", trace_kwargs={{"rate": 8.0}},
                       eval_every=1.0)
    exp = build_experiment("fedpac_soap", async_cfg=AsyncConfig(**ACFG),
                           traffic=tc, **kw)
    attach(exp, JsonlSink(tracefile))
    if mode == "full":
        exp.run_stream(sim_budget=3.0)
        exp.save_checkpoint(ckdir)
        seq0 = exp.tracer.seq
    else:
        exp.load_checkpoint(ckdir)
        seq0 = exp.tracer.seq
    exp.run_stream(sim_budget=7.0)
    print("RESULT " + json.dumps({{
        "seq0": seq0,
        "history": exp.history,
        "eval": exp.eval_history,
        "sim_now": exp.sim_now,
    }}))
""")


def _run_ckpt(mode, ckdir, tracefile):
    script = _CKPT_SCRIPT.format(src=os.path.abspath(SRC),
                                 testdir=os.path.dirname(__file__))
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    proc = subprocess.run([sys.executable, "-c", script,
                           mode, ckdir, tracefile],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def _events(path, seq0):
    """Trace events from seq0 on, wall-clock durations stripped."""
    out = []
    with open(path) as f:
        for line in f:
            ev = json.loads(line)
            if ev["seq"] < seq0:
                continue
            ev.pop("dur_s", None)
            out.append(ev)
    return out


def test_midstream_checkpoint_rollback_fresh_process(tmp_path):
    """Stop at sim time t, restore in a fresh process, replay: trailing
    trace events and final metrics identical to the uninterrupted run."""
    ckdir = str(tmp_path / "ck")
    full = _run_ckpt("full", ckdir, str(tmp_path / "full.jsonl"))
    resumed = _run_ckpt("resume", ckdir, str(tmp_path / "resume.jsonl"))
    assert resumed["seq0"] == full["seq0"]
    assert resumed["history"] == full["history"]
    assert resumed["eval"] == full["eval"]
    assert resumed["sim_now"] == full["sim_now"]
    assert _events(str(tmp_path / "resume.jsonl"), resumed["seq0"]) == \
        _events(str(tmp_path / "full.jsonl"), full["seq0"])


# ------------------- satellite: sharded executor on a multi-device mesh

_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, {src!r})
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core.engine.executors import ExecutorConfig, \\
        make_cohort_executor

    assert len(jax.devices()) == 4, jax.devices()
    mesh = jax.make_mesh((4,), ("data",))

    def one_client(batch):
        return {{"out": batch * 2.0, "s": jnp.tanh(batch @ batch.T).sum()}}

    rng = np.random.default_rng(0)
    batches = jnp.asarray(rng.normal(size=(8, 5, 5)).astype(np.float32))
    ref = make_cohort_executor(ExecutorConfig("vmap"))(one_client, batches)
    for backend in ("shard_map", "sharded"):
        got = make_cohort_executor(ExecutorConfig(
            backend, chunk_size=1, mesh=mesh))(one_client, batches)
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6)
    print("SHARDED-4DEV-OK")
""")


def test_sharded_executor_on_forced_multidevice_mesh():
    """The population-scale 'sharded' executor on a real 4-device mesh
    (subprocess: jax pins the device count at first init)."""
    script = _SHARDED_SCRIPT.format(src=os.path.abspath(SRC))
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=900,
                          env=dict(os.environ))
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED-4DEV-OK" in proc.stdout
